//! END-TO-END driver (DESIGN.md deliverable): train a ~134M-parameter FFN
//! (n = 8,192, L = 2 — the TP-equivalent model is 2*8192^2 = 134.2M
//! parameters) for a few hundred steps on the synthetic Gaussian-teacher
//! corpus, through ALL layers of the stack:
//!
//!   native fused kernels (rust/src/runtime/native.rs; or AOT HLO via
//!   PJRT with the `xla` feature + `make artifacts`)
//!     -> 8 rank workers + collective fabric (rust/src/comm, coordinator)
//!     -> virtual-time energy ledger (rust/src/energy, simnet)
//!
//! Logs the loss curve for both phantom and tensor parallelism and reports
//! the energy ledger. Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with:  cargo run --release --example train_ffn_e2e [pp_iters] [tp_iters]

use anyhow::Result;
use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::runtime::ExecServer;
use phantom::util::table::{fmt_joules, fmt_params, fmt_secs, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let pp_iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let tp_iters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40);

    let server = ExecServer::native();
    let mut table = Table::new(
        "End-to-end: n=8,192 L=2 p=8 (TP model 134M params)",
        &["mode", "iters", "first loss", "final loss", "params", "energy/iter", "E total", "virtual wall"],
    );

    for (mode, iters) in [(Parallelism::Phantom, pp_iters), (Parallelism::Tensor, tp_iters)] {
        let mut cfg = preset("e2e", mode)?;
        cfg.train.max_iters = iters;
        eprintln!(
            "[e2e] training {} for {} iterations (n=8192, p=8, k={}) ...",
            mode.name(),
            iters,
            cfg.model.k
        );
        let t0 = std::time::Instant::now();
        let r = coordinator::train(&cfg, &server)?;
        eprintln!(
            "[e2e] {} done in {:.1}s real time; loss curve:",
            mode.name(),
            t0.elapsed().as_secs_f64()
        );
        let stride = (r.losses.len() / 12).max(1);
        for (i, l) in r.losses.iter().enumerate() {
            if i % stride == 0 || i + 1 == r.losses.len() {
                eprintln!("[e2e]   {:>8} iter {i:>4}  loss {l:.6}", mode.name());
            }
        }
        assert!(
            r.losses.last().unwrap() < r.losses.first().unwrap(),
            "{} loss must decrease",
            mode.name()
        );
        table.row(vec![
            mode.name().to_uppercase(),
            r.iterations.to_string(),
            format!("{:.5}", r.losses.first().unwrap()),
            format!("{:.5}", r.losses.last().unwrap()),
            fmt_params(r.model_params),
            fmt_joules(r.energy_per_iter_j()),
            fmt_joules(r.energy_train_j),
            fmt_secs(r.wall_train_s),
        ]);
    }

    println!("\n{}", table.markdown());
    Ok(())
}
