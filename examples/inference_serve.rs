//! Inference serving comparison: the "inferencing" half of the paper's
//! title. Serves batched forward-only queries through the PP and TP
//! pipelines and reports per-batch latency, throughput, and energy per
//! 1k queries — PP's forward path saves the same All-Gather traffic per
//! query as per training iteration (Table II).
//!
//! Run with:  cargo run --release --example inference_serve [batches]

use anyhow::Result;
use phantom::config::{preset, Parallelism};
use phantom::coordinator::driver::infer;
use phantom::runtime::ExecServer;
use phantom::util::stats::summarize;
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> Result<()> {
    let batches: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let server = ExecServer::native();

    let mut table = Table::new(
        &format!("Inference serving — n=1,024, p=8, {batches} batches of 32 queries"),
        &["mode", "p50 latency", "p95 latency", "throughput (q/s, virtual)", "energy / 1k queries"],
    );
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = preset("small", mode)?;
        eprintln!("serving {} ...", mode.name());
        let r = infer(&cfg, &server, batches)?;
        let s = summarize(&r.latencies_s);
        let queries = ((batches - 1) * cfg.train.batch) as f64;
        table.row(vec![
            mode.name().to_uppercase(),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            format!("{:.0}", r.throughput),
            fmt_joules(r.energy_j / queries * 1000.0),
        ]);
    }
    print!("{}", table.markdown());
    println!("\nPer-query PP moves 2*k*batch floats vs TP's (n + n/p)*batch (Table II).");
    Ok(())
}
