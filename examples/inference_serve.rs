//! Inference serving comparison: the "inferencing" half of the paper's
//! title, on top of the persistent serve subsystem (rust/src/serve,
//! DESIGN.md §7) instead of spawning fresh ranks per run.
//!
//! A long-lived rank pool holds the weight shards; an open-loop Poisson
//! arrival stream flows through the bounded admission queue and dynamic
//! micro-batcher; the report compares PP and TP on p50/p95 latency,
//! throughput, and energy per 1k queries — PP's forward path saves the
//! same All-Gather traffic per query as per training iteration (Table II).
//!
//! Run with:  cargo run --release --example inference_serve [queries] [rate_qps]

use anyhow::Result;
use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::runtime::ExecServer;
use phantom::serve::{run_load, LoadGenConfig};
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> Result<()> {
    let queries: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let rate_qps: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(2_000.0);

    let mut table = Table::new(
        &format!("Inference serving — n=1,024, p=8, {queries} queries @ {rate_qps} q/s (virtual)"),
        &[
            "mode",
            "batches",
            "mean batch",
            "p50 latency",
            "p95 latency",
            "throughput (q/s, virtual)",
            "energy / 1k queries",
        ],
    );
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = preset("small", mode)?;
        let server = ExecServer::for_run(&cfg)?;
        let scfg = ServeConfig { mode, ..ServeConfig::default() };
        let lcfg = LoadGenConfig { queries, rate_qps, ..LoadGenConfig::default() };
        eprintln!("serving {} ...", mode.name());
        let r = run_load(&cfg, &scfg, &lcfg, &server)?;
        assert_eq!(r.misordered, 0, "responses must come back in order");
        assert_eq!(r.completed, queries, "blocking backpressure drops nothing");
        table.row(vec![
            mode.name().to_uppercase(),
            r.batches.to_string(),
            format!("{:.1}", r.mean_batch),
            fmt_secs(r.latency.p50),
            fmt_secs(r.latency.p95),
            format!("{:.0}", r.throughput_qps),
            fmt_joules(r.energy_per_kq_j),
        ]);
    }
    print!("{}", table.markdown());
    println!("\nPer-query PP moves 2*k*batch floats vs TP's (n + n/p)*batch (Table II);");
    println!("the rank pool holds shards across requests, idling at the static draw B");
    println!("between batches. `phantom serve` runs the same harness from the CLI.");
    Ok(())
}
