//! Regenerate the paper's Table III: fit the unified collective model
//! comm_time(m, p) = c1*log2(p) + c2*m + c3 on a synthetic measurement grid
//! (m = 2^2..2^26 floats, p = 2..256 — the paper's own grid) and compare
//! the recovered constants with the paper's.
//!
//! Run with:  cargo run --release --example comm_model_fit

use anyhow::Result;
use phantom::experiments;

fn main() -> Result<()> {
    let r = experiments::run("table3", None)?;
    print!("{}", r.render_markdown());
    println!("\nThe latency constants (c1) of All-Gather/Reduce-Scatter are ~4x those of");
    println!("Broadcast/All-Reduce — this is why PP's tiny k-float collectives are");
    println!("latency-bound and TP's n*batch collectives are bandwidth-bound (Fig 5a).");
    Ok(())
}
