//! Quickstart: train a small FFN with phantom parallelism on 4 simulated
//! ranks, then compare against the tensor-parallel baseline.
//!
//! Run with:  cargo run --release --example quickstart
//!
//! ## Serving
//!
//! The same pipelines serve inference traffic through the persistent serve
//! subsystem (rust/src/serve, DESIGN.md §7): a long-lived rank pool keeps
//! the weight shards resident, a bounded admission queue applies
//! backpressure, and a dynamic micro-batcher coalesces queries:
//!
//! ```text
//! cargo run --release -- serve --backend native      # PP vs TP, writes BENCH_serve.json
//! cargo run --release --example inference_serve      # library-level harness
//! ```
//!
//! ## Native vs the `xla` feature
//!
//! By default this runs on the NATIVE backend (runtime/native.rs): fused
//! pure-Rust kernels over the blocked-GEMM tensor substrate. It is fully
//! self-contained — no `make artifacts`, no PJRT/XLA install, nothing but
//! `cargo run`. To execute the AOT HLO artifacts through PJRT instead,
//! build with `--features xla` (supplying the `xla` crate, see
//! rust/Cargo.toml), run `make artifacts`, and swap in
//! `ExecServer::start(default_artifact_dir())?` — every downstream line is
//! backend-agnostic, the two paths compute the same numbers (DESIGN.md §3).

use anyhow::Result;
use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::runtime::ExecServer;
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> Result<()> {
    let server = ExecServer::native();

    let mut table = Table::new(
        "Quickstart — n=256, L=2, p=4, 60 iterations",
        &["mode", "final loss", "params", "energy", "energy/iter", "virtual wall", "floats moved"],
    );

    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let mut cfg = preset("quickstart", mode)?;
        cfg.train.max_iters = 60;
        println!("training {} ...", mode.name());
        let r = coordinator::train(&cfg, &server)?;
        println!(
            "  {}: loss {:.5} -> {:.5} over {} iters",
            mode.name(),
            r.losses.first().unwrap(),
            r.losses.last().unwrap(),
            r.iterations
        );
        let floats: u64 = r.per_rank.iter().map(|x| x.stats.floats_moved).sum();
        table.row(vec![
            mode.name().to_uppercase(),
            format!("{:.5}", r.losses.last().unwrap()),
            r.model_params.to_string(),
            fmt_joules(r.energy_train_j),
            fmt_joules(r.energy_per_iter_j()),
            fmt_secs(r.wall_train_s),
            floats.to_string(),
        ]);
    }

    println!("\n{}", table.markdown());
    println!("PP trains a smaller model with k-width phantom exchanges;");
    println!("TP moves full activations. See EXPERIMENTS.md for the paper-scale results.");
    Ok(())
}
