//! Table-I style energy comparison: train TP and PP models to the SAME
//! fixed loss and compare model size, iteration count, energy per iteration
//! and total energy — the paper's Sec. VI-B protocol at measured scale
//! (n = 1,024, 2..8 simulated ranks).
//!
//! Run with:  cargo run --release --example energy_comparison

use anyhow::Result;
use phantom::experiments::fig7::{convergence_sweep, fig7a, fig7b, fig7c, table1};
use phantom::runtime::ExecServer;

fn main() -> Result<()> {
    let server = ExecServer::native();
    eprintln!("running the fixed-loss convergence sweep (9 training runs)...");
    let sweep = convergence_sweep(&server)?;
    eprintln!("target loss lambda = {:.6}\n", sweep.target_loss);

    for result in [
        fig7a(&sweep)?,
        fig7b(&sweep)?,
        fig7c(&sweep)?,
        table1(&sweep)?,
    ] {
        print!("{}", result.render_markdown());
    }
    Ok(())
}
