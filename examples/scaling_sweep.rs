//! Scaling sweep: regenerate the paper's Fig 5a/5b/5c and Fig 6 series
//! (modeled at Frontier scale via the calibrated perfmodel) plus a measured
//! anchor at n = 2,048 on the simulated cluster to validate the model's
//! orderings at a scale we can actually execute.
//!
//! Run with:  cargo run --release --example scaling_sweep

use anyhow::Result;
use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::experiments;
use phantom::runtime::ExecServer;
use phantom::util::table::{fmt_secs, Table};

fn main() -> Result<()> {
    // Modeled figures (no artifacts needed).
    for id in ["fig5a", "fig5b", "fig5c", "fig6"] {
        let r = experiments::run(id, None)?;
        print!("{}", r.render_markdown());
    }

    // Measured anchor: per-iteration comm split at n=2,048, p=8.
    let server = ExecServer::native();
    let mut table = Table::new(
        "Measured anchor — per-iteration comm/compute split (n=2,048, p=8, 5 iters)",
        &["mode", "busy/rank", "comm/rank", "idle/rank", "floats moved/rank"],
    );
    for mode in [Parallelism::Tensor, Parallelism::Phantom] {
        let mut cfg = preset("medium", mode)?;
        cfg.train.max_iters = 5;
        let r = coordinator::train(&cfg, &server)?;
        let p = r.per_rank.len() as f64;
        let busy: f64 = r.per_rank.iter().map(|x| x.ledger.busy_s).sum::<f64>() / p;
        let comm: f64 = r.per_rank.iter().map(|x| x.ledger.comm_s).sum::<f64>() / p;
        let idle: f64 = r.per_rank.iter().map(|x| x.ledger.idle_s).sum::<f64>() / p;
        let floats: u64 =
            r.per_rank.iter().map(|x| x.stats.floats_moved).sum::<u64>() / r.per_rank.len() as u64;
        table.row(vec![
            mode.name().to_uppercase(),
            fmt_secs(busy / 5.0),
            fmt_secs(comm / 5.0),
            fmt_secs(idle / 5.0),
            (floats / 5).to_string(),
        ]);
    }
    print!("{}", table.markdown());
    println!("\nPP's wire traffic is k*batch per layer vs TP's n*batch-scale messages —");
    println!("the measured split validates the modeled Fig 5a ordering.");
    Ok(())
}
