//! Elastic checkpoint walkthrough: train TP, crash, resume bit-identically,
//! re-shard the trained model down to a 2-rank phantom layout, and hot-swap
//! it into a running serve pool — the paper's "train big TP, serve small
//! PP" energy scenario end-to-end (DESIGN.md §8).
//!
//! Run with:  cargo run --release --example ckpt_elastic

use anyhow::Result;
use phantom::ckpt::{reshard, Snapshot};
use phantom::config::{preset, CkptPolicy, Parallelism, ServeConfig};
use phantom::coordinator::{train_with, TrainOptions};
use phantom::runtime::ExecServer;
use phantom::serve::Server;
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;

fn main() -> Result<()> {
    let ckpt_dir =
        std::env::temp_dir().join(format!("phantom-ckpt-elastic-{}", std::process::id()));

    // ---- 1. train a TP p=4 model with periodic snapshots -----------------
    let mut cfg = preset("tiny", Parallelism::Tensor)?;
    cfg.train.max_iters = 12;
    let server = ExecServer::for_run(&cfg)?;
    println!(
        "[1] training TP p={} n={} for 12 iterations, snapshot every 4...",
        cfg.p, cfg.model.n
    );
    let policy = CkptPolicy { every: 4, dir: ckpt_dir.clone() };
    let opts = TrainOptions { ckpt: Some(policy), resume: None, ..Default::default() };
    let full = train_with(&cfg, &server, opts)?;
    println!("    final loss {:.6}", full.losses.last().unwrap());

    // ---- 2. "crash" after iteration 8, resume to 12 ----------------------
    println!("[2] crash-resume from {}...", ckpt_dir.join("ckpt-000008").display());
    let snap8 = Snapshot::load(&ckpt_dir.join("ckpt-000008"))?;
    let mut resume_cfg = snap8.config.clone();
    resume_cfg.train.max_iters = 12;
    let resumed = train_with(
        &resume_cfg,
        &server,
        TrainOptions { ckpt: None, resume: Some(snap8), ..Default::default() },
    )?;
    assert_eq!(
        resumed.losses, full.losses,
        "resumed trajectory must be bit-identical to the uninterrupted run"
    );
    println!("    resumed losses match the uninterrupted run bit for bit");

    // ---- 3. re-shard the trained TP p=4 model to PP p=2 ------------------
    let tp_snap = Snapshot::load(&ckpt_dir.join("ckpt-000012"))?;
    let pp_snap = reshard(&tp_snap, 2, Parallelism::Phantom)?;
    println!(
        "[3] resharded TP p={} -> PP p={} (dense-phantom, k = {})",
        tp_snap.p(),
        pp_snap.p(),
        pp_snap.k()
    );
    let mut rng = Prng::new(42);
    let x = Tensor::randn(&[4, tp_snap.n()], 1.0, &mut rng);
    let (y_tp, y_pp) = (tp_snap.forward_host(&x)?, pp_snap.forward_host(&x)?);
    let worst = y_tp
        .data()
        .iter()
        .zip(y_pp.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("    forward equivalence: worst |Δ| = {worst:.3e}");
    assert!(worst < 1e-3, "re-sharded model must be forward-equivalent");

    // ---- 4. hot-swap the re-sharded model into a serve pool --------------
    let mut pool_cfg = cfg.clone();
    pool_cfg.mode = Parallelism::Phantom;
    pool_cfg.p = 2;
    pool_cfg.artifact = Some("elastic_pool".to_string());
    let pool_server = ExecServer::for_run(&pool_cfg)?;
    let scfg = ServeConfig { mode: Parallelism::Phantom, ..ServeConfig::default() };
    let mut serve = Server::start(&pool_cfg, scfg, &pool_server)?;
    println!("[4] serve pool up (PP p=2); hot-swapping the trained snapshot in...");
    serve.hot_swap(&pp_snap)?;
    let n = tp_snap.n();
    for i in 0..8usize {
        let mut rowrng = Prng::new(1000 + i as u64);
        let row = Tensor::randn(&[n], 1.0, &mut rowrng);
        serve.submit_blocking(1e-3 * (i + 1) as f64, row)?;
    }
    let (responses, stats, _) = serve.finish()?;
    assert_eq!(responses.len(), 8, "no query may be dropped across the swap");
    println!(
        "    served {} queries in {} batches with the re-sharded weights; none dropped",
        responses.len(),
        stats.batches
    );

    std::fs::remove_dir_all(&ckpt_dir).ok();
    println!("\ntrain TP p=4 -> crash -> resume -> reshard -> serve PP p=2: done.");
    Ok(())
}
