//! Deterministic fault-injection fabric + differential conformance harness
//! (DESIGN.md §9).
//!
//! Four pieces, layered on the comm fault hooks:
//!
//! * `fault` — `FaultPlan`: seeded, byte-reproducible fault schedules
//!   (per-collective delay, message drop -> timeout, rank crash at
//!   iteration i, poison storms) armed onto `comm::Endpoint`s through
//!   `InjectorFactory`, so `rank_tp`/`rank_pp`/`serve::pool` run
//!   unmodified.
//! * `oracle` — `ReferenceTrainer`: the dense single-rank reference
//!   (forward + backward + optimizer on the logical model, collectives
//!   replaced by their rank-ordered definitions), bit-matching the
//!   distributed trainer; plus an independent naive-math implementation
//!   for gradient cross-checks.
//! * `differential` — the randomized `(n, p, dp, TP|PP, backend, batch)`
//!   conformance sweep asserting distributed ≡ oracle ≡ naive and
//!   TP ≡ PP across re-sharding, with hybrid DP×(TP|PP) layouts swept
//!   at dp ∈ {1, 2, 4}.
//! * `chaos` — scripted failure drivers: crash-resume bit-identity for
//!   training, crash + hot-swap recovery with zero dropped/reordered
//!   queries for serving.
//!
//! Exposed to operators as `phantom chaos` (cli), exercised in CI by
//! tests/conformance.rs and tests/chaos_integration.rs.

pub mod chaos;
pub mod differential;
pub mod fault;
pub mod oracle;

pub use chaos::{serve_crash_swap, train_crash_resume, CrashResumeReport, ServeChaosReport};
pub use differential::{run_sweep, CaseReport, SweepConfig, SweepReport};
pub use fault::{
    collectives_per_forward, collectives_per_train_iter, FaultEvent, FaultPlan, FiredFault,
    StormSpec,
};
pub use oracle::ReferenceTrainer;
