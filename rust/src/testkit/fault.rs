//! Seeded, reproducible fault schedules over the comm-layer fault hooks.
//!
//! A `FaultPlan` is a concrete, fully materialized schedule: a sorted list
//! of `(rank, collective seq, fault kind)` events. Plans are built either
//! explicitly (`crash`, `delay`, `drop_message`, `crash_at_iter`) or
//! generated from a seed (`FaultPlan::generate`) — same seed, same spec,
//! same schedule, byte for byte (`canonical_bytes`).
//!
//! **Determinism contract** (DESIGN.md §9): because faults key on the
//! per-endpoint *collective sequence number* — virtual-time state, not
//! wall-clock state — the same plan armed on the same workload fires the
//! same faults at the same points on every run. Every firing is recorded
//! in a shared log; `fired_bytes()` canonicalizes it so two runs can be
//! compared byte-identically (tests/conformance.rs asserts this).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use crate::comm::{FaultAction, FaultInjector, InjectorFactory};
use crate::config::Parallelism;
use crate::util::prng::Prng;

/// One scheduled fault: at the `seq`-th rendezvous collective issued by
/// `rank`'s endpoint, apply `action`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub rank: usize,
    pub seq: u64,
    pub action: FaultAction,
}

/// One observed firing, recorded by the armed injectors.
#[derive(Debug, Clone, PartialEq)]
pub struct FiredFault {
    pub rank: usize,
    pub seq: u64,
    pub op: &'static str,
    pub action: FaultAction,
}

/// Spec for seeded random schedule generation ("poison storms" et al.).
#[derive(Debug, Clone)]
pub struct StormSpec {
    /// Number of ranks faults may target.
    pub p: usize,
    /// Collective-sequence horizon faults are placed within.
    pub horizon: u64,
    /// How many events to generate.
    pub events: usize,
    /// Mean injected delay in virtual seconds (delays are sampled uniform
    /// in (0, 2*mean_delay_s)).
    pub mean_delay_s: f64,
    /// Include Drop events (peers then ride the rendezvous timeout).
    pub allow_drops: bool,
    /// Include Poison events (out-of-band fabric poisoning bursts).
    pub allow_poison: bool,
}

impl Default for StormSpec {
    fn default() -> Self {
        StormSpec {
            p: 2,
            horizon: 16,
            events: 4,
            mean_delay_s: 1e-3,
            allow_drops: false,
            allow_poison: false,
        }
    }
}

/// A concrete fault schedule plus the shared firing log.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    log: Arc<Mutex<Vec<FiredFault>>>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Add one event (builder style).
    pub fn with(mut self, rank: usize, seq: u64, action: FaultAction) -> FaultPlan {
        self.events.push(FaultEvent { rank, seq, action });
        self.normalize();
        self
    }

    /// Crash `rank` at its `seq`-th collective.
    pub fn crash(rank: usize, seq: u64) -> FaultPlan {
        FaultPlan::new().with(rank, seq, FaultAction::Crash)
    }

    /// Stall `rank` by `seconds` of virtual time at its `seq`-th collective.
    pub fn delay(rank: usize, seq: u64, seconds: f64) -> FaultPlan {
        FaultPlan::new().with(rank, seq, FaultAction::Delay { seconds })
    }

    /// Drop `rank`'s message at its `seq`-th collective (peers time out).
    pub fn drop_message(rank: usize, seq: u64) -> FaultPlan {
        FaultPlan::new().with(rank, seq, FaultAction::Drop)
    }

    /// Crash `rank` at the first collective of training iteration `iter`
    /// (0-based) for the given pipeline shape — the "kill rank r at
    /// iteration i" chaos scenario.
    pub fn crash_at_iter(rank: usize, iter: u64, mode: Parallelism, layers: usize) -> FaultPlan {
        FaultPlan::crash(rank, iter * collectives_per_train_iter(mode, layers))
    }

    /// Seeded random schedule: same `(seed, spec)` always yields the same
    /// events (the generation-side half of the determinism contract).
    /// Collisions on a (rank, seq) slot are resampled, so the plan carries
    /// exactly `spec.events` events whenever the (p × horizon) grid has
    /// room for them.
    pub fn generate(seed: u64, spec: &StormSpec) -> FaultPlan {
        let mut rng = Prng::new(seed ^ 0xFA_17B0A7); // "FAULTBOAT"
        let mut plan = FaultPlan::new();
        let mut used: BTreeSet<(usize, u64)> = BTreeSet::new();
        let target = spec.events.min(spec.p.max(1) * spec.horizon.max(1) as usize);
        // Bounded resampling keeps generation total even near a full grid.
        let mut attempts = 0usize;
        while plan.events.len() < target && attempts < 64 * target.max(1) {
            attempts += 1;
            let rank = rng.int_in(0, spec.p.max(1) as u64 - 1) as usize;
            let seq = rng.int_in(0, spec.horizon.max(1) - 1);
            if !used.insert((rank, seq)) {
                continue; // slot taken: resample instead of silently dropping
            }
            let mut kinds: Vec<u8> = vec![0]; // delay is always allowed
            if spec.allow_drops {
                kinds.push(1);
            }
            if spec.allow_poison {
                kinds.push(2);
            }
            let kind = kinds[rng.int_in(0, kinds.len() as u64 - 1) as usize];
            let action = match kind {
                0 => FaultAction::Delay { seconds: rng.next_f64() * 2.0 * spec.mean_delay_s },
                1 => FaultAction::Drop,
                _ => FaultAction::Poison,
            };
            plan.events.push(FaultEvent { rank, seq, action });
        }
        plan.normalize();
        plan
    }

    /// Sort by (rank, seq) and keep the first event per slot so lookup is
    /// unambiguous and serialization is canonical.
    fn normalize(&mut self) {
        self.events.sort_by_key(|e| (e.rank, e.seq));
        self.events.dedup_by_key(|e| (e.rank, e.seq));
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Canonical byte serialization of the *schedule* — one line per event,
    /// sorted. Two plans are the same schedule iff these bytes are equal.
    pub fn canonical_bytes(&self) -> Vec<u8> {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&format!("{} {} {}\n", e.rank, e.seq, action_str(&e.action)));
        }
        out.into_bytes()
    }

    /// Everything the armed injectors fired so far, in canonical
    /// (rank, seq) order.
    pub fn fired(&self) -> Vec<FiredFault> {
        let mut v = self.log.lock().expect("fault log poisoned").clone();
        v.sort_by_key(|f| (f.rank, f.seq));
        v
    }

    /// Canonical byte serialization of the *observed* firings — the
    /// run-side half of the determinism contract: two runs of the same
    /// workload under the same plan must produce identical bytes.
    pub fn fired_bytes(&self) -> Vec<u8> {
        let mut out = String::new();
        for f in self.fired() {
            out.push_str(&format!(
                "{} {} {} {}\n",
                f.rank,
                f.seq,
                f.op,
                action_str(&f.action)
            ));
        }
        out.into_bytes()
    }

    /// Clear the firing log (between runs of the same plan).
    pub fn reset_log(&self) {
        self.log.lock().expect("fault log poisoned").clear();
    }

    /// The per-rank injector source drivers accept
    /// (`TrainOptions::faults`, `PoolOptions::faults`).
    pub fn injector_factory(&self) -> InjectorFactory {
        let mut by_rank: BTreeMap<usize, BTreeMap<u64, FaultAction>> = BTreeMap::new();
        for e in &self.events {
            by_rank.entry(e.rank).or_default().insert(e.seq, e.action.clone());
        }
        let log = self.log.clone();
        InjectorFactory::new(move |rank| {
            let events = by_rank.get(&rank)?.clone();
            Some(Box::new(PlanInjector { events, log: log.clone() }) as Box<dyn FaultInjector>)
        })
    }
}

/// f64 seconds serialized via to_bits so canonical bytes are exact.
fn action_str(a: &FaultAction) -> String {
    match a {
        FaultAction::Proceed => "proceed".to_string(),
        FaultAction::Delay { seconds } => format!("delay:{:016x}", seconds.to_bits()),
        FaultAction::Drop => "drop".to_string(),
        FaultAction::Poison => "poison".to_string(),
        FaultAction::Crash => "crash".to_string(),
    }
}

struct PlanInjector {
    events: BTreeMap<u64, FaultAction>,
    log: Arc<Mutex<Vec<FiredFault>>>,
}

impl FaultInjector for PlanInjector {
    fn on_collective(&mut self, rank: usize, seq: u64, op: &'static str) -> FaultAction {
        match self.events.get(&seq) {
            None => FaultAction::Proceed,
            Some(action) => {
                let action = action.clone();
                if let Ok(mut log) = self.log.lock() {
                    log.push(FiredFault { rank, seq, op, action: action.clone() });
                }
                action
            }
        }
    }
}

/// Rendezvous collectives one training iteration issues per rank:
/// PP = L forward All-Gathers + L backward Reduce-Scatters; TP = L forward
/// All-Gathers + (L-1) backward All-Reduces (`charge_modeled` entries are
/// not rendezvous and do not tick the fault clock).
pub fn collectives_per_train_iter(mode: Parallelism, layers: usize) -> u64 {
    match mode {
        Parallelism::Phantom => 2 * layers as u64,
        Parallelism::Tensor => (2 * layers).saturating_sub(1) as u64,
    }
}

/// Rendezvous collectives one forward-only (serving) batch issues per
/// rank: L All-Gathers in both pipelines.
pub fn collectives_per_forward(layers: usize) -> u64 {
    layers as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_seed_deterministic() {
        let spec = StormSpec { p: 4, horizon: 32, events: 12, ..Default::default() };
        let a = FaultPlan::generate(7, &spec);
        let b = FaultPlan::generate(7, &spec);
        assert_eq!(a.canonical_bytes(), b.canonical_bytes());
        assert_eq!(a.events().len(), 12, "collisions are resampled, not dropped");
        let c = FaultPlan::generate(8, &spec);
        assert_ne!(a.canonical_bytes(), c.canonical_bytes(), "different seed, different plan");
    }

    #[test]
    fn canonical_bytes_are_sorted_and_deduped() {
        let plan = FaultPlan::new()
            .with(1, 5, FaultAction::Crash)
            .with(0, 2, FaultAction::Drop)
            .with(1, 5, FaultAction::Drop); // duplicate slot: first wins
        let text = String::from_utf8(plan.canonical_bytes()).unwrap();
        assert_eq!(text, "0 2 drop\n1 5 crash\n");
    }

    #[test]
    fn injector_fires_and_logs() {
        let plan = FaultPlan::delay(1, 2, 0.25);
        let factory = plan.injector_factory();
        assert!(factory.for_rank(0).is_none(), "rank 0 has no events");
        let mut inj = factory.for_rank(1).unwrap();
        assert_eq!(inj.on_collective(1, 0, "all_gather"), FaultAction::Proceed);
        assert_eq!(inj.on_collective(1, 1, "all_gather"), FaultAction::Proceed);
        assert_eq!(
            inj.on_collective(1, 2, "reduce_scatter"),
            FaultAction::Delay { seconds: 0.25 }
        );
        let fired = plan.fired();
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].rank, 1);
        assert_eq!(fired[0].seq, 2);
        assert_eq!(fired[0].op, "reduce_scatter");
        plan.reset_log();
        assert!(plan.fired().is_empty());
    }

    #[test]
    fn iter_targeting_matches_schedule_arithmetic() {
        assert_eq!(collectives_per_train_iter(Parallelism::Phantom, 2), 4);
        assert_eq!(collectives_per_train_iter(Parallelism::Tensor, 2), 3);
        assert_eq!(collectives_per_train_iter(Parallelism::Tensor, 1), 1);
        let plan = FaultPlan::crash_at_iter(1, 3, Parallelism::Phantom, 2);
        assert_eq!(plan.events(), &[FaultEvent { rank: 1, seq: 12, action: FaultAction::Crash }]);
    }
}
