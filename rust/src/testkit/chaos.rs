//! Chaos drivers: scripted failure scenarios over the real subsystems.
//!
//! * `train_crash_resume` — kill a rank mid-train with an injected crash,
//!   verify the failure surfaces as a structured error (rank id + the
//!   injected-fault payload), then resume from the last periodic snapshot
//!   and assert the continued loss trajectory is **bit-identical** to an
//!   uninterrupted run (the ckpt subsystem's durability contract under an
//!   actual crash, not just a polite stop).
//! * `serve_crash_swap` — crash a serve-pool rank mid-stream, rebuild the
//!   pool, hot-swap it onto a *reseeded* snapshot (`RankPool::load_weights`
//!   with weights distinguishable from the rebuilt pool's own init, so a
//!   silently dropped swap cannot pass), replay the failed batch and
//!   finish the stream; assert nothing is dropped — every answer bitwise
//!   matches its weight-set's fault-free reference.
//!
//! Both drivers are deterministic end to end: the fault schedules key on
//! virtual-time collective sequence numbers, so reruns reproduce the same
//! failure at the same point (tests/chaos_integration.rs relies on this).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::Snapshot;
use crate::config::{CkptPolicy, RunConfig, ServeConfig};
use crate::coordinator::{train_with, TrainOptions};
use crate::runtime::ExecServer;
use crate::serve::{PoolOptions, RankPool};
use crate::tensor::Tensor;
use crate::testkit::fault::FaultPlan;
use crate::util::prng::Prng;

/// Outcome of the train crash-resume scenario.
#[derive(Debug, Clone)]
pub struct CrashResumeReport {
    /// Loss trajectory of the uninterrupted reference run.
    pub baseline: Vec<f64>,
    /// The structured error the crashed run surfaced.
    pub crash_error: String,
    /// Iteration count of the snapshot the resume started from.
    pub resumed_from: u64,
    /// Full trajectory of crashed-then-resumed training.
    pub resumed: Vec<f64>,
    /// `resumed == baseline`, f64-bit for f64-bit.
    pub bit_identical: bool,
}

/// Run the crash-resume scenario: train `total_iters` with snapshots every
/// `ckpt_every` into `dir`, crash `crash_rank` at the start of iteration
/// `crash_iter`, resume from the newest surviving snapshot, and compare
/// against an uninterrupted run of the same config.
pub fn train_crash_resume(
    cfg: &RunConfig,
    total_iters: usize,
    ckpt_every: usize,
    crash_rank: usize,
    crash_iter: u64,
    dir: &Path,
) -> Result<CrashResumeReport> {
    if crash_rank >= cfg.p {
        bail!("crash rank {crash_rank} out of range for p={}", cfg.p);
    }
    if crash_iter == 0 || crash_iter as usize >= total_iters {
        bail!("crash iteration {crash_iter} must be inside (0, {total_iters})");
    }
    if ckpt_every == 0 || (crash_iter as usize) < ckpt_every {
        bail!(
            "crash iteration {crash_iter} precedes the first snapshot \
             (ckpt every {ckpt_every}) — there would be nothing to resume from"
        );
    }
    let mut cfg = cfg.clone();
    cfg.train.max_iters = total_iters;
    cfg.train.target_loss = None;
    let server = ExecServer::for_run(&cfg)?;

    // Uninterrupted reference.
    let baseline = train_with(&cfg, &server, TrainOptions::default())
        .context("baseline run")?
        .losses;

    // Crashed run: periodic snapshots + an injected crash.
    std::fs::create_dir_all(dir).context("creating checkpoint dir")?;
    let plan = FaultPlan::crash_at_iter(crash_rank, crash_iter, cfg.mode, cfg.model.layers);
    let err = match train_with(
        &cfg,
        &server,
        TrainOptions {
            ckpt: Some(CkptPolicy { every: ckpt_every, dir: dir.to_path_buf() }),
            faults: Some(plan.injector_factory()),
            ..Default::default()
        },
    ) {
        Ok(_) => bail!("the injected crash did not surface as an error"),
        Err(e) => format!("{e:#}"),
    };
    if !err.contains("injected fault") {
        bail!("crash error lost the injected-fault payload: {err}");
    }

    // Resume from the newest snapshot at or before the crash point.
    let resumed_dir = latest_snapshot(dir, crash_iter)?;
    let snap = Snapshot::load(&resumed_dir)
        .with_context(|| format!("loading {}", resumed_dir.display()))?;
    let resumed_from = snap.progress.iter;
    let mut resume_cfg = snap.config.clone();
    resume_cfg.train.max_iters = total_iters;
    let resumed = train_with(
        &resume_cfg,
        &server,
        TrainOptions { resume: Some(snap), ..Default::default() },
    )
    .context("resumed run")?
    .losses;

    let bit_identical = resumed == baseline;
    Ok(CrashResumeReport { baseline, crash_error: err, resumed_from, resumed, bit_identical })
}

/// Newest `ckpt-NNNNNN` under `dir` with NNNNNN <= `limit`.
fn latest_snapshot(dir: &Path, limit: u64) -> Result<std::path::PathBuf> {
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(iter) = name
            .to_str()
            .and_then(|s| s.strip_prefix("ckpt-"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        if iter <= limit && best.as_ref().map(|(b, _)| iter > *b).unwrap_or(true) {
            best = Some((iter, entry.path()));
        }
    }
    best.map(|(_, p)| p)
        .ok_or_else(|| anyhow!("no snapshot at or before iteration {limit} in {}", dir.display()))
}

/// Outcome of the serve crash + hot-swap recovery scenario.
#[derive(Debug, Clone)]
pub struct ServeChaosReport {
    pub batches: usize,
    /// Error surfaced by the batch the crash landed in.
    pub crash_error: String,
    /// Structured shutdown error of the dead pool (rank id + payload).
    pub shutdown_error: String,
    /// Index of the batch that was replayed after recovery.
    pub recovered_batch: usize,
    /// Every answer equals its expected reference, bit for bit: old
    /// weights before the crash, swap-snapshot weights from the replayed
    /// batch on. This is also the zero-dropped proof — a missing answer
    /// can't match anything. (Zero-reordered is enforced inside
    /// `RankPool::execute` itself, which rejects out-of-sequence
    /// completions.)
    pub outputs_match: bool,
    /// The swap snapshot's answers differ from the original weights' on
    /// the replayed batch — i.e. the hot swap was actually observable,
    /// so a silently dropped `load_weights` cannot pass.
    pub swap_observable: bool,
}

/// Run the serve-pool chaos scenario: stream `batches` deterministic query
/// batches through a pool whose `crash_rank` crashes at its
/// `crash_collective`-th collective; on the failed batch, rebuild the
/// pool, hot-swap it onto a *different* snapshot (`load_weights` of a
/// reseeded init — distinguishable from the rebuilt pool's own weights),
/// replay the failed batch and finish the stream. Every answer is compared
/// bitwise against fault-free reference runs of the matching weights.
pub fn serve_crash_swap(
    cfg: &RunConfig,
    scfg: &ServeConfig,
    batches: usize,
    crash_rank: usize,
    crash_collective: u64,
) -> Result<ServeChaosReport> {
    let mut cfg = cfg.clone();
    // Serving weights are deterministic in (seed, mode, rank); align the
    // run config's mode so snapshots and pools agree on the pipeline.
    cfg.mode = scfg.mode;
    if crash_rank >= cfg.p {
        bail!("crash rank {crash_rank} out of range for p={}", cfg.p);
    }
    let server = ExecServer::for_run(&cfg)?;
    let batch_of = |b: usize| -> Tensor {
        let mut rng = Prng::new(cfg.train.seed ^ 0x5E7E ^ (b as u64).wrapping_mul(0x9E37));
        Tensor::randn(&[cfg.train.batch, cfg.model.n], 1.0, &mut rng)
    };
    // The recovery snapshot: same geometry, different seed, so serving it
    // produces visibly different answers than the crashed pool's weights.
    let mut swap_cfg = cfg.clone();
    swap_cfg.train.seed ^= 0xA11A;
    let swap_snap = Snapshot::init(&swap_cfg)?;

    // Fault-free reference answers for both weight sets.
    let mut ref_old = Vec::with_capacity(batches);
    let mut pool = RankPool::start(&cfg, scfg, &server)?;
    for b in 0..batches {
        let (y, _) = pool.execute(pool.free_s(), &batch_of(b))?;
        ref_old.push(y);
    }
    pool.shutdown().context("reference pool shutdown")?;
    let mut ref_swap = Vec::with_capacity(batches);
    let mut pool = RankPool::start(&cfg, scfg, &server)?;
    pool.load_weights(&swap_snap).context("reference swap pool")?;
    for b in 0..batches {
        let (y, _) = pool.execute(pool.free_s(), &batch_of(b))?;
        ref_swap.push(y);
    }
    pool.shutdown().context("swap reference pool shutdown")?;

    // Faulted run.
    let plan = FaultPlan::crash(crash_rank, crash_collective);
    let opts = PoolOptions { faults: Some(plan.injector_factory()), ..Default::default() };
    let mut pool = RankPool::start_with(&cfg, scfg, &server, opts)?;
    let mut answers: Vec<Option<Tensor>> = (0..batches).map(|_| None).collect();
    let mut crash_error = String::new();
    let mut shutdown_error = String::new();
    let mut recovered_batch = usize::MAX;
    let mut b = 0;
    while b < batches {
        match pool.execute(pool.free_s(), &batch_of(b)) {
            Ok((y, _)) => {
                answers[b] = Some(y);
                b += 1;
            }
            Err(e) => {
                if recovered_batch != usize::MAX {
                    return Err(e.context("pool failed again after recovery"));
                }
                crash_error = format!("{e:#}");
                // The pool is dead (fabric poisoned, one rank gone):
                // tear it down — the panicked rank surfaces structurally —
                // then rebuild and hot-swap onto the recovery snapshot.
                shutdown_error = match pool.shutdown() {
                    Ok(_) => bail!("crashed pool shut down without surfacing the panic"),
                    Err(se) => format!("{se:#}"),
                };
                pool = RankPool::start(&cfg, scfg, &server)?;
                pool.load_weights(&swap_snap).context("hot-swapping the rebuilt pool")?;
                recovered_batch = b;
                // Replay the failed batch: nothing is dropped.
            }
        }
    }
    pool.shutdown().context("recovered pool shutdown")?;

    if recovered_batch == usize::MAX {
        bail!("the injected crash never fired (crash_collective {crash_collective} too large?)");
    }
    if !crash_error.contains("poisoned") && !crash_error.contains("injected") {
        bail!("crash error lost its cause: {crash_error}");
    }
    if !shutdown_error.contains(&format!("serve rank {crash_rank} panicked")) {
        bail!("shutdown error is not structured: {shutdown_error}");
    }

    // Expected answers: old weights before the crash, swap weights from
    // the replayed batch on.
    let outputs_match = answers.iter().enumerate().all(|(i, a)| {
        let want = if i < recovered_batch { &ref_old[i] } else { &ref_swap[i] };
        a.as_ref().map(|y| y == want).unwrap_or(false)
    });
    let swap_observable = ref_swap[recovered_batch] != ref_old[recovered_batch];
    Ok(ServeChaosReport {
        batches,
        crash_error,
        shutdown_error,
        recovered_batch,
        outputs_match,
        swap_observable,
    })
}
