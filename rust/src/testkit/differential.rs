//! Differential conformance runner: randomized `(n, p, dp, mode, backend,
//! batch, layers, optimizer, seed)` configs, each asserting the full
//! equivalence chain
//!
//! ```text
//! distributed train (p*dp ranks, grouped fabric, fused kernels)
//!   ≡ ReferenceTrainer (single thread, simulated collectives)   [tight]
//!   ≡ naive unfused math (matmul_naive, paper equations)        [float tol]
//! TP layout ≡ PP layout (reshard + host-side forward)           [float tol]
//! ```
//!
//! The dp dimension (ISSUE 5) sweeps hybrid DP×TP and DP×PP layouts —
//! dp ∈ {1, 2, 4}, including batch % dp != 0 splits — against the same
//! oracle, which simulates the DP row sharding and the replica-ordered
//! gradient All-Reduce exactly.
//!
//! so every future perf PR can be checked against a fixed oracle: if the
//! fabric, the drivers, the fused kernels, or the re-sharding algebra
//! drift, a sweep case fails and names the config that exposed it.

use anyhow::{bail, Context, Result};

use crate::ckpt::{reshard, Snapshot};
use crate::config::{
    BackendKind, HardwareConfig, ModelConfig, OptimizerConfig, Parallelism, RunConfig,
    Schedule, TrainConfig,
};
use crate::coordinator;
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::testkit::oracle::ReferenceTrainer;
use crate::util::prng::Prng;

/// Sweep shape and tolerances.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Randomized configs to draw (each runs BOTH parallelism modes).
    pub cases: usize,
    pub seed: u64,
    /// Training iterations per case.
    pub iters: usize,
    /// Max relative loss deviation, distributed vs oracle (bitwise in
    /// practice; the tolerance only absorbs hypothetical platform drift).
    pub loss_rtol: f64,
    /// Max normalized gradient deviation, fused kernels vs naive math.
    pub grad_rtol: f32,
    /// Max normalized forward deviation, TP vs re-sharded PP layout.
    pub forward_rtol: f32,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            cases: 25,
            seed: 0xD1FF,
            iters: 3,
            loss_rtol: 1e-7,
            grad_rtol: 2e-2,
            forward_rtol: 1e-3,
        }
    }
}

/// One sampled config and its worst observed deviations.
#[derive(Debug, Clone)]
pub struct CaseReport {
    pub n: usize,
    pub p: usize,
    pub dp: usize,
    pub k: usize,
    pub layers: usize,
    pub batch: usize,
    pub optimizer: &'static str,
    pub seed: u64,
    pub backend: &'static str,
    /// PP schedule swept for the phantom-mode run ("sync" or "1f1b").
    pub schedule: &'static str,
    /// ZeRO-1 sharded optimizer state (active when dp > 1).
    pub sharded: bool,
    /// Worst relative loss deviation across both modes and all iterations.
    pub loss_dev: f64,
    /// Worst normalized gradient deviation (kernel vs naive), both modes.
    pub grad_dev: f32,
    /// Worst normalized forward deviation across TP->PP and PP->TP reshard.
    pub forward_dev: f32,
}

/// The whole sweep.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    pub cases: Vec<CaseReport>,
    pub max_loss_dev: f64,
    pub max_grad_dev: f32,
    pub max_forward_dev: f32,
}

impl SweepReport {
    /// Flat records for BENCH_conformance.json.
    pub fn records(&self) -> Vec<(String, f64)> {
        let hybrid = self.cases.iter().filter(|c| c.dp > 1).count();
        let sharded = self.cases.iter().filter(|c| c.sharded && c.dp > 1).count();
        let one_f_one_b = self.cases.iter().filter(|c| c.schedule == "1f1b").count();
        vec![
            ("conformance_cases".to_string(), self.cases.len() as f64),
            ("conformance_hybrid_cases".to_string(), hybrid as f64),
            ("conformance_sharded_cases".to_string(), sharded as f64),
            ("conformance_1f1b_cases".to_string(), one_f_one_b as f64),
            ("conformance_loss_max_rel_dev".to_string(), self.max_loss_dev),
            ("conformance_grad_max_rel_dev".to_string(), self.max_grad_dev as f64),
            ("conformance_forward_max_rel_dev".to_string(), self.max_forward_dev as f64),
        ]
    }
}

/// Draw one random case geometry.
fn sample_case(rng: &mut Prng, iters: usize) -> (RunConfig, &'static str) {
    let p = rng.int_in(2, 4) as usize;
    let m = rng.int_in(3, 8) as usize;
    let n = p * m;
    let layers = rng.int_in(1, 3) as usize;
    // Hybrid dimension: dp ∈ {1, 2, 4}; batch >= dp, deliberately NOT
    // forced divisible so the remainder row split is swept too.
    let dp = [1usize, 2, 4][rng.int_in(0, 2) as usize];
    let batch = rng.int_in(dp.max(2) as u64, 6) as usize;
    let k = rng.int_in(1, (m - 1).min(4) as u64) as usize;
    let (optimizer, opt_name): (OptimizerConfig, &'static str) = match rng.int_in(0, 2) {
        0 => (OptimizerConfig::Sgd { lr: 0.1 }, "sgd"),
        1 => (OptimizerConfig::Momentum { lr: 0.05, beta: 0.9 }, "momentum"),
        _ => (
            OptimizerConfig::Adam { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            "adam",
        ),
    };
    let seed = rng.next_u64();
    // ISSUE 10 dimensions: ZeRO-1 sharded optimizer state and the 1F1B
    // schedule, swept against the same dense oracle. Both are bit-exact
    // vs the flat/sync baselines at micro = 1 (the rank-ordered
    // reduce-scatter fold matches the all-reduce fold, and 1F1B at one
    // micro-batch degenerates to the synchronous order), so the oracle
    // needs no schedule/sharding awareness.
    let sharded = rng.int_in(0, 1) == 1;
    let schedule = if rng.int_in(0, 1) == 1 { Schedule::OneFOneB } else { Schedule::Sync };
    let cfg = RunConfig {
        mode: Parallelism::Phantom, // per-mode runs overwrite this
        p,
        dp,
        model: ModelConfig { n, layers, k },
        train: TrainConfig {
            batch,
            optimizer,
            seed,
            max_iters: iters,
            target_loss: None,
            warmup_iters: 1,
            dataset_batches: 2,
            micro: 1,
            schedule,
            sharded_state: sharded,
        },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some("conformance-case".to_string()),
        // The sweep dimension is the backend the distributed run executes
        // on; only the native backend exists in a default build (the PJRT
        // path needs the `xla` cargo feature + artifacts).
        backend: BackendKind::Native,
    };
    (cfg, opt_name)
}

/// Worst normalized elementwise deviation: |a-b| / (atol + max(|a|,|b|)).
/// Non-finite values (NaN/inf on either side) count as infinite deviation —
/// `max` and `>` both silently discard NaN, and a conformance gate that
/// waves NaN math through would be worse than none.
fn worst_dev(a: &[f32], b: &[f32], atol: f32) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut worst = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        if !x.is_finite() || !y.is_finite() {
            return f32::INFINITY;
        }
        let dev = (x - y).abs() / (atol + x.abs().max(y.abs()));
        worst = worst.max(dev);
    }
    worst
}

/// Run one case for one mode: distributed vs oracle (loss trajectory) and
/// kernel vs naive (gradients). Returns (worst loss dev, worst grad dev).
fn run_mode(cfg: &RunConfig, sw: &SweepConfig) -> Result<(f64, f32)> {
    let server = ExecServer::for_run(cfg).context("starting backend")?;
    let report = coordinator::train(cfg, &server).context("distributed train")?;
    let mut oracle = ReferenceTrainer::new(cfg)?;
    oracle.run(sw.iters)?;
    if report.losses.len() != oracle.losses.len() {
        bail!(
            "{}: distributed ran {} iterations, oracle {}",
            cfg.mode.name(),
            report.losses.len(),
            oracle.losses.len()
        );
    }
    let mut loss_dev = 0.0f64;
    for (i, (a, b)) in report.losses.iter().zip(&oracle.losses).enumerate() {
        let dev = if a.is_finite() && b.is_finite() {
            (a - b).abs() / b.abs().max(1e-12)
        } else {
            f64::INFINITY // NaN/inf must fail the gate, not slip past max()
        };
        loss_dev = loss_dev.max(dev);
        if dev > sw.loss_rtol {
            bail!(
                "{} iter {i}: distributed loss {a} vs oracle {b} (rel dev {dev:.3e} > {:.1e})",
                cfg.mode.name(),
                sw.loss_rtol
            );
        }
    }
    // Gradient cross-check at the evolved state (end of the short run).
    let (lk, gk) = oracle.forward_backward(oracle.iterations())?;
    let (ln, gn) = oracle.naive_forward_backward(oracle.iterations())?;
    let mut grad_dev = if lk.is_finite() && ln.is_finite() {
        ((lk - ln).abs() / lk.abs().max(1e-12)) as f32
    } else {
        f32::INFINITY
    };
    if grad_dev > sw.grad_rtol {
        bail!(
            "{}: kernel vs naive loss dev {grad_dev:.3e} > {:.1e}",
            cfg.mode.name(),
            sw.grad_rtol
        );
    }
    for (rank, (a, b)) in gk.iter().zip(&gn).enumerate() {
        if a.len() != b.len() {
            bail!("rank {rank}: {} kernel grads vs {} naive", a.len(), b.len());
        }
        for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
            let dev = worst_dev(ta.data(), tb.data(), 1e-5);
            grad_dev = grad_dev.max(dev);
            if dev > sw.grad_rtol {
                bail!(
                    "{} rank {rank} grad {i}: kernel vs naive dev {dev:.3e} > {:.1e}",
                    cfg.mode.name(),
                    sw.grad_rtol
                );
            }
        }
    }
    Ok((loss_dev, grad_dev))
}

/// Cross-layout forward equivalence through the re-sharding algebra:
/// TP -> dense-phantom PP and PP -> TP, both checked against the source
/// layout's host-side forward on a shared batch.
fn cross_layout_dev(
    pp_cfg: &RunConfig,
    tp_cfg: &RunConfig,
    case_seed: u64,
    sw: &SweepConfig,
) -> Result<f32> {
    let mut rng = Prng::new(case_seed ^ 0xF0B0);
    let x = Tensor::randn(&[4, tp_cfg.model.n], 1.0, &mut rng);
    let mut worst = 0.0f32;

    let snap_tp = Snapshot::init(tp_cfg)?;
    let as_pp = reshard(&snap_tp, tp_cfg.p, Parallelism::Phantom)?;
    let y_tp = snap_tp.forward_host(&x)?;
    let y_pp = as_pp.forward_host(&x)?;
    worst = worst.max(worst_dev(y_tp.data(), y_pp.data(), 1e-4));

    let snap_pp = Snapshot::init(pp_cfg)?;
    let as_tp = reshard(&snap_pp, pp_cfg.p, Parallelism::Tensor)?;
    let y_src = snap_pp.forward_host(&x)?;
    let y_dst = as_tp.forward_host(&x)?;
    worst = worst.max(worst_dev(y_src.data(), y_dst.data(), 1e-4));

    if worst > sw.forward_rtol {
        bail!("cross-layout forward dev {worst:.3e} > {:.1e}", sw.forward_rtol);
    }
    Ok(worst)
}

/// Run the full sweep. Every case asserts the whole equivalence chain;
/// the report carries the worst observed deviations for the bench record.
pub fn run_sweep(sw: &SweepConfig) -> Result<SweepReport> {
    let mut rng = Prng::new(sw.seed);
    let mut report = SweepReport::default();
    for case in 0..sw.cases {
        let (base, opt_name) = sample_case(&mut rng, sw.iters);
        let mut pp_cfg = base.clone();
        pp_cfg.mode = Parallelism::Phantom;
        let mut tp_cfg = base.clone();
        tp_cfg.mode = Parallelism::Tensor;
        // Pipelining is a PP-only knob; the TP leg of the case keeps the
        // sharded_state dimension but runs the (only legal) sync schedule.
        tp_cfg.train.schedule = Schedule::Sync;

        let ctx = format!(
            "case {case}: n={} p={} dp={} k={} L={} batch={} opt={} sched={} sharded={} seed={:#x}",
            base.model.n, base.p, base.dp, base.model.k, base.model.layers,
            base.train.batch, opt_name, base.train.schedule.name(),
            base.train.sharded_state, base.train.seed
        );
        let (pp_loss, pp_grad) = run_mode(&pp_cfg, sw).context(ctx.clone())?;
        let (tp_loss, tp_grad) = run_mode(&tp_cfg, sw).context(ctx.clone())?;
        let fwd = cross_layout_dev(&pp_cfg, &tp_cfg, base.train.seed, sw).context(ctx)?;

        let loss_dev = pp_loss.max(tp_loss);
        let grad_dev = pp_grad.max(tp_grad);
        report.max_loss_dev = report.max_loss_dev.max(loss_dev);
        report.max_grad_dev = report.max_grad_dev.max(grad_dev);
        report.max_forward_dev = report.max_forward_dev.max(fwd);
        report.cases.push(CaseReport {
            n: base.model.n,
            p: base.p,
            dp: base.dp,
            k: base.model.k,
            layers: base.model.layers,
            batch: base.train.batch,
            optimizer: opt_name,
            seed: base.train.seed,
            backend: base.backend.name(),
            schedule: base.train.schedule.name(),
            sharded: base.train.sharded_state,
            loss_dev,
            grad_dev,
            forward_dev: fwd,
        });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_small_sweep_passes_and_is_deterministic() {
        let sw = SweepConfig { cases: 3, seed: 0x5EED, iters: 2, ..Default::default() };
        let a = run_sweep(&sw).unwrap();
        assert_eq!(a.cases.len(), 3);
        let b = run_sweep(&sw).unwrap();
        // Same seed, same cases, same (bitwise) observed deviations.
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.n, y.n);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.loss_dev.to_bits(), y.loss_dev.to_bits());
        }
    }
}
