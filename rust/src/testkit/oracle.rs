//! The dense single-rank reference oracle: forward + backward + optimizer
//! on the *logical* model, executed serially on one thread with no fabric.
//!
//! `ReferenceTrainer` materializes every rank's parameter shard (the same
//! deterministic init the distributed workers use), runs the identical
//! per-rank kernel schedule through `runtime::native::run_entry`, and
//! replaces each collective with its mathematical definition evaluated in
//! canonical rank order — exactly the order the fabric's last-arriver
//! combine uses. Because the kernels, the collectives' summation order,
//! and the driver's rank-ordered f64 loss aggregation are all replicated,
//! the oracle's loss trajectory matches a distributed `coordinator::train`
//! run **bit for bit**; the differential runner (testkit::differential)
//! asserts this within a tight tolerance on randomized configs.
//!
//! `naive_forward_backward` is a second, independent implementation of the
//! same math — unfused, `matmul_naive`-based, written from the paper's
//! equations (18–21) rather than from the kernels — used to cross-check
//! gradients within a loose float tolerance. A fused-kernel bug and a
//! schedule bug cannot both hide: the distributed run is checked against
//! the oracle, and the oracle against the naive math.

use anyhow::{bail, Result};

use crate::config::{Parallelism, RunConfig};
use crate::coordinator::rank_pp::param_shapes;
use crate::data::{dp_row_range, row_slice, Teacher};
use crate::model::{PhantomRankParams, TpRankParams};
use crate::runtime::native::run_entry;
use crate::runtime::ManifestConfig;
use crate::tensor::Tensor;
use crate::train::Optimizer;

/// The serial single-thread reference trainer (see module docs).
pub struct ReferenceTrainer {
    pub cfg: RunConfig,
    geo: ManifestConfig,
    teacher: Teacher,
    state: RankStates,
    opts: Vec<Optimizer>,
    /// Global loss per completed iteration (same scaling as the driver).
    pub losses: Vec<f64>,
    iter: u64,
}

enum RankStates {
    Pp(Vec<PhantomRankParams>),
    Tp(Vec<TpRankParams>),
}

impl ReferenceTrainer {
    pub fn new(cfg: &RunConfig) -> Result<ReferenceTrainer> {
        cfg.model.validate(cfg.p)?;
        if cfg.train.batch == 0 {
            bail!("batch must be positive");
        }
        if cfg.dp == 0 || cfg.train.batch < cfg.dp {
            bail!(
                "hybrid oracle needs 1 <= dp <= batch (dp={}, batch={})",
                cfg.dp,
                cfg.train.batch
            );
        }
        let geo = ManifestConfig::native(
            "testkit-oracle",
            cfg.p,
            cfg.model.n,
            cfg.model.k,
            cfg.train.batch,
        );
        let mut opts = Vec::with_capacity(cfg.p);
        let state = match cfg.mode {
            Parallelism::Phantom => {
                let mut ranks = Vec::with_capacity(cfg.p);
                for rank in 0..cfg.p {
                    let params =
                        PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    opts.push(Optimizer::new(cfg.train.optimizer, &param_shapes(&params)));
                    ranks.push(params);
                }
                RankStates::Pp(ranks)
            }
            Parallelism::Tensor => {
                let mut ranks = Vec::with_capacity(cfg.p);
                for rank in 0..cfg.p {
                    let params = TpRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    let shapes: Vec<Vec<usize>> = params
                        .weights
                        .iter()
                        .map(|t| t.shape().to_vec())
                        .chain(params.biases.iter().map(|t| t.shape().to_vec()))
                        .collect();
                    opts.push(Optimizer::new(cfg.train.optimizer, &shapes));
                    ranks.push(params);
                }
                RankStates::Tp(ranks)
            }
        };
        Ok(ReferenceTrainer {
            cfg: cfg.clone(),
            geo,
            teacher: Teacher::new(cfg.model.n, cfg.train.seed),
            state,
            opts,
            losses: Vec::new(),
            iter: 0,
        })
    }

    /// Completed iterations.
    pub fn iterations(&self) -> u64 {
        self.iter
    }

    /// The shared DP decomposition both math paths run through: generate
    /// iteration `iter`'s batch ONCE (fixed dataset, batch i %
    /// dataset_batches — the `BatchCache` contract), feed each replica's
    /// contiguous `dp_row_range` rows (column-cut per model rank, bitwise
    /// the driver's shards) through `replica_fb`, fold the local losses in
    /// world-rank order (replicas outer, ranks inner — the leader's
    /// canonical f64 sum), and sum gradients across replicas in replica
    /// order — exactly the fabric's `dp_all_reduce` combine. Keeping this
    /// in ONE place is what lets the kernel and naive paths disagree only
    /// in per-replica math, never in DP summation order.
    fn dp_accumulate(
        &self,
        iter: u64,
        mut replica_fb: impl FnMut(&[Tensor], &[Tensor]) -> Result<(Vec<f64>, Vec<Vec<Tensor>>)>,
    ) -> Result<(f64, Vec<Vec<Tensor>>)> {
        let dp = self.cfg.dp.max(1);
        let batch = self.cfg.train.batch;
        let scale = 1.0 / (batch as f64 * self.cfg.model.n as f64);
        let key = iter % self.cfg.train.dataset_batches.max(1) as u64;
        let (x, t) = self.teacher.batch(batch, key)?;
        let mut total = 0.0f64;
        let mut grads_acc: Option<Vec<Vec<Tensor>>> = None;
        for d in 0..dp {
            let (start, len) = dp_row_range(batch, dp, d);
            let xs = row_slice(&x, start, len)?.col_shards(self.cfg.p)?;
            let ts = row_slice(&t, start, len)?.col_shards(self.cfg.p)?;
            let (loss_locals, grads) = replica_fb(&xs, &ts)?;
            for l in &loss_locals {
                total += l;
            }
            match &mut grads_acc {
                None => grads_acc = Some(grads),
                Some(acc) => {
                    for (acc_rank, g_rank) in acc.iter_mut().zip(&grads) {
                        for (a, g) in acc_rank.iter_mut().zip(g_rank) {
                            a.add_assign(g);
                        }
                    }
                }
            }
        }
        Ok((total * scale, grads_acc.expect("dp >= 1")))
    }

    /// One full iteration's loss and per-MODEL-rank gradients (optimizer
    /// parameter order), computed with the production kernels but WITHOUT
    /// touching the trainer state. Hybrid DP×(TP|PP) is simulated exactly
    /// (see `dp_accumulate`), so the distributed hybrid run matches bit
    /// for bit.
    pub fn forward_backward(&self, iter: u64) -> Result<(f64, Vec<Vec<Tensor>>)> {
        self.dp_accumulate(iter, |xs, ts| match &self.state {
            RankStates::Pp(ranks) => self.pp_forward_backward(ranks, xs, ts),
            RankStates::Tp(ranks) => self.tp_forward_backward(ranks, xs, ts),
        })
    }

    /// Advance one iteration: forward + backward + optimizer, exactly the
    /// distributed schedule. Returns the global loss.
    pub fn step(&mut self) -> Result<f64> {
        let (loss, grads) = self.forward_backward(self.iter)?;
        match &mut self.state {
            RankStates::Pp(ranks) => {
                for (params, (opt, glist)) in
                    ranks.iter_mut().zip(self.opts.iter_mut().zip(&grads))
                {
                    let mut tensors = params.named_tensors();
                    let mut refs: Vec<&mut Tensor> =
                        tensors.iter_mut().map(|(_, t)| &mut **t).collect();
                    opt.step(&mut refs, glist);
                }
            }
            RankStates::Tp(ranks) => {
                for (params, (opt, glist)) in
                    ranks.iter_mut().zip(self.opts.iter_mut().zip(&grads))
                {
                    let mut tensors = params.named_tensors();
                    let mut refs: Vec<&mut Tensor> =
                        tensors.iter_mut().map(|(_, t)| &mut **t).collect();
                    opt.step(&mut refs, glist);
                }
            }
        }
        self.losses.push(loss);
        self.iter += 1;
        Ok(loss)
    }

    /// Run `iters` iterations; returns the loss trajectory so far.
    pub fn run(&mut self, iters: usize) -> Result<&[f64]> {
        for _ in 0..iters {
            self.step()?;
        }
        Ok(&self.losses)
    }

    // -- collective simulations (canonical rank order, as the fabric) ------

    /// All-Gather: rank-ordered stack — what every rank receives.
    fn sim_all_gather(parts: &[Tensor]) -> Result<Tensor> {
        Tensor::stack(parts)
    }

    /// Reduce-Scatter: slot j summed across ranks in rank order, delivered
    /// to rank j. Mirrors `Endpoint::reduce_scatter`'s combine exactly.
    fn sim_reduce_scatter(parts: &[Tensor]) -> Vec<Tensor> {
        let p = parts.len();
        let mut out = Vec::with_capacity(p);
        for j in 0..p {
            let mut acc = parts[0].unstack_at(j);
            for part in &parts[1..] {
                acc.add_assign(&part.unstack_at(j));
            }
            out.push(acc);
        }
        out
    }

    /// All-Reduce: elementwise sum in rank order, as the fabric combines.
    fn sim_all_reduce(parts: &[Tensor]) -> Tensor {
        let mut acc = parts[0].clone();
        for part in &parts[1..] {
            acc.add_assign(part);
        }
        acc
    }

    // -- phantom-parallel schedule ------------------------------------------

    /// One replica's PP schedule over its (already row-sharded) column
    /// shards. Returns the per-rank UNSCALED local losses in rank order
    /// plus per-rank gradients; the caller owns scaling and DP summation.
    fn pp_forward_backward(
        &self,
        ranks: &[PhantomRankParams],
        xs: &[Tensor],
        ts: &[Tensor],
    ) -> Result<(Vec<f64>, Vec<Vec<Tensor>>)> {
        let p = self.cfg.p;
        let layers = self.cfg.model.layers;
        let geo = &self.geo;

        // forward: ys[l][r], zs[l][r], g_alls[l][r] (own slot zeroed).
        let mut ys: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
        let mut zs: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
        let mut g_alls: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
        for l in 0..layers {
            let mut z_locs = Vec::with_capacity(p);
            let mut gs = Vec::with_capacity(p);
            for r in 0..p {
                let y_in = if l == 0 { &xs[r] } else { &ys[l - 1][r] };
                let out = run_entry(
                    geo,
                    "pp_fwd_local",
                    &[y_in, &ranks[r].locals[l], &ranks[r].compressors[l]],
                )?;
                let [z_loc, g] = two(out)?;
                z_locs.push(z_loc);
                gs.push(g);
            }
            let gathered = Self::sim_all_gather(&gs)?;
            let mut y_row = Vec::with_capacity(p);
            let mut z_row = Vec::with_capacity(p);
            let mut g_row = Vec::with_capacity(p);
            for r in 0..p {
                let mut g_all = gathered.clone();
                g_all.zero_slot(r);
                let out = run_entry(
                    geo,
                    "pp_fwd_combine",
                    &[&z_locs[r], &g_all, &ranks[r].decompressors[l], &ranks[r].biases[l]],
                )?;
                let [y_out, z] = two(out)?;
                y_row.push(y_out);
                z_row.push(z);
                g_row.push(g_all);
            }
            ys.push(y_row);
            zs.push(z_row);
            g_alls.push(g_row);
        }

        // loss + top-layer error compression (per-rank local losses; the
        // caller folds them in the driver's canonical order).
        let mut loss_locals = Vec::with_capacity(p);
        let mut deltas = Vec::with_capacity(p);
        let mut h_outs = Vec::with_capacity(p);
        for r in 0..p {
            let out = run_entry(
                geo,
                "mse_delta",
                &[&ys[layers - 1][r], &zs[layers - 1][r], &ts[r]],
            )?;
            let [loss_t, delta] = two(out)?;
            loss_locals.push(loss_t.data()[0] as f64);
            let out = run_entry(
                geo,
                "pp_bwd_compress",
                &[&delta, &ranks[r].decompressors[layers - 1]],
            )?;
            let [h_out] = one(out)?;
            deltas.push(delta);
            h_outs.push(h_out);
        }
        let mut h_sums = Self::sim_reduce_scatter(&h_outs);

        // backward: per layer, per rank: pp_grads, then the fused
        // combine(l)+compress(l-1) composition and the Reduce-Scatter.
        let mut grads: Vec<Vec<Option<[Tensor; 4]>>> =
            (0..p).map(|_| (0..layers).map(|_| None).collect()).collect();
        for l in (0..layers).rev() {
            for r in 0..p {
                let y_prev = if l == 0 { &xs[r] } else { &ys[l - 1][r] };
                let out = run_entry(
                    geo,
                    "pp_grads",
                    &[y_prev, &deltas[r], &h_sums[r], &g_alls[l][r]],
                )?;
                let [dl, dc, dd, db] = four(out)?;
                grads[r][l] = Some([dl, dc, dd, db]);
            }
            if l > 0 {
                let mut next_h = Vec::with_capacity(p);
                for r in 0..p {
                    let out = run_entry(
                        geo,
                        "pp_bwd_combine",
                        &[
                            &deltas[r],
                            &h_sums[r],
                            &ranks[r].locals[l],
                            &ranks[r].compressors[l],
                            &zs[l - 1][r],
                        ],
                    )?;
                    let [delta_prev] = one(out)?;
                    let out = run_entry(
                        geo,
                        "pp_bwd_compress",
                        &[&delta_prev, &ranks[r].decompressors[l - 1]],
                    )?;
                    let [h_out] = one(out)?;
                    deltas[r] = delta_prev;
                    next_h.push(h_out);
                }
                h_sums = Self::sim_reduce_scatter(&next_h);
            }
        }

        // optimizer order: L*, C*, D*, b* (rank_pp::iteration).
        let mut out = Vec::with_capacity(p);
        for rank_grads in grads {
            out.push(order_pp_grads(rank_grads));
        }
        Ok((loss_locals, out))
    }

    // -- tensor-parallel schedule -------------------------------------------

    /// One replica's TP schedule; same contract as `pp_forward_backward`.
    fn tp_forward_backward(
        &self,
        ranks: &[TpRankParams],
        xs: &[Tensor],
        ts: &[Tensor],
    ) -> Result<(Vec<f64>, Vec<Vec<Tensor>>)> {
        let p = self.cfg.p;
        let layers = self.cfg.model.layers;
        let m = self.cfg.model.n / p;
        let geo = &self.geo;

        let mut y_shards: Vec<Tensor> = xs.to_vec();
        let mut y_fulls: Vec<Tensor> = Vec::with_capacity(layers);
        let mut zs: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
        for l in 0..layers {
            let gathered = Self::sim_all_gather(&y_shards)?;
            let y_full = gathered.concat_shards_stacked()?;
            let mut z_row = Vec::with_capacity(p);
            for r in 0..p {
                let out = run_entry(
                    geo,
                    "tp_fwd",
                    &[&y_full, &ranks[r].weights[l], &ranks[r].biases[l]],
                )?;
                let [y_out, z] = two(out)?;
                y_shards[r] = y_out;
                z_row.push(z);
            }
            y_fulls.push(y_full);
            zs.push(z_row);
        }

        let mut loss_locals = Vec::with_capacity(p);
        let mut deltas = Vec::with_capacity(p);
        for r in 0..p {
            let out = run_entry(
                geo,
                "mse_delta",
                &[&y_shards[r], &zs[layers - 1][r], &ts[r]],
            )?;
            let [loss_t, delta] = two(out)?;
            loss_locals.push(loss_t.data()[0] as f64);
            deltas.push(delta);
        }

        let mut grads: Vec<Vec<Option<[Tensor; 2]>>> =
            (0..p).map(|_| (0..layers).map(|_| None).collect()).collect();
        for r in 0..p {
            let out = run_entry(geo, "tp_grads", &[&y_fulls[layers - 1], &deltas[r]])?;
            let [dw, db] = two(out)?;
            grads[r][layers - 1] = Some([dw, db]);
        }
        for l in (1..layers).rev() {
            let mut partials = Vec::with_capacity(p);
            for r in 0..p {
                let out = run_entry(geo, "tp_bwd_partial", &[&deltas[r], &ranks[r].weights[l]])?;
                let [dy] = one(out)?;
                partials.push(dy);
            }
            let dy_full = Self::sim_all_reduce(&partials);
            for r in 0..p {
                let dy_shard = dy_full.col_slice(r * m, m)?;
                let out = run_entry(geo, "tp_bwd_finish", &[&dy_shard, &zs[l - 1][r]])?;
                let [delta] = one(out)?;
                let out = run_entry(geo, "tp_grads", &[&y_fulls[l - 1], &delta])?;
                let [dw, db] = two(out)?;
                deltas[r] = delta;
                grads[r][l - 1] = Some([dw, db]);
            }
        }

        // optimizer order: W*, b* (rank_tp::iteration).
        let mut out = Vec::with_capacity(p);
        for rank_grads in grads {
            let mut dws = Vec::with_capacity(layers);
            let mut dbs = Vec::with_capacity(layers);
            for g in rank_grads {
                let [dw, db] = g.expect("every layer produced grads");
                dws.push(dw);
                dbs.push(db);
            }
            let mut glist = dws;
            glist.append(&mut dbs);
            out.push(glist);
        }
        Ok((loss_locals, out))
    }

    // -- independent naive reference ---------------------------------------

    /// The same iteration computed by a second, unfused implementation:
    /// `matmul_naive`, explicit loops, paper-equation gradient formulas —
    /// through the SAME DP decomposition (`dp_accumulate`). Returns
    /// (loss, per-rank grads) in the same order as `forward_backward`;
    /// agreement is within float tolerance, not bitwise (summation orders
    /// differ by construction).
    pub fn naive_forward_backward(&self, iter: u64) -> Result<(f64, Vec<Vec<Tensor>>)> {
        self.dp_accumulate(iter, |xs, ts| match &self.state {
            RankStates::Pp(ranks) => naive_pp(&self.cfg, ranks, xs, ts),
            RankStates::Tp(ranks) => naive_tp(&self.cfg, ranks, xs, ts),
        })
    }
}

fn order_pp_grads(rank_grads: Vec<Option<[Tensor; 4]>>) -> Vec<Tensor> {
    let layers = rank_grads.len();
    let mut dls = Vec::with_capacity(layers);
    let mut dcs = Vec::with_capacity(layers);
    let mut dds = Vec::with_capacity(layers);
    let mut dbs = Vec::with_capacity(layers);
    for g in rank_grads {
        let [dl, dc, dd, db] = g.expect("every layer produced grads");
        dls.push(dl);
        dcs.push(dc);
        dds.push(dd);
        dbs.push(db);
    }
    let mut glist = dls;
    glist.append(&mut dcs);
    glist.append(&mut dds);
    glist.append(&mut dbs);
    glist
}

fn one(mut v: Vec<Tensor>) -> Result<[Tensor; 1]> {
    if v.len() != 1 {
        bail!("expected 1 output, got {}", v.len());
    }
    Ok([v.pop().expect("length checked")])
}

fn two(v: Vec<Tensor>) -> Result<[Tensor; 2]> {
    if v.len() != 2 {
        bail!("expected 2 outputs, got {}", v.len());
    }
    Ok(v.try_into().map_err(|_| ()).expect("length checked"))
}

fn four(v: Vec<Tensor>) -> Result<[Tensor; 4]> {
    if v.len() != 4 {
        bail!("expected 4 outputs, got {}", v.len());
    }
    Ok(v.try_into().map_err(|_| ()).expect("length checked"))
}

// -- naive math (independent of runtime::native) ----------------------------

fn relu_mask_into(z: &Tensor, t: &mut Tensor) {
    for (o, &zv) in t.data_mut().iter_mut().zip(z.data()) {
        if zv <= 0.0 {
            *o = 0.0;
        }
    }
}

fn add_bias_relu(mut z: Tensor, b: &Tensor) -> (Tensor, Tensor) {
    let m = b.numel();
    for row in z.data_mut().chunks_mut(m) {
        for (v, &bv) in row.iter_mut().zip(b.data()) {
            *v += bv;
        }
    }
    let y = z.relu();
    (y, z)
}

fn col_sum(t: &Tensor) -> Tensor {
    let m = *t.shape().last().expect("2-D tensor");
    let mut out = Tensor::zeros(&[m]);
    for row in t.data().chunks(m) {
        for (o, &v) in out.data_mut().iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

fn mse_and_delta(y: &Tensor, z: &Tensor, t: &Tensor, scale: f32) -> (f64, Tensor) {
    let mut delta = Tensor::zeros(y.shape());
    let mut loss = 0.0f64;
    for i in 0..y.numel() {
        let diff = y.data()[i] - t.data()[i];
        loss += (diff as f64) * (diff as f64);
        delta.data_mut()[i] = if z.data()[i] > 0.0 { 2.0 * scale * diff } else { 0.0 };
    }
    (loss, delta)
}

/// One replica's naive PP math over its (already row-sharded) column
/// shards: per-rank unscaled local losses + per-rank grads. The delta
/// scale stays the GLOBAL batch's 1/(B*n) — exactly what the kernels bake
/// in — so replica gradient sums reproduce the full-batch gradient.
fn naive_pp(
    cfg: &RunConfig,
    ranks: &[PhantomRankParams],
    xs: &[Tensor],
    ts: &[Tensor],
) -> Result<(Vec<f64>, Vec<Vec<Tensor>>)> {
    let p = cfg.p;
    let layers = cfg.model.layers;
    let scale = 1.0 / (cfg.train.batch as f64 * cfg.model.n as f64);

    let mut ys: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
    let mut zs: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
    let mut g_alls: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
    for l in 0..layers {
        let mut gs = Vec::with_capacity(p);
        let mut z_locs = Vec::with_capacity(p);
        for r in 0..p {
            let y_in = if l == 0 { &xs[r] } else { &ys[l - 1][r] };
            z_locs.push(y_in.matmul_naive(&ranks[r].locals[l])?);
            gs.push(y_in.matmul_naive(&ranks[r].compressors[l])?);
        }
        let gathered = Tensor::stack(&gs)?;
        let mut y_row = Vec::with_capacity(p);
        let mut z_row = Vec::with_capacity(p);
        let mut g_row = Vec::with_capacity(p);
        for r in 0..p {
            let mut g_all = gathered.clone();
            g_all.zero_slot(r);
            let mut z = z_locs[r].clone();
            for src in 0..p {
                if src == r {
                    continue;
                }
                let d = ranks[r].decompressors[l].unstack_at(src);
                z.add_assign(&g_all.unstack_at(src).matmul_naive(&d)?);
            }
            let (y, z) = add_bias_relu(z, &ranks[r].biases[l]);
            y_row.push(y);
            z_row.push(z);
            g_row.push(g_all);
        }
        ys.push(y_row);
        zs.push(z_row);
        g_alls.push(g_row);
    }

    let mut loss_locals = Vec::with_capacity(p);
    let mut deltas = Vec::with_capacity(p);
    for r in 0..p {
        let (lr, d) =
            mse_and_delta(&ys[layers - 1][r], &zs[layers - 1][r], &ts[r], scale as f32);
        loss_locals.push(lr);
        deltas.push(d);
    }

    // h_out[r] = delta_r · D_r[i]ᵀ per destination i; h_sum by slot sum.
    let h_sum_of = |deltas: &[Tensor], layer: usize| -> Result<Vec<Tensor>> {
        let mut h_sums: Vec<Option<Tensor>> = (0..p).map(|_| None).collect();
        for r in 0..p {
            for i in 0..p {
                let d = ranks[r].decompressors[layer].unstack_at(i);
                let h = deltas[r].matmul_naive(&d.transpose()?)?;
                match &mut h_sums[i] {
                    None => h_sums[i] = Some(h),
                    Some(acc) => acc.add_assign(&h),
                }
            }
        }
        Ok(h_sums.into_iter().map(|h| h.expect("every slot summed")).collect())
    };
    let mut h_sums = h_sum_of(&deltas, layers - 1)?;

    let mut grads: Vec<Vec<Option<[Tensor; 4]>>> =
        (0..p).map(|_| (0..layers).map(|_| None).collect()).collect();
    for l in (0..layers).rev() {
        for r in 0..p {
            let y_prev = if l == 0 { &xs[r] } else { &ys[l - 1][r] };
            let y_prev_t = y_prev.transpose()?;
            let dl = y_prev_t.matmul_naive(&deltas[r])?;
            let dc = y_prev_t.matmul_naive(&h_sums[r])?;
            let (pk, kk, mm) =
                match ranks[r].decompressors[l].shape() {
                    [a, b, c] => (*a, *b, *c),
                    s => bail!("decompressor must be 3-D, got {s:?}"),
                };
            let mut dd = Tensor::zeros(&[pk, kk, mm]);
            for i in 0..p {
                if i == r {
                    continue; // own slot: structurally zero
                }
                let gi = g_alls[l][r].unstack_at(i);
                let block = gi.transpose()?.matmul_naive(&deltas[r])?;
                dd.data_mut()[i * kk * mm..(i + 1) * kk * mm].copy_from_slice(block.data());
            }
            let db = col_sum(&deltas[r]);
            grads[r][l] = Some([dl, dc, dd, db]);
        }
        if l > 0 {
            let mut next = Vec::with_capacity(p);
            for r in 0..p {
                let mut d = deltas[r].matmul_naive(&ranks[r].locals[l].transpose()?)?;
                d.add_assign(&h_sums[r].matmul_naive(&ranks[r].compressors[l].transpose()?)?);
                relu_mask_into(&zs[l - 1][r], &mut d);
                next.push(d);
            }
            deltas = next;
            h_sums = h_sum_of(&deltas, l - 1)?;
        }
    }

    let mut out = Vec::with_capacity(p);
    for rank_grads in grads {
        out.push(order_pp_grads(rank_grads));
    }
    Ok((loss_locals, out))
}

/// One replica's naive TP math; same contract as `naive_pp`.
fn naive_tp(
    cfg: &RunConfig,
    ranks: &[TpRankParams],
    xs: &[Tensor],
    ts: &[Tensor],
) -> Result<(Vec<f64>, Vec<Vec<Tensor>>)> {
    let p = cfg.p;
    let layers = cfg.model.layers;
    let m = cfg.model.n / p;
    let scale = 1.0 / (cfg.train.batch as f64 * cfg.model.n as f64);

    let mut y_shards: Vec<Tensor> = xs.to_vec();
    let mut y_fulls = Vec::with_capacity(layers);
    let mut zs: Vec<Vec<Tensor>> = Vec::with_capacity(layers);
    for l in 0..layers {
        let y_full = Tensor::from_col_shards(&y_shards)?;
        let mut z_row = Vec::with_capacity(p);
        for r in 0..p {
            let z = y_full.matmul_naive(&ranks[r].weights[l])?;
            let (y, z) = add_bias_relu(z, &ranks[r].biases[l]);
            y_shards[r] = y;
            z_row.push(z);
        }
        y_fulls.push(y_full);
        zs.push(z_row);
    }

    let mut loss_locals = Vec::with_capacity(p);
    let mut deltas = Vec::with_capacity(p);
    for r in 0..p {
        let (lr, d) = mse_and_delta(&y_shards[r], &zs[layers - 1][r], &ts[r], scale as f32);
        loss_locals.push(lr);
        deltas.push(d);
    }

    let mut grads: Vec<Vec<Option<[Tensor; 2]>>> =
        (0..p).map(|_| (0..layers).map(|_| None).collect()).collect();
    for r in 0..p {
        let dw = y_fulls[layers - 1].transpose()?.matmul_naive(&deltas[r])?;
        grads[r][layers - 1] = Some([dw, col_sum(&deltas[r])]);
    }
    for l in (1..layers).rev() {
        let mut dy_full: Option<Tensor> = None;
        for r in 0..p {
            let partial = deltas[r].matmul_naive(&ranks[r].weights[l].transpose()?)?;
            match &mut dy_full {
                None => dy_full = Some(partial),
                Some(acc) => acc.add_assign(&partial),
            }
        }
        let dy_full = dy_full.expect("p >= 1");
        for r in 0..p {
            let mut delta = dy_full.col_slice(r * m, m)?;
            relu_mask_into(&zs[l - 1][r], &mut delta);
            let dw = y_fulls[l - 1].transpose()?.matmul_naive(&delta)?;
            let db = col_sum(&delta);
            deltas[r] = delta;
            grads[r][l - 1] = Some([dw, db]);
        }
    }

    let mut out = Vec::with_capacity(p);
    for rank_grads in grads {
        let mut dws = Vec::with_capacity(layers);
        let mut dbs = Vec::with_capacity(layers);
        for g in rank_grads {
            let [dw, db] = g.expect("every layer produced grads");
            dws.push(dw);
            dbs.push(db);
        }
        let mut glist = dws;
        glist.append(&mut dbs);
        out.push(glist);
    }
    Ok((loss_locals, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::util::proptest::assert_close;

    #[test]
    fn oracle_runs_and_losses_fall_both_modes() {
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.train.max_iters = 6;
            let mut oracle = ReferenceTrainer::new(&cfg).unwrap();
            oracle.run(6).unwrap();
            assert_eq!(oracle.losses.len(), 6);
            assert!(
                oracle.losses[5] < oracle.losses[0],
                "{}: {:?}",
                mode.name(),
                oracle.losses
            );
        }
    }

    #[test]
    fn kernel_and_naive_paths_agree_on_loss_and_grads() {
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.model.layers = 3;
            let mut oracle = ReferenceTrainer::new(&cfg).unwrap();
            // Check at init and again at an evolved state.
            for round in 0..2 {
                let (loss_k, grads_k) = oracle.forward_backward(oracle.iterations()).unwrap();
                let (loss_n, grads_n) =
                    oracle.naive_forward_backward(oracle.iterations()).unwrap();
                let rel = (loss_k - loss_n).abs() / loss_k.abs().max(1e-12);
                assert!(rel < 1e-5, "{} round {round}: loss {loss_k} vs {loss_n}", mode.name());
                assert_eq!(grads_k.len(), grads_n.len());
                for (r, (gk, gn)) in grads_k.iter().zip(&grads_n).enumerate() {
                    assert_eq!(gk.len(), gn.len(), "rank {r}");
                    for (i, (a, b)) in gk.iter().zip(gn).enumerate() {
                        assert_eq!(a.shape(), b.shape(), "rank {r} grad {i}");
                        assert_close(a.data(), b.data(), 1e-3, 1e-5).unwrap_or_else(|e| {
                            panic!("{} round {round} rank {r} grad {i}: {e}", mode.name())
                        });
                    }
                }
                oracle.step().unwrap();
                oracle.step().unwrap();
            }
        }
    }

    #[test]
    fn oracle_is_deterministic() {
        let cfg = preset("tiny", Parallelism::Phantom).unwrap();
        let run = || {
            let mut o = ReferenceTrainer::new(&cfg).unwrap();
            o.run(4).unwrap().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn hybrid_oracle_trains_and_matches_full_batch_gradients() {
        // The DP decomposition is a pure re-bracketing of the full-batch
        // sums: per-replica gradients (computed at the GLOBAL loss scale)
        // summed across replicas must equal the dp=1 gradients within
        // float tolerance — including an uneven split (batch % dp != 0).
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.train.batch = 7; // odd: dp=2 rows split 4 + 3
            for dp in [2usize, 4] {
                let mut full = cfg.clone();
                full.dp = 1;
                let mut hybrid = cfg.clone();
                hybrid.dp = dp;
                let o_full = ReferenceTrainer::new(&full).unwrap();
                let o_hyb = ReferenceTrainer::new(&hybrid).unwrap();
                let (l_full, g_full) = o_full.forward_backward(0).unwrap();
                let (l_hyb, g_hyb) = o_hyb.forward_backward(0).unwrap();
                let rel = (l_full - l_hyb).abs() / l_full.abs().max(1e-12);
                assert!(rel < 1e-5, "{} dp={dp}: loss {l_full} vs {l_hyb}", mode.name());
                for (r, (a, b)) in g_full.iter().zip(&g_hyb).enumerate() {
                    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
                        assert_close(ta.data(), tb.data(), 1e-3, 1e-5).unwrap_or_else(|e| {
                            panic!("{} dp={dp} rank {r} grad {i}: {e}", mode.name())
                        });
                    }
                }
            }
            // And the hybrid oracle actually trains.
            let mut hybrid = cfg.clone();
            hybrid.dp = 2;
            let mut o = ReferenceTrainer::new(&hybrid).unwrap();
            o.run(5).unwrap();
            assert!(o.losses[4] < o.losses[0], "{}: {:?}", mode.name(), o.losses);
        }
    }

    #[test]
    fn hybrid_oracle_kernel_and_naive_agree() {
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.train.batch = 6;
            cfg.dp = 2;
            let mut oracle = ReferenceTrainer::new(&cfg).unwrap();
            oracle.step().unwrap();
            let (lk, gk) = oracle.forward_backward(oracle.iterations()).unwrap();
            let (ln, gn) = oracle.naive_forward_backward(oracle.iterations()).unwrap();
            let rel = (lk - ln).abs() / lk.abs().max(1e-12);
            assert!(rel < 1e-5, "{}: loss {lk} vs naive {ln}", mode.name());
            for (r, (a, b)) in gk.iter().zip(&gn).enumerate() {
                for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
                    assert_close(ta.data(), tb.data(), 1e-3, 1e-5).unwrap_or_else(|e| {
                        panic!("{} rank {r} grad {i}: {e}", mode.name())
                    });
                }
            }
        }
    }
}
