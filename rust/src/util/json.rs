//! Minimal JSON parser/serializer.
//!
//! The offline crate set for this repo contains only `xla` and `anyhow`, so
//! JSON handling (artifact manifest, experiment configs, result files) is
//! implemented here. Supports the full JSON grammar; numbers are kept as
//! f64 with an i64 fast path (artifact shapes are exact integers).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic — experiment result files diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn int(x: i64) -> Json {
        Json::Num(x as f64)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if !pretty {
                            out.push(' ');
                        }
                    }
                    pad(out, indent + 1);
                    e.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if !pretty {
                            out.push(' ');
                        }
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Schema version stamped into the reserved `meta` key of every
/// BENCH_*.json record file. Bump when the record format itself changes
/// shape (not when individual record keys come and go).
pub const BENCH_SCHEMA_VERSION: i64 = 1;

/// Provenance header for BENCH_*.json record files: which scenario wrote
/// the file, on which SIMD ISA, and how long the virtual run was. Written
/// under the reserved `meta` key (the only nesting the flat record format
/// allows); `read_records_json` skips it when reading records back.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchMeta {
    pub scenario: String,
    pub isa: String,
    /// Virtual (simulated) duration of the run that produced the records,
    /// in seconds; 0.0 for scenarios with no virtual clock.
    pub virtual_s: f64,
}

impl BenchMeta {
    /// Header for `scenario`, stamped with the active SIMD ISA.
    pub fn new(scenario: &str, virtual_s: f64) -> BenchMeta {
        BenchMeta {
            scenario: scenario.to_string(),
            isa: crate::tensor::simd::active().name().to_string(),
            virtual_s,
        }
    }

    /// The header as a JSON object — what lands under the `meta` key.
    /// Public so nested result files (e.g. BENCH_plan.json) can embed the
    /// same header without going through the flat record writer.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::int(BENCH_SCHEMA_VERSION)),
            ("scenario", Json::str(self.scenario.as_str())),
            ("isa", Json::str(self.isa.as_str())),
            ("virtual_s", Json::num(self.virtual_s)),
        ])
    }
}

/// Write flat (key, value) records as a pretty JSON object — the
/// machine-readable perf-trajectory format (BENCH_*.json) that benches,
/// tests and the CLI diff across PRs.
pub fn write_records_json(
    path: &std::path::Path,
    records: &[(String, f64)],
) -> Result<(), std::io::Error> {
    let obj = Json::obj(records.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect());
    std::fs::write(path, obj.pretty())
}

/// `write_records_json` plus the `meta` provenance header. Record keys
/// named "meta" would collide with the header and are rejected.
pub fn write_records_json_with_meta(
    path: &std::path::Path,
    records: &[(String, f64)],
    meta: &BenchMeta,
) -> Result<(), std::io::Error> {
    if records.iter().any(|(k, _)| k == "meta") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            "record key 'meta' is reserved for the BenchMeta header",
        ));
    }
    let mut pairs: Vec<(&str, Json)> = vec![("meta", meta.to_json())];
    pairs.extend(records.iter().map(|(k, v)| (k.as_str(), Json::num(*v))));
    std::fs::write(path, Json::obj(pairs).pretty())
}

/// Write an arbitrary (possibly nested) JSON value pretty-printed. Used
/// for structured result files like BENCH_plan.json whose sweep arrays do
/// not fit the flat record schema of `write_records_json`.
pub fn write_json(path: &std::path::Path, value: &Json) -> Result<(), std::io::Error> {
    std::fs::write(path, value.pretty())
}

/// Read and parse a JSON file; parse failures surface as
/// `io::ErrorKind::InvalidData` so callers have one error channel for both
/// missing and malformed files. Used for checkpoint-manifest reads.
pub fn read_json(path: &std::path::Path) -> Result<Json, std::io::Error> {
    let text = std::fs::read_to_string(path)?;
    Json::parse(&text).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("{}: {e}", path.display()))
    })
}

/// Counterpart of `write_records_json`: read a flat (key, value) record
/// file back as ordered pairs. Rejects nesting — the perf-trajectory format
/// is a single object of numbers, and a file that stopped being flat should
/// fail loudly rather than be half-read. The one exception is the reserved
/// `meta` key (the `BenchMeta` provenance header), which is skipped.
pub fn read_records_json(path: &std::path::Path) -> Result<Vec<(String, f64)>, std::io::Error> {
    let invalid = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    let j = read_json(path)?;
    let obj = j
        .as_obj()
        .ok_or_else(|| invalid(format!("{}: records file must be an object", path.display())))?;
    let mut out = Vec::with_capacity(obj.len());
    for (k, v) in obj {
        if k == "meta" && v.as_obj().is_some() {
            continue;
        }
        let x = v.as_f64().ok_or_else(|| {
            invalid(format!("{}: record '{k}' is not a number", path.display()))
        })?;
        out.push((k.clone(), x));
    }
    Ok(out)
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']' in array"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.src.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x", "\"\u{1}\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a": [1, 2.5, -3], "b": {"c": "d\ne"}, "e": [], "f": {}}"#,
            r#"[null, true, false, 0, "x"]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.pretty()).unwrap();
            let v3 = Json::parse(&v.compact()).unwrap();
            assert_eq!(v, v2);
            assert_eq!(v, v3);
        }
    }

    #[test]
    fn integer_accessors() {
        let v = Json::parse("{\"n\": 8, \"x\": 1.5}").unwrap();
        assert_eq!(v.get("n").as_usize(), Some(8));
        assert_eq!(v.get("n").as_i64(), Some(8));
        assert_eq!(v.get("x").as_i64(), None);
        assert_eq!(v.get("x").as_f64(), Some(1.5));
    }

    #[test]
    fn records_roundtrip_and_reject_nesting() {
        let dir = std::env::temp_dir().join(format!("phantom-json-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("records.json");
        let records = vec![
            ("alpha".to_string(), 1.5),
            ("beta".to_string(), -3.0),
            ("gamma".to_string(), 0.125),
        ];
        write_records_json(&path, &records).unwrap();
        let back = read_records_json(&path).unwrap();
        // Object keys serialize sorted; compare as sets of exact pairs.
        assert_eq!(back.len(), records.len());
        for (k, v) in &records {
            let got = back.iter().find(|(bk, _)| bk == k).unwrap_or_else(|| panic!("{k}"));
            assert_eq!(got.1, *v, "{k}");
        }

        std::fs::write(&path, r#"{"a": {"nested": 1}}"#).unwrap();
        assert!(read_records_json(&path).is_err(), "nested value must be rejected");
        std::fs::write(&path, "[1, 2]").unwrap();
        assert!(read_records_json(&path).is_err(), "non-object must be rejected");
        std::fs::write(&path, "{bad").unwrap();
        let err = read_json(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(read_json(&dir.join("missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn records_meta_header_roundtrips_and_is_skipped() {
        let dir =
            std::env::temp_dir().join(format!("phantom-json-meta-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        let records = vec![("alpha".to_string(), 1.5), ("beta".to_string(), 2.0)];
        let meta =
            BenchMeta { scenario: "serve".to_string(), isa: "scalar".to_string(), virtual_s: 3.25 };
        write_records_json_with_meta(&path, &records, &meta).unwrap();

        // Reading records back skips the header...
        let back = read_records_json(&path).unwrap();
        assert_eq!(back.len(), records.len());
        assert!(back.iter().all(|(k, _)| k != "meta"));

        // ...but it is present and well-formed in the raw JSON.
        let j = read_json(&path).unwrap();
        assert_eq!(j.get("meta").get("schema").as_i64(), Some(BENCH_SCHEMA_VERSION));
        assert_eq!(j.get("meta").get("scenario").as_str(), Some("serve"));
        assert_eq!(j.get("meta").get("isa").as_str(), Some("scalar"));
        assert_eq!(j.get("meta").get("virtual_s").as_f64(), Some(3.25));

        // A record key named "meta" would collide with the header.
        let clash = vec![("meta".to_string(), 1.0)];
        assert!(write_records_json_with_meta(&path, &clash, &meta).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builders_serialize() {
        let v = Json::obj(vec![
            ("name", Json::str("tiny")),
            ("p", Json::int(4)),
            ("vals", Json::arr(vec![Json::num(1.5), Json::int(2)])),
        ]);
        assert_eq!(v.compact(), r#"{"name": "tiny", "p": 4, "vals": [1.5, 2]}"#);
    }
}
