//! Seeded random-case property-test driver.
//!
//! A lightweight stand-in for the `proptest` crate (not available in the
//! offline crate set): runs a property over many PRNG-generated cases and
//! reports the failing seed so a case can be replayed deterministically
//! (`PHANTOM_PROP_SEED=<seed> cargo test ...`).

use super::prng::Prng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Env override lets a failing case be replayed exactly.
        let seed = std::env::var("PHANTOM_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        PropConfig { cases: 64, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. Each case gets an independent
/// PRNG stream derived from the base seed; on failure, panics with the
/// case index and per-case seed for replay.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let mut root = Prng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = root.next_u64();
        let mut rng = Prng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} (case_seed={case_seed:#x}, \
                 base seed={:#x}): {msg}\nreplay: PHANTOM_PROP_SEED={} cargo test",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

/// Convenience: run with default config.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

/// Assert two f32 slices are elementwise close; returns an Err describing
/// the worst violation (for use inside properties).
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0.0f32);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let diff = (x - y).abs();
        let bound = atol + rtol * y.abs().max(x.abs());
        if diff > bound && diff > worst.1 {
            worst = (i, diff);
        }
    }
    if worst.1 > 0.0 {
        return Err(format!(
            "mismatch at [{}]: {} vs {} (|diff|={}, rtol={rtol}, atol={atol})",
            worst.0, a[worst.0], b[worst.0], worst.1
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        quickcheck("u64 is even or odd", |rng| {
            let v = rng.next_u64();
            if v % 2 == 0 || v % 2 == 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", PropConfig { cases: 3, seed: 1 }, |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }
}
