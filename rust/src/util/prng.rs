//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 core with Box–Muller normal sampling. Used by the data
//! generator (Gaussian teacher), parameter initialization, and the
//! property-test driver. Determinism across runs matters: the fixed-loss
//! convergence experiments (Table I / Fig 7) compare TP and PP on *identical*
//! training data, as the paper requires.

/// SplitMix64: tiny, fast, passes BigCrush for this use. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
    /// Cached second output of the Box–Muller pair.
    spare_normal: Option<f64>,
}

/// The complete serializable state of a `Prng`. Capturing and restoring it
/// splits a stream without perturbing it: the restored stream continues
/// bit-identically to the uninterrupted one (checkpoint resume relies on
/// this). The Box–Muller spare is part of the state — dropping it would
/// desynchronize any stream captured after an odd number of normal draws.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrngState {
    pub state: u64,
    pub spare_normal: Option<f64>,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed, spare_normal: None }
    }

    /// Capture the full generator state (checkpointing).
    pub fn state(&self) -> PrngState {
        PrngState { state: self.state, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator that continues exactly where `state` was taken.
    pub fn from_state(s: PrngState) -> Prng {
        Prng { state: s.state, spare_normal: s.spare_normal }
    }

    /// Derive an independent stream (e.g. one per rank / per layer).
    pub fn split(&mut self, tag: u64) -> Prng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Prng::new(mixed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_in_bounds() {
        let mut r = Prng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int_in(3, 10);
            assert!((3..=10).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 10;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(123);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn state_split_and_restore_equals_uninterrupted() {
        // Property: capture the state at an arbitrary cut point (after a
        // mixed sequence of u64 / uniform / normal draws, so the Box–Muller
        // spare is sometimes pending), restore into a fresh Prng, and the
        // restored stream must equal the uninterrupted one bit for bit.
        crate::util::proptest::quickcheck("prng split-and-restore", |rng| {
            let seed = rng.next_u64();
            let pre = (rng.next_u64() % 17) as usize;
            let post = 1 + (rng.next_u64() % 17) as usize;
            let normals_odd = rng.next_u64() % 2 == 1;

            let mut a = Prng::new(seed);
            for i in 0..pre {
                match i % 3 {
                    0 => {
                        a.next_u64();
                    }
                    1 => {
                        a.next_f64();
                    }
                    _ => {
                        a.normal();
                    }
                }
            }
            if normals_odd {
                // Leave a spare Box–Muller sample pending at the cut.
                a.normal();
            }

            let cut = a.state();
            let mut b = Prng::from_state(cut);
            for j in 0..post {
                let (ua, ub) = (a.next_u64(), b.next_u64());
                if ua != ub {
                    return Err(format!("u64 draw {j} diverged: {ua} vs {ub}"));
                }
                let (na, nb) = (a.normal(), b.normal());
                if na.to_bits() != nb.to_bits() {
                    return Err(format!("normal draw {j} diverged: {na} vs {nb}"));
                }
            }
            if a.state() != b.state() {
                return Err("final states diverged".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn state_roundtrips_the_pending_spare() {
        let mut a = Prng::new(99);
        a.normal(); // leaves the Box–Muller spare pending
        let s = a.state();
        assert!(s.spare_normal.is_some());
        let mut b = Prng::from_state(s);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Prng::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
