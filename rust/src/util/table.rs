//! Markdown / CSV table rendering for experiment reports.
//!
//! Every experiment in `experiments/` emits its rows through this module so
//! EXPERIMENTS.md and the bench output share one formatting path.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as a GitHub-flavored markdown table with aligned pipes.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably (ns/us/ms/s picked by magnitude).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Format Joules (J / kJ / MJ).
pub fn fmt_joules(j: f64) -> String {
    if j < 1e3 {
        format!("{j:.1} J")
    } else if j < 1e6 {
        format!("{:.1} kJ", j / 1e3)
    } else {
        format!("{:.2} MJ", j / 1e6)
    }
}

/// Format a parameter count (K/M/B).
pub fn fmt_params(n: u64) -> String {
    if n < 1_000 {
        format!("{n}")
    } else if n < 1_000_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else if n < 1_000_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else {
        format!("{:.2}B", n as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | long_header |"));
        assert!(md.contains("| 1 | 2           |"));
    }

    #[test]
    fn csv_quotes_specials() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["a,b".into()]);
        t.row(vec!["q\"q".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
        assert_eq!(fmt_secs(3.2e-5), "32.0 µs");
        assert_eq!(fmt_secs(0.004), "4.00 ms");
        assert_eq!(fmt_secs(2.0), "2.00 s");
        assert_eq!(fmt_joules(10.0), "10.0 J");
        assert_eq!(fmt_joules(82_084.0), "82.1 kJ");
        assert_eq!(fmt_joules(3_113_741.0), "3.11 MJ");
        assert_eq!(fmt_params(537_000_000), "537.0M");
        assert_eq!(fmt_params(71_000_000), "71.0M");
        assert_eq!(fmt_params(950), "950");
    }
}
