//! Foundation utilities built in-repo (the offline crate set contains only
//! `xla` and `anyhow`): JSON, PRNG, statistics, table rendering, and a
//! property-test driver.

pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
