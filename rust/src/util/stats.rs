//! Small statistics + linear least-squares toolkit.
//!
//! Used by the benchmark harness (timing summaries) and by `simnet::fit`,
//! which regenerates the paper's Table III by fitting the collective
//! communication model  t(m, p) = c1*log2(p) + c2*m + c3  to measurements.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Finite samples the statistics cover.
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    /// Tail percentile for SLO-style reporting (fleet serving headlines
    /// quote p50/p99, matching the live metrics histograms).
    pub p99: f64,
    /// Non-finite samples (NaN/inf) dropped from the statistics. A single
    /// NaN must degrade the summary, not panic the whole serve/bench
    /// report: the old `partial_cmp(..).unwrap()` sort did exactly that.
    pub dropped: usize,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let mut sorted: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    let dropped = xs.len() - sorted.len();
    if sorted.is_empty() {
        // Every sample was NaN/inf: report that honestly instead of
        // crashing — all statistics are NaN, n = 0, dropped = len.
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
            p99: f64::NAN,
            dropped,
        };
    }
    // total_cmp is a total order: no panic even if the filter above is
    // ever relaxed.
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
        p99: percentile_sorted(&sorted, 99.0),
        dropped,
    }
}

/// Percentile with linear interpolation; input must be sorted.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (pct / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Solve the ordinary least-squares problem  X beta = y  via normal
/// equations with Gaussian elimination (partial pivoting). X is row-major
/// with `cols` features per row. Small systems only (cols <= ~8), which is
/// all the communication-model fit needs (3 features).
pub fn least_squares(x: &[f64], cols: usize, y: &[f64]) -> Option<Vec<f64>> {
    let rows = y.len();
    assert_eq!(x.len(), rows * cols, "design matrix shape mismatch");
    if rows < cols {
        return None;
    }
    // Normal equations: (X'X) beta = X'y
    let mut xtx = vec![0.0; cols * cols];
    let mut xty = vec![0.0; cols];
    for r in 0..rows {
        for i in 0..cols {
            let xi = x[r * cols + i];
            xty[i] += xi * y[r];
            for j in 0..cols {
                xtx[i * cols + j] += xi * x[r * cols + j];
            }
        }
    }
    solve_linear(&mut xtx, &mut xty, cols)
}

/// In-place Gaussian elimination with partial pivoting; returns the solution
/// of A x = b or None if A is (numerically) singular.
pub fn solve_linear(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            b.swap(col, piv);
        }
        // eliminate
        for r in (col + 1)..n {
            let f = a[r * n + col] / a[col * n + col];
            for j in col..n {
                a[r * n + j] -= f * a[col * n + j];
            }
            b[r] -= f * b[col];
        }
    }
    // back-substitute
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for j in (col + 1)..n {
            s -= a[col * n + j] * x[j];
        }
        x[col] = s / a[col * n + col];
    }
    Some(x)
}

/// Root-mean-square error of predictions vs observations.
pub fn rmse(pred: &[f64], obs: &[f64]) -> f64 {
    assert_eq!(pred.len(), obs.len());
    let n = pred.len() as f64;
    (pred.iter().zip(obs).map(|(p, o)| (p - o) * (p - o)).sum::<f64>() / n).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn summarize_survives_nan_samples() {
        // Regression: a single NaN used to panic the whole summary via
        // `partial_cmp(..).unwrap()` in the sort comparator.
        let s = summarize(&[3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.dropped, 1);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.p50 - 2.0).abs() < 1e-12);

        // Infinities are dropped and counted too.
        let s = summarize(&[f64::INFINITY, 5.0]);
        assert_eq!((s.n, s.dropped), (1, 1));
        assert_eq!(s.max, 5.0);

        // All-NaN input degrades honestly instead of crashing.
        let s = summarize(&[f64::NAN, f64::NAN]);
        assert_eq!((s.n, s.dropped), (0, 2));
        assert!(s.mean.is_nan() && s.p95.is_nan());

        // Clean samples are unaffected.
        let s = summarize(&[1.0, 2.0]);
        assert_eq!(s.dropped, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn solves_linear_system() {
        // [2 1; 1 3] x = [5; 10] -> x = [1, 3]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve_linear(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-10);
        assert!((x[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn singular_returns_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve_linear(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn least_squares_recovers_exact_model() {
        // y = 3*f0 + 0.5*f1 - 2
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            let f0 = i as f64;
            let f1 = (i * i) as f64 * 0.1;
            xs.extend_from_slice(&[f0, f1, 1.0]);
            ys.push(3.0 * f0 + 0.5 * f1 - 2.0);
        }
        let beta = least_squares(&xs, 3, &ys).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-8);
        assert!((beta[1] - 0.5).abs() < 1e-8);
        assert!((beta[2] + 2.0).abs() < 1e-8);
    }

    #[test]
    fn least_squares_underdetermined_is_none() {
        assert!(least_squares(&[1.0, 2.0, 3.0], 3, &[1.0]).is_none());
    }

    #[test]
    fn rmse_zero_for_perfect_fit() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}
