//! Synthetic dataset: the paper's Gaussian teacher.
//!
//! "The data set was generated as {(x_i, y_i)} pairs where x_i, y_i in R^n
//! and y_i = sigma(W sigma(x_i)) with sigma = ReLU" over a standard Gaussian
//! matrix W kept fixed for all examples (Sec. VI, Data and Hardware).
//!
//! Batches are generated deterministically from (seed, iteration): every
//! rank regenerates the same full batch locally and slices its own shard —
//! identical data across TP and PP runs, no data-path communication.
//! For large n the teacher W (n x n) is never materialized: a seeded
//! column-stream generator produces W rows on the fly per batch (O(n) memory).

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// The fixed teacher. `sigma_w` = 1/sqrt(n) keeps post-activation magnitudes
/// O(1) (a "standard Gaussian matrix" rescaled; the paper's loss values are
/// arbitrary-scale, only relative behaviour matters).
#[derive(Debug, Clone)]
pub struct Teacher {
    pub n: usize,
    pub seed: u64,
    sigma_w: f32,
}

impl Teacher {
    pub fn new(n: usize, seed: u64) -> Teacher {
        Teacher { n, seed, sigma_w: 1.0 / (n as f32).sqrt() }
    }

    /// Generate batch `iter`: (x [B, n], y [B, n]) with y = relu(W relu(x)).
    ///
    /// W rows are streamed from the seed so the teacher is fixed across
    /// iterations but never stored. Cost is O(B * n^2) compute per batch —
    /// acceptable for the measured configs (n <= 8192).
    pub fn batch(&self, batch: usize, iter: u64) -> Result<(Tensor, Tensor)> {
        let n = self.n;
        let mut xrng = Prng::new(self.seed ^ 0xDA7A ^ iter.wrapping_mul(0x9E3779B97F4A7C15));
        let mut x = Tensor::zeros(&[batch, n]);
        xrng.fill_normal(x.data_mut(), 1.0);

        // h = relu(x)
        let h = x.relu();
        // y[b, j] = relu( sum_i W[j, i] * h[b, i] ), W rows streamed.
        let mut y = Tensor::zeros(&[batch, n]);
        let mut wrow = vec![0.0f32; n];
        for j in 0..n {
            let mut wrng = Prng::new(
                self.seed ^ 0x7EAC_4E12 ^ (j as u64).wrapping_mul(0xD1B54A32D192ED03),
            );
            wrng.fill_normal(&mut wrow, self.sigma_w);
            for b in 0..batch {
                let hrow = &h.data()[b * n..(b + 1) * n];
                let mut acc = 0.0f32;
                for i in 0..n {
                    acc += wrow[i] * hrow[i];
                }
                y.data_mut()[b * n + j] = acc.max(0.0);
            }
        }
        Ok((x, y))
    }

    /// The shard of batch `iter` owned by `rank` out of `p`:
    /// (x_shard [B, n/p], y_shard [B, n/p]).
    pub fn batch_shard(
        &self,
        batch: usize,
        iter: u64,
        rank: usize,
        p: usize,
    ) -> Result<(Tensor, Tensor)> {
        self.hybrid_shard(batch, iter, rank, p, 0, 1)
    }

    /// The hybrid shard of batch `iter` owned by (model rank, DP replica):
    /// the replica's contiguous row range of the global batch, column-cut
    /// to the model rank's n/p feature slice. Shard boundaries key only on
    /// (dp_rank, model_rank): every member of one model group sees the same
    /// rows, and concatenating all replicas' rows reproduces the full
    /// batch bitwise — including when `batch % dp != 0` (leading replicas
    /// carry the remainder rows).
    pub fn hybrid_shard(
        &self,
        batch: usize,
        iter: u64,
        model_rank: usize,
        p: usize,
        dp_rank: usize,
        dp: usize,
    ) -> Result<(Tensor, Tensor)> {
        let (x, y) = self.batch(batch, iter)?;
        let (start, len) = dp_row_range(batch, dp, dp_rank);
        let xs = row_slice(&x, start, len)?.col_shards(p)?;
        let ys = row_slice(&y, start, len)?.col_shards(p)?;
        Ok((xs[model_rank].clone(), ys[model_rank].clone()))
    }
}

/// The contiguous row range [start, start+len) of a `batch`-row global
/// batch owned by DP replica `d` of `dp`. The first `batch % dp` replicas
/// carry one extra row, so the ranges tile the batch exactly for any
/// remainder.
pub fn dp_row_range(batch: usize, dp: usize, d: usize) -> (usize, usize) {
    assert!(dp >= 1 && d < dp, "replica {d} out of range for dp={dp}");
    let base = batch / dp;
    let extra = batch % dp;
    let start = d * base + d.min(extra);
    let len = base + usize::from(d < extra);
    (start, len)
}

/// Rows [start, start+len) of a [B, n] tensor (rows are contiguous in the
/// row-major layout, so this is a pure copy). Shared with the testkit
/// oracle, which must reproduce the DP row sharding bitwise.
pub fn row_slice(t: &Tensor, start: usize, len: usize) -> Result<Tensor> {
    let n = t.shape()[1];
    Tensor::from_vec(&[len, n], t.data()[start * n..(start + len) * n].to_vec())
}

/// A shared, memoized FIXED dataset for multi-rank runs. The paper trains
/// on a fixed set of (x, y) pairs ("kept fixed for all the examples");
/// iteration i uses batch i % num_batches, so `num_batches` iterations are
/// one epoch. Shards are materialized once per distinct batch and shared
/// across ranks and epochs. With `dp > 1` the cache holds the hybrid
/// layout: per batch, `dp` row ranges × `p` column shards, indexed by
/// world rank (`world = dp_rank * p + model_rank`).
pub struct BatchCache {
    teacher: Teacher,
    batch: usize,
    p: usize,
    dp: usize,
    num_batches: u64,
    /// key -> per-world-rank shards, world-rank order.
    inner: std::sync::Mutex<std::collections::HashMap<u64, Vec<(Tensor, Tensor)>>>,
}

impl BatchCache {
    pub fn new(
        teacher: Teacher,
        batch: usize,
        p: usize,
        dp: usize,
        num_batches: usize,
    ) -> BatchCache {
        assert!(num_batches >= 1);
        assert!(p >= 1 && dp >= 1);
        BatchCache {
            teacher,
            batch,
            p,
            dp,
            num_batches: num_batches as u64,
            inner: std::sync::Mutex::new(Default::default()),
        }
    }

    /// The shard of training iteration `iter` owned by `world_rank`
    /// (= `dp_rank * p + model_rank`; with dp = 1 this is the model rank).
    pub fn shard(&self, iter: u64, world_rank: usize) -> Result<(Tensor, Tensor)> {
        let key = iter % self.num_batches;
        // Poison recovery: the cached shards are read-rebuildable pure
        // data, so a sibling rank that panicked while holding this lock
        // must not take the whole cluster down with an opaque secondary
        // "batch cache poisoned" panic — recover the guard and let the
        // original rank's panic payload name the true first failure.
        let mut g = self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        if !g.contains_key(&key) {
            let (x, y) = self.teacher.batch(self.batch, key)?;
            let mut shards = Vec::with_capacity(self.dp * self.p);
            for d in 0..self.dp {
                let (start, len) = dp_row_range(self.batch, self.dp, d);
                let xs = row_slice(&x, start, len)?.col_shards(self.p)?;
                let ys = row_slice(&y, start, len)?.col_shards(self.p)?;
                for (xr, yr) in xs.into_iter().zip(ys) {
                    shards.push((xr, yr));
                }
            }
            g.insert(key, shards);
        }
        let shards = g.get(&key).expect("inserted above");
        let (x, y) = &shards[world_rank];
        Ok((x.clone(), y.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teacher_is_fixed_across_calls() {
        let t = Teacher::new(32, 42);
        let (x1, y1) = t.batch(4, 0).unwrap();
        let (x2, y2) = t.batch(4, 0).unwrap();
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn batches_differ_across_iters_but_share_teacher() {
        let t = Teacher::new(32, 42);
        let (x0, _) = t.batch(4, 0).unwrap();
        let (x1, _) = t.batch(4, 1).unwrap();
        assert_ne!(x0, x1, "inputs must vary per iteration");

        // Same x row must map to the same y regardless of the iteration
        // (the teacher W is fixed): craft this by checking linearity of the
        // generator instead — y depends only on x and seed.
        let (xa, ya) = t.batch(2, 5).unwrap();
        let (xb, yb) = t.batch(2, 5).unwrap();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn outputs_are_relu_images() {
        let t = Teacher::new(16, 1);
        let (_, y) = t.batch(8, 3).unwrap();
        assert!(y.data().iter().all(|&v| v >= 0.0));
        assert!(y.data().iter().any(|&v| v > 0.0));
    }

    #[test]
    fn shards_tile_the_batch() {
        let t = Teacher::new(32, 9);
        let (x, y) = t.batch(4, 2).unwrap();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for r in 0..4 {
            let (xr, yr) = t.batch_shard(4, 2, r, 4).unwrap();
            xs.push(xr);
            ys.push(yr);
        }
        assert_eq!(Tensor::from_col_shards(&xs).unwrap(), x);
        assert_eq!(Tensor::from_col_shards(&ys).unwrap(), y);
    }

    #[test]
    fn cache_agrees_with_direct() {
        let t = Teacher::new(32, 9);
        let cache = BatchCache::new(t.clone(), 4, 4, 1, 8);
        for iter in [0u64, 1, 2, 1] {
            for r in [0usize, 3, 1] {
                let (xc, yc) = cache.shard(iter, r).unwrap();
                let (xd, yd) = t.batch_shard(4, iter, r, 4).unwrap();
                assert_eq!(xc, xd, "iter {iter} rank {r}");
                assert_eq!(yc, yd);
            }
        }
    }

    #[test]
    fn cache_cycles_the_fixed_dataset() {
        let t = Teacher::new(32, 9);
        let cache = BatchCache::new(t, 4, 2, 1, 4);
        // iteration 6 reuses batch 6 % 4 = 2
        let (x6, y6) = cache.shard(6, 1).unwrap();
        let (x2, y2) = cache.shard(2, 1).unwrap();
        assert_eq!(x6, x2);
        assert_eq!(y6, y2);
        // distinct batches differ
        let (x1, _) = cache.shard(1, 1).unwrap();
        assert_ne!(x1, x2);
    }

    #[test]
    fn teacher_differs_across_seeds() {
        let (xa, ya) = Teacher::new(16, 1).batch(2, 0).unwrap();
        let (xb, yb) = Teacher::new(16, 2).batch(2, 0).unwrap();
        assert_ne!(xa, xb);
        assert_ne!(ya, yb);
    }

    #[test]
    fn dp_row_ranges_tile_the_batch_with_remainders() {
        for (batch, dp) in [(8usize, 2usize), (7, 2), (7, 3), (5, 4), (4, 4), (3, 1)] {
            let mut covered = 0usize;
            for d in 0..dp {
                let (start, len) = dp_row_range(batch, dp, d);
                assert_eq!(start, covered, "batch={batch} dp={dp} d={d}");
                covered += len;
                // Balanced to within one row.
                assert!(len >= batch / dp && len <= batch / dp + 1);
            }
            assert_eq!(covered, batch, "ranges must tile batch={batch} for dp={dp}");
        }
    }

    #[test]
    fn hybrid_shards_reassemble_the_batch_bitwise() {
        // Including batch % dp != 0: dp=3 over batch=7.
        let t = Teacher::new(24, 11);
        let (batch, p, dp) = (7usize, 2usize, 3usize);
        let (x, y) = t.batch(batch, 4).unwrap();
        let mut x_rows: Vec<Tensor> = Vec::new();
        let mut y_rows: Vec<Tensor> = Vec::new();
        for d in 0..dp {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for r in 0..p {
                let (xr, yr) = t.hybrid_shard(batch, 4, r, p, d, dp).unwrap();
                xs.push(xr);
                ys.push(yr);
            }
            x_rows.push(Tensor::from_col_shards(&xs).unwrap());
            y_rows.push(Tensor::from_col_shards(&ys).unwrap());
        }
        let cat = |rows: &[Tensor]| {
            let n = rows[0].shape()[1];
            let mut data = Vec::new();
            for r in rows {
                data.extend_from_slice(r.data());
            }
            Tensor::from_vec(&[batch, n], data).unwrap()
        };
        assert_eq!(cat(&x_rows), x, "row-concat of replica shards must equal the batch");
        assert_eq!(cat(&y_rows), y);
    }

    #[test]
    fn hybrid_cache_agrees_with_direct_hybrid_shards() {
        let t = Teacher::new(24, 13);
        let (batch, p, dp) = (5usize, 2usize, 2usize);
        let cache = BatchCache::new(t.clone(), batch, p, dp, 4);
        for iter in [0u64, 3, 1] {
            for world in 0..p * dp {
                let (xc, yc) = cache.shard(iter, world).unwrap();
                let (xd, yd) =
                    t.hybrid_shard(batch, iter % 4, world % p, p, world / p, dp).unwrap();
                assert_eq!(xc, xd, "iter {iter} world {world}");
                assert_eq!(yc, yd);
            }
        }
    }

    #[test]
    fn poisoned_cache_recovers_instead_of_cascading() {
        use std::sync::Arc;
        let cache = Arc::new(BatchCache::new(Teacher::new(16, 3), 4, 2, 1, 2));
        // Warm the cache, then poison its mutex: a thread panics while
        // holding the guard (what a crashing sibling rank does).
        cache.shard(0, 0).unwrap();
        let c2 = cache.clone();
        let _ = std::thread::spawn(move || {
            let _guard = c2.inner.lock().unwrap_or_else(|p| p.into_inner());
            panic!("simulated rank crash while holding the batch cache");
        })
        .join();
        // Before the fix this was `lock().expect("batch cache poisoned")`:
        // every surviving rank died with that opaque secondary panic,
        // masking the true first failure. Now the cache recovers.
        let (x, y) = cache.shard(0, 1).expect("poisoned cache must recover");
        let (xd, yd) = Teacher::new(16, 3).batch_shard(4, 0, 1, 2).unwrap();
        assert_eq!(x, xd);
        assert_eq!(y, yd);
    }
}
