//! Synthetic dataset: the paper's Gaussian teacher.
//!
//! "The data set was generated as {(x_i, y_i)} pairs where x_i, y_i in R^n
//! and y_i = sigma(W sigma(x_i)) with sigma = ReLU" over a standard Gaussian
//! matrix W kept fixed for all examples (Sec. VI, Data and Hardware).
//!
//! Batches are generated deterministically from (seed, iteration): every
//! rank regenerates the same full batch locally and slices its own shard —
//! identical data across TP and PP runs, no data-path communication.
//! For large n the teacher W (n x n) is never materialized: a seeded
//! column-stream generator produces W rows on the fly per batch (O(n) memory).

use anyhow::Result;

use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// The fixed teacher. `sigma_w` = 1/sqrt(n) keeps post-activation magnitudes
/// O(1) (a "standard Gaussian matrix" rescaled; the paper's loss values are
/// arbitrary-scale, only relative behaviour matters).
#[derive(Debug, Clone)]
pub struct Teacher {
    pub n: usize,
    pub seed: u64,
    sigma_w: f32,
}

impl Teacher {
    pub fn new(n: usize, seed: u64) -> Teacher {
        Teacher { n, seed, sigma_w: 1.0 / (n as f32).sqrt() }
    }

    /// Generate batch `iter`: (x [B, n], y [B, n]) with y = relu(W relu(x)).
    ///
    /// W rows are streamed from the seed so the teacher is fixed across
    /// iterations but never stored. Cost is O(B * n^2) compute per batch —
    /// acceptable for the measured configs (n <= 8192).
    pub fn batch(&self, batch: usize, iter: u64) -> Result<(Tensor, Tensor)> {
        let n = self.n;
        let mut xrng = Prng::new(self.seed ^ 0xDA7A ^ iter.wrapping_mul(0x9E3779B97F4A7C15));
        let mut x = Tensor::zeros(&[batch, n]);
        xrng.fill_normal(x.data_mut(), 1.0);

        // h = relu(x)
        let h = x.relu();
        // y[b, j] = relu( sum_i W[j, i] * h[b, i] ), W rows streamed.
        let mut y = Tensor::zeros(&[batch, n]);
        let mut wrow = vec![0.0f32; n];
        for j in 0..n {
            let mut wrng = Prng::new(
                self.seed ^ 0x7EAC_4E12 ^ (j as u64).wrapping_mul(0xD1B54A32D192ED03),
            );
            wrng.fill_normal(&mut wrow, self.sigma_w);
            for b in 0..batch {
                let hrow = &h.data()[b * n..(b + 1) * n];
                let mut acc = 0.0f32;
                for i in 0..n {
                    acc += wrow[i] * hrow[i];
                }
                y.data_mut()[b * n + j] = acc.max(0.0);
            }
        }
        Ok((x, y))
    }

    /// The shard of batch `iter` owned by `rank` out of `p`:
    /// (x_shard [B, n/p], y_shard [B, n/p]).
    pub fn batch_shard(
        &self,
        batch: usize,
        iter: u64,
        rank: usize,
        p: usize,
    ) -> Result<(Tensor, Tensor)> {
        let (x, y) = self.batch(batch, iter)?;
        let xs = x.col_shards(p)?;
        let ys = y.col_shards(p)?;
        Ok((xs[rank].clone(), ys[rank].clone()))
    }
}

/// A shared, memoized FIXED dataset for multi-rank runs. The paper trains
/// on a fixed set of (x, y) pairs ("kept fixed for all the examples");
/// iteration i uses batch i % num_batches, so `num_batches` iterations are
/// one epoch. Shards are materialized once per distinct batch and shared
/// across ranks and epochs.
pub struct BatchCache {
    teacher: Teacher,
    batch: usize,
    p: usize,
    num_batches: u64,
    inner: std::sync::Mutex<std::collections::HashMap<u64, (Vec<Tensor>, Vec<Tensor>)>>,
}

impl BatchCache {
    pub fn new(teacher: Teacher, batch: usize, p: usize, num_batches: usize) -> BatchCache {
        assert!(num_batches >= 1);
        BatchCache {
            teacher,
            batch,
            p,
            num_batches: num_batches as u64,
            inner: std::sync::Mutex::new(Default::default()),
        }
    }

    pub fn shard(&self, iter: u64, rank: usize) -> Result<(Tensor, Tensor)> {
        let key = iter % self.num_batches;
        let mut g = self.inner.lock().expect("batch cache poisoned");
        if !g.contains_key(&key) {
            let (x, y) = self.teacher.batch(self.batch, key)?;
            g.insert(key, (x.col_shards(self.p)?, y.col_shards(self.p)?));
        }
        let (xs, ys) = g.get(&key).unwrap();
        Ok((xs[rank].clone(), ys[rank].clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teacher_is_fixed_across_calls() {
        let t = Teacher::new(32, 42);
        let (x1, y1) = t.batch(4, 0).unwrap();
        let (x2, y2) = t.batch(4, 0).unwrap();
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn batches_differ_across_iters_but_share_teacher() {
        let t = Teacher::new(32, 42);
        let (x0, _) = t.batch(4, 0).unwrap();
        let (x1, _) = t.batch(4, 1).unwrap();
        assert_ne!(x0, x1, "inputs must vary per iteration");

        // Same x row must map to the same y regardless of the iteration
        // (the teacher W is fixed): craft this by checking linearity of the
        // generator instead — y depends only on x and seed.
        let (xa, ya) = t.batch(2, 5).unwrap();
        let (xb, yb) = t.batch(2, 5).unwrap();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn outputs_are_relu_images() {
        let t = Teacher::new(16, 1);
        let (_, y) = t.batch(8, 3).unwrap();
        assert!(y.data().iter().all(|&v| v >= 0.0));
        assert!(y.data().iter().any(|&v| v > 0.0));
    }

    #[test]
    fn shards_tile_the_batch() {
        let t = Teacher::new(32, 9);
        let (x, y) = t.batch(4, 2).unwrap();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for r in 0..4 {
            let (xr, yr) = t.batch_shard(4, 2, r, 4).unwrap();
            xs.push(xr);
            ys.push(yr);
        }
        assert_eq!(Tensor::from_col_shards(&xs).unwrap(), x);
        assert_eq!(Tensor::from_col_shards(&ys).unwrap(), y);
    }

    #[test]
    fn cache_agrees_with_direct() {
        let t = Teacher::new(32, 9);
        let cache = BatchCache::new(t.clone(), 4, 4, 8);
        for iter in [0u64, 1, 2, 1] {
            for r in [0usize, 3, 1] {
                let (xc, yc) = cache.shard(iter, r).unwrap();
                let (xd, yd) = t.batch_shard(4, iter, r, 4).unwrap();
                assert_eq!(xc, xd, "iter {iter} rank {r}");
                assert_eq!(yc, yd);
            }
        }
    }

    #[test]
    fn cache_cycles_the_fixed_dataset() {
        let t = Teacher::new(32, 9);
        let cache = BatchCache::new(t, 4, 2, 4);
        // iteration 6 reuses batch 6 % 4 = 2
        let (x6, y6) = cache.shard(6, 1).unwrap();
        let (x2, y2) = cache.shard(2, 1).unwrap();
        assert_eq!(x6, x2);
        assert_eq!(y6, y2);
        // distinct batches differ
        let (x1, _) = cache.shard(1, 1).unwrap();
        assert_ne!(x1, x2);
    }

    #[test]
    fn teacher_differs_across_seeds() {
        let (xa, ya) = Teacher::new(16, 1).batch(2, 0).unwrap();
        let (xb, yb) = Teacher::new(16, 2).batch(2, 0).unwrap();
        assert_ne!(xa, xb);
        assert_ne!(ya, yb);
    }
}
