//! Observability substrate: per-rank span tracing in virtual time, exact
//! energy attribution against the `EnergyLedger`, Chrome trace-event export
//! (Perfetto-viewable), a rolling serve metrics registry, and the leveled
//! `PHANTOM_LOG` logger.
//!
//! The paper's argument (Eqn. 1) splits every Joule into busy vs.
//! idle/communicating time; this module splits the same Joules one level
//! finer — per collective, per kernel launch, per batcher decision — while
//! keeping the ledger the single source of truth. Spans never *charge*
//! time; they only label intervals the ledger already recorded, so the
//! attribution rollup reconciles exactly with `LedgerSummary` (tested
//! invariant, see `attr`).
//!
//! Recording is opt-in per ledger (`EnergyLedger::arm_tracing`) and every
//! hook is a no-op when no recorder is armed, so untraced runs pay one
//! branch per hook. See DESIGN.md §13 for the span taxonomy.

pub mod attr;
pub mod log;
pub mod metrics;
pub mod span;
pub mod trace;

pub use attr::{attribute, Attribution, CategoryEnergy};
pub use metrics::{MetricsRegistry, MetricsSnapshot};
pub use span::{Arg, Event, Span, SpanRecorder, TraceCapture};
pub use trace::{chrome_trace, Track};
