//! Rolling metrics registry: counters, gauges, windowed histograms, and
//! EWMAs — the live-feedback interface the serve pool exposes (queue
//! depth, shed rate, latency p50/p99, J/query) and that ROADMAP items 1
//! (online re-planning) and 3 (energy-aware fleet routing) consume.
//!
//! The registry is owned mutably by its producer (the `serve::Server`
//! drives it from its own thread), so there is no interior mutability or
//! locking; consumers read point-in-time `MetricsSnapshot`s.

use std::collections::BTreeMap;

/// Ring buffer of the last `cap` observations; quantiles are computed on
/// snapshot, not on the hot path.
#[derive(Debug, Clone)]
struct WindowHist {
    buf: Vec<f64>,
    pos: usize,
    count: u64,
    sum: f64,
}

impl WindowHist {
    fn new(cap: usize) -> WindowHist {
        WindowHist { buf: Vec::with_capacity(cap.min(4096)), pos: 0, count: 0, sum: 0.0 }
    }

    fn observe(&mut self, v: f64, cap: usize) {
        self.count += 1;
        self.sum += v;
        if self.buf.len() < cap {
            self.buf.push(v);
        } else {
            self.buf[self.pos] = v;
            self.pos = (self.pos + 1) % cap;
        }
    }

    /// Quantile over the current window, `q` in [0, 1]. Uses the shared
    /// interpolating `percentile_sorted`, the same estimator the loadgen's
    /// `Summary` uses — so with the window un-wrapped, the live latency
    /// p50/p99 and the `LoadReport` percentiles agree exactly rather than
    /// merely approximately (the fleet router and the latency-accounting
    /// regression test both rely on this).
    fn quantile(&self, q: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_by(f64::total_cmp);
        Some(crate::util::stats::percentile_sorted(&sorted, q * 100.0))
    }
}

#[derive(Debug, Clone, Copy)]
struct Ewma {
    value: f64,
    alpha: f64,
}

/// Default histogram window (observations kept per histogram).
pub const DEFAULT_WINDOW: usize = 1024;

/// Rolling metrics registry. Metric names are `&'static str` so the hot
/// path never allocates for a lookup key.
#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    window: usize,
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, WindowHist>,
    ewmas: BTreeMap<&'static str, Ewma>,
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new(DEFAULT_WINDOW)
    }
}

impl MetricsRegistry {
    pub fn new(window: usize) -> MetricsRegistry {
        assert!(window > 0);
        MetricsRegistry {
            window,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            ewmas: BTreeMap::new(),
        }
    }

    /// Increment a monotone counter.
    pub fn inc(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    /// Set a point-in-time gauge (e.g. queue depth).
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Record one observation into a windowed histogram.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        let window = self.window;
        self.hists.entry(name).or_insert_with(|| WindowHist::new(window)).observe(v, window);
    }

    /// Fold `v` into an exponentially-weighted moving average. The first
    /// observation seeds the average.
    pub fn ewma(&mut self, name: &'static str, v: f64, alpha: f64) {
        match self.ewmas.get_mut(name) {
            Some(e) => e.value = e.alpha * v + (1.0 - e.alpha) * e.value,
            None => {
                self.ewmas.insert(name, Ewma { value: v, alpha });
            }
        }
    }

    /// Point-in-time snapshot: flat (name, value) records. Counters and
    /// gauges keep their names; histograms expand to `<name>_p50`,
    /// `<name>_p99`, `<name>_count`; EWMAs to `<name>_ewma`.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut records: Vec<(String, f64)> = Vec::new();
        for (k, v) in &self.counters {
            records.push((k.to_string(), *v as f64));
        }
        for (k, v) in &self.gauges {
            records.push((k.to_string(), *v));
        }
        for (k, h) in &self.hists {
            if let (Some(p50), Some(p99)) = (h.quantile(0.5), h.quantile(0.99)) {
                records.push((format!("{k}_p50"), p50));
                records.push((format!("{k}_p99"), p99));
            }
            records.push((format!("{k}_count"), h.count as f64));
        }
        for (k, e) in &self.ewmas {
            records.push((format!("{k}_ewma"), e.value));
        }
        MetricsSnapshot { records }
    }
}

/// Flat point-in-time view of a registry.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub records: Vec<(String, f64)>,
}

impl MetricsSnapshot {
    pub fn get(&self, name: &str) -> Option<f64> {
        self.records.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_ewma() {
        let mut m = MetricsRegistry::default();
        m.inc("shed");
        m.add("shed", 2);
        m.set_gauge("queue_depth", 7.0);
        m.ewma("j_per_query", 10.0, 0.5);
        m.ewma("j_per_query", 20.0, 0.5);
        let s = m.snapshot();
        assert_eq!(s.get("shed"), Some(3.0));
        assert_eq!(s.get("queue_depth"), Some(7.0));
        assert_eq!(s.get("j_per_query_ewma"), Some(15.0));
        assert_eq!(s.get("absent"), None);
    }

    #[test]
    fn histogram_quantiles_over_window() {
        let mut m = MetricsRegistry::new(8);
        for v in 1..=100 {
            m.observe("latency_s", v as f64);
        }
        let s = m.snapshot();
        // Window keeps the last 8 observations: 93..=100.
        assert_eq!(s.get("latency_s_count"), Some(100.0));
        let p50 = s.get("latency_s_p50").unwrap();
        assert!((93.0..=100.0).contains(&p50), "p50={p50}");
        let p99 = s.get("latency_s_p99").unwrap();
        // Interpolated tail percentile: just below the window max.
        assert!((99.0..=100.0).contains(&p99), "p99={p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn single_observation_quantiles() {
        let mut m = MetricsRegistry::default();
        m.observe("x", 4.25);
        let s = m.snapshot();
        assert_eq!(s.get("x_p50"), Some(4.25));
        assert_eq!(s.get("x_p99"), Some(4.25));
        assert_eq!(s.get("x_count"), Some(1.0));
    }
}
