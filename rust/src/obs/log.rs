//! Leveled, rank-prefixed structured logger — the logging front end for
//! the whole crate (`PHANTOM_LOG` selects the level).
//!
//! Resolution order: `PHANTOM_LOG` (error|warn|info|debug|trace|off) wins;
//! otherwise the default installed by `init` (the `phantom` binary
//! installs `info` at startup); otherwise `warn`, so library users and
//! tier-1 tests stay quiet. Rank threads call `set_rank` once so every
//! line they emit is prefixed `[level rN] …`; host/driver threads log as
//! `[level] …`. Output goes to stderr, leaving stdout to command output.
//!
//! Use the `log_error!`/`log_warn!`/`log_info!`/`log_debug!`/`log_trace!`
//! macros: format arguments are only evaluated when the level is enabled.

use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Parse a `PHANTOM_LOG` value. `off` (or `none`) disables everything;
/// unrecognized values are reported as None so the caller keeps its
/// default rather than silently going quiet.
fn parse_level(s: &str) -> Option<Option<Level>> {
    match s.trim().to_ascii_lowercase().as_str() {
        "error" => Some(Some(Level::Error)),
        "warn" | "warning" => Some(Some(Level::Warn)),
        "info" => Some(Some(Level::Info)),
        "debug" => Some(Some(Level::Debug)),
        "trace" => Some(Some(Level::Trace)),
        "off" | "none" => Some(None),
        _ => None,
    }
}

const UNSET: u8 = 0xFF;
const OFF: u8 = 0xFE;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn resolve(default: Level) -> u8 {
    match std::env::var("PHANTOM_LOG") {
        Ok(v) => match parse_level(&v) {
            Some(Some(l)) => l as u8,
            Some(None) => OFF,
            None => {
                eprintln!(
                    "[warn] PHANTOM_LOG={v:?} is not a level \
                     (error|warn|info|debug|trace|off); using {}",
                    default.tag()
                );
                default as u8
            }
        },
        Err(_) => default as u8,
    }
}

/// Install `default` as the level used when `PHANTOM_LOG` is unset. The
/// `phantom` binary calls this with `Info` at startup; libraries never
/// call it and inherit the quiet `Warn` default.
pub fn init(default: Level) {
    LEVEL.store(resolve(default), Ordering::Relaxed);
}

fn current() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return v;
    }
    let resolved = resolve(Level::Warn);
    LEVEL.store(resolved, Ordering::Relaxed);
    resolved
}

/// Is `level` currently enabled? The log macros check this before
/// evaluating their format arguments.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= current()
}

thread_local! {
    static RANK: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Tag this thread's log lines with a world-rank prefix. Called once at
/// the top of each rank loop.
pub fn set_rank(rank: usize) {
    RANK.with(|r| r.set(Some(rank)));
}

/// Emit one line (used via the `log_*!` macros, which gate on `enabled`).
pub fn write(level: Level, args: std::fmt::Arguments<'_>) {
    let rank = RANK.with(|r| r.get());
    match rank {
        Some(r) => eprintln!("[{} r{r}] {args}", level.tag()),
        None => eprintln!("[{}] {args}", level.tag()),
    }
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write($crate::obs::log::Level::Error, format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write($crate::obs::log::Level::Warn, format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write($crate::obs::log::Level::Info, format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write($crate::obs::log::Level::Debug, format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Trace) {
            $crate::obs::log::write($crate::obs::log::Level::Trace, format_args!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_levels() {
        assert_eq!(parse_level("info"), Some(Some(Level::Info)));
        assert_eq!(parse_level(" WARN "), Some(Some(Level::Warn)));
        assert_eq!(parse_level("warning"), Some(Some(Level::Warn)));
        assert_eq!(parse_level("off"), Some(None));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn default_is_quiet_under_tests() {
        // Unless the environment overrides it, libraries (and the test
        // harness) run at Warn: info/debug/trace stay silent.
        if std::env::var("PHANTOM_LOG").is_err() {
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Info));
            assert!(!enabled(Level::Trace));
        }
    }
}
