//! Exact energy attribution: fold recorded spans against the ledger's raw
//! intervals so every Joule lands in a span category.
//!
//! The ledger is the source of truth for *when* energy was drawn (interval
//! activity → Watts under Eqn. 1); spans only say *what the rank was doing*
//! then. Attribution flattens the strictly-nested spans into disjoint
//! "leaf segments" — each instant labeled by the deepest covering span —
//! then intersects those segments with the ledger intervals. Interval time
//! no segment covers (pre-arming lead-in, dropped spans, untraced gaps)
//! goes to the `untraced` bucket at that interval's own power draw, so
//!
//!   Σ_category energy + untraced energy == ledger energy (exact)
//!
//! up to float summation noise. The tier-1 test asserts this within 1e-9
//! relative error on the quickstart TP and PP configs.

use std::collections::BTreeMap;

use crate::energy::{Activity, Interval, PowerModel};

use super::span::Span;

/// Time and energy assigned to one span category.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CategoryEnergy {
    /// Seconds charged at the busy draw A (Compute intervals).
    pub busy_s: f64,
    /// Seconds charged at the static draw B (Communicate/Idle/DpComm).
    pub stall_s: f64,
    pub energy_j: f64,
}

impl CategoryEnergy {
    fn add(&mut self, dur_s: f64, activity: Activity, model: &PowerModel) {
        match activity {
            Activity::Compute => {
                self.busy_s += dur_s;
                self.energy_j += model.busy_w * dur_s;
            }
            _ => {
                self.stall_s += dur_s;
                self.energy_j += model.idle_w * dur_s;
            }
        }
    }

    fn accumulate(&mut self, other: &CategoryEnergy) {
        self.busy_s += other.busy_s;
        self.stall_s += other.stall_s;
        self.energy_j += other.energy_j;
    }
}

/// Per-category energy rollup for one rank (or, after `accumulate`, a
/// whole run).
#[derive(Debug, Clone, Default)]
pub struct Attribution {
    pub by_category: BTreeMap<String, CategoryEnergy>,
    /// Interval time no span covered, at the intervals' own draw.
    pub untraced: CategoryEnergy,
}

impl Attribution {
    /// Total energy across all categories plus the untraced bucket — the
    /// quantity that must reconcile with `LedgerSummary::energy_j`.
    pub fn total_j(&self) -> f64 {
        self.by_category.values().map(|c| c.energy_j).sum::<f64>() + self.untraced.energy_j
    }

    /// Does the rollup reconcile with the exact ledger energy within
    /// relative error `rel`?
    pub fn reconciles(&self, exact_j: f64, rel: f64) -> bool {
        let diff = (self.total_j() - exact_j).abs();
        diff <= rel * exact_j.abs().max(1e-12)
    }

    /// Merge another rank's attribution into this rollup.
    pub fn accumulate(&mut self, other: &Attribution) {
        for (cat, ce) in &other.by_category {
            self.by_category.entry(cat.clone()).or_default().accumulate(ce);
        }
        self.untraced.accumulate(&other.untraced);
    }
}

/// A maximal segment of time labeled with the deepest covering span's
/// category. Segments are disjoint and sorted.
#[derive(Debug, Clone, Copy)]
struct Segment {
    start_s: f64,
    end_s: f64,
    cat: &'static str,
}

/// Flatten strictly-nested spans into disjoint leaf segments via a stack
/// sweep. Spans are sorted parents-first (earlier start, or same start and
/// later end); each emitted segment carries the category of the deepest
/// span active over it.
fn leaf_segments(spans: &[Span]) -> Vec<Segment> {
    let mut sorted: Vec<&Span> = spans.iter().filter(|s| s.end_s > s.start_s).collect();
    sorted.sort_by(|a, b| {
        a.start_s
            .partial_cmp(&b.start_s)
            .unwrap()
            .then(b.end_s.partial_cmp(&a.end_s).unwrap())
            .then(a.depth.cmp(&b.depth))
    });
    let mut segs: Vec<Segment> = Vec::new();
    // (end_s, cat) of currently-active spans, outermost first.
    let mut stack: Vec<(f64, &'static str)> = Vec::new();
    let mut cursor = f64::NEG_INFINITY;
    let mut emit = |segs: &mut Vec<Segment>, start: f64, end: f64, cat: &'static str| {
        if end > start {
            segs.push(Segment { start_s: start, end_s: end, cat });
        }
    };
    for sp in sorted {
        // Close spans that finish before this one starts, emitting their
        // uncovered tails deepest-first.
        while let Some(&(end, cat)) = stack.last() {
            if end <= sp.start_s {
                emit(&mut segs, cursor.max(f64::MIN), end, cat);
                cursor = cursor.max(end);
                stack.pop();
            } else {
                break;
            }
        }
        // The enclosing span (if any) owns the gap up to this span's start.
        if let Some(&(_, cat)) = stack.last() {
            emit(&mut segs, cursor, sp.start_s, cat);
        }
        cursor = cursor.max(sp.start_s);
        stack.push((sp.end_s, sp.cat));
    }
    while let Some((end, cat)) = stack.pop() {
        emit(&mut segs, cursor, end, cat);
        cursor = cursor.max(end);
    }
    segs
}

/// Attribute every Joule of `intervals` to the category of the deepest
/// span covering it; uncovered time goes to `untraced`.
pub fn attribute(spans: &[Span], intervals: &[Interval], model: &PowerModel) -> Attribution {
    let segs = leaf_segments(spans);
    let mut out = Attribution::default();
    let mut si = 0usize;
    for iv in intervals {
        let (s, e) = (iv.start_s, iv.end_s);
        if e <= s {
            continue;
        }
        // Ledger intervals are chronological, so the segment cursor only
        // moves forward — but rewind defensively if an interval starts
        // before the previous one ended (compacted ledgers).
        while si > 0 && segs[si - 1].end_s > s {
            si -= 1;
        }
        while si < segs.len() && segs[si].end_s <= s {
            si += 1;
        }
        let mut covered = 0.0;
        let mut j = si;
        while j < segs.len() && segs[j].start_s < e {
            let o_start = segs[j].start_s.max(s);
            let o_end = segs[j].end_s.min(e);
            if o_end > o_start {
                let dur = o_end - o_start;
                covered += dur;
                out.by_category
                    .entry(segs[j].cat.to_string())
                    .or_default()
                    .add(dur, iv.activity, model);
            }
            j += 1;
        }
        let uncovered = (e - s) - covered;
        if uncovered > 0.0 {
            out.untraced.add(uncovered, iv.activity, model);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::SpanRecorder;

    fn span(cat: &'static str, start: f64, end: f64, depth: u32) -> Span {
        Span { cat, name: cat.to_string(), start_s: start, end_s: end, depth, args: vec![] }
    }

    #[test]
    fn leaf_segments_take_deepest_cover() {
        // iter [0,10) wrapping exec [1,4) and comm [6,8).
        let spans = vec![
            span("exec", 1.0, 4.0, 1),
            span("comm", 6.0, 8.0, 1),
            span("iter", 0.0, 10.0, 0),
        ];
        let segs = leaf_segments(&spans);
        let got: Vec<(f64, f64, &str)> = segs.iter().map(|s| (s.start_s, s.end_s, s.cat)).collect();
        assert_eq!(
            got,
            vec![
                (0.0, 1.0, "iter"),
                (1.0, 4.0, "exec"),
                (4.0, 6.0, "iter"),
                (6.0, 8.0, "comm"),
                (8.0, 10.0, "iter"),
            ]
        );
    }

    #[test]
    fn attribution_reconciles_exactly() {
        let model = PowerModel::frontier();
        let mut ledger = crate::energy::EnergyLedger::new();
        ledger.arm_tracing(0);
        ledger.span_begin("iter", "iter 0");
        ledger.span_begin("exec", "fwd");
        ledger.advance(0.5, Activity::Compute);
        ledger.span_end();
        ledger.span_begin("comm.wire", "all_gather");
        ledger.advance(0.2, Activity::Communicate);
        ledger.span_end();
        // Idle gap inside the iteration, covered by the iter span.
        ledger.sync_to(1.0);
        ledger.span_end();
        // Trailing time no span covers → untraced.
        ledger.advance(0.25, Activity::Compute);
        let exact = ledger.energy_j(&model);
        let cap = ledger.take_trace().unwrap();
        let attr = cap.attribution(&model);
        assert!(attr.reconciles(exact, 1e-12), "total={} exact={exact}", attr.total_j());
        let exec = attr.by_category.get("exec").unwrap();
        assert!((exec.energy_j - 560.0 * 0.5).abs() < 1e-9);
        let wire = attr.by_category.get("comm.wire").unwrap();
        assert!((wire.energy_j - 90.0 * 0.2).abs() < 1e-9);
        let iter = attr.by_category.get("iter").unwrap();
        assert!((iter.energy_j - 90.0 * 0.3).abs() < 1e-9, "idle gap stays with iter");
        assert!((attr.untraced.energy_j - 560.0 * 0.25).abs() < 1e-9);
    }

    #[test]
    fn dropped_spans_fall_to_untraced_and_still_reconcile() {
        let model = PowerModel::frontier();
        let mut rec = SpanRecorder::with_cap(0, 1);
        let mut intervals = Vec::new();
        let mut t = 0.0;
        for i in 0..4 {
            rec.begin("exec", "k", t);
            intervals.push(Interval { start_s: t, end_s: t + 1.0, activity: Activity::Compute });
            t += 1.0;
            rec.end(t);
            let _ = i;
        }
        assert_eq!(rec.dropped(), 3);
        let attr = attribute(rec.spans(), &intervals, &model);
        let exact = 560.0 * 4.0;
        assert!(attr.reconciles(exact, 1e-12));
        assert!((attr.by_category.get("exec").unwrap().energy_j - 560.0).abs() < 1e-9);
        assert!((attr.untraced.energy_j - 560.0 * 3.0).abs() < 1e-9);
    }

    #[test]
    fn rollup_accumulates_across_ranks() {
        let model = PowerModel { busy_w: 100.0, idle_w: 10.0 };
        let a = attribute(
            &[span("exec", 0.0, 1.0, 0)],
            &[Interval { start_s: 0.0, end_s: 1.0, activity: Activity::Compute }],
            &model,
        );
        let b = attribute(
            &[span("exec", 0.0, 2.0, 0)],
            &[Interval { start_s: 0.0, end_s: 2.0, activity: Activity::Idle }],
            &model,
        );
        let mut total = Attribution::default();
        total.accumulate(&a);
        total.accumulate(&b);
        let exec = total.by_category.get("exec").unwrap();
        assert_eq!(exec.busy_s, 1.0);
        assert_eq!(exec.stall_s, 2.0);
        assert!((total.total_j() - 120.0).abs() < 1e-9);
    }

    #[test]
    fn empty_spans_put_everything_in_untraced() {
        let model = PowerModel::frontier();
        let intervals = [Interval { start_s: 0.0, end_s: 2.0, activity: Activity::Idle }];
        let attr = attribute(&[], &intervals, &model);
        assert!(attr.by_category.is_empty());
        assert!((attr.untraced.energy_j - 180.0).abs() < 1e-9);
        assert!(attr.reconciles(180.0, 1e-12));
    }
}
