//! Bounded per-rank span recorder stamped in *virtual* time.
//!
//! A rank's `SpanRecorder` lives inside its `EnergyLedger` (the one object
//! already threaded through every hook site) and is stamped from the
//! ledger's virtual clock, so spans and energy intervals share one
//! timeline by construction. Ranks are single-threaded, so spans are
//! strictly nested: `begin`/`end` maintain an open-span stack and closed
//! spans carry their nesting depth.
//!
//! The recorder is bounded: once `cap` closed spans (or events) are held,
//! further ones are counted in `dropped` instead of stored. Dropped spans
//! simply leave their intervals unlabeled — the attribution pass assigns
//! that time to the `untraced` bucket, so the energy reconciliation
//! invariant survives overflow.

use crate::energy::Interval;

/// A typed span/event argument. Numbers stay numbers so the trace export
/// and BENCH rollups don't round-trip through strings.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    F(f64),
    I(i64),
    S(String),
}

/// A closed span on one rank's virtual timeline. `cat` is the attribution
/// category (taxonomy in DESIGN.md §13); `name` is the display label.
#[derive(Debug, Clone)]
pub struct Span {
    pub cat: &'static str,
    pub name: String,
    pub start_s: f64,
    pub end_s: f64,
    pub depth: u32,
    pub args: Vec<(&'static str, Arg)>,
}

/// An instant (zero-duration) event — batcher decisions, checkpoint
/// writes, hot swaps.
#[derive(Debug, Clone)]
pub struct Event {
    pub cat: &'static str,
    pub name: String,
    pub t_s: f64,
    pub args: Vec<(&'static str, Arg)>,
}

#[derive(Debug, Clone)]
struct OpenSpan {
    cat: &'static str,
    name: String,
    start_s: f64,
}

/// Default bound on stored spans/events per rank. A quickstart-sized
/// traced run records a few thousand spans; the cap exists so a
/// forgotten-armed long-lived serve rank degrades to counting drops
/// instead of growing without bound.
pub const DEFAULT_SPAN_CAP: usize = 1 << 20;

/// Per-rank bounded span/event recorder.
#[derive(Debug, Clone)]
pub struct SpanRecorder {
    pub rank: usize,
    cap: usize,
    spans: Vec<Span>,
    events: Vec<Event>,
    stack: Vec<OpenSpan>,
    dropped: u64,
}

impl SpanRecorder {
    pub fn new(rank: usize) -> SpanRecorder {
        SpanRecorder::with_cap(rank, DEFAULT_SPAN_CAP)
    }

    pub fn with_cap(rank: usize, cap: usize) -> SpanRecorder {
        SpanRecorder {
            rank,
            cap,
            spans: Vec::new(),
            events: Vec::new(),
            stack: Vec::new(),
            dropped: 0,
        }
    }

    /// Open a span at virtual time `now_s`.
    pub fn begin(&mut self, cat: &'static str, name: &str, now_s: f64) {
        self.stack.push(OpenSpan { cat, name: name.to_string(), start_s: now_s });
    }

    /// Close the innermost open span at `now_s`.
    pub fn end(&mut self, now_s: f64) {
        self.end_args(now_s, Vec::new());
    }

    /// Close the innermost open span, attaching args known only at the end
    /// (measured wall time, FLOP tallies, arrival stamps).
    pub fn end_args(&mut self, now_s: f64, args: Vec<(&'static str, Arg)>) {
        let Some(open) = self.stack.pop() else {
            debug_assert!(false, "span_end without matching span_begin");
            return;
        };
        if self.spans.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.spans.push(Span {
            cat: open.cat,
            name: open.name,
            start_s: open.start_s,
            end_s: now_s,
            depth: self.stack.len() as u32,
            args,
        });
    }

    /// Record an instant event at `t_s`.
    pub fn event(
        &mut self,
        cat: &'static str,
        name: &str,
        t_s: f64,
        args: Vec<(&'static str, Arg)>,
    ) {
        if self.events.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.events.push(Event { cat, name: name.to_string(), t_s, args });
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Open spans still on the stack (should be zero after a clean run).
    pub fn open_depth(&self) -> usize {
        self.stack.len()
    }
}

/// Everything needed to attribute and export one rank's timeline,
/// extracted from its ledger at the end of a traced run: the recorded
/// spans plus a snapshot of the raw energy intervals they label.
#[derive(Debug, Clone)]
pub struct TraceCapture {
    pub recorder: SpanRecorder,
    pub intervals: Vec<Interval>,
}

impl TraceCapture {
    pub fn rank(&self) -> usize {
        self.recorder.rank
    }

    /// Fold the spans against the interval snapshot (see `attr`).
    pub fn attribution(&self, model: &crate::energy::PowerModel) -> super::attr::Attribution {
        super::attr::attribute(self.recorder.spans(), &self.intervals, model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_nested_spans_with_depth() {
        let mut r = SpanRecorder::new(3);
        r.begin("iter", "iter 0", 0.0);
        r.begin("exec", "fwd", 0.0);
        r.end(1.0);
        r.begin("comm.wire", "all_gather", 1.0);
        r.end_args(1.5, vec![("seq", Arg::I(7))]);
        r.end(1.5);
        assert_eq!(r.spans().len(), 3);
        assert_eq!(r.open_depth(), 0);
        // Children close first and carry depth 1; the iter span is depth 0.
        assert_eq!(r.spans()[0].name, "fwd");
        assert_eq!(r.spans()[0].depth, 1);
        assert_eq!(r.spans()[1].args, vec![("seq", Arg::I(7))]);
        assert_eq!(r.spans()[2].cat, "iter");
        assert_eq!(r.spans()[2].depth, 0);
        assert_eq!(r.spans()[2].end_s, 1.5);
    }

    #[test]
    fn cap_counts_drops_instead_of_growing() {
        let mut r = SpanRecorder::with_cap(0, 2);
        for i in 0..5 {
            r.begin("exec", "k", i as f64);
            r.end(i as f64 + 0.5);
        }
        assert_eq!(r.spans().len(), 2);
        assert_eq!(r.dropped(), 3);
        r.event("ckpt", "write", 9.0, vec![]);
        assert_eq!(r.events().len(), 1, "event budget is separate from the span vec");
    }

    #[test]
    fn unmatched_end_is_ignored_in_release() {
        let mut r = SpanRecorder::new(0);
        r.begin("iter", "i", 0.0);
        r.end(1.0);
        // A stray end must not panic in release builds (debug_assert only).
        if !cfg!(debug_assertions) {
            r.end(2.0);
            assert_eq!(r.spans().len(), 1);
        }
    }
}
