//! Chrome trace-event JSON export (the format Perfetto and
//! `chrome://tracing` load natively).
//!
//! Each rank becomes one track (`pid` 0, `tid` = track id): closed spans
//! are emitted as "X" complete events with `ts`/`dur` in microseconds of
//! *virtual* time, instants as "i" events, and an "M" metadata event names
//! the track. Events are sorted per track by start time (parents before
//! children on ties) so per-track timestamps are nondecreasing — the
//! property the CI trace validator checks.

use crate::util::json::Json;

use super::span::{Arg, SpanRecorder};

/// One timeline in the exported trace: a rank's recorder plus its display
/// name (e.g. "rank 2 (pp)" or "host").
pub struct Track<'a> {
    pub name: String,
    pub tid: i64,
    pub recorder: &'a SpanRecorder,
}

fn arg_json(a: &Arg) -> Json {
    match a {
        Arg::F(x) => Json::num(*x),
        Arg::I(x) => Json::int(*x),
        Arg::S(s) => Json::str(s.clone()),
    }
}

fn args_obj(args: &[(&'static str, Arg)]) -> Json {
    Json::Obj(args.iter().map(|(k, v)| (k.to_string(), arg_json(v))).collect())
}

const US: f64 = 1e6;

/// Build the full trace document: `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace(tracks: &[Track]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for track in tracks {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::int(0)),
            ("tid", Json::int(track.tid)),
            ("args", Json::obj(vec![("name", Json::str(track.name.clone()))])),
        ]));
        // (start_us, depth, event) — sort by start, parents first on ties.
        let mut timed: Vec<(f64, u32, Json)> = Vec::new();
        for sp in track.recorder.spans() {
            let ts = sp.start_s * US;
            timed.push((
                ts,
                sp.depth,
                Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(sp.name.clone())),
                    ("cat", Json::str(sp.cat)),
                    ("pid", Json::int(0)),
                    ("tid", Json::int(track.tid)),
                    ("ts", Json::num(ts)),
                    ("dur", Json::num((sp.end_s - sp.start_s) * US)),
                    ("args", args_obj(&sp.args)),
                ]),
            ));
        }
        for ev in track.recorder.events() {
            let ts = ev.t_s * US;
            timed.push((
                ts,
                u32::MAX,
                Json::obj(vec![
                    ("ph", Json::str("i")),
                    ("name", Json::str(ev.name.clone())),
                    ("cat", Json::str(ev.cat)),
                    ("pid", Json::int(0)),
                    ("tid", Json::int(track.tid)),
                    ("ts", Json::num(ts)),
                    ("s", Json::str("t")),
                    ("args", args_obj(&ev.args)),
                ]),
            ));
        }
        timed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        events.extend(timed.into_iter().map(|(_, _, e)| e));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Validate a parsed trace document: the structure a Perfetto load needs,
/// plus nondecreasing per-track timestamps. Returns a description of the
/// first violation, if any. Used by the `phantom trace` CLI and the CI
/// trace-smoke job.
pub fn validate_trace(doc: &Json) -> Result<(), String> {
    let events = doc
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut last_ts: std::collections::BTreeMap<i64, f64> = std::collections::BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev.get("ph").as_str().ok_or_else(|| format!("event {i}: missing ph"))?;
        if ev.get("name").as_str().is_none() {
            return Err(format!("event {i}: missing name"));
        }
        let tid = ev.get("tid").as_i64().ok_or_else(|| format!("event {i}: missing tid"))?;
        match ph {
            "M" => continue,
            "X" | "i" => {
                let ts = ev.get("ts").as_f64().ok_or_else(|| format!("event {i}: missing ts"))?;
                if !ts.is_finite() || ts < 0.0 {
                    return Err(format!("event {i}: bad ts {ts}"));
                }
                if ph == "X" {
                    let dur =
                        ev.get("dur").as_f64().ok_or_else(|| format!("event {i}: missing dur"))?;
                    if !dur.is_finite() || dur < 0.0 {
                        return Err(format!("event {i}: bad dur {dur}"));
                    }
                }
                let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
                if ts < *prev {
                    return Err(format!("event {i}: ts {ts} < previous {prev} on track {tid}"));
                }
                *prev = ts;
            }
            other => return Err(format!("event {i}: unknown ph {other:?}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Arg;

    #[test]
    fn exports_sorted_valid_trace() {
        let mut r = SpanRecorder::new(1);
        r.begin("iter", "iter 0", 0.0);
        r.begin("exec", "fwd", 0.001);
        r.end(0.002);
        let args = vec![("loss", Arg::F(0.5)), ("iter", Arg::I(0)), ("mode", Arg::S("pp".into()))];
        r.end_args(0.003, args);
        r.event("ckpt", "write", 0.0005, vec![]);
        // Recorder stores children before parents (close order); export must
        // still come out start-sorted.
        let doc = chrome_trace(&[Track { name: "rank 1".into(), tid: 1, recorder: &r }]);
        validate_trace(&doc).unwrap();
        let events = doc.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get("ph").as_str(), Some("M"));
        assert_eq!(events[1].get("name").as_str(), Some("iter 0"));
        assert_eq!(events[1].get("ts").as_f64(), Some(0.0));
        assert_eq!(events[1].get("dur").as_f64(), Some(3000.0));
        assert_eq!(events[2].get("ph").as_str(), Some("i"));
        assert_eq!(events[3].get("name").as_str(), Some("fwd"));
        // Round-trips through the parser.
        let parsed = Json::parse(&doc.pretty()).unwrap();
        validate_trace(&parsed).unwrap();
    }

    #[test]
    fn validator_rejects_non_monotone_and_malformed() {
        let bad = Json::parse(
            r#"{"traceEvents": [
                {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 5.0, "dur": 1.0, "args": {}},
                {"ph": "X", "name": "b", "pid": 0, "tid": 0, "ts": 2.0, "dur": 1.0, "args": {}}
            ]}"#,
        )
        .unwrap();
        assert!(validate_trace(&bad).unwrap_err().contains("track 0"));
        let missing = Json::parse(r#"{"other": 1}"#).unwrap();
        assert!(validate_trace(&missing).is_err());
        let neg = Json::parse(
            r#"{"traceEvents": [{"ph": "X", "name": "a", "tid": 0, "ts": 1.0, "dur": -2.0}]}"#,
        )
        .unwrap();
        assert!(validate_trace(&neg).is_err());
    }
}
