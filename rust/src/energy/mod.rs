//! Energy model and accounting (the ROCm-SMI substitute).
//!
//! Paper Eqn. (1):  e(n, p, L) = A * alpha + B * beta
//! where A is the busy (dynamic) power draw and B the idle (static) draw of
//! one device. On Frontier A ~ 560 W, B ~ 90 W. Each rank keeps a ledger of
//! busy (compute) seconds and idle-or-communicating seconds in *virtual*
//! time; energy is integrated exactly as A*busy + B*(comm + idle).
//!
//! A `PowerSensor` mirrors the paper's background monitoring script: it
//! samples the ledger at a fixed interval into a power-time curve whose
//! trapezoidal integral must agree with the exact ledger (tested), and which
//! lets reports exclude initialization lead-in the way the paper does.

/// Vendor power constants in Watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerModel {
    /// Dynamic (busy) draw, W.
    pub busy_w: f64,
    /// Static (idle / communicating) draw, W.
    pub idle_w: f64,
}

impl PowerModel {
    /// Frontier MI250X GCD constants from the paper (Sec. II-A).
    pub fn frontier() -> PowerModel {
        PowerModel { busy_w: 560.0, idle_w: 90.0 }
    }

    /// Energy in Joules for a busy/idle split (Eqn. 1 per iteration).
    pub fn energy(&self, busy_s: f64, idle_s: f64) -> f64 {
        self.busy_w * busy_s + self.idle_w * idle_s
    }
}

/// Least-squares fit of the Eqn. 1 power split from measured run summaries:
/// rows of (busy_s, stall_s, energy_j) where stall is everything charged at
/// the static draw (comm + idle + dp). This is the calibration path from
/// BENCH records back to the (A, B) constants (perfmodel::calib). Returns
/// None when the system is under-determined (< 2 rows, or all rows share
/// the same busy/stall mix) or the solution is unphysical (A <= 0, B < 0,
/// or A <= B — the paper requires the dynamic draw to exceed static).
pub fn fit_power(rows: &[(f64, f64, f64)]) -> Option<PowerModel> {
    if rows.len() < 2 {
        return None;
    }
    let mut x = Vec::with_capacity(rows.len() * 2);
    let mut y = Vec::with_capacity(rows.len());
    for &(busy_s, stall_s, energy_j) in rows {
        x.extend_from_slice(&[busy_s, stall_s]);
        y.push(energy_j);
    }
    let beta = crate::util::stats::least_squares(&x, 2, &y)?;
    let (busy_w, idle_w) = (beta[0], beta[1]);
    if !busy_w.is_finite() || !idle_w.is_finite() || busy_w <= 0.0 || idle_w < 0.0 {
        return None;
    }
    if busy_w <= idle_w {
        return None;
    }
    Some(PowerModel { busy_w, idle_w })
}

/// What a rank was doing during an interval of virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activity {
    /// Executing compute (charged at A).
    Compute,
    /// Driving / waiting on a model-parallel collective (charged at B).
    Communicate,
    /// Waiting at a rendezvous for slower peers (charged at B).
    Idle,
    /// Driving the data-parallel gradient All-Reduce (charged at B, like
    /// any collective, but tracked as its own bucket so hybrid DP×(TP|PP)
    /// reports can separate the Huber-style DP sync cost from the
    /// model-parallel traffic the paper compares). Pure model-parallel
    /// runs (dp = 1) never record this activity.
    DpComm,
}

/// One recorded interval.
#[derive(Debug, Clone, Copy)]
pub struct Interval {
    pub start_s: f64,
    pub end_s: f64,
    pub activity: Activity,
}

/// Per-rank energy/time ledger in virtual time.
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    intervals: Vec<Interval>,
    /// Current virtual clock of this rank (seconds).
    pub now_s: f64,
    /// Optional span recorder (obs): armed for traced runs so every hook
    /// site that already holds the ledger can label the intervals it
    /// charges. Boxed to keep the untraced ledger small.
    recorder: Option<Box<crate::obs::SpanRecorder>>,
    /// Comm wire time deferred for compute overlap (the 1F1B schedule):
    /// while `defer_armed`, endpoints park their wire seconds here instead
    /// of advancing the clock. Subsequent Compute advances drain the
    /// register at zero cost — the NIC moves bytes while the ALUs are busy,
    /// and the busy draw A already dominates the static draw B — and
    /// `drain_deferred` charges whatever compute could not hide as real
    /// stall time. The rendezvous *wait* is never deferred: peers must
    /// still arrive, so clocks stay aligned across ranks.
    deferred_s: f64,
    defer_armed: bool,
}

impl EnergyLedger {
    pub fn new() -> EnergyLedger {
        EnergyLedger::default()
    }

    // -- span tracing (obs) ----------------------------------------------
    //
    // Spans never charge time; they only label intervals this ledger
    // records, stamped from the same virtual clock. Every method below is
    // a no-op (one branch) when no recorder is armed.

    /// Arm span recording for this rank. Ranks are single-threaded, so
    /// spans are strictly nested per recorder.
    pub fn arm_tracing(&mut self, rank: usize) {
        self.recorder = Some(Box::new(crate::obs::SpanRecorder::new(rank)));
    }

    /// Is a span recorder armed?
    pub fn traced(&self) -> bool {
        self.recorder.is_some()
    }

    /// Open a span at the current virtual time.
    pub fn span_begin(&mut self, cat: &'static str, name: &str) {
        if let Some(r) = &mut self.recorder {
            let now = self.now_s;
            r.begin(cat, name, now);
        }
    }

    /// Close the innermost open span at the current virtual time.
    pub fn span_end(&mut self) {
        if let Some(r) = &mut self.recorder {
            let now = self.now_s;
            r.end(now);
        }
    }

    /// Close the innermost open span with args built lazily — the closure
    /// only runs when a recorder is armed, so untraced hot paths never
    /// allocate.
    pub fn span_end_with<F>(&mut self, args: F)
    where
        F: FnOnce() -> Vec<(&'static str, crate::obs::Arg)>,
    {
        if let Some(r) = &mut self.recorder {
            let now = self.now_s;
            r.end_args(now, args());
        }
    }

    /// Record an instant event at the current virtual time.
    pub fn trace_event<F>(&mut self, cat: &'static str, name: &str, args: F)
    where
        F: FnOnce() -> Vec<(&'static str, crate::obs::Arg)>,
    {
        if let Some(r) = &mut self.recorder {
            let now = self.now_s;
            r.event(cat, name, now, args());
        }
    }

    /// Disarm the recorder and return it together with a snapshot of the
    /// raw intervals it labeled — the inputs to the attribution pass.
    pub fn take_trace(&mut self) -> Option<crate::obs::TraceCapture> {
        self.recorder
            .take()
            .map(|r| crate::obs::TraceCapture { recorder: *r, intervals: self.intervals.clone() })
    }

    /// Advance the clock by `dur_s` doing `activity`. Compute advances
    /// additionally drain the deferred-comm register: up to `dur_s` of
    /// parked wire time completes concurrently with the compute, costing
    /// no extra virtual time or energy (comm hidden under the busy draw).
    pub fn advance(&mut self, dur_s: f64, activity: Activity) {
        assert!(dur_s >= 0.0, "negative duration {dur_s}");
        if dur_s == 0.0 {
            return;
        }
        if activity == Activity::Compute && self.deferred_s > 0.0 {
            self.deferred_s = (self.deferred_s - dur_s).max(0.0);
        }
        let start = self.now_s;
        self.now_s += dur_s;
        self.intervals.push(Interval { start_s: start, end_s: self.now_s, activity });
    }

    // -- comm/compute overlap (1F1B) -------------------------------------

    /// Arm or disarm wire-time deferral. While armed, `Endpoint::charge`
    /// parks wire seconds via `defer_comm` instead of advancing the clock.
    pub fn set_defer(&mut self, armed: bool) {
        self.defer_armed = armed;
    }

    /// Is wire-time deferral armed?
    pub fn defer_armed(&self) -> bool {
        self.defer_armed
    }

    /// Park `dur_s` of collective wire time on the overlap register
    /// (no clock movement; see `advance` / `drain_deferred`).
    pub fn defer_comm(&mut self, dur_s: f64) {
        assert!(dur_s >= 0.0, "negative deferred duration {dur_s}");
        self.deferred_s += dur_s;
    }

    /// Wire seconds currently parked on the overlap register.
    pub fn deferred_s(&self) -> f64 {
        self.deferred_s
    }

    /// Charge the un-hidden remainder of the overlap register as real
    /// stall time under `activity` and clear it. Schedulers call this at
    /// the overlap boundary (before the DP sync / optimizer step) so no
    /// wire time silently vanishes from the accounting.
    pub fn drain_deferred(&mut self, activity: Activity) {
        let rest = self.deferred_s;
        self.deferred_s = 0.0;
        if rest > 0.0 {
            self.advance(rest, activity);
        }
    }

    /// Jump the clock forward to `t_s` (rendezvous with slower peers),
    /// recording the gap as Idle. No-op if already past `t_s`.
    pub fn sync_to(&mut self, t_s: f64) {
        if t_s > self.now_s {
            let gap = t_s - self.now_s;
            self.advance(gap, Activity::Idle);
        }
    }

    pub fn busy_s(&self) -> f64 {
        self.total(Activity::Compute)
    }

    pub fn comm_s(&self) -> f64 {
        self.total(Activity::Communicate)
    }

    pub fn idle_s(&self) -> f64 {
        self.total(Activity::Idle)
    }

    /// Time spent driving the DP gradient All-Reduce (zero unless the rank
    /// belongs to a data-parallel group of size > 1).
    pub fn dp_comm_s(&self) -> f64 {
        self.total(Activity::DpComm)
    }

    fn total(&self, a: Activity) -> f64 {
        self.intervals
            .iter()
            .filter(|i| i.activity == a)
            .map(|i| i.end_s - i.start_s)
            .sum()
    }

    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    /// Exact energy under `model` (Eqn. 1): busy at A, comm+idle+dp at B.
    pub fn energy_j(&self, model: &PowerModel) -> f64 {
        model.energy(self.busy_s(), self.comm_s() + self.idle_s() + self.dp_comm_s())
    }

    /// Exact energy restricted to [t0, t1) — used to exclude initialization
    /// lead-in from the accounting, as the paper's monitoring script does.
    pub fn energy_j_between(&self, model: &PowerModel, t0: f64, t1: f64) -> f64 {
        let mut e = 0.0;
        for iv in &self.intervals {
            let s = iv.start_s.max(t0);
            let t = iv.end_s.min(t1);
            if t > s {
                let w = match iv.activity {
                    Activity::Compute => model.busy_w,
                    _ => model.idle_w,
                };
                e += w * (t - s);
            }
        }
        e
    }

    /// Merge the recorded intervals into one aggregate interval per
    /// activity. Totals (`busy_s`/`comm_s`/`idle_s`), the clock, and
    /// `energy_j` are preserved exactly; fine-grained windowed queries
    /// (`energy_j_between`) become approximate past the compaction point.
    /// Long-lived serving ranks call this per batch so their ledgers stay
    /// O(1) instead of growing with every kernel and collective.
    ///
    /// No-op while a span recorder is armed: attribution needs the raw
    /// interval sequence, and traced runs are bounded diagnostic runs.
    pub fn compact(&mut self) {
        if self.recorder.is_some() {
            return;
        }
        let (busy, comm, idle, dp) =
            (self.busy_s(), self.comm_s(), self.idle_s(), self.dp_comm_s());
        self.intervals.clear();
        let mut t = self.now_s - (busy + comm + idle + dp);
        for (dur, activity) in [
            (busy, Activity::Compute),
            (comm, Activity::Communicate),
            (idle, Activity::Idle),
            (dp, Activity::DpComm),
        ] {
            if dur > 0.0 {
                self.intervals.push(Interval { start_s: t, end_s: t + dur, activity });
                t += dur;
            }
        }
    }

    /// Merge another rank's ledger total into a cluster summary.
    pub fn summary(&self) -> LedgerSummary {
        LedgerSummary {
            busy_s: self.busy_s(),
            comm_s: self.comm_s(),
            idle_s: self.idle_s(),
            dp_comm_s: self.dp_comm_s(),
            end_s: self.now_s,
        }
    }
}

/// Aggregated view of one or more ledgers.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LedgerSummary {
    pub busy_s: f64,
    pub comm_s: f64,
    pub idle_s: f64,
    /// DP gradient All-Reduce time (its own bucket; zero when dp = 1).
    pub dp_comm_s: f64,
    pub end_s: f64,
}

impl LedgerSummary {
    pub fn accumulate(&mut self, other: &LedgerSummary) {
        self.busy_s += other.busy_s;
        self.comm_s += other.comm_s;
        self.idle_s += other.idle_s;
        self.dp_comm_s += other.dp_comm_s;
        self.end_s = self.end_s.max(other.end_s);
    }

    pub fn energy_j(&self, model: &PowerModel) -> f64 {
        model.energy(self.busy_s, self.comm_s + self.idle_s + self.dp_comm_s)
    }
}

/// Sampled power sensor: the rocm-smi substitute. Samples the instantaneous
/// draw of a ledger at fixed intervals, producing the power-time curve whose
/// area the paper integrates.
#[derive(Debug, Clone)]
pub struct PowerSensor {
    pub interval_s: f64,
}

impl PowerSensor {
    pub fn new(interval_s: f64) -> PowerSensor {
        assert!(interval_s > 0.0);
        PowerSensor { interval_s }
    }

    /// Sample the ledger: returns (time, Watts) pairs covering [0, now].
    pub fn sample(&self, ledger: &EnergyLedger, model: &PowerModel) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= ledger.now_s + 1e-12 {
            out.push((t, self.power_at(ledger, model, t)));
            t += self.interval_s;
        }
        out
    }

    fn power_at(&self, ledger: &EnergyLedger, model: &PowerModel, t: f64) -> f64 {
        for iv in ledger.intervals() {
            if t >= iv.start_s && t < iv.end_s {
                return match iv.activity {
                    Activity::Compute => model.busy_w,
                    _ => model.idle_w,
                };
            }
        }
        model.idle_w
    }

    /// Left-Riemann integral of the sampled curve over [t0, t1] — the
    /// paper's "area under the power-time curve over the training phase".
    pub fn integrate(&self, samples: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
        let mut e = 0.0;
        for w in samples.windows(2) {
            let (ta, pa) = w[0];
            let (tb, _) = w[1];
            let s = ta.max(t0);
            let t = tb.min(t1);
            if t > s {
                e += pa * (t - s);
            }
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_constants() {
        let m = PowerModel::frontier();
        assert_eq!(m.busy_w, 560.0);
        assert_eq!(m.idle_w, 90.0);
        assert!(m.busy_w > m.idle_w, "paper requires A > B");
    }

    #[test]
    fn fit_power_recovers_constants() {
        let truth = PowerModel::frontier();
        // Three runs with distinct busy/stall mixes, energies exact.
        let rows: Vec<(f64, f64, f64)> = [(2.0, 0.5), (1.0, 3.0), (4.0, 1.0)]
            .iter()
            .map(|&(b, s)| (b, s, truth.energy(b, s)))
            .collect();
        let fit = fit_power(&rows).unwrap();
        assert!((fit.busy_w - truth.busy_w).abs() < 1e-6, "A={}", fit.busy_w);
        assert!((fit.idle_w - truth.idle_w).abs() < 1e-6, "B={}", fit.idle_w);
    }

    #[test]
    fn fit_power_rejects_degenerate_inputs() {
        assert!(fit_power(&[]).is_none());
        assert!(fit_power(&[(1.0, 1.0, 650.0)]).is_none(), "one row is under-determined");
        // Identical busy/stall mixes: the normal equations are singular.
        assert!(fit_power(&[(1.0, 1.0, 650.0), (2.0, 2.0, 1300.0)]).is_none());
        // Unphysical split (stall draws more than busy) is refused.
        let inverted = PowerModel { busy_w: 90.0, idle_w: 560.0 };
        let rows: Vec<(f64, f64, f64)> = [(2.0, 0.5), (1.0, 3.0), (4.0, 1.0)]
            .iter()
            .map(|&(b, s)| (b, s, inverted.energy(b, s)))
            .collect();
        assert!(fit_power(&rows).is_none());
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = EnergyLedger::new();
        l.advance(2.0, Activity::Compute);
        l.advance(1.0, Activity::Communicate);
        l.advance(0.5, Activity::Idle);
        assert_eq!(l.busy_s(), 2.0);
        assert_eq!(l.comm_s(), 1.0);
        assert_eq!(l.idle_s(), 0.5);
        assert_eq!(l.now_s, 3.5);
        let m = PowerModel::frontier();
        let e = l.energy_j(&m);
        assert!((e - (560.0 * 2.0 + 90.0 * 1.5)).abs() < 1e-9);
    }

    #[test]
    fn sync_to_records_idle() {
        let mut l = EnergyLedger::new();
        l.advance(1.0, Activity::Compute);
        l.sync_to(3.0);
        assert_eq!(l.idle_s(), 2.0);
        l.sync_to(2.0); // past: no-op
        assert_eq!(l.now_s, 3.0);
    }

    #[test]
    fn zero_duration_is_noop() {
        let mut l = EnergyLedger::new();
        l.advance(0.0, Activity::Compute);
        assert!(l.intervals().is_empty());
    }

    #[test]
    fn compact_preserves_totals_clock_and_energy() {
        let mut l = EnergyLedger::new();
        l.advance(0.5, Activity::Compute);
        l.advance(0.25, Activity::Communicate);
        l.sync_to(1.0);
        l.advance(0.5, Activity::Compute);
        let m = PowerModel::frontier();
        let (busy, comm, idle, now, e) =
            (l.busy_s(), l.comm_s(), l.idle_s(), l.now_s, l.energy_j(&m));
        l.compact();
        assert!(l.intervals().len() <= 3);
        assert_eq!(l.busy_s(), busy);
        assert_eq!(l.comm_s(), comm);
        assert_eq!(l.idle_s(), idle);
        assert_eq!(l.now_s, now);
        assert!((l.energy_j(&m) - e).abs() < 1e-12);
        // Compaction is idempotent and keeps accepting new intervals.
        l.compact();
        l.advance(1.0, Activity::Idle);
        assert_eq!(l.idle_s(), idle + 1.0);
        assert_eq!(l.now_s, now + 1.0);
    }

    #[test]
    fn energy_between_excludes_leadin() {
        let mut l = EnergyLedger::new();
        l.advance(1.0, Activity::Idle); // "initialization"
        l.advance(2.0, Activity::Compute); // "training"
        let m = PowerModel::frontier();
        let full = l.energy_j(&m);
        let train_only = l.energy_j_between(&m, 1.0, 3.0);
        assert!((full - (90.0 + 1120.0)).abs() < 1e-9);
        assert!((train_only - 1120.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_curve_integral_matches_exact() {
        let mut l = EnergyLedger::new();
        l.advance(0.4, Activity::Compute);
        l.advance(0.2, Activity::Communicate);
        l.advance(0.4, Activity::Compute);
        let m = PowerModel::frontier();
        // Sample finer than the shortest interval so the Riemann sum is exact
        // (all interval boundaries are multiples of the sampling step).
        let sensor = PowerSensor::new(0.01);
        let samples = sensor.sample(&l, &m);
        let integral = sensor.integrate(&samples, 0.0, l.now_s);
        let exact = l.energy_j(&m);
        assert!(
            (integral - exact).abs() / exact < 1e-6,
            "integral={integral} exact={exact}"
        );
    }

    #[test]
    fn integrate_empty_samples_is_zero() {
        let sensor = PowerSensor::new(0.1);
        assert_eq!(sensor.integrate(&[], 0.0, 10.0), 0.0);
        // A single sample has no complete step either: the left-Riemann sum
        // needs two points to bound a rectangle.
        assert_eq!(sensor.integrate(&[(0.0, 560.0)], 0.0, 10.0), 0.0);
    }

    #[test]
    fn integrate_degenerate_window_is_zero() {
        let sensor = PowerSensor::new(0.5);
        let samples = vec![(0.0, 560.0), (0.5, 560.0), (1.0, 90.0)];
        assert_eq!(sensor.integrate(&samples, 0.5, 0.5), 0.0, "t0 == t1");
        assert_eq!(sensor.integrate(&samples, 0.8, 0.2), 0.0, "inverted window");
    }

    #[test]
    fn integrate_window_past_last_sample_clamps() {
        let sensor = PowerSensor::new(0.5);
        let samples = vec![(0.0, 560.0), (0.5, 90.0), (1.0, 90.0)];
        // The curve is only defined up to the last sample; asking for more
        // integrates exactly the covered area.
        let covered = sensor.integrate(&samples, 0.0, 1.0);
        let over = sensor.integrate(&samples, 0.0, 100.0);
        assert_eq!(over, covered);
        assert!((covered - (560.0 * 0.5 + 90.0 * 0.5)).abs() < 1e-12);
        // A window entirely past the last sample is empty.
        assert_eq!(sensor.integrate(&samples, 2.0, 5.0), 0.0);
    }

    #[test]
    fn integrate_partial_window_takes_left_power() {
        let sensor = PowerSensor::new(1.0);
        let samples = vec![(0.0, 560.0), (1.0, 90.0), (2.0, 90.0)];
        // [0.25, 1.5): 0.75 s at 560 W (left sample of step 1), then
        // 0.5 s at 90 W (left sample of step 2).
        let e = sensor.integrate(&samples, 0.25, 1.5);
        assert!((e - (560.0 * 0.75 + 90.0 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn traced_ledger_records_and_gates_compaction() {
        let mut l = EnergyLedger::new();
        assert!(!l.traced());
        l.span_begin("exec", "never-armed"); // no-op without a recorder
        l.span_end();
        assert!(l.take_trace().is_none());

        l.arm_tracing(2);
        assert!(l.traced());
        l.span_begin("exec", "fwd");
        l.advance(1.0, Activity::Compute);
        l.span_end_with(|| vec![("flops", crate::obs::Arg::F(8.0))]);
        l.advance(0.5, Activity::Communicate);
        l.trace_event("swap", "hot_swap", Vec::new);
        // compact() must preserve the raw intervals while traced.
        l.compact();
        assert_eq!(l.intervals().len(), 2);
        let cap = l.take_trace().unwrap();
        assert!(!l.traced(), "take_trace disarms");
        assert_eq!(cap.rank(), 2);
        assert_eq!(cap.recorder.spans().len(), 1);
        assert_eq!(cap.recorder.events().len(), 1);
        assert_eq!(cap.intervals.len(), 2);
        // Once disarmed, compaction works again.
        l.compact();
        assert!(l.intervals().len() <= 2);
    }

    #[test]
    fn deferred_comm_hides_under_compute_and_remainder_is_charged() {
        let mut l = EnergyLedger::new();
        l.set_defer(true);
        assert!(l.defer_armed());
        l.defer_comm(0.3);
        assert_eq!(l.deferred_s(), 0.3);
        assert_eq!(l.now_s, 0.0, "deferral must not move the clock");
        l.advance(0.2, Activity::Compute); // hides 0.2 s of parked wire
        assert!((l.deferred_s() - 0.1).abs() < 1e-12);
        l.defer_comm(0.05);
        l.set_defer(false);
        l.drain_deferred(Activity::Communicate);
        assert_eq!(l.deferred_s(), 0.0);
        // 0.2 s compute + 0.15 s un-hidden wire remainder.
        assert!((l.busy_s() - 0.2).abs() < 1e-12);
        assert!((l.comm_s() - 0.15).abs() < 1e-12);
        assert!((l.now_s - 0.35).abs() < 1e-12);
        let s = l.summary();
        assert!((s.busy_s + s.comm_s + s.idle_s + s.dp_comm_s - s.end_s).abs() < 1e-12);
        // A register fully covered by compute costs nothing at the drain.
        l.defer_comm(0.01);
        l.advance(1.0, Activity::Compute);
        l.drain_deferred(Activity::Communicate);
        assert!((l.now_s - 1.35).abs() < 1e-12);
        assert!((l.comm_s() - 0.15).abs() < 1e-12);
        // Idle waiting never hides wire time (the bubble stays a bubble).
        l.defer_comm(0.02);
        l.advance(0.5, Activity::Idle);
        assert!((l.deferred_s() - 0.02).abs() < 1e-12);
        l.drain_deferred(Activity::Communicate);
        assert!((l.comm_s() - 0.17).abs() < 1e-12);
    }

    #[test]
    fn summary_accumulate() {
        let mut a =
            LedgerSummary { busy_s: 1.0, comm_s: 2.0, idle_s: 3.0, dp_comm_s: 0.0, end_s: 6.0 };
        let b = LedgerSummary { busy_s: 0.5, comm_s: 0.5, idle_s: 0.5, dp_comm_s: 0.0, end_s: 7.0 };
        a.accumulate(&b);
        assert_eq!(a.busy_s, 1.5);
        assert_eq!(a.end_s, 7.0);
        let m = PowerModel { busy_w: 100.0, idle_w: 10.0 };
        assert!((a.energy_j(&m) - (150.0 + 60.0)).abs() < 1e-9);
    }

    #[test]
    fn dp_comm_is_its_own_bucket_charged_at_static_draw() {
        let mut l = EnergyLedger::new();
        l.advance(2.0, Activity::Compute);
        l.advance(1.0, Activity::Communicate);
        l.advance(0.5, Activity::DpComm);
        assert_eq!(l.dp_comm_s(), 0.5);
        assert_eq!(l.comm_s(), 1.0, "DP time must not leak into the model-parallel bucket");
        assert_eq!(l.now_s, 3.5);
        let m = PowerModel::frontier();
        // DP comm is charged at the static draw B, like any collective.
        assert!((l.energy_j(&m) - (560.0 * 2.0 + 90.0 * 1.5)).abs() < 1e-9);
        // Windowed accounting treats DpComm at B too.
        assert!((l.energy_j_between(&m, 3.0, 3.5) - 90.0 * 0.5).abs() < 1e-9);
        // Summary carries the bucket and the four buckets partition time.
        let s = l.summary();
        assert_eq!(s.dp_comm_s, 0.5);
        assert!((s.busy_s + s.comm_s + s.idle_s + s.dp_comm_s - s.end_s).abs() < 1e-12);
        assert!((s.energy_j(&m) - l.energy_j(&m)).abs() < 1e-9);
        // Compaction preserves the bucket.
        l.compact();
        assert_eq!(l.dp_comm_s(), 0.5);
        assert_eq!(l.now_s, 3.5);
    }
}
