//! Network performance model: the paper's unified collective cost model.
//!
//! Paper Appendix, Eqn. (26):
//!     comm_time(m, p) = c1 * log2(p) + c2 * m + c3        [microseconds]
//! with per-collective constants fitted on Frontier (Table III). We use the
//! paper's constants to advance the virtual clock whenever the in-memory
//! fabric executes a collective, and provide a least-squares fitting routine
//! (`fit`) that regenerates Table III from (synthetic or measured) timings.

use crate::util::stats;

/// The collectives the paper's pipelines use (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    Broadcast,
    AllReduce,
    AllGather,
    ReduceScatter,
}

impl Collective {
    pub const ALL: [Collective; 4] = [
        Collective::Broadcast,
        Collective::AllReduce,
        Collective::AllGather,
        Collective::ReduceScatter,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Collective::Broadcast => "Broadcast",
            Collective::AllReduce => "All-Reduce",
            Collective::AllGather => "All-Gather",
            Collective::ReduceScatter => "Reduce-Scatter",
        }
    }
}

/// Fitted constants of Eqn. (26) for one collective.
/// c1: latency term (us per log2 p), c2: bandwidth term (us per float),
/// c3: constant overhead (us) — ~0 on Frontier, carried for completeness.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveModel {
    pub c1: f64,
    pub c2: f64,
    pub c3: f64,
}

impl CollectiveModel {
    /// Predicted time in SECONDS for message size `m` floats across `p` ranks.
    ///
    /// `p <= 1` is priced at exactly zero — a single rank has no peers to
    /// talk to. That makes this model WRONG as a ranking signal for
    /// single-rank configurations: any sweep comparing p = 1 against real
    /// parallel cells through this model would "discover" free
    /// communication and crown the degenerate config. Consumers that rank
    /// configurations must exclude p < 2 from the search space
    /// (`perfmodel::Workload::validate` and the planner both do) and price
    /// a dense single-device baseline separately if they need one.
    pub fn time(&self, m: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0; // no communication without peers
        }
        let us = self.c1 * (p as f64).log2() + self.c2 * m as f64 + self.c3;
        us * 1e-6
    }
}

/// A full network profile: one model per collective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    pub broadcast: CollectiveModel,
    pub all_reduce: CollectiveModel,
    pub all_gather: CollectiveModel,
    pub reduce_scatter: CollectiveModel,
}

impl NetworkProfile {
    /// The paper's Table III: Frontier, RCCL, message sizes 2^2..2^26 floats,
    /// p in 2..256. c3 ~ 0 for all collectives (paper ignores it).
    pub fn frontier() -> NetworkProfile {
        NetworkProfile {
            broadcast: CollectiveModel { c1: 35.5, c2: 1.12e-3, c3: 0.0 },
            all_reduce: CollectiveModel { c1: 33.4, c2: 2.56e-3, c3: 0.0 },
            all_gather: CollectiveModel { c1: 149.94, c2: 2.07e-3, c3: 0.0 },
            reduce_scatter: CollectiveModel { c1: 145.52, c2: 2.40e-3, c3: 0.0 },
        }
    }

    /// An idealized zero-cost network (for ablations / communication-free
    /// energy estimates, Fig. 7a).
    pub fn zero() -> NetworkProfile {
        let z = CollectiveModel { c1: 0.0, c2: 0.0, c3: 0.0 };
        NetworkProfile { broadcast: z, all_reduce: z, all_gather: z, reduce_scatter: z }
    }

    pub fn model(&self, c: Collective) -> &CollectiveModel {
        match c {
            Collective::Broadcast => &self.broadcast,
            Collective::AllReduce => &self.all_reduce,
            Collective::AllGather => &self.all_gather,
            Collective::ReduceScatter => &self.reduce_scatter,
        }
    }

    /// Predicted collective time in seconds.
    pub fn time(&self, c: Collective, msg_floats: usize, p: usize) -> f64 {
        self.model(c).time(msg_floats, p)
    }
}

/// One timing observation for the fit.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub msg_floats: usize,
    pub p: usize,
    pub time_us: f64,
}

/// Result of fitting Eqn. (26) to observations.
#[derive(Debug, Clone, Copy)]
pub struct FitResult {
    pub model: CollectiveModel,
    /// RMSE in log2(us), the metric Table III reports.
    pub rmse_log2_us: f64,
}

/// Least-squares fit of comm_time(m, p) = c1 log2(p) + c2 m + c3.
///
/// The fit is RELATIVE-error weighted (each row scaled by 1/observed):
/// collective timings span five orders of magnitude with multiplicative
/// noise, so an unweighted linear fit lets the huge-message rows drown the
/// latency term c1. Residuals are reported in log2(microseconds), the
/// paper's Table III metric.
pub fn fit(observations: &[Observation]) -> Option<FitResult> {
    let rows = observations.len();
    if rows < 3 {
        return None;
    }
    // Iteratively reweighted least squares: round 0 weights by 1/observed,
    // later rounds by 1/predicted. Weighting by the observation correlates
    // the weight with the noise (low-noise rows get inflated weight, biasing
    // the bandwidth term down); reweighting by the model's own prediction
    // removes that correlation.
    let mut weights: Vec<f64> = observations.iter().map(|o| 1.0 / o.time_us.max(1e-9)).collect();
    let mut model = CollectiveModel { c1: 0.0, c2: 0.0, c3: 0.0 };
    for _round in 0..3 {
        let mut x = Vec::with_capacity(rows * 3);
        let mut y = Vec::with_capacity(rows);
        for (o, &w) in observations.iter().zip(&weights) {
            x.extend_from_slice(&[
                (o.p as f64).log2() * w,
                o.msg_floats as f64 * w,
                w,
            ]);
            y.push(o.time_us * w);
        }
        let beta = stats::least_squares(&x, 3, &y)?;
        model = CollectiveModel { c1: beta[0], c2: beta[1], c3: beta[2] };
        for (o, w) in observations.iter().zip(weights.iter_mut()) {
            *w = 1.0 / (model.time(o.msg_floats, o.p) * 1e6).max(1e-9);
        }
    }

    let pred_log: Vec<f64> = observations
        .iter()
        .map(|o| (model.time(o.msg_floats, o.p) * 1e6).max(1e-9).log2())
        .collect();
    let obs_log: Vec<f64> = observations.iter().map(|o| o.time_us.max(1e-9).log2()).collect();
    Some(FitResult { model, rmse_log2_us: stats::rmse(&pred_log, &obs_log) })
}

/// Generate synthetic observations from a ground-truth model with
/// multiplicative log-normal noise — the substitute for re-running the
/// paper's microbenchmark campaign on Frontier (see DESIGN.md §2). Sweeps
/// the paper's grid: m = 2^2..2^26 floats, p = 2..256.
pub fn synthesize_observations(
    truth: &CollectiveModel,
    noise_sigma: f64,
    rng: &mut crate::util::prng::Prng,
) -> Vec<Observation> {
    let mut out = Vec::new();
    let mut p = 2usize;
    while p <= 256 {
        for logm in 2..=26 {
            let m = 1usize << logm;
            let t = truth.time(m, p) * 1e6; // us
            let noisy = t * (rng.normal() * noise_sigma).exp();
            out.push(Observation { msg_floats: m, p, time_us: noisy });
        }
        p *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn frontier_constants_match_table3() {
        let f = NetworkProfile::frontier();
        assert_eq!(f.all_gather.c1, 149.94);
        assert_eq!(f.reduce_scatter.c2, 2.40e-3);
        assert_eq!(f.broadcast.c1, 35.5);
        assert_eq!(f.all_reduce.c2, 2.56e-3);
    }

    #[test]
    fn time_scales_with_p_and_m() {
        let f = NetworkProfile::frontier();
        let small = f.time(Collective::AllGather, 64, 8);
        let wider = f.time(Collective::AllGather, 64, 64);
        let bigger = f.time(Collective::AllGather, 1 << 20, 8);
        assert!(wider > small, "latency term should grow with p");
        assert!(bigger > small, "bandwidth term should grow with m");
        // 64-float All-Gather at p=8: ~ 150*3 us latency-dominated
        assert!((small - 449.95e-6).abs() < 1e-6);
    }

    #[test]
    fn p1_is_free() {
        let f = NetworkProfile::frontier();
        assert_eq!(f.time(Collective::AllReduce, 1 << 20, 1), 0.0);
    }

    #[test]
    fn pp_beats_tp_communication_per_iteration() {
        // Paper Eqn. (9): k < n/p implies beta_pi < beta_tau. Check with the
        // paper's own Table II message sizes and Table III constants.
        let f = NetworkProfile::frontier();
        let (n, p, k, batch) = (16_384usize, 32usize, 4usize, 32usize);
        let tp = f.time(Collective::Broadcast, n * batch, p)
            + f.time(Collective::AllGather, n / p * batch, p)
            + f.time(Collective::AllReduce, n * batch, p)
            + f.time(Collective::ReduceScatter, n / p * batch, p);
        let pp = f.time(Collective::AllGather, k * batch, p)
            + f.time(Collective::ReduceScatter, k * batch, p);
        assert!(pp < tp, "pp={pp} tp={tp}");
    }

    #[test]
    fn fit_recovers_truth_noiseless() {
        let truth = CollectiveModel { c1: 100.0, c2: 2.5e-3, c3: 1.0 };
        let mut rng = Prng::new(1);
        let obs = synthesize_observations(&truth, 0.0, &mut rng);
        let fitres = fit(&obs).unwrap();
        assert!((fitres.model.c1 - truth.c1).abs() < 1e-6);
        assert!((fitres.model.c2 - truth.c2).abs() < 1e-9);
        assert!((fitres.model.c3 - truth.c3).abs() < 1e-4);
        assert!(fitres.rmse_log2_us < 1e-6);
    }

    #[test]
    fn fit_recovers_truth_with_noise() {
        let truth = CollectiveModel { c1: 145.52, c2: 2.40e-3, c3: 0.0 };
        let mut rng = Prng::new(2);
        let obs = synthesize_observations(&truth, 0.3, &mut rng);
        let fitres = fit(&obs).unwrap();
        // Bandwidth term is identified by the huge-message rows; should be
        // within ~15% despite noise.
        assert!(
            (fitres.model.c2 - truth.c2).abs() / truth.c2 < 0.15,
            "c2={} vs {}",
            fitres.model.c2,
            truth.c2
        );
        assert!(fitres.rmse_log2_us > 0.0);
    }

    #[test]
    fn fit_needs_enough_rows() {
        assert!(fit(&[]).is_none());
        let o = Observation { msg_floats: 4, p: 2, time_us: 1.0 };
        assert!(fit(&[o, o]).is_none());
    }
}
