//! Experiment harness: one module per table/figure of the paper's
//! evaluation (Sec. VI + Appendix). Each experiment returns `Tables`
//! (markdown/CSV-renderable) plus a raw JSON blob written to results/.
//!
//! Index (DESIGN.md §5):
//!   fig5a  — comm time/epoch, TP vs PP, n=65,536 L=6 k=64      [modeled]
//!   fig5b  — total time/epoch, n=4,096  L=2                     [modeled]
//!   fig5c  — total time/epoch, n=16,384 L=2                     [modeled]
//!   fig6   — time/epoch at n=131,072 / 262,144 (flip-flop, OOM) [modeled]
//!   fig7a  — comm-free energy estimate to fixed loss            [measured]
//!   fig7b  — measured energy to fixed loss                      [measured]
//!   fig7c  — wall time to fixed loss                            [measured]
//!   table1 — the full Table I at measured scale                 [measured]
//!   table3 — collective model fit (Appendix Table III)          [synthetic]
//!
//! "measured" experiments train real models through the configured backend
//! (native fused kernels by default; PJRT with `--backend xla`) on the
//! simulated cluster at reduced width (n=1,024; see DESIGN.md §2
//! substitutions); "modeled" experiments use the calibrated analytic
//! perfmodel at the paper's own scales.

pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table3;

use anyhow::Result;

use crate::runtime::ExecServer;
use crate::util::json::Json;
use crate::util::table::Table;

/// The result of one experiment.
pub struct ExperimentResult {
    pub id: &'static str,
    pub tables: Vec<Table>,
    pub raw: Json,
}

impl ExperimentResult {
    pub fn render_markdown(&self) -> String {
        let mut out = format!("## Experiment {}\n\n", self.id);
        for t in &self.tables {
            out.push_str(&t.markdown());
            out.push('\n');
        }
        out
    }
}

/// Experiment ids in run order.
pub const ALL: &[&str] = &[
    "fig5a", "fig5b", "fig5c", "fig6", "fig7a", "fig7b", "fig7c", "table1", "table3",
];

/// Run one experiment by id. `server` is only used by the measured ones;
/// passing None degrades those to an error message.
pub fn run(id: &str, server: Option<&ExecServer>) -> Result<ExperimentResult> {
    match id {
        "fig5a" => fig5::fig5a(),
        "fig5b" => fig5::fig5b(),
        "fig5c" => fig5::fig5c(),
        "fig6" => fig6::fig6(),
        "fig7a" | "fig7b" | "fig7c" | "table1" => {
            let server = server.ok_or_else(|| {
                anyhow::anyhow!("experiment {id} needs artifacts (run `make artifacts`)")
            })?;
            let sweep = fig7::convergence_sweep(server)?;
            match id {
                "fig7a" => fig7::fig7a(&sweep),
                "fig7b" => fig7::fig7b(&sweep),
                "fig7c" => fig7::fig7c(&sweep),
                _ => fig7::table1(&sweep),
            }
        }
        "table3" => table3::table3(),
        _ => anyhow::bail!("unknown experiment '{id}' (have: {})", ALL.join(", ")),
    }
}
