//! Fig. 5: parallel execution performance for a fixed number of epochs.
//!
//! (a) communication time per epoch, TP vs PP — n=65,536, L=6, k=64,
//!     p in {32, 64, 128}. The paper shows PP communication far below TP.
//! (b) total execution time per epoch, small model — n=4,096, L=2,
//!     p in {8..256}; the paper shows PP ahead but CONVERGING to TP as p
//!     grows (latency-bound regime).
//! (c) same at n=16,384: PP regains a clear advantage.
//!
//! All three are modeled at the paper's scales with the calibrated
//! perfmodel + the paper's own Table III collective constants.

use anyhow::Result;

use super::ExperimentResult;
use crate::config::Parallelism::{Phantom, Tensor};
use crate::perfmodel::{predict, GemmModel, Workload};
use crate::simnet::NetworkProfile;
use crate::util::json::Json;
use crate::util::table::{fmt_secs, Table};

/// Paper's per-p phantom widths for the small-model sweeps (Fig. 5b labels
/// k=16..3; Fig. 5c labels k=16..4).
fn k_for(p: usize, n: usize) -> usize {
    let m = n / p;
    // k shrinks with p, floored at 3-4 as in the paper's labels
    (m / 64).clamp(if n >= 16_384 { 4 } else { 3 }, 64)
}

pub fn fig5a() -> Result<ExperimentResult> {
    let net = NetworkProfile::frontier();
    let mut table = Table::new(
        "Fig 5a — Communication time per iteration (n=65,536, L=6, k=64) [modeled]",
        &["p", "TP comm", "PP comm", "TP/PP ratio"],
    );
    let mut rows = Vec::new();
    for p in [32usize, 64, 128] {
        let w = Workload { n: 65_536, layers: 6, p, k: 64, batch: 32 };
        let tp = crate::perfmodel::tp_comm_s(&w, &net);
        let pp = crate::perfmodel::pp_comm_s(&w, &net);
        table.row(vec![
            p.to_string(),
            fmt_secs(tp),
            fmt_secs(pp),
            format!("{:.1}x", tp / pp),
        ]);
        rows.push(Json::obj(vec![
            ("p", Json::int(p as i64)),
            ("tp_comm_s", Json::num(tp)),
            ("pp_comm_s", Json::num(pp)),
        ]));
    }
    Ok(ExperimentResult { id: "fig5a", tables: vec![table], raw: Json::arr(rows) })
}

fn total_time_sweep(id: &'static str, n: usize, title: &str) -> Result<ExperimentResult> {
    let net = NetworkProfile::frontier();
    let g = GemmModel::frontier();
    let mut table = Table::new(title, &["p", "k (PP)", "TP total", "PP total", "winner"]);
    let mut rows = Vec::new();
    for p in [8usize, 16, 32, 64, 128, 256] {
        let k = k_for(p, n);
        let w = Workload { n, layers: 2, p, k, batch: 32 };
        let tp = predict(Tensor, &w, &g, &net)?.total_s();
        let pp = predict(Phantom, &w, &g, &net)?.total_s();
        table.row(vec![
            p.to_string(),
            k.to_string(),
            fmt_secs(tp),
            fmt_secs(pp),
            if pp < tp { "PP" } else { "TP" }.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("p", Json::int(p as i64)),
            ("k", Json::int(k as i64)),
            ("tp_s", Json::num(tp)),
            ("pp_s", Json::num(pp)),
        ]));
    }
    Ok(ExperimentResult { id, tables: vec![table], raw: Json::arr(rows) })
}

pub fn fig5b() -> Result<ExperimentResult> {
    total_time_sweep(
        "fig5b",
        4_096,
        "Fig 5b — Total time per iteration (n=4,096, L=2) [modeled]",
    )
}

pub fn fig5c() -> Result<ExperimentResult> {
    total_time_sweep(
        "fig5c",
        16_384,
        "Fig 5c — Total time per iteration (n=16,384, L=2) [modeled]",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5a_pp_comm_below_tp_everywhere() {
        let r = fig5a().unwrap();
        for row in r.raw.as_arr().unwrap() {
            let tp = row.get("tp_comm_s").as_f64().unwrap();
            let pp = row.get("pp_comm_s").as_f64().unwrap();
            assert!(pp < tp, "{row:?}");
            assert!(tp / pp > 3.0, "paper shows a wide gap: {row:?}");
        }
    }

    #[test]
    fn fig5b_pp_wins_small_p_and_converges() {
        // PP ahead at p=8; the advantage shrinks as p grows (paper: "the
        // relative performance tends to converge" for the small model; in
        // our model the quadratic peer term eventually flips it).
        let r = fig5b().unwrap();
        let rows = r.raw.as_arr().unwrap();
        let gap = |row: &Json| {
            row.get("tp_s").as_f64().unwrap() / row.get("pp_s").as_f64().unwrap()
        };
        assert!(gap(&rows[0]) > 1.0, "PP should win at p=8: gap {}", gap(&rows[0]));
        assert!(
            gap(&rows[rows.len() - 1]) < gap(&rows[0]),
            "gap should shrink with p: first {} last {}",
            gap(&rows[0]),
            gap(&rows[rows.len() - 1])
        );
    }

    #[test]
    fn fig5c_pp_wins_at_moderate_p() {
        // Paper Fig 5c: PP regains its advantage at n=16,384. Our model
        // reproduces the PP win through p=64 (the quadratic peer term takes
        // over beyond that; the paper's plot shows rough parity there).
        let r = fig5c().unwrap();
        for row in r.raw.as_arr().unwrap() {
            let p = row.get("p").as_usize().unwrap();
            if p <= 64 {
                let tp = row.get("tp_s").as_f64().unwrap();
                let pp = row.get("pp_s").as_f64().unwrap();
                assert!(pp < tp, "PP should win at p={p}");
            }
        }
    }

    #[test]
    fn k_respects_eqn8() {
        for n in [4_096usize, 16_384] {
            for p in [8usize, 64, 256] {
                let k = k_for(p, n);
                assert!(k < n / p, "n={n} p={p} k={k}");
            }
        }
    }
}
