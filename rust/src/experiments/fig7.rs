//! Fig. 7 + Table I: energy / time to a FIXED loss (measured).
//!
//! The paper's protocol (Sec. VI-B): train the TP baseline to a loss
//! lambda, then train PP models with various (p, k) to the SAME lambda,
//! recording iterations, energy/iteration, and totals. The paper runs
//! n = 16,384 on 8..256 GPUs; our measured reproduction runs n = 1,024 on
//! 2..8 simulated ranks (artifact set `small*`).
//!
//! Reproduction note (EXPERIMENTS.md §Departures): at this reduced scale
//! and with matched hyperparameters, our PP models need MORE iterations to
//! reach lambda than TP (the dense TP model is the teacher's own
//! architecture and is better-conditioned); the paper reports the
//! opposite at n = 16,384 on Frontier. The per-iteration claims (Eqn. 10:
//! smaller model, less communication, less energy/iteration) reproduce in
//! both the measured and modeled paths; see the fixed-budget table emitted
//! alongside Table I, which isolates them from the convergence question.

use anyhow::Result;

use super::ExperimentResult;
use crate::config::{preset, Parallelism, RunConfig};
use crate::coordinator::{self, TrainReport};
use crate::runtime::ExecServer;
use crate::util::json::Json;
use crate::util::table::{fmt_joules, fmt_params, fmt_secs, Table};

/// One cell of the sweep.
pub struct SweepRow {
    pub label: String,
    pub report: TrainReport,
}

/// The shared measured sweep: one TP probe fixes lambda; every row trains
/// to that lambda.
pub struct ConvergenceSweep {
    pub target_loss: f64,
    pub rows: Vec<SweepRow>,
}

/// Iteration cap for sweep rows (a row that cannot reach lambda within the
/// cap is reported with reached_target = false).
const CAP: usize = 400;
/// Probe length that defines lambda.
const PROBE_ITERS: usize = 60;
/// Margin above the probe's final loss (absorbs per-batch loss noise).
const LAMBDA_MARGIN: f64 = 1.05;

fn sweep_config(artifact: &str, mode: Parallelism, target: Option<f64>) -> Result<RunConfig> {
    let mut cfg = preset(artifact, mode)?;
    cfg.train.max_iters = if target.is_some() { CAP } else { PROBE_ITERS };
    cfg.train.target_loss = target;
    Ok(cfg)
}

/// Run the full measured sweep (used by fig7a/b/c and table1; the CLI and
/// benches run it once and reuse it).
pub fn convergence_sweep(server: &ExecServer) -> Result<ConvergenceSweep> {
    // 1. lambda from a TP probe at p=8.
    let probe = sweep_config("small", Parallelism::Tensor, None)?;
    let probe_report = coordinator::train(&probe, server)?;
    let lambda = probe_report.losses.last().copied().unwrap() * LAMBDA_MARGIN;

    // 2. The sweep grid: TP at p in {2,4,8}; PP at p in {2,4,8} with k=16
    //    plus the k sweep at p=8 (paper Table I varies k with p).
    let grid: &[(&str, Parallelism, &str)] = &[
        ("small_p2", Parallelism::Tensor, "TP p=2"),
        ("small_p4", Parallelism::Tensor, "TP p=4"),
        ("small", Parallelism::Tensor, "TP p=8"),
        ("small_p2", Parallelism::Phantom, "PP p=2 k=16"),
        ("small_p4", Parallelism::Phantom, "PP p=4 k=16"),
        ("small", Parallelism::Phantom, "PP p=8 k=16"),
        ("small_k4", Parallelism::Phantom, "PP p=8 k=4"),
        ("small_k8", Parallelism::Phantom, "PP p=8 k=8"),
        ("small_k32", Parallelism::Phantom, "PP p=8 k=32"),
    ];
    let mut rows = Vec::new();
    for (artifact, mode, label) in grid {
        let mut cfg = sweep_config(artifact, *mode, Some(lambda))?;
        if *mode == Parallelism::Phantom {
            // k comes from the artifact geometry
            cfg.model.k = server.manifest.config(artifact)?.k;
        }
        let report = coordinator::train(&cfg, server)?;
        rows.push(SweepRow { label: label.to_string(), report });
    }
    Ok(ConvergenceSweep { target_loss: lambda, rows })
}

fn raw_row(r: &SweepRow) -> Json {
    Json::obj(vec![
        ("label", Json::str(r.label.clone())),
        ("mode", Json::str(r.report.mode.name())),
        ("p", Json::int(r.report.p as i64)),
        ("k", Json::int(r.report.k as i64)),
        ("model_params", Json::int(r.report.model_params as i64)),
        ("iterations", Json::int(r.report.iterations as i64)),
        ("reached_target", Json::Bool(r.report.reached_target)),
        ("energy_train_j", Json::num(r.report.energy_train_j)),
        ("energy_per_iter_j", Json::num(r.report.energy_per_iter_j())),
        ("wall_train_s", Json::num(r.report.wall_train_s)),
    ])
}

/// Fig 7a: communication-free energy ESTIMATE — model size x iterations
/// (the paper's proxy: "the product of the iteration count ... and the
/// model size is expected to scale with the net energy").
pub fn fig7a(sweep: &ConvergenceSweep) -> Result<ExperimentResult> {
    let mut table = Table::new(
        &format!(
            "Fig 7a — Comm-free energy estimate to loss {:.5} (params x iters) [measured]",
            sweep.target_loss
        ),
        &["run", "model size", "iters", "estimate (param-iters)", "reached"],
    );
    let mut raw = Vec::new();
    for r in &sweep.rows {
        let est = r.report.model_params as f64 * r.report.iterations as f64;
        table.row(vec![
            r.label.clone(),
            fmt_params(r.report.model_params),
            r.report.iterations.to_string(),
            format!("{est:.3e}"),
            r.report.reached_target.to_string(),
        ]);
        raw.push(raw_row(r));
    }
    Ok(ExperimentResult { id: "fig7a", tables: vec![table], raw: Json::arr(raw) })
}

/// Fig 7b: measured energy to the fixed loss.
pub fn fig7b(sweep: &ConvergenceSweep) -> Result<ExperimentResult> {
    let mut table = Table::new(
        &format!(
            "Fig 7b — Measured energy to loss {:.5} [measured, virtual-time ledger]",
            sweep.target_loss
        ),
        &["run", "energy/iter", "iters", "total energy", "reached"],
    );
    let mut raw = Vec::new();
    for r in &sweep.rows {
        table.row(vec![
            r.label.clone(),
            fmt_joules(r.report.energy_per_iter_j()),
            r.report.iterations.to_string(),
            fmt_joules(r.report.energy_train_j),
            r.report.reached_target.to_string(),
        ]);
        raw.push(raw_row(r));
    }
    Ok(ExperimentResult { id: "fig7b", tables: vec![table], raw: Json::arr(raw) })
}

/// Fig 7c: wall time to the fixed loss.
pub fn fig7c(sweep: &ConvergenceSweep) -> Result<ExperimentResult> {
    let mut table = Table::new(
        &format!("Fig 7c — Wall time to loss {:.5} [measured, virtual time]", sweep.target_loss),
        &["run", "wall time", "iters", "reached"],
    );
    let mut raw = Vec::new();
    for r in &sweep.rows {
        table.row(vec![
            r.label.clone(),
            fmt_secs(r.report.wall_train_s),
            r.report.iterations.to_string(),
            r.report.reached_target.to_string(),
        ]);
        raw.push(raw_row(r));
    }
    Ok(ExperimentResult { id: "fig7c", tables: vec![table], raw: Json::arr(raw) })
}

/// Table I at measured scale: the full comparison table.
pub fn table1(sweep: &ConvergenceSweep) -> Result<ExperimentResult> {
    let mut table = Table::new(
        &format!(
            "Table I — TP vs PP to fixed loss {:.5} (n=1,024, L=2) [measured]",
            sweep.target_loss
        ),
        &["run", "model size", "energy/iter", "iters", "total energy", "wall time"],
    );
    let mut raw = Vec::new();
    for r in &sweep.rows {
        table.row(vec![
            r.label.clone(),
            fmt_params(r.report.model_params),
            fmt_joules(r.report.energy_per_iter_j()),
            r.report.iterations.to_string(),
            fmt_joules(r.report.energy_train_j),
            fmt_secs(r.report.wall_train_s),
        ]);
        raw.push(raw_row(r));
    }

    // Fixed-iteration-budget comparison: isolates the per-iteration energy
    // claim (Eqn. 10) from convergence-speed differences by charging both
    // modes for the same 150 iterations.
    let mut fixed = Table::new(
        "Fixed 150-iteration budget — per-iteration energy isolation",
        &["run", "energy/iter", "comm s/iter (cluster)", "floats/iter (cluster)"],
    );
    for r in &sweep.rows {
        let iters = r.report.iterations.max(1) as f64;
        let comm: f64 =
            r.report.per_rank.iter().map(|x| x.stats.comm_s).sum::<f64>() / iters;
        let floats: f64 =
            r.report.per_rank.iter().map(|x| x.stats.floats_moved as f64).sum::<f64>() / iters;
        fixed.row(vec![
            r.label.clone(),
            fmt_joules(r.report.energy_per_iter_j()),
            fmt_secs(comm),
            format!("{floats:.0}"),
        ]);
    }

    // Headline ratios (the paper's ~50% claim at its largest p; ours at p=8).
    let find = |label: &str| sweep.rows.iter().find(|r| r.label == label);
    let mut summary = Table::new(
        "Table I headline — PP/TP total-energy ratio at matched p",
        &["p", "TP total", "PP total", "PP/TP"],
    );
    for (tp_l, pp_l, p) in [
        ("TP p=2", "PP p=2 k=16", 2),
        ("TP p=4", "PP p=4 k=16", 4),
        ("TP p=8", "PP p=8 k=16", 8),
    ] {
        if let (Some(tp), Some(pp)) = (find(tp_l), find(pp_l)) {
            summary.row(vec![
                p.to_string(),
                fmt_joules(tp.report.energy_train_j),
                fmt_joules(pp.report.energy_train_j),
                format!("{:.2}", pp.report.energy_train_j / tp.report.energy_train_j),
            ]);
        }
    }
    Ok(ExperimentResult {
        id: "table1",
        tables: vec![table, fixed, summary],
        raw: Json::arr(raw),
    })
}
