//! Fig. 6: TP vs PP at the paper's largest model sizes (modeled).
//!
//! n = 131,072: PP wins up to p = 128; TP overtakes at p = 256 (the
//! "flip-flop" the paper traces to small-GEMM inefficiency + p-proportional
//! gradient-aggregation management).
//! n = 262,144: PP wins everywhere tested; TP cannot even run at p = 32
//! (64 GB GCD memory exhausted), while PP fits.

use anyhow::Result;

use super::ExperimentResult;
use crate::config::Parallelism::{Phantom, Tensor};
use crate::perfmodel::{fits_memory, predict, GemmModel, Workload};
use crate::simnet::NetworkProfile;
use crate::util::json::Json;
use crate::util::table::{fmt_secs, Table};

pub fn fig6() -> Result<ExperimentResult> {
    let net = NetworkProfile::frontier();
    let g = GemmModel::frontier();
    let mut tables = Vec::new();
    let mut raw = Vec::new();
    for n in [131_072usize, 262_144] {
        let mut table = Table::new(
            &format!("Fig 6 — Time per iteration, n={n}, L=2, k=64 [modeled]"),
            &["p", "TP total", "PP total", "winner"],
        );
        for p in [32usize, 64, 128, 256] {
            let w = Workload { n, layers: 2, p, k: 64, batch: 32 };
            let tp_fits = fits_memory(Tensor, &w);
            let pp_fits = fits_memory(Phantom, &w);
            assert!(pp_fits, "PP must fit everywhere in Fig 6");
            let pp = predict(Phantom, &w, &g, &net)?.total_s();
            let (tp_cell, winner, tp_json) = if tp_fits {
                let tp = predict(Tensor, &w, &g, &net)?.total_s();
                (
                    fmt_secs(tp),
                    if pp < tp { "PP" } else { "TP" },
                    Json::num(tp),
                )
            } else {
                ("OOM".to_string(), "PP", Json::Null)
            };
            table.row(vec![p.to_string(), tp_cell, fmt_secs(pp), winner.to_string()]);
            raw.push(Json::obj(vec![
                ("n", Json::int(n as i64)),
                ("p", Json::int(p as i64)),
                ("tp_s", tp_json),
                ("pp_s", Json::num(pp)),
                ("tp_oom", Json::Bool(!tp_fits)),
            ]));
        }
        tables.push(table);
    }
    Ok(ExperimentResult { id: "fig6", tables, raw: Json::arr(raw) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_matches_paper_structure() {
        let r = fig6().unwrap();
        for row in r.raw.as_arr().unwrap() {
            let n = row.get("n").as_usize().unwrap();
            let p = row.get("p").as_usize().unwrap();
            let oom = row.get("tp_oom").as_bool().unwrap();
            if n == 262_144 && p == 32 {
                assert!(oom, "paper: TP OOMs at n=262144, p=32");
                continue;
            }
            assert!(!oom, "only (262144, 32) should OOM: n={n} p={p}");
            let tp = row.get("tp_s").as_f64().unwrap();
            let pp = row.get("pp_s").as_f64().unwrap();
            let pp_should_win = !(n == 131_072 && p == 256);
            if pp_should_win {
                assert!(pp < tp, "n={n} p={p}: PP should win (pp={pp} tp={tp})");
            } else {
                assert!(tp < pp, "n={n} p={p}: TP should win — the flip-flop");
            }
        }
    }
}
