//! Table III: the unified collective communication model fit (Appendix).
//!
//! The paper fits comm_time(m, p) = c1 log2 p + c2 m + c3 per collective
//! from microbenchmarks on Frontier (m = 2^2..2^26 floats, p = 2..256).
//! Our substitute (DESIGN.md §2): synthesize the same measurement grid from
//! the paper's ground-truth constants plus log-normal noise matched to the
//! paper's reported residuals (RMSE ~ 3-4 in log2 microseconds), run the
//! same least-squares fit, and show the recovered constants side by side.

use anyhow::Result;

use super::ExperimentResult;
use crate::simnet::{fit, synthesize_observations, Collective, NetworkProfile};
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::table::Table;

pub fn table3() -> Result<ExperimentResult> {
    let truth = NetworkProfile::frontier();
    let mut rng = Prng::new(0x7AB7E3);
    let mut table = Table::new(
        "Table III — Collective model fit: paper constants vs refit on synthetic grid",
        &[
            "collective",
            "c1 paper",
            "c1 refit",
            "c2 paper",
            "c2 refit",
            "RMSE log2(us)",
        ],
    );
    let mut raw = Vec::new();
    // Multiplicative noise on the synthetic grid. The paper's residuals
    // (RMSE 2.6-3.9 log2 us) include real-fabric congestion effects our
    // clean synthetic grid does not model; 0.5 gives a visible but
    // recoverable scatter (log2-RMSE ~ 0.7).
    let noise = 0.35;
    for c in Collective::ALL {
        let truth_model = truth.model(c);
        let obs = synthesize_observations(truth_model, noise, &mut rng);
        let fitres = fit(&obs).ok_or_else(|| anyhow::anyhow!("fit failed"))?;
        table.row(vec![
            c.name().to_string(),
            format!("{:.2}", truth_model.c1),
            format!("{:.2}", fitres.model.c1),
            format!("{:.2e}", truth_model.c2),
            format!("{:.2e}", fitres.model.c2),
            format!("{:.2}", fitres.rmse_log2_us),
        ]);
        raw.push(Json::obj(vec![
            ("collective", Json::str(c.name())),
            ("c1_paper", Json::num(truth_model.c1)),
            ("c1_refit", Json::num(fitres.model.c1)),
            ("c2_paper", Json::num(truth_model.c2)),
            ("c2_refit", Json::num(fitres.model.c2)),
            ("rmse_log2_us", Json::num(fitres.rmse_log2_us)),
        ]));
    }
    Ok(ExperimentResult { id: "table3", tables: vec![table], raw: Json::arr(raw) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refit_recovers_paper_constants() {
        let r = table3().unwrap();
        for row in r.raw.as_arr().unwrap() {
            let c1p = row.get("c1_paper").as_f64().unwrap();
            let c1r = row.get("c1_refit").as_f64().unwrap();
            let c2p = row.get("c2_paper").as_f64().unwrap();
            let c2r = row.get("c2_refit").as_f64().unwrap();
            assert!(
                (c1r - c1p).abs() / c1p < 0.5,
                "c1 recovery off: {row:?}"
            );
            assert!(
                (c2r - c2p).abs() / c2p < 0.2,
                "c2 (bandwidth) recovery off: {row:?}"
            );
        }
    }
}
