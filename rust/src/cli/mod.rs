//! Hand-rolled CLI: flag parsing + subcommand dispatch for the `phantom`
//! launcher (the offline crate set has no clap).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: positionals + `--key value` / `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (excluding argv[0]). `--key value` and
    /// `--key=value` both work; a `--key` followed by another option or
    /// nothing is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {s}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Reject unknown options (typo guard). `known` lists valid option and
    /// flag names.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (valid: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
phantom — phantom-parallelism training system (Seal et al., 2025 reproduction)

USAGE:
    phantom <command> [options]

COMMANDS:
    train        Train an FFN on the simulated cluster (measured mode)
                   --preset <name>        artifact preset (tiny|quickstart|small|...)
                   --mode <tp|pp>         parallelism strategy    [pp]
                   --dp <N>               data-parallel replicas  [1]
                                          (hybrid DP x TP|PP: runs p*N ranks,
                                          shards the batch by replica, adds one
                                          DP gradient all-reduce per iteration,
                                          accounted as its own energy bucket)
                   --micro <M>            micro-batches per iteration (PP) [1]
                   --schedule <sync|1f1b> micro-batch schedule (PP) [sync]
                                          (1f1b interleaves fwd/bwd and hides
                                          boundary-collective wire time behind
                                          the next chunk's compute)
                   --sharded              ZeRO-1: shard optimizer state across
                                          the DP group (reduce-scatter grads,
                                          step the owned slice, all-gather);
                                          bit-identical losses, ~1/dp per-rank
                                          optimizer-state floats
                   --backend <native|xla> compute backend         [native]
                                          (native = pure-Rust fused kernels,
                                           no artifacts needed; xla = PJRT
                                           over AOT artifacts, needs the
                                           `xla` cargo feature)
                   --iters <N>            iteration cap           [preset default]
                   --target-loss <x>      stop at this loss
                   --lr <x>               SGD learning rate       [0.05]
                   --optimizer <sgd|momentum|adam>
                   --seed <n>             data/init seed
                   --out <file.json>      write the full report as JSON
                   --ckpt-every <N>       snapshot every N iterations
                   --ckpt-dir <dir>       where ckpt-NNNNNN snapshots go
                                          (both --ckpt-* flags go together)
                   --resume <dir>         continue from a snapshot directory
                                          (bit-identical loss trajectory; the
                                          snapshot fixes preset/mode/schedule/
                                          sharding/optimizer, only --iters/
                                          --target-loss/--ckpt-* may be
                                          combined)
    pipeline     Schedule/sharding bench: sync vs 1f1b, flat vs ZeRO-sharded
                   --preset <name>        artifact preset          [tiny]
                   --iters <N>            iterations per arm       [8]
                   --micro <M>            micro-batches per iteration
                                          [min(batch, 4)]
                   --dp <N>               replicas for the sharded arm [2]
                   --seed <n>             data/init seed
                   --out <file.json>      bench records [BENCH_pipeline.json]
                                          (J/step, bubble fraction, opt-state
                                          floats per arm; verdicts
                                          bubble_reduced, schedule_bitwise,
                                          sharded_bitwise)
    experiment   Regenerate a paper table/figure
                   <id|all>               fig5a fig5b fig5c fig6 fig7a fig7b
                                          fig7c table1 table3
                   --backend <native|xla> backend for measured runs [native]
                   --out-dir <dir>        write markdown+json per experiment
    serve        Persistent serving with dynamic batching (load harness)
                   --preset <name>        artifact preset          [small]
                   --mode <tp|pp|both>    pipeline(s) to serve     [both]
                   --backend <native|xla> compute backend          [native]
                   --queries <N>          arrival-stream length    [512]
                   --rate <qps>           mean arrival rate (virtual) [2000]
                   --max-batch <B>        micro-batcher cap        [preset batch]
                   --linger-ms <x>        batcher linger deadline  [2.0]
                   --queue-depth <D>      admission queue bound    [4*max-batch]
                   --open-loop            shed on a full queue instead of
                                          blocking the arrival stream
                   --seed <n>             arrival/payload seed
                   --out <file.json>      perf-trajectory records  [BENCH_serve.json]
    fleet        DP replica fleet: energy-routed serving under bursty load
                   --preset <name>        artifact preset          [quickstart]
                   --mode <tp|pp>         pipeline to serve        [pp]
                   --backend <native|xla> compute backend          [native]
                   --replicas <list>      max replica counts to run [2,3]
                   --policy <list|all>    rr | least | energy      [all]
                   --queries <N>          arrival-trace length     [480]
                   --base-qps <x>         burst-model base rate    [2000]
                   --max-batch <B>        micro-batcher cap        [preset batch]
                   --linger-ms <x>        batcher linger deadline  [2.0]
                   --queue-depth <D>      per-replica queue bound  [max-batch]
                   --seed <n>             trace/payload seed
                   --out <file.json>      fleet records [BENCH_fleet.json]
                                          (per replica-count x policy rows:
                                          p50/p99 latency, shed rate, mean
                                          active replicas, J/1k-queries;
                                          verdicts fleet_misordered and
                                          energy_beats_rr)
    ckpt         Inspect, re-shard and verify checkpoint snapshots
                   inspect --dir <D>      manifest + shard summary
                   reshard --dir <D> --out <D2> [--p <P>] [--mode <tp|pp>]
                                          gather + re-slice to a new layout
                                          (TP<->PP, elastic p changes)
                   verify  --dir <D> [--against <D2>] [--batch <B>] [--seed <n>]
                           [--tol <x>]    integrity check + host-side forward;
                                          with --against, proves forward
                                          equivalence on a shared batch
    chaos        Deterministic fault-injection & conformance harness
                   --scenario <sweep|train|serve|all>   which drivers to run [all]
                   --configs <N>          differential-sweep size   [25]
                   --iters <N>            train iterations per case [3]
                   --seed <s>             sweep + fault-plan seed
                   --preset <name>        chaos scenario geometry   [tiny_p2]
                   --crash-rank <r>       rank killed by the chaos runs [1]
                   --crash-iter <i>       training iteration of the kill [3]
                   --out <file.json>      conformance records [BENCH_conformance.json]
                                          (sweep: distributed ≡ single-rank
                                          oracle ≡ naive math, TP ≡ PP across
                                          reshard; train: crash -> resume is
                                          bit-identical; serve: crash ->
                                          hot_swap recovery, zero drops)
    predict      One-shot analytic prediction (Frontier scale)
                   --n <n> --p <p> --k <k> [--layers 2] [--batch 32]
    plan         Energy-optimal configuration search (calibrated perfmodel)
                   --objective <train|serve>  minimize J/step or J/query [train]
                   --n <n> --layers <L>   model size             [256, 2]
                   --p <list>             model-parallel sizes   [2,4,8]
                   --dp <list>            DP replica counts (train) [1,2]
                   --k <list>             phantom widths (PP cells) [4,16]
                   --batch <list>         batch sizes            [16]
                   --linger-ms <list>     batcher lingers (serve) [0,2]
                   --slo-ms <x>           latency SLO filter (step or
                                          worst-case query latency)
                   --calib <file.json>    measured records to fit the model
                                          [ci/bench_seed/BENCH_calib.json];
                                          missing groups fall back to the
                                          Table III / Frontier constants
                                          with a logged warning
                   --iters <N>            validation train iters  [6]
                   --queries <N>          validation serve queries [96]
                   --no-validate          skip running best/worst for real
                   --out <file.json>      sweep + predictions + measurements
                                          + ranking verdict [BENCH_plan.json]
                   --write-calib          measure THIS machine's GEMM rates,
                                          stamp fabric comm/power rows, and
                                          write the calibration fixture to
                                          --out instead of planning
    inspect      List artifact configs in the manifest
                   --backend <native|xla> which manifest           [native]
    fit-comm     Fit the collective model (Table III) and print constants
    tune         Autotune the GEMM kernels and persist the winners
                   --shapes <set|list>    tracked | tiny | MxKxN[,MxKxN...]
                                          [tracked]
                   --iters <N>            timing repeats per candidate [5]
                   --quick                small candidate grid (CI smoke)
                   --fresh                discard an existing manifest
                                          instead of merging into it
                   --out <file.json>      manifest path [phantom-tune.json,
                                          or $PHANTOM_TUNE when set]
                   --show                 print the active ISA + manifest
                                          and exit (no benchmarking)
    trace        Span-trace the train/serve drivers (Perfetto export)
                   --scenario <train|serve|all>  which drivers to trace [all]
                   --preset <name>        artifact preset          [quickstart]
                   --mode <tp|pp>         parallelism strategy     [pp]
                   --iters <N>            traced train iterations  [12]
                   --queries <N>          traced serve queries     [64]
                   --rate <qps>           serve arrival rate       [2000]
                   --seed <n>             serve payload seed
                   --runs <N>             timing repeats per arm   [3]
                                          (overhead fraction = min traced
                                          wall vs min untraced wall)
                   --out-dir <dir>        where trace_train.json and
                                          trace_serve.json go      [.]
                   --bench-out <file>     overhead + per-category energy
                                          attribution records
                                          [BENCH_trace.json]
                                          (open the trace JSONs in
                                          ui.perfetto.dev or chrome://tracing)
    help         Show this text

ENVIRONMENT:
    PHANTOM_LOG   stderr log level: error|warn|info|debug|trace
                  (binary defaults to info; libraries/tests default to warn)
    PHANTOM_TUNE  GEMM tuning-manifest path for `tune` and kernel dispatch
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_positional_options_flags() {
        let a = parse(&["train", "--mode", "pp", "--iters=30", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.opt("mode"), Some("pp"));
        assert_eq!(a.opt_parse::<usize>("iters").unwrap(), Some(30));
        assert!(a.flag("verbose"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn option_value_starting_with_dashes_via_equals() {
        let a = parse(&["x", "--name=--weird"]);
        assert_eq!(a.opt("name"), Some("--weird"));
    }

    #[test]
    fn trailing_option_is_flag() {
        let a = parse(&["x", "--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn require_and_unknown() {
        let a = parse(&["x", "--good", "1"]);
        assert_eq!(a.require("good").unwrap(), "1");
        assert!(a.require("absent").is_err());
        assert!(a.check_known(&["good"]).is_ok());
        assert!(a.check_known(&["other"]).is_err());
    }

    #[test]
    fn bad_parse_reports() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_parse::<usize>("n").is_err());
    }
}
