//! Energy-optimal configuration planner (`phantom plan`).
//!
//! Enumerates (mode, p, dp, k, batch, linger) cells over a plan space,
//! filters them through the feasibility guard (divisibility, Eqn. 8,
//! `fits_memory`, p >= 2 — see `Workload::validate`), prices each feasible
//! cell with the calibrated analytic model (`predict` for training,
//! `predict_forward` + batcher linger for serving, plus the hybrid DP
//! All-Reduce term the base model does not cover), and picks the
//! minimum-J/step or minimum-J/query cell subject to an optional latency
//! SLO.
//!
//! Validation is empirical: `validate` actually runs the predicted-best and
//! predicted-worst feasible cells through the measured simulator (the
//! coordinator driver for training, the serving stack for queries) and
//! checks that the measured Joule ranking agrees with the predicted one.
//! `report_json` serializes sweep + predictions + measurements + verdict as
//! BENCH_plan.json.

use anyhow::{bail, Context, Result};

use crate::config::{
    BackendKind, HardwareConfig, ModelConfig, Parallelism, RunConfig, ServeConfig, TrainConfig,
};
use crate::runtime::ExecServer;
use crate::serve::{self, LoadGenConfig};
use crate::simnet::Collective;
use crate::util::json::Json;

use super::calib::Calibration;
use super::{fits_memory, predict, predict_forward, rank_param_floats, IterCost, Workload};

/// What the planner minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Cluster Joules per training step (paper Table I's energy column).
    TrainJPerStep,
    /// Cluster Joules per served query.
    ServeJPerQuery,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "train" | "j-per-step" => Ok(Objective::TrainJPerStep),
            "serve" | "j-per-query" => Ok(Objective::ServeJPerQuery),
            _ => bail!("unknown objective '{s}' (want train|serve)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::TrainJPerStep => "train",
            Objective::ServeJPerQuery => "serve",
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Objective::TrainJPerStep => "J/step",
            Objective::ServeJPerQuery => "J/query",
        }
    }
}

/// The search space: a fixed model (n, layers) crossed with configuration
/// choices. TP cells ignore `k_choices` (they carry the canonical k = 0);
/// `dp_choices` applies to training only (a serving replica pool serves
/// independent traffic, so J/query is dp-invariant under this model);
/// `linger_choices_s` applies to serving only.
#[derive(Debug, Clone)]
pub struct PlanSpace {
    pub n: usize,
    pub layers: usize,
    pub modes: Vec<Parallelism>,
    pub p_choices: Vec<usize>,
    pub dp_choices: Vec<usize>,
    pub k_choices: Vec<usize>,
    pub batch_choices: Vec<usize>,
    pub linger_choices_s: Vec<f64>,
}

impl PlanSpace {
    /// A small default sweep around a model size — the CI smoke grid.
    pub fn small_sweep(n: usize, layers: usize) -> PlanSpace {
        PlanSpace {
            n,
            layers,
            modes: vec![Parallelism::Phantom, Parallelism::Tensor],
            p_choices: vec![2, 4, 8],
            dp_choices: vec![1],
            k_choices: vec![4, 16],
            batch_choices: vec![16],
            linger_choices_s: vec![0.0, 2e-3],
        }
    }
}

/// One candidate configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanCell {
    pub mode: Parallelism,
    pub p: usize,
    pub dp: usize,
    /// Phantom width; 0 for TP cells (ignored by the TP math).
    pub k: usize,
    pub batch: usize,
    /// Batcher linger deadline (serving cells; 0 for training).
    pub linger_s: f64,
}

impl PlanCell {
    pub fn label(&self) -> String {
        let mut s = format!("{} p={} dp={}", self.mode.name(), self.p, self.dp);
        if self.mode == Parallelism::Phantom {
            s.push_str(&format!(" k={}", self.k));
        }
        s.push_str(&format!(" b={}", self.batch));
        if self.linger_s > 0.0 {
            s.push_str(&format!(" linger={:.1}ms", self.linger_s * 1e3));
        }
        s
    }
}

/// Priced analytic prediction for a feasible cell.
#[derive(Debug, Clone, Copy)]
pub struct CellPrediction {
    /// Per-rank model-parallel cost of one step (train) / one dispatched
    /// batch (serve).
    pub cost: IterCost,
    /// Hybrid DP gradient All-Reduce seconds (training, dp > 1).
    pub dp_comm_s: f64,
    /// Predicted latency: step time (train) or worst-case query time
    /// including the full linger wait (serve).
    pub latency_s: f64,
    /// Cluster energy of one step / one batch across all p * dp ranks.
    pub cluster_j: f64,
    /// The objective: J/step (train) or J/query (serve).
    pub j_per_unit: f64,
}

/// Outcome of pricing one cell.
#[derive(Debug, Clone)]
pub enum CellOutcome {
    Priced(CellPrediction),
    Infeasible(String),
}

impl CellOutcome {
    pub fn prediction(&self) -> Option<&CellPrediction> {
        match self {
            CellOutcome::Priced(p) => Some(p),
            CellOutcome::Infeasible(_) => None,
        }
    }
}

/// The full sweep: every enumerated cell with its outcome, plus the argmin
/// and argmax over the feasible ones.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub objective: Objective,
    pub n: usize,
    pub layers: usize,
    pub slo_s: Option<f64>,
    pub cells: Vec<(PlanCell, CellOutcome)>,
    /// Index into `cells` of the minimum-J feasible cell.
    pub best: Option<usize>,
    /// Index into `cells` of the maximum-J feasible cell.
    pub worst: Option<usize>,
}

impl PlanReport {
    pub fn feasible_count(&self) -> usize {
        self.cells.iter().filter(|(_, o)| o.prediction().is_some()).count()
    }
}

/// Enumerate and price the whole space. Infeasible cells are kept in the
/// report with their rejection reason — the sweep record shows WHY a cell
/// was excluded, not just that it was.
pub fn plan(
    space: &PlanSpace,
    objective: Objective,
    slo_s: Option<f64>,
    calib: &Calibration,
) -> Result<PlanReport> {
    if let Some(slo) = slo_s {
        if !(slo > 0.0) {
            bail!("latency SLO must be positive, got {slo}");
        }
    }
    let dp_choices: &[usize] = match objective {
        Objective::TrainJPerStep => &space.dp_choices,
        Objective::ServeJPerQuery => &[1],
    };
    let linger_choices: &[f64] = match objective {
        Objective::TrainJPerStep => &[0.0],
        Objective::ServeJPerQuery => &space.linger_choices_s,
    };
    let mut cells: Vec<PlanCell> = Vec::new();
    for &mode in &space.modes {
        let k_choices: &[usize] = match mode {
            Parallelism::Phantom => &space.k_choices,
            Parallelism::Tensor => &[0],
        };
        for &p in &space.p_choices {
            for &dp in dp_choices {
                for &k in k_choices {
                    for &batch in &space.batch_choices {
                        for &linger_s in linger_choices {
                            cells.push(PlanCell { mode, p, dp, k, batch, linger_s });
                        }
                    }
                }
            }
        }
    }
    if cells.is_empty() {
        bail!("empty plan space (no modes, p, k or batch choices)");
    }
    let priced: Vec<(PlanCell, CellOutcome)> = cells
        .into_iter()
        .map(|cell| {
            let outcome = price_cell(&cell, space, objective, slo_s, calib);
            (cell, outcome)
        })
        .collect();
    let mut best: Option<usize> = None;
    let mut worst: Option<usize> = None;
    let (mut best_j, mut worst_j) = (f64::INFINITY, f64::NEG_INFINITY);
    for (i, (_, o)) in priced.iter().enumerate() {
        if let Some(pred) = o.prediction() {
            if pred.j_per_unit < best_j {
                best_j = pred.j_per_unit;
                best = Some(i);
            }
            if pred.j_per_unit > worst_j {
                worst_j = pred.j_per_unit;
                worst = Some(i);
            }
        }
    }
    Ok(PlanReport {
        objective,
        n: space.n,
        layers: space.layers,
        slo_s,
        cells: priced,
        best,
        worst,
    })
}

/// Price one cell, or explain why it cannot be priced.
fn price_cell(
    cell: &PlanCell,
    space: &PlanSpace,
    objective: Objective,
    slo_s: Option<f64>,
    calib: &Calibration,
) -> CellOutcome {
    if cell.p < 2 {
        // Satellite bugfix (ISSUE 7): simnet prices p <= 1 collectives at
        // zero seconds, so a single-rank cell would always "win" on free
        // communication. It is excluded from the parallel search space;
        // price a dense single-device baseline separately if needed.
        return CellOutcome::Infeasible(
            "p=1 excluded: the collective model prices single-rank communication as free \
             (simnet p <= 1 => 0 s), so the parallel cost model cannot rank it honestly"
                .to_string(),
        );
    }
    if cell.dp == 0 || cell.batch < cell.dp {
        return CellOutcome::Infeasible(format!(
            "batch={} cannot be row-sharded over dp={} replicas",
            cell.batch, cell.dp
        ));
    }
    if cell.mode == Parallelism::Phantom && cell.k == 0 {
        return CellOutcome::Infeasible("PP needs k >= 1 (zero-width compressor)".to_string());
    }
    // Per-replica workload: the DP batch is row-sharded; the slowest
    // replica carries ceil(batch / dp) rows and sets the step time.
    let replica_batch = cell.batch.div_ceil(cell.dp);
    let w = match Workload::new(space.n, space.layers, cell.p, cell.k, replica_batch) {
        Ok(w) => w,
        Err(e) => return CellOutcome::Infeasible(format!("{e:#}")),
    };
    if !fits_memory(cell.mode, &w) {
        return CellOutcome::Infeasible(format!(
            "exceeds the {} GiB GCD HBM budget",
            super::FRONTIER_HBM_BYTES >> 30
        ));
    }
    let power = &calib.power;
    match objective {
        Objective::TrainJPerStep => {
            let cost = match predict(cell.mode, &w, &calib.gemm, &calib.net) {
                Ok(c) => c,
                Err(e) => return CellOutcome::Infeasible(format!("{e:#}")),
            };
            // DP extension: one flat gradient All-Reduce of the per-rank
            // parameter shard across the dp replicas, per step, charged at
            // the static draw like any collective.
            let dp_comm_s = if cell.dp > 1 {
                let payload = rank_param_floats(cell.mode, &w) as usize;
                calib.net.time(Collective::AllReduce, payload, cell.dp)
            } else {
                0.0
            };
            let latency_s = cost.total_s() + dp_comm_s;
            if let Some(slo) = slo_s {
                if latency_s > slo {
                    return CellOutcome::Infeasible(format!(
                        "predicted step latency {latency_s:.3e} s exceeds the SLO {slo:.3e} s"
                    ));
                }
            }
            let ranks = (cell.p * cell.dp) as f64;
            let cluster_j = ranks
                * (power.busy_w * cost.compute_s
                    + power.idle_w * (cost.comm_s + cost.dispatch_s + dp_comm_s));
            CellOutcome::Priced(CellPrediction {
                cost,
                dp_comm_s,
                latency_s,
                cluster_j,
                j_per_unit: cluster_j,
            })
        }
        Objective::ServeJPerQuery => {
            let cost = match predict_forward(cell.mode, &w, &calib.gemm, &calib.net) {
                Ok(c) => c,
                Err(e) => return CellOutcome::Infeasible(format!("{e:#}")),
            };
            // Linger extension: a full batch dispatches after waiting up to
            // linger_s for stragglers; the pool idles (static draw) while
            // the batch forms. Worst-case query latency = full linger wait
            // + the batch's forward time.
            let latency_s = cell.linger_s + cost.total_s();
            if let Some(slo) = slo_s {
                if latency_s > slo {
                    return CellOutcome::Infeasible(format!(
                        "predicted worst-case query latency {latency_s:.3e} s exceeds the \
                         SLO {slo:.3e} s"
                    ));
                }
            }
            let ranks = cell.p as f64;
            let batch_j = ranks
                * (power.busy_w * cost.compute_s
                    + power.idle_w * (cost.comm_s + cost.dispatch_s + cell.linger_s));
            CellOutcome::Priced(CellPrediction {
                cost,
                dp_comm_s: 0.0,
                latency_s,
                cluster_j: batch_j,
                j_per_unit: batch_j / cell.batch as f64,
            })
        }
    }
}

// ---------------------------------------------------------------------------
// Empirical validation: run predicted-best and predicted-worst for real
// ---------------------------------------------------------------------------

/// Knobs for the validation runs.
#[derive(Debug, Clone, Copy)]
pub struct ValidateOptions {
    /// Training iterations per measured cell (>= 2: one warmup iteration
    /// is excluded from the energy accounting).
    pub iters: usize,
    /// Queries per measured serving cell.
    pub queries: usize,
    /// Arrival rate for serving cells (virtual q/s).
    pub rate_qps: f64,
    pub seed: u64,
}

impl Default for ValidateOptions {
    fn default() -> Self {
        ValidateOptions { iters: 6, queries: 96, rate_qps: 2_000.0, seed: 0x71A2 }
    }
}

/// One empirically measured cell.
#[derive(Debug, Clone)]
pub struct MeasuredCell {
    pub cell: PlanCell,
    pub predicted_j: f64,
    pub measured_j: f64,
}

/// The verdict: did the measured Joule ranking agree with the prediction?
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub best: MeasuredCell,
    pub worst: MeasuredCell,
    /// True iff measured(best) < measured(worst), strictly.
    pub ranking_holds: bool,
}

/// Run the report's predicted-best and predicted-worst cells through the
/// measured simulator and compare rankings. Fails if the report has fewer
/// than two distinct feasible cells.
pub fn validate(
    report: &PlanReport,
    space: &PlanSpace,
    opts: &ValidateOptions,
) -> Result<ValidationReport> {
    let bi = report.best.context("no feasible cells to validate")?;
    let wi = report.worst.context("no feasible cells to validate")?;
    if bi == wi {
        bail!("only one feasible cell; ranking validation needs at least two");
    }
    let best = measure_cell(&report.cells[bi], space, report.objective, opts)
        .context("measuring predicted-best cell")?;
    let worst = measure_cell(&report.cells[wi], space, report.objective, opts)
        .context("measuring predicted-worst cell")?;
    let ranking_holds = best.measured_j < worst.measured_j;
    Ok(ValidationReport { best, worst, ranking_holds })
}

fn measure_cell(
    entry: &(PlanCell, CellOutcome),
    space: &PlanSpace,
    objective: Objective,
    opts: &ValidateOptions,
) -> Result<MeasuredCell> {
    let (cell, outcome) = entry;
    let pred = outcome.prediction().context("cell was not priced")?;
    let name = format!(
        "plan-{}-p{}-dp{}-k{}-b{}",
        cell.mode.name(),
        cell.p,
        cell.dp,
        cell.k,
        cell.batch
    );
    let cfg = RunConfig {
        mode: cell.mode,
        p: cell.p,
        dp: cell.dp,
        model: ModelConfig { n: space.n, layers: space.layers, k: cell.k },
        train: TrainConfig {
            batch: cell.batch,
            seed: opts.seed,
            max_iters: opts.iters.max(2),
            ..TrainConfig::default()
        },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some(name),
        backend: BackendKind::Native,
    };
    cfg.validate().with_context(|| format!("validation config for {}", cell.label()))?;
    let server = ExecServer::native_for(&cfg)?;
    let measured_j = match objective {
        Objective::TrainJPerStep => {
            let report = crate::coordinator::train(&cfg, &server)?;
            report.energy_per_iter_j()
        }
        Objective::ServeJPerQuery => {
            let scfg = ServeConfig {
                queue_depth: 4 * cell.batch,
                max_batch: cell.batch,
                linger_s: cell.linger_s,
                mode: cell.mode,
            };
            let lcfg = LoadGenConfig {
                queries: opts.queries,
                rate_qps: opts.rate_qps,
                seed: opts.seed,
                open_loop: false,
            };
            let report = serve::run_load(&cfg, &scfg, &lcfg, &server)?;
            report.energy_per_kq_j / 1_000.0
        }
    };
    Ok(MeasuredCell { cell: *cell, predicted_j: pred.j_per_unit, measured_j })
}

// ---------------------------------------------------------------------------
// BENCH_plan.json
// ---------------------------------------------------------------------------

fn cell_json(cell: &PlanCell) -> Vec<(&'static str, Json)> {
    vec![
        ("mode", Json::str(cell.mode.name())),
        ("p", Json::int(cell.p as i64)),
        ("dp", Json::int(cell.dp as i64)),
        ("k", Json::int(cell.k as i64)),
        ("batch", Json::int(cell.batch as i64)),
        ("linger_s", Json::num(cell.linger_s)),
    ]
}

fn measured_json(m: &MeasuredCell) -> Json {
    let mut fields = cell_json(&m.cell);
    fields.push(("predicted_j", Json::num(m.predicted_j)));
    fields.push(("measured_j", Json::num(m.measured_j)));
    Json::obj(fields)
}

/// Serialize the full sweep + predictions (+ measurements and verdict when
/// validation ran) — the structured BENCH_plan.json payload.
pub fn report_json(
    report: &PlanReport,
    calib: &Calibration,
    validation: Option<&ValidationReport>,
) -> Json {
    let sweep: Vec<Json> = report
        .cells
        .iter()
        .map(|(cell, outcome)| {
            let mut fields = cell_json(cell);
            match outcome {
                CellOutcome::Priced(p) => {
                    fields.push(("feasible", Json::Bool(true)));
                    fields.push(("predicted_j", Json::num(p.j_per_unit)));
                    fields.push(("predicted_latency_s", Json::num(p.latency_s)));
                    fields.push(("dp_comm_s", Json::num(p.dp_comm_s)));
                }
                CellOutcome::Infeasible(reason) => {
                    fields.push(("feasible", Json::Bool(false)));
                    fields.push(("infeasible_reason", Json::str(reason.as_str())));
                }
            }
            Json::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("objective", Json::str(report.objective.name())),
        ("unit", Json::str(report.objective.unit())),
        ("n", Json::int(report.n as i64)),
        ("layers", Json::int(report.layers as i64)),
        ("slo_s", report.slo_s.map(Json::num).unwrap_or(Json::Null)),
        ("calibration_source", Json::str(calib.source.describe())),
        (
            "calibration_warnings",
            Json::arr(calib.warnings.iter().map(|w| Json::str(w.as_str())).collect()),
        ),
        ("sweep", Json::arr(sweep)),
        ("feasible_cells", Json::int(report.feasible_count() as i64)),
        (
            "predicted_best",
            report
                .best
                .map(|i| Json::obj(cell_json(&report.cells[i].0)))
                .unwrap_or(Json::Null),
        ),
        (
            "predicted_worst",
            report
                .worst
                .map(|i| Json::obj(cell_json(&report.cells[i].0)))
                .unwrap_or(Json::Null),
        ),
    ];
    match validation {
        Some(v) => {
            fields.push(("measured_best", measured_json(&v.best)));
            fields.push(("measured_worst", measured_json(&v.worst)));
            fields.push(("ranking_holds", Json::Bool(v.ranking_holds)));
        }
        None => fields.push(("ranking_holds", Json::Null)),
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn calib() -> Calibration {
        Calibration::frontier_defaults()
    }

    #[test]
    fn small_sweep_prices_both_modes_and_excludes_p1() {
        let mut space = PlanSpace::small_sweep(256, 2);
        space.p_choices = vec![1, 2, 4];
        let report = plan(&space, Objective::TrainJPerStep, None, &calib()).unwrap();
        assert!(report.feasible_count() >= 3, "{}", report.feasible_count());
        let mut saw = (false, false);
        for (cell, outcome) in &report.cells {
            match outcome {
                CellOutcome::Priced(pred) => {
                    assert!(cell.p >= 2, "p=1 must never be priced");
                    assert!(pred.j_per_unit > 0.0);
                    match cell.mode {
                        Parallelism::Phantom => saw.0 = true,
                        Parallelism::Tensor => saw.1 = true,
                    }
                }
                CellOutcome::Infeasible(reason) => {
                    if cell.p == 1 {
                        assert!(reason.contains("p=1"), "{reason}");
                    }
                }
            }
        }
        assert!(saw.0 && saw.1, "both modes must appear in the feasible set");
        assert!(report.best.is_some() && report.worst.is_some());
        let b = report.cells[report.best.unwrap()].1.prediction().unwrap().j_per_unit;
        let w = report.cells[report.worst.unwrap()].1.prediction().unwrap().j_per_unit;
        assert!(b <= w);
    }

    #[test]
    fn no_feasible_cell_violates_the_guards() {
        // Property sweep: randomized spaces; every PRICED cell satisfies
        // divisibility, Eqn. 8, fits_memory and p >= 2.
        let mut rng = Prng::new(0x9A7);
        let mut pick = move |lo: u64, hi: u64| -> usize { rng.int_in(lo, hi) as usize };
        for _ in 0..40 {
            let n = [48usize, 96, 100, 256, 1024][pick(0, 4)];
            let space = PlanSpace {
                n,
                layers: pick(1, 3),
                modes: vec![Parallelism::Phantom, Parallelism::Tensor],
                p_choices: vec![pick(1, 9), pick(1, 9), 7],
                dp_choices: vec![1, pick(1, 4)],
                k_choices: vec![pick(0, 59), pick(1, 12)],
                batch_choices: vec![pick(1, 33)],
                linger_choices_s: vec![0.0],
            };
            for objective in [Objective::TrainJPerStep, Objective::ServeJPerQuery] {
                let report = plan(&space, objective, None, &calib()).unwrap();
                for (cell, outcome) in &report.cells {
                    let Some(_) = outcome.prediction() else { continue };
                    assert!(cell.p >= 2, "{}", cell.label());
                    assert_eq!(space.n % cell.p, 0, "{}", cell.label());
                    let m = space.n / cell.p;
                    assert!(
                        (cell.k as f64) < m as f64 * (1.0 - 1.0 / cell.p as f64),
                        "Eqn. 8: {}",
                        cell.label()
                    );
                    let rb = cell.batch.div_ceil(cell.dp);
                    let w = Workload::new(space.n, space.layers, cell.p, cell.k, rb)
                        .unwrap_or_else(|e| panic!("{}: {e}", cell.label()));
                    assert!(fits_memory(cell.mode, &w), "{}", cell.label());
                }
            }
        }
    }

    #[test]
    fn slo_filters_slow_cells() {
        let space = PlanSpace::small_sweep(256, 2);
        let open = plan(&space, Objective::TrainJPerStep, None, &calib()).unwrap();
        // An SLO below every cell's latency leaves nothing feasible.
        let strict = plan(&space, Objective::TrainJPerStep, Some(1e-12), &calib()).unwrap();
        assert_eq!(strict.feasible_count(), 0);
        assert!(strict.best.is_none());
        // A generous SLO changes nothing.
        let loose = plan(&space, Objective::TrainJPerStep, Some(1e9), &calib()).unwrap();
        assert_eq!(loose.feasible_count(), open.feasible_count());
        assert!(plan(&space, Objective::TrainJPerStep, Some(-1.0), &calib()).is_err());
    }

    #[test]
    fn dp_cells_price_the_allreduce_term() {
        let mut space = PlanSpace::small_sweep(256, 2);
        space.p_choices = vec![4];
        space.dp_choices = vec![1, 2];
        space.k_choices = vec![4];
        space.batch_choices = vec![16];
        let report = plan(&space, Objective::TrainJPerStep, None, &calib()).unwrap();
        let find = |dp: usize| {
            report
                .cells
                .iter()
                .find(|(c, _)| c.dp == dp && c.mode == Parallelism::Phantom)
                .and_then(|(_, o)| o.prediction())
                .copied()
                .unwrap()
        };
        let (solo, hybrid) = (find(1), find(2));
        assert_eq!(solo.dp_comm_s, 0.0);
        assert!(hybrid.dp_comm_s > 0.0, "dp=2 must price the gradient All-Reduce");
        // Serving ignores dp_choices entirely.
        let serve = plan(&space, Objective::ServeJPerQuery, None, &calib()).unwrap();
        assert!(serve.cells.iter().all(|(c, _)| c.dp == 1));
    }

    #[test]
    fn serve_cells_price_linger_and_latency_includes_it() {
        let mut space = PlanSpace::small_sweep(256, 2);
        space.p_choices = vec![4];
        space.k_choices = vec![4];
        space.linger_choices_s = vec![0.0, 5e-3];
        let report = plan(&space, Objective::ServeJPerQuery, None, &calib()).unwrap();
        let find = |linger: f64, mode: Parallelism| {
            report
                .cells
                .iter()
                .find(|(c, _)| c.linger_s == linger && c.mode == mode)
                .and_then(|(_, o)| o.prediction())
                .copied()
                .unwrap()
        };
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let (fast, lingering) = (find(0.0, mode), find(5e-3, mode));
            assert!(lingering.j_per_unit > fast.j_per_unit, "linger idles the pool");
            assert!((lingering.latency_s - fast.latency_s - 5e-3).abs() < 1e-9);
        }
    }

    #[test]
    fn report_json_round_trips_the_verdict_shape() {
        let space = PlanSpace::small_sweep(256, 2);
        let report = plan(&space, Objective::TrainJPerStep, None, &calib()).unwrap();
        let j = report_json(&report, &calib(), None);
        assert_eq!(j.get("objective").as_str(), Some("train"));
        assert_eq!(j.get("ranking_holds"), &Json::Null);
        assert_eq!(
            j.get("sweep").as_arr().unwrap().len(),
            report.cells.len(),
            "every cell, feasible or not, appears in the sweep record"
        );
        // Parse back: the serialized form is valid JSON.
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(back.get("n").as_usize(), Some(256));
    }
}
