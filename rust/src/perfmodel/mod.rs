//! Analytic performance model for Frontier-scale predictions.
//!
//! The measured coordinator runs real PJRT compute for n <= 8192; the
//! paper's large-model figures (Fig. 5a at n = 65,536; Fig. 6 at
//! n = 131,072 / 262,144) are far beyond CPU reach (a single TP layer at
//! n = 262,144 is 68 G-params). This module reproduces those figures from
//! first principles:
//!
//!   * per-rank FLOP counts of the exact GEMM schedule the coordinator runs
//!     (paper Sec. IV complexity analysis),
//!   * a GEMM-efficiency curve that degrades with the smallest matrix
//!     dimension (the NVIDIA/AMD small-GEMM effect the paper cites [21] for
//!     its p = 256 "flip-flop"),
//!   * per-source launch/management overhead that grows with p (the paper:
//!     "an increase in PP overhead from the management of additional data
//!     structures required for gradient aggregation which is proportional
//!     to p"),
//!   * the paper's own collective model (simnet, Table III constants),
//!   * the energy model e = A*alpha + B*beta (energy, Eqn. 1),
//!   * a per-rank memory model for the Fig. 6 OOM boundary.
//!
//! Constants are calibrated once (tests pin the calibration) so that the
//! paper's qualitative structure holds: who wins, where the p = 256
//! flip-flop falls, and which configs OOM. Absolute milliseconds are *not*
//! the claim (see DESIGN.md §2).

use crate::energy::PowerModel;
use crate::simnet::{Collective, NetworkProfile};

/// Hardware constants for the analytic model (one Frontier MI250X GCD).
#[derive(Debug, Clone, Copy)]
pub struct GemmModel {
    /// Peak sustained GEMM throughput at full efficiency (FLOP/s).
    pub peak_flops: f64,
    /// Efficiency floor for tiny GEMMs.
    pub min_eff: f64,
    /// Dimension at which a GEMM reaches full efficiency.
    pub full_eff_dim: f64,
    /// Fixed overhead per GEMM launch (seconds).
    pub launch_overhead_s: f64,
    /// Host-side per-float cost of assembling/aggregating the decompressor
    /// outputs each layer (seconds per activation float touched): the
    /// eager-mode "management of additional data structures required for
    /// gradient aggregation" the paper blames for PP overhead. Charged at
    /// IDLE power: the device waits while the host works.
    pub host_float_s: f64,
    /// Quadratic peer-bookkeeping term (seconds per p^2 per layer): p
    /// per-peer module lists, each over p slots, per layer. This is what
    /// makes PP overhead grow with GPU count and produces the paper's
    /// p = 256 flip-flop at n = 131,072.
    pub peer_quad_s: f64,
}

impl GemmModel {
    pub fn frontier() -> GemmModel {
        GemmModel {
            peak_flops: 20.0e12,
            min_eff: 0.05,
            full_eff_dim: 128.0,
            launch_overhead_s: 0.5e-6,
            // Calibrated jointly to the paper's structural results: Fig 5b
            // (PP ahead at small p for n=4,096, converging at large p),
            // Fig 5c (PP ahead through p=64 at n=16,384), Fig 6 (TP
            // overtakes PP ONLY at (n=131,072, p=256); PP ahead everywhere
            // at n=262,144), and Table-I-style energy ordering at small p.
            // See DESIGN.md §Perfmodel-calibration.
            host_float_s: 1.5e-9,
            peer_quad_s: 0.0875e-6,
        }
    }

    /// Efficiency of an (M x K) @ (K x N) GEMM: limited by the smallest
    /// dimension (matrix-core tiles go underutilized below ~128).
    pub fn efficiency(&self, m: usize, n: usize, k: usize) -> f64 {
        let min_dim = m.min(n).min(k) as f64;
        (min_dim / self.full_eff_dim).clamp(self.min_eff, 1.0)
    }

    /// Time of one (M x K) @ (K x N) GEMM in seconds.
    pub fn gemm_s(&self, m: usize, n: usize, k: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        flops / (self.peak_flops * self.efficiency(m, n, k)) + self.launch_overhead_s
    }
}

/// A workload point: one (mode, n, L, p, k, batch) cell of a paper figure.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub n: usize,
    pub layers: usize,
    pub p: usize,
    pub k: usize,
    pub batch: usize,
}

impl Workload {
    pub fn m(&self) -> usize {
        self.n / self.p
    }
}

/// Predicted per-iteration cost breakdown for one rank.
#[derive(Debug, Clone, Copy, Default)]
pub struct IterCost {
    /// Device compute seconds (alpha contribution of this rank).
    pub compute_s: f64,
    /// Communication seconds (beta contribution).
    pub comm_s: f64,
    /// Host dispatch seconds (device idle while the host drives per-peer
    /// modules; zero for TP whose per-layer module count is O(1)).
    pub dispatch_s: f64,
}

impl IterCost {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.dispatch_s
    }

    /// Energy per iteration for this rank (paper Eqn. 1): busy time at A,
    /// communication and host-dispatch stalls at B.
    pub fn energy_j(&self, power: &PowerModel) -> f64 {
        power.busy_w * self.compute_s + power.idle_w * (self.comm_s + self.dispatch_s)
    }
}

// ---------------------------------------------------------------------------
// Tensor parallelism (paper Sec. II-B, Table II)
// ---------------------------------------------------------------------------

/// TP per-rank compute seconds per iteration.
pub fn tp_compute_s(w: &Workload, g: &GemmModel) -> f64 {
    let (b, n, m, l) = (w.batch, w.n, w.m(), w.layers);
    let fwd = g.gemm_s(b, m, n); // y_full @ W
    let grads = g.gemm_s(n, m, b); // y_full^T @ delta
    let partial = g.gemm_s(b, n, m); // delta @ W^T   (L-1 layers)
    (l as f64) * (fwd + grads) + ((l - 1) as f64) * partial
}

/// TP per-rank communication seconds per iteration (Table II schedule).
pub fn tp_comm_s(w: &Workload, net: &NetworkProfile) -> f64 {
    let (b, n, m, l, p) = (w.batch, w.n, w.m(), w.layers, w.p);
    let fwd = net.time(Collective::AllGather, m * b, p) + net.time(Collective::Broadcast, n * b, p);
    let bwd_each = net.time(Collective::ReduceScatter, m * b, p);
    let bwd_prop = net.time(Collective::AllReduce, n * b, p);
    (l as f64) * fwd + ((l - 1) as f64) * bwd_prop + ((l - 1) as f64) * bwd_each
}

/// TP per-rank memory footprint in bytes: parameters + gradients + two
/// optimizer slots (Adam-style, f32) + forward stash (y_full per layer).
pub fn tp_rank_mem_bytes(w: &Workload) -> u64 {
    let (b, n, m, l) = (w.batch as u64, w.n as u64, w.m() as u64, w.layers as u64);
    let params = l * (n * m + m);
    let stash = l * (b * n + 2 * b * m);
    4 * (4 * params + stash)
}

// ---------------------------------------------------------------------------
// Phantom parallelism (paper Sec. IV)
// ---------------------------------------------------------------------------

/// PP per-rank compute seconds per iteration, following the coordinator's
/// exact GEMM schedule (rank_pp.rs).
pub fn pp_compute_s(w: &Workload, g: &GemmModel) -> f64 {
    let (b, m, k, p, l) = (w.batch, w.m(), w.k, w.p, w.layers);
    let pm1 = (p - 1) as f64;
    // forward: local + compress (fused on TPU; two GEMMs on GPU) +
    // per-source decompression
    let fwd = g.gemm_s(b, m, m) + g.gemm_s(b, k, m) + pm1 * g.gemm_s(b, m, k);
    // backward: error compression to p destinations, gradient GEMMs,
    // delta propagation
    let bwd_compress = (p as f64) * g.gemm_s(b, k, m);
    let bwd_grads = g.gemm_s(m, m, b) + g.gemm_s(m, k, b) + pm1 * g.gemm_s(k, m, b);
    let bwd_combine = g.gemm_s(b, m, m) + g.gemm_s(b, m, k);
    (l as f64) * (fwd + bwd_compress + bwd_grads) + ((l - 1) as f64) * bwd_combine
}

/// PP host-dispatch seconds per iteration: per layer the host touches the
/// full decompressed width (batch * n floats across the p-1 outputs) and
/// pays quadratic peer bookkeeping (p module lists over p slots). Charged
/// at idle power (the device waits on the host).
pub fn pp_dispatch_s(w: &Workload, g: &GemmModel) -> f64 {
    let per_layer = g.host_float_s * (w.batch as f64) * (w.n as f64)
        + g.peer_quad_s * (w.p as f64) * (w.p as f64);
    (w.layers as f64) * per_layer
}

/// PP per-rank communication seconds per iteration (Table II: one k*batch
/// All-Gather forward, one k*batch Reduce-Scatter backward, per layer).
pub fn pp_comm_s(w: &Workload, net: &NetworkProfile) -> f64 {
    let (b, k, p, l) = (w.batch, w.k, w.p, w.layers);
    (l as f64)
        * (net.time(Collective::AllGather, k * b, p)
            + net.time(Collective::ReduceScatter, k * b, p))
}

/// PP per-rank memory footprint in bytes.
pub fn pp_rank_mem_bytes(w: &Workload) -> u64 {
    let (b, m, k, p, l) =
        (w.batch as u64, w.m() as u64, w.k as u64, w.p as u64, w.layers as u64);
    let params = l * (m * m + m * k + p * k * m + m);
    let stash = l * (2 * b * m + p * b * k);
    4 * (4 * params + stash)
}

/// Frontier GCD HBM capacity (bytes): 64 GB.
pub const FRONTIER_HBM_BYTES: u64 = 64 * (1 << 30);

/// Full per-iteration prediction for a workload in one mode.
pub fn predict(
    mode: crate::config::Parallelism,
    w: &Workload,
    g: &GemmModel,
    net: &NetworkProfile,
) -> IterCost {
    match mode {
        crate::config::Parallelism::Tensor => IterCost {
            compute_s: tp_compute_s(w, g),
            comm_s: tp_comm_s(w, net),
            dispatch_s: 0.0,
        },
        crate::config::Parallelism::Phantom => IterCost {
            compute_s: pp_compute_s(w, g),
            comm_s: pp_comm_s(w, net),
            dispatch_s: pp_dispatch_s(w, g),
        },
    }
}

/// Does this workload fit in GCD memory?
pub fn fits_memory(mode: crate::config::Parallelism, w: &Workload) -> bool {
    let bytes = match mode {
        crate::config::Parallelism::Tensor => tp_rank_mem_bytes(w),
        crate::config::Parallelism::Phantom => pp_rank_mem_bytes(w),
    };
    bytes <= FRONTIER_HBM_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism::{Phantom, Tensor};

    fn net() -> NetworkProfile {
        NetworkProfile::frontier()
    }

    fn gm() -> GemmModel {
        GemmModel::frontier()
    }

    #[test]
    fn efficiency_curve_monotone() {
        let g = gm();
        assert!(g.efficiency(32, 512, 512) < g.efficiency(128, 512, 512));
        assert_eq!(g.efficiency(128, 256, 512), 1.0);
        assert!(g.efficiency(1, 1, 1) >= g.min_eff);
    }

    #[test]
    fn alpha_pi_less_than_alpha_tau_under_eqn8() {
        // Paper Eqn. (7): total PP FLOPs < total TP FLOPs when Eqn. (8)
        // holds. Check raw FLOP counts (efficiency-independent).
        for (n, p, k) in [(16_384, 32, 64), (65_536, 64, 64), (131_072, 128, 64)] {
            let w = Workload { n, layers: 2, p, k, batch: 32 };
            let m = w.m();
            assert!((k as f64) < m as f64 * (1.0 - 1.0 / p as f64), "precondition");
            // FLOP counts per rank (drop overheads by zeroing them)
            let ideal = GemmModel {
                launch_overhead_s: 0.0,
                host_float_s: 0.0,
                peer_quad_s: 0.0,
                min_eff: 1.0,
                full_eff_dim: 1.0,
                ..gm()
            };
            let pp = pp_compute_s(&w, &ideal);
            let tp = tp_compute_s(&w, &ideal);
            assert!(pp < tp, "n={n} p={p}: pp={pp} tp={tp}");
        }
    }

    #[test]
    fn beta_pi_less_than_beta_tau() {
        // Paper Eqn. (9) at the paper's Fig. 5a point: n=65536, L=6, k=64.
        for p in [32, 64, 128] {
            let w = Workload { n: 65_536, layers: 6, p, k: 64, batch: 32 };
            let pp = pp_comm_s(&w, &net());
            let tp = tp_comm_s(&w, &net());
            assert!(pp < tp, "p={p}: pp={pp} tp={tp}");
            // Fig 5a shows PP comm several times below TP
            assert!(tp / pp > 3.0, "p={p}: ratio {}", tp / pp);
        }
    }

    #[test]
    fn fig6_flip_flop_at_131072() {
        // Paper Fig. 6 (left): at n=131072, k=64, PP wins up to p=128 but
        // TP overtakes at p=256.
        let g = gm();
        for p in [32, 64, 128] {
            let w = Workload { n: 131_072, layers: 2, p, k: 64, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).total_s();
            let tp = predict(Tensor, &w, &g, &net()).total_s();
            assert!(pp < tp, "p={p}: pp={pp} tp={tp} (PP should win)");
        }
        let w = Workload { n: 131_072, layers: 2, p: 256, k: 64, batch: 32 };
        let pp = predict(Phantom, &w, &g, &net()).total_s();
        let tp = predict(Tensor, &w, &g, &net()).total_s();
        assert!(tp < pp, "p=256 flip-flop: tp={tp} pp={pp} (TP should win)");
    }

    #[test]
    fn fig6_no_flip_at_262144() {
        // Paper Fig. 6 (right): at n=262144 PP wins across ALL tested p.
        let g = gm();
        for p in [64, 128, 256] {
            let w = Workload { n: 262_144, layers: 2, p, k: 64, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).total_s();
            let tp = predict(Tensor, &w, &g, &net()).total_s();
            assert!(pp < tp, "p={p}: pp={pp} tp={tp}");
        }
    }

    #[test]
    fn fig6_tp_oom_at_262144_p32() {
        // Paper: "TP could not be executed on p=32 due to memory exhaustion"
        let w = Workload { n: 262_144, layers: 2, p: 32, k: 64, batch: 32 };
        assert!(!fits_memory(Tensor, &w), "TP at n=262144 p=32 must OOM");
        assert!(fits_memory(Phantom, &w), "PP must fit (reduced footprint)");
        // and TP fits at p=64
        let w64 = Workload { n: 262_144, layers: 2, p: 64, k: 64, batch: 32 };
        assert!(fits_memory(Tensor, &w64));
    }

    #[test]
    fn pp_memory_below_tp() {
        for p in [8, 32, 128] {
            let w = Workload { n: 131_072, layers: 2, p, k: 64, batch: 32 };
            assert!(pp_rank_mem_bytes(&w) < tp_rank_mem_bytes(&w), "p={p}");
        }
    }

    #[test]
    fn energy_per_iter_pp_below_tp() {
        // Eqn. (10): e_pi < e_tau for fixed n, p, L with k < n/p.
        // Asserted for the small-p regime the paper's Table I covers most
        // clearly; at p >= 64 the model's dispatch calibration (tuned to
        // the Fig. 6 crossover) overestimates PP overhead at n = 16,384 —
        // measured-mode runs cover that regime (see EXPERIMENTS.md).
        let power = PowerModel::frontier();
        let g = gm();
        for (n, p) in [(16_384, 8), (16_384, 16), (65_536, 64)] {
            let w = Workload { n, layers: 2, p, k: 16, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).energy_j(&power);
            let tp = predict(Tensor, &w, &g, &net()).energy_j(&power);
            assert!(pp < tp, "n={n} p={p}: pp={pp} tp={tp}");
        }
    }
}
