//! Analytic performance model for Frontier-scale predictions.
//!
//! The measured coordinator runs real PJRT compute for n <= 8192; the
//! paper's large-model figures (Fig. 5a at n = 65,536; Fig. 6 at
//! n = 131,072 / 262,144) are far beyond CPU reach (a single TP layer at
//! n = 262,144 is 68 G-params). This module reproduces those figures from
//! first principles:
//!
//!   * per-rank FLOP counts of the exact GEMM schedule the coordinator runs
//!     (paper Sec. IV complexity analysis),
//!   * a GEMM-efficiency curve that degrades with the smallest matrix
//!     dimension (the NVIDIA/AMD small-GEMM effect the paper cites [21] for
//!     its p = 256 "flip-flop"),
//!   * per-source launch/management overhead that grows with p (the paper:
//!     "an increase in PP overhead from the management of additional data
//!     structures required for gradient aggregation which is proportional
//!     to p"),
//!   * the paper's own collective model (simnet, Table III constants),
//!   * the energy model e = A*alpha + B*beta (energy, Eqn. 1),
//!   * a per-rank memory model for the Fig. 6 OOM boundary.
//!
//! Constants are calibrated once (tests pin the calibration) so that the
//! paper's qualitative structure holds: who wins, where the p = 256
//! flip-flop falls, and which configs OOM. Absolute milliseconds are *not*
//! the claim (see DESIGN.md §2).

use anyhow::{bail, Result};

use crate::energy::PowerModel;
use crate::simnet::{Collective, NetworkProfile};

pub mod calib;
pub mod plan;

/// Hardware constants for the analytic model (one Frontier MI250X GCD).
#[derive(Debug, Clone, Copy)]
pub struct GemmModel {
    /// Peak sustained GEMM throughput at full efficiency (FLOP/s).
    pub peak_flops: f64,
    /// Efficiency floor for tiny GEMMs.
    pub min_eff: f64,
    /// Dimension at which a GEMM reaches full efficiency.
    pub full_eff_dim: f64,
    /// Fixed overhead per GEMM launch (seconds).
    pub launch_overhead_s: f64,
    /// Host-side per-float cost of assembling/aggregating the decompressor
    /// outputs each layer (seconds per activation float touched): the
    /// eager-mode "management of additional data structures required for
    /// gradient aggregation" the paper blames for PP overhead. Charged at
    /// IDLE power: the device waits while the host works.
    pub host_float_s: f64,
    /// Quadratic peer-bookkeeping term (seconds per p^2 per layer): p
    /// per-peer module lists, each over p slots, per layer. This is what
    /// makes PP overhead grow with GPU count and produces the paper's
    /// p = 256 flip-flop at n = 131,072.
    pub peer_quad_s: f64,
}

impl GemmModel {
    pub fn frontier() -> GemmModel {
        GemmModel {
            peak_flops: 20.0e12,
            min_eff: 0.05,
            full_eff_dim: 128.0,
            launch_overhead_s: 0.5e-6,
            // Calibrated jointly to the paper's structural results: Fig 5b
            // (PP ahead at small p for n=4,096, converging at large p),
            // Fig 5c (PP ahead through p=64 at n=16,384), Fig 6 (TP
            // overtakes PP ONLY at (n=131,072, p=256); PP ahead everywhere
            // at n=262,144), and Table-I-style energy ordering at small p.
            // See DESIGN.md §Perfmodel-calibration.
            host_float_s: 1.5e-9,
            peer_quad_s: 0.0875e-6,
        }
    }

    /// Efficiency of an (M x K) @ (K x N) GEMM: limited by the smallest
    /// dimension (matrix-core tiles go underutilized below ~128).
    pub fn efficiency(&self, m: usize, n: usize, k: usize) -> f64 {
        let min_dim = m.min(n).min(k) as f64;
        (min_dim / self.full_eff_dim).clamp(self.min_eff, 1.0)
    }

    /// Time of one (M x K) @ (K x N) GEMM in seconds.
    pub fn gemm_s(&self, m: usize, n: usize, k: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        flops / (self.peak_flops * self.efficiency(m, n, k)) + self.launch_overhead_s
    }
}

/// A workload point: one (mode, n, L, p, k, batch) cell of a paper figure.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub n: usize,
    pub layers: usize,
    pub p: usize,
    pub k: usize,
    pub batch: usize,
}

impl Workload {
    /// Validated constructor: the only way callers should obtain a Workload
    /// they intend to price. Rejects geometries the runtime cannot run
    /// (non-divisor n/p) and PP widths outside the paper's Eqn. 8 regime.
    pub fn new(n: usize, layers: usize, p: usize, k: usize, batch: usize) -> Result<Workload> {
        let w = Workload { n, layers, p, k, batch };
        w.validate()?;
        Ok(w)
    }

    /// Feasibility guard enforced by `predict`/`predict_forward` and the
    /// planner. Checks, in order:
    ///   * positive n / layers / batch,
    ///   * p >= 2 — at p = 1 every collective is free (simnet prices
    ///     p <= 1 at zero), so a single-rank cell would always "win";
    ///     the dense baseline must be priced explicitly, not through the
    ///     parallel cost model,
    ///   * p | n — `m()` floor-divides, so a non-divisor geometry would be
    ///     silently priced as a smaller model than requested while
    ///     `RunConfig::validate` rejects it at runtime,
    ///   * k < m (hard width requirement), and
    ///   * Eqn. 8: k < m * (1 - 1/p), the precondition for every PP-vs-TP
    ///     complexity claim the model encodes (k is ignored by TP math, but
    ///     a Workload carries one value for both modes; TP cells use k = 0).
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.layers == 0 || self.batch == 0 {
            bail!(
                "workload n={}, layers={}, batch={} must all be positive",
                self.n,
                self.layers,
                self.batch
            );
        }
        if self.p < 2 {
            bail!(
                "p={} has no parallel decomposition: the collective model prices p <= 1 \
                 communication as free, so single-rank cells must be priced as the dense \
                 baseline, not through predict()",
                self.p
            );
        }
        if self.n % self.p != 0 {
            bail!(
                "n={} is not divisible by p={}: this geometry cannot run (RunConfig \
                 rejects it) and must not be priced",
                self.n,
                self.p
            );
        }
        let m = self.n / self.p;
        if self.k >= m {
            bail!("k={} must be < n/p = {m}", self.k);
        }
        let bound = m as f64 * (1.0 - 1.0 / self.p as f64);
        if self.k as f64 >= bound {
            bail!(
                "k={} violates Eqn. 8: k < (n/p)(1 - 1/p) = {bound:.1} at n={}, p={}",
                self.k,
                self.n,
                self.p
            );
        }
        Ok(())
    }

    /// Per-rank slice width n/p. Callers must hold a validated workload;
    /// the division floors otherwise (the bug `validate` exists to stop).
    pub fn m(&self) -> usize {
        debug_assert!(
            self.p > 0 && self.n % self.p == 0,
            "unvalidated workload: n={} p={}",
            self.n,
            self.p
        );
        self.n / self.p
    }
}

/// Predicted per-iteration cost breakdown for one rank.
#[derive(Debug, Clone, Copy, Default)]
pub struct IterCost {
    /// Device compute seconds (alpha contribution of this rank).
    pub compute_s: f64,
    /// Communication seconds (beta contribution).
    pub comm_s: f64,
    /// Host dispatch seconds (device idle while the host drives per-peer
    /// modules; zero for TP whose per-layer module count is O(1)).
    pub dispatch_s: f64,
}

impl IterCost {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s + self.dispatch_s
    }

    /// Energy per iteration for this rank (paper Eqn. 1): busy time at A,
    /// communication and host-dispatch stalls at B.
    pub fn energy_j(&self, power: &PowerModel) -> f64 {
        power.busy_w * self.compute_s + power.idle_w * (self.comm_s + self.dispatch_s)
    }
}

// ---------------------------------------------------------------------------
// Tensor parallelism (paper Sec. II-B, Table II)
// ---------------------------------------------------------------------------

/// TP per-rank compute seconds per iteration.
pub fn tp_compute_s(w: &Workload, g: &GemmModel) -> f64 {
    let (b, n, m, l) = (w.batch, w.n, w.m(), w.layers);
    let fwd = g.gemm_s(b, m, n); // y_full @ W
    let grads = g.gemm_s(n, m, b); // y_full^T @ delta
    let partial = g.gemm_s(b, n, m); // delta @ W^T   (L-1 layers)
    (l as f64) * (fwd + grads) + ((l - 1) as f64) * partial
}

/// TP per-rank communication seconds per iteration (Table II schedule).
pub fn tp_comm_s(w: &Workload, net: &NetworkProfile) -> f64 {
    let (b, n, m, l, p) = (w.batch, w.n, w.m(), w.layers, w.p);
    let fwd = net.time(Collective::AllGather, m * b, p) + net.time(Collective::Broadcast, n * b, p);
    let bwd_each = net.time(Collective::ReduceScatter, m * b, p);
    let bwd_prop = net.time(Collective::AllReduce, n * b, p);
    (l as f64) * fwd + ((l - 1) as f64) * bwd_prop + ((l - 1) as f64) * bwd_each
}

/// TP per-rank memory footprint in bytes: parameters + gradients + two
/// optimizer slots (Adam-style, f32) + forward stash (y_full per layer).
pub fn tp_rank_mem_bytes(w: &Workload) -> u64 {
    let (b, n, m, l) = (w.batch as u64, w.n as u64, w.m() as u64, w.layers as u64);
    let stash = l * (b * n + 2 * b * m);
    4 * (4 * tp_rank_param_floats(w) + stash)
}

/// TP per-rank parameter count in floats: the column shard W[:, m] plus
/// bias slice, per layer. This is also the per-rank gradient payload of the
/// hybrid DP All-Reduce.
pub fn tp_rank_param_floats(w: &Workload) -> u64 {
    let (n, m, l) = (w.n as u64, w.m() as u64, w.layers as u64);
    l * (n * m + m)
}

// ---------------------------------------------------------------------------
// Phantom parallelism (paper Sec. IV)
// ---------------------------------------------------------------------------

/// PP per-rank compute seconds per iteration, following the coordinator's
/// exact GEMM schedule (rank_pp.rs).
pub fn pp_compute_s(w: &Workload, g: &GemmModel) -> f64 {
    let (b, m, k, p, l) = (w.batch, w.m(), w.k, w.p, w.layers);
    let pm1 = (p - 1) as f64;
    // forward: local + compress (fused on TPU; two GEMMs on GPU) +
    // per-source decompression
    let fwd = g.gemm_s(b, m, m) + g.gemm_s(b, k, m) + pm1 * g.gemm_s(b, m, k);
    // backward: error compression to p destinations, gradient GEMMs,
    // delta propagation
    let bwd_compress = (p as f64) * g.gemm_s(b, k, m);
    let bwd_grads = g.gemm_s(m, m, b) + g.gemm_s(m, k, b) + pm1 * g.gemm_s(k, m, b);
    let bwd_combine = g.gemm_s(b, m, m) + g.gemm_s(b, m, k);
    (l as f64) * (fwd + bwd_compress + bwd_grads) + ((l - 1) as f64) * bwd_combine
}

/// PP host-dispatch seconds per iteration: per layer the host touches the
/// full decompressed width (batch * n floats across the p-1 outputs) and
/// pays quadratic peer bookkeeping (p module lists over p slots). Charged
/// at idle power (the device waits on the host).
pub fn pp_dispatch_s(w: &Workload, g: &GemmModel) -> f64 {
    let per_layer = g.host_float_s * (w.batch as f64) * (w.n as f64)
        + g.peer_quad_s * (w.p as f64) * (w.p as f64);
    (w.layers as f64) * per_layer
}

/// PP per-rank communication seconds per iteration (Table II: one k*batch
/// All-Gather forward, one k*batch Reduce-Scatter backward, per layer).
pub fn pp_comm_s(w: &Workload, net: &NetworkProfile) -> f64 {
    let (b, k, p, l) = (w.batch, w.k, w.p, w.layers);
    (l as f64)
        * (net.time(Collective::AllGather, k * b, p)
            + net.time(Collective::ReduceScatter, k * b, p))
}

/// Exposed PP communication seconds per iteration under a pipeline
/// schedule (DESIGN.md §15). The batch is split into `micro` row chunks
/// with the DP remainder tiling (first `batch % micro` chunks get one
/// extra row).
///
/// * `sync` (GPipe-style): every chunk's collectives are exposed — the
///   sum over chunks, which at micro = 1 is exactly `pp_comm_s` and grows
///   with micro (each chunk pays the per-collective latency term).
/// * `1f1b`: only the pipeline-fill (micro 0's forward All-Gathers) and
///   drain (the last micro's backward Reduce-Scatters) are exposed; the
///   steady state hides interior wire time under neighboring micro-batch
///   compute (the ledger's deferral register). This is the optimistic
///   bound — the runtime charges any un-hidden remainder, the model
///   prices the fill/drain bubble floor.
///
/// Invariants (pinned by tests): 1f1b <= sync at every micro, with
/// equality at micro = 1.
pub fn pp_schedule_comm_s(
    w: &Workload,
    net: &NetworkProfile,
    micro: usize,
    one_f_one_b: bool,
) -> f64 {
    let micro = micro.clamp(1, w.batch.max(1));
    let rows = |i: usize| w.batch / micro + usize::from(i < w.batch % micro);
    let l = w.layers as f64;
    if !one_f_one_b || micro == 1 {
        (0..micro)
            .map(|i| {
                l * (net.time(Collective::AllGather, w.k * rows(i), w.p)
                    + net.time(Collective::ReduceScatter, w.k * rows(i), w.p))
            })
            .sum()
    } else {
        l * net.time(Collective::AllGather, w.k * rows(0), w.p)
            + l * net.time(Collective::ReduceScatter, w.k * rows(micro - 1), w.p)
    }
}

/// PP per-rank memory footprint in bytes.
pub fn pp_rank_mem_bytes(w: &Workload) -> u64 {
    let (b, m, k, p, l) = (w.batch as u64, w.m() as u64, w.k as u64, w.p as u64, w.layers as u64);
    let stash = l * (2 * b * m + p * b * k);
    4 * (4 * pp_rank_param_floats(w) + stash)
}

/// PP per-rank parameter count in floats: local block, compressor, p
/// decompressors and bias slice, per layer. The DP All-Reduce payload.
pub fn pp_rank_param_floats(w: &Workload) -> u64 {
    let (m, k, p, l) = (w.m() as u64, w.k as u64, w.p as u64, w.layers as u64);
    l * (m * m + m * k + p * k * m + m)
}

/// Per-rank parameter floats for a mode — the gradient payload one rank
/// contributes to the hybrid DP gradient All-Reduce.
pub fn rank_param_floats(mode: crate::config::Parallelism, w: &Workload) -> u64 {
    match mode {
        crate::config::Parallelism::Tensor => tp_rank_param_floats(w),
        crate::config::Parallelism::Phantom => pp_rank_param_floats(w),
    }
}

/// Frontier GCD HBM capacity (bytes): 64 GB.
pub const FRONTIER_HBM_BYTES: u64 = 64 * (1 << 30);

/// Full per-iteration (forward + backward + update) prediction for a
/// workload in one mode. Fails on workloads that violate the feasibility
/// guard (`Workload::validate`): non-divisor n/p, p < 2, or Eqn. 8.
pub fn predict(
    mode: crate::config::Parallelism,
    w: &Workload,
    g: &GemmModel,
    net: &NetworkProfile,
) -> Result<IterCost> {
    w.validate()?;
    Ok(match mode {
        crate::config::Parallelism::Tensor => IterCost {
            compute_s: tp_compute_s(w, g),
            comm_s: tp_comm_s(w, net),
            dispatch_s: 0.0,
        },
        crate::config::Parallelism::Phantom => IterCost {
            compute_s: pp_compute_s(w, g),
            comm_s: pp_comm_s(w, net),
            dispatch_s: pp_dispatch_s(w, g),
        },
    })
}

/// Forward-only (inference) per-rank prediction: the cost of serving one
/// batch of `w.batch` queries. Same feasibility guard as `predict`.
///
/// TP forward per layer: the local GEMM against the column shard, an
/// All-Gather of the m*b partial and the n*b activation Broadcast. PP
/// forward per layer: local block + compressor GEMMs, (p-1) decompressions,
/// one k*b All-Gather, and the host-side assembly of the decompressor
/// outputs (batch * n floats) — the backward-only gradient-aggregation
/// bookkeeping (peer_quad_s) is not charged.
pub fn predict_forward(
    mode: crate::config::Parallelism,
    w: &Workload,
    g: &GemmModel,
    net: &NetworkProfile,
) -> Result<IterCost> {
    w.validate()?;
    let (b, m, k, p, l) = (w.batch, w.m(), w.k, w.p, w.layers as f64);
    Ok(match mode {
        crate::config::Parallelism::Tensor => IterCost {
            compute_s: l * g.gemm_s(b, m, w.n),
            comm_s: l
                * (net.time(Collective::AllGather, m * b, p)
                    + net.time(Collective::Broadcast, w.n * b, p)),
            dispatch_s: 0.0,
        },
        crate::config::Parallelism::Phantom => IterCost {
            compute_s: l
                * (g.gemm_s(b, m, m) + g.gemm_s(b, k, m) + (p - 1) as f64 * g.gemm_s(b, m, k)),
            comm_s: l * net.time(Collective::AllGather, k * b, p),
            dispatch_s: l * g.host_float_s * (b as f64) * (w.n as f64),
        },
    })
}

/// Does this workload fit in GCD memory?
pub fn fits_memory(mode: crate::config::Parallelism, w: &Workload) -> bool {
    let bytes = match mode {
        crate::config::Parallelism::Tensor => tp_rank_mem_bytes(w),
        crate::config::Parallelism::Phantom => pp_rank_mem_bytes(w),
    };
    bytes <= FRONTIER_HBM_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Parallelism::{Phantom, Tensor};

    fn net() -> NetworkProfile {
        NetworkProfile::frontier()
    }

    fn gm() -> GemmModel {
        GemmModel::frontier()
    }

    #[test]
    fn efficiency_curve_monotone() {
        let g = gm();
        assert!(g.efficiency(32, 512, 512) < g.efficiency(128, 512, 512));
        assert_eq!(g.efficiency(128, 256, 512), 1.0);
        assert!(g.efficiency(1, 1, 1) >= g.min_eff);
    }

    #[test]
    fn alpha_pi_less_than_alpha_tau_under_eqn8() {
        // Paper Eqn. (7): total PP FLOPs < total TP FLOPs when Eqn. (8)
        // holds. Check raw FLOP counts (efficiency-independent).
        for (n, p, k) in [(16_384, 32, 64), (65_536, 64, 64), (131_072, 128, 64)] {
            let w = Workload { n, layers: 2, p, k, batch: 32 };
            let m = w.m();
            assert!((k as f64) < m as f64 * (1.0 - 1.0 / p as f64), "precondition");
            // FLOP counts per rank (drop overheads by zeroing them)
            let ideal = GemmModel {
                launch_overhead_s: 0.0,
                host_float_s: 0.0,
                peer_quad_s: 0.0,
                min_eff: 1.0,
                full_eff_dim: 1.0,
                ..gm()
            };
            let pp = pp_compute_s(&w, &ideal);
            let tp = tp_compute_s(&w, &ideal);
            assert!(pp < tp, "n={n} p={p}: pp={pp} tp={tp}");
        }
    }

    #[test]
    fn beta_pi_less_than_beta_tau() {
        // Paper Eqn. (9) at the paper's Fig. 5a point: n=65536, L=6, k=64.
        for p in [32, 64, 128] {
            let w = Workload { n: 65_536, layers: 6, p, k: 64, batch: 32 };
            let pp = pp_comm_s(&w, &net());
            let tp = tp_comm_s(&w, &net());
            assert!(pp < tp, "p={p}: pp={pp} tp={tp}");
            // Fig 5a shows PP comm several times below TP
            assert!(tp / pp > 3.0, "p={p}: ratio {}", tp / pp);
        }
    }

    #[test]
    fn fig6_flip_flop_at_131072() {
        // Paper Fig. 6 (left): at n=131072, k=64, PP wins up to p=128 but
        // TP overtakes at p=256.
        let g = gm();
        for p in [32, 64, 128] {
            let w = Workload { n: 131_072, layers: 2, p, k: 64, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).unwrap().total_s();
            let tp = predict(Tensor, &w, &g, &net()).unwrap().total_s();
            assert!(pp < tp, "p={p}: pp={pp} tp={tp} (PP should win)");
        }
        let w = Workload { n: 131_072, layers: 2, p: 256, k: 64, batch: 32 };
        let pp = predict(Phantom, &w, &g, &net()).unwrap().total_s();
        let tp = predict(Tensor, &w, &g, &net()).unwrap().total_s();
        assert!(tp < pp, "p=256 flip-flop: tp={tp} pp={pp} (TP should win)");
    }

    #[test]
    fn fig6_no_flip_at_262144() {
        // Paper Fig. 6 (right): at n=262144 PP wins across ALL tested p.
        let g = gm();
        for p in [64, 128, 256] {
            let w = Workload { n: 262_144, layers: 2, p, k: 64, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).unwrap().total_s();
            let tp = predict(Tensor, &w, &g, &net()).unwrap().total_s();
            assert!(pp < tp, "p={p}: pp={pp} tp={tp}");
        }
    }

    #[test]
    fn fig6_tp_oom_at_262144_p32() {
        // Paper: "TP could not be executed on p=32 due to memory exhaustion"
        let w = Workload { n: 262_144, layers: 2, p: 32, k: 64, batch: 32 };
        assert!(!fits_memory(Tensor, &w), "TP at n=262144 p=32 must OOM");
        assert!(fits_memory(Phantom, &w), "PP must fit (reduced footprint)");
        // and TP fits at p=64
        let w64 = Workload { n: 262_144, layers: 2, p: 64, k: 64, batch: 32 };
        assert!(fits_memory(Tensor, &w64));
    }

    #[test]
    fn pp_memory_below_tp() {
        for p in [8, 32, 128] {
            let w = Workload { n: 131_072, layers: 2, p, k: 64, batch: 32 };
            assert!(pp_rank_mem_bytes(&w) < tp_rank_mem_bytes(&w), "p={p}");
        }
    }

    #[test]
    fn energy_per_iter_pp_below_tp() {
        // Eqn. (10): e_pi < e_tau for fixed n, p, L with k < n/p.
        // Asserted for the small-p regime the paper's Table I covers most
        // clearly; at p >= 64 the model's dispatch calibration (tuned to
        // the Fig. 6 crossover) overestimates PP overhead at n = 16,384 —
        // measured-mode runs cover that regime (see EXPERIMENTS.md).
        let power = PowerModel::frontier();
        let g = gm();
        for (n, p) in [(16_384, 8), (16_384, 16), (65_536, 64)] {
            let w = Workload { n, layers: 2, p, k: 16, batch: 32 };
            let pp = predict(Phantom, &w, &g, &net()).unwrap().energy_j(&power);
            let tp = predict(Tensor, &w, &g, &net()).unwrap().energy_j(&power);
            assert!(pp < tp, "n={n} p={p}: pp={pp} tp={tp}");
        }
    }

    #[test]
    fn non_divisor_geometry_cannot_be_priced() {
        // Regression (ISSUE 7): m() used to floor-divide silently, so a
        // (n=100, p=3) workload was priced as if n were 99.
        assert!(Workload::new(100, 2, 3, 4, 32).is_err());
        let w = Workload { n: 100, layers: 2, p: 3, k: 4, batch: 32 };
        for mode in [Tensor, Phantom] {
            assert!(predict(mode, &w, &gm(), &net()).is_err(), "{mode:?}");
            assert!(predict_forward(mode, &w, &gm(), &net()).is_err(), "{mode:?}");
        }
    }

    #[test]
    fn eqn8_violations_are_rejected() {
        // k >= m: hard width violation.
        assert!(Workload::new(64, 2, 4, 16, 32).is_err());
        // k in [m(1-1/p), m): passes the hard check, fails Eqn. 8.
        // n=64, p=4: m=16, bound = 12. k=13 must be rejected, k=11 accepted.
        assert!(Workload::new(64, 2, 4, 13, 32).is_err());
        assert!(Workload::new(64, 2, 4, 11, 32).is_ok());
        let w = Workload { n: 64, layers: 2, p: 4, k: 13, batch: 32 };
        assert!(predict(Phantom, &w, &gm(), &net()).is_err());
    }

    #[test]
    fn p1_cannot_be_priced_through_the_parallel_model() {
        // simnet prices p <= 1 collectives at zero; predict() must refuse
        // rather than report free communication for a single-rank "cluster".
        assert!(Workload::new(64, 2, 1, 0, 32).is_err());
        let w = Workload { n: 64, layers: 2, p: 1, k: 0, batch: 32 };
        for mode in [Tensor, Phantom] {
            assert!(predict(mode, &w, &gm(), &net()).is_err(), "{mode:?}");
        }
    }

    #[test]
    fn forward_prediction_is_a_strict_subset_of_training() {
        for mode in [Tensor, Phantom] {
            let w = Workload::new(16_384, 2, 16, 16, 32).unwrap();
            let full = predict(mode, &w, &gm(), &net()).unwrap();
            let fwd = predict_forward(mode, &w, &gm(), &net()).unwrap();
            assert!(fwd.compute_s > 0.0 && fwd.compute_s < full.compute_s, "{mode:?}");
            assert!(fwd.comm_s > 0.0 && fwd.comm_s < full.comm_s, "{mode:?}");
            assert!(fwd.dispatch_s <= full.dispatch_s, "{mode:?}");
        }
    }

    #[test]
    fn one_f_one_b_exposed_comm_never_exceeds_sync() {
        let w = Workload::new(16_384, 4, 16, 16, 32).unwrap();
        let n = net();
        let base = pp_comm_s(&w, &n);
        for micro in [1usize, 2, 4, 8] {
            let sync = pp_schedule_comm_s(&w, &n, micro, false);
            let ofob = pp_schedule_comm_s(&w, &n, micro, true);
            assert!(
                ofob <= sync + 1e-15,
                "micro={micro}: 1f1b exposed {ofob} > sync {sync}"
            );
            if micro == 1 {
                assert!((sync - base).abs() < 1e-15, "sync micro=1 must equal pp_comm_s");
                assert!((ofob - base).abs() < 1e-15, "1f1b micro=1 must equal pp_comm_s");
            } else {
                assert!(
                    ofob < sync,
                    "micro={micro}: 1f1b must strictly beat sync ({ofob} vs {sync})"
                );
                assert!(sync >= base, "chunking adds latency, never removes it");
            }
        }
        // Deeper pipelines shrink the exposed fraction: the fill/drain
        // bubble is one chunk's collectives, which shrink with micro.
        let e2 = pp_schedule_comm_s(&w, &n, 2, true);
        let e8 = pp_schedule_comm_s(&w, &n, 8, true);
        assert!(e8 < e2, "more micro-batches must shrink the exposed bubble");
    }

    #[test]
    fn param_floats_match_memory_model_and_dp_payload_sanity() {
        let w = Workload::new(1024, 2, 8, 16, 32).unwrap();
        // Eqn. 8 regime: PP carries fewer parameters per rank than TP.
        assert!(pp_rank_param_floats(&w) < tp_rank_param_floats(&w));
        assert_eq!(rank_param_floats(Tensor, &w), tp_rank_param_floats(&w));
        assert_eq!(rank_param_floats(Phantom, &w), pp_rank_param_floats(&w));
        // The memory model counts 4 f32 copies of the parameters + stash.
        assert!(tp_rank_mem_bytes(&w) > 16 * tp_rank_param_floats(&w));
    }
}
