//! Calibration layer: fit the analytic model's constants to measured
//! BENCH records, falling back to the paper's Table III / Frontier
//! constants with a logged warning when measurements are absent.
//!
//! Record format is the flat perf-trajectory schema
//! (`util::json::read_records_json` — one object of numbers), so a
//! calibration file is just another BENCH_*.json. Recognized keys:
//!
//!   gemm_m{M}_n{N}_k{K}_gflops    measured rate of an (M x K)@(K x N) GEMM
//!   comm_{coll}_m{M}_p{P}_us      collective time, coll in {bcast,
//!                                 allreduce, allgather, reducescatter}
//!   run{I}_busy_s / run{I}_stall_s / run{I}_energy_j
//!                                 per-run Eqn. 1 summaries for the power fit
//!   power_busy_w / power_idle_w   direct power override (wins over runs)
//!   gemm_launch_overhead_s, gemm_host_float_s, gemm_peer_quad_s
//!                                 direct GEMM-overhead overrides
//!
//! Unknown keys are ignored (BENCH files carry other records too). Each
//! constant group falls back independently: a file with only GEMM rows
//! still calibrates the GEMM curve while the network and power stay at
//! their defaults, each fallback noted in `warnings`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::energy::{fit_power, PowerModel};
use crate::simnet::{self, Collective, NetworkProfile, Observation};

use super::GemmModel;

/// Default committed fixture (relative to the repo root): the measured
/// seed the planner's tests and CI calibrate against.
pub const DEFAULT_CALIB_PATH: &str = "ci/bench_seed/BENCH_calib.json";

/// Where a calibration's constants came from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CalibSource {
    /// Fitted from a measured record file.
    Measured(String),
    /// Table III / Frontier defaults (no usable measurements).
    Defaults,
}

impl CalibSource {
    pub fn describe(&self) -> String {
        match self {
            CalibSource::Measured(path) => format!("measured ({path})"),
            CalibSource::Defaults => "Table III / Frontier defaults".to_string(),
        }
    }
}

/// A complete set of model constants, with provenance.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub gemm: GemmModel,
    pub net: NetworkProfile,
    pub power: PowerModel,
    pub source: CalibSource,
    /// One note per constant group that fell back to defaults.
    pub warnings: Vec<String>,
}

impl Calibration {
    /// The uncalibrated baseline: paper constants everywhere.
    pub fn frontier_defaults() -> Calibration {
        Calibration {
            gemm: GemmModel::frontier(),
            net: NetworkProfile::frontier(),
            power: PowerModel::frontier(),
            source: CalibSource::Defaults,
            warnings: Vec::new(),
        }
    }

    /// Load a record file and fit. A missing or unreadable file is a
    /// logged fallback (a warning in the returned calibration), NOT an
    /// error — the planner must run on a fresh checkout with no
    /// measurements at all.
    pub fn load_or_default(path: &Path) -> Calibration {
        match crate::util::json::read_records_json(path) {
            Ok(records) => {
                let mut c = Calibration::from_records(&records);
                c.source = CalibSource::Measured(path.display().to_string());
                c
            }
            Err(e) => {
                let mut c = Calibration::frontier_defaults();
                c.warnings.push(format!(
                    "{}: {e}; using Table III / Frontier defaults for all constants",
                    path.display()
                ));
                c
            }
        }
    }

    /// Print every fallback warning to stderr (the "logged" part of the
    /// logged-fallback contract).
    pub fn log_warnings(&self) {
        for w in &self.warnings {
            crate::log_warn!("calib: warning: {w}");
        }
    }

    /// Fit each constant group from whatever rows are present.
    pub fn from_records(records: &[(String, f64)]) -> Calibration {
        let mut c = Calibration::frontier_defaults();
        c.source = CalibSource::Measured("<records>".to_string());
        fit_gemm(records, &mut c);
        fit_net(records, &mut c);
        fit_power_group(records, &mut c);
        c
    }

    /// Calibrate from the real measured trajectories the other subsystems
    /// emit at the repo root — the kernel gate's GEMM timings
    /// (BENCH_kernels.json), the hybrid smoke's Eqn. 1 energy summaries
    /// (BENCH_hybrid.json), and any calib-format rows a future serve bench
    /// emits (BENCH_serve.json) — falling back to the committed
    /// `ci/bench_seed` fixture for every group no real trajectory covers,
    /// and entirely when none of the three files exists.
    ///
    /// Merge rule per group: >= 3 real GEMM points displace the seed's GEMM
    /// rows; >= 2 real run triples (or a direct power override) displace the
    /// seed's power rows; the seed's collective rows are always kept, since
    /// no current bench times isolated collectives. `source` names the
    /// contributing files so the planner can log provenance.
    pub fn auto_load(root: &Path) -> Calibration {
        let read = |name: &str| {
            crate::util::json::read_records_json(&root.join(name)).unwrap_or_default()
        };
        let mut real: Vec<(String, f64)> = Vec::new();
        let mut sources: Vec<&str> = Vec::new();
        let kernel_rows = translate_kernel_records(&read("BENCH_kernels.json"));
        if !kernel_rows.is_empty() {
            sources.push("BENCH_kernels.json");
            real.extend(kernel_rows);
        }
        let hybrid_rows = translate_hybrid_records(&read("BENCH_hybrid.json"));
        if !hybrid_rows.is_empty() {
            sources.push("BENCH_hybrid.json");
            real.extend(hybrid_rows);
        }
        // Serve rows are already flat records; none match calib keys today,
        // but the fitter ignores unknown rows, so a future serve schema that
        // emits calib-format rows calibrates with no loader change.
        let serve_rows = read("BENCH_serve.json");
        if !serve_rows.is_empty() {
            sources.push("BENCH_serve.json");
            real.extend(serve_rows);
        }
        if sources.is_empty() {
            let mut c = Self::load_or_default(&root.join(DEFAULT_CALIB_PATH));
            c.warnings.insert(
                0,
                "no measured BENCH_{kernels,hybrid,serve}.json trajectories found; \
                 calibrating from the committed seed fixture"
                    .to_string(),
            );
            return c;
        }
        let real_gemm_points = real.iter().filter(|(k, _)| is_gemm_point_row(k)).count();
        let real_run_triples = real
            .iter()
            .filter(|(k, _)| k.starts_with("run") && k.ends_with("_energy_j"))
            .count();
        let real_power_override = real.iter().any(|(k, _)| k == "power_busy_w")
            && real.iter().any(|(k, _)| k == "power_idle_w");
        let seed = crate::util::json::read_records_json(&root.join(DEFAULT_CALIB_PATH))
            .unwrap_or_default();
        let mut records: Vec<(String, f64)> = seed
            .into_iter()
            .filter(|(k, _)| {
                if real_gemm_points >= 3 && is_gemm_point_row(k) {
                    return false;
                }
                if (real_run_triples >= 2 || real_power_override) && is_power_row(k) {
                    return false;
                }
                true
            })
            .collect();
        records.extend(real);
        let mut c = Calibration::from_records(&records);
        c.source = CalibSource::Measured(format!(
            "{} (+ seed fixture for unmeasured groups)",
            sources.join(" + ")
        ));
        c
    }
}

/// A `gemm_m{M}_n{N}_k{K}_gflops` rate row (not a direct overhead override).
fn is_gemm_point_row(key: &str) -> bool {
    let toks: Vec<&str> = key.split('_').collect();
    matches!(toks.as_slice(), ["gemm", m, n, k, "gflops"]
        if field(m, "m").is_some() && field(n, "n").is_some() && field(k, "k").is_some())
}

/// A row the power fitter consumes: direct overrides or run triples.
fn is_power_row(key: &str) -> bool {
    key == "power_busy_w"
        || key == "power_idle_w"
        || (key.starts_with("run")
            && (key.ends_with("_busy_s")
                || key.ends_with("_stall_s")
                || key.ends_with("_energy_j")))
}

/// Translate the kernel gate's tuned-engine wall times
/// (`gemm_{m}x{k}x{n}_ns`, the simulator's own GEMM engine) into
/// `gemm_m{M}_n{N}_k{K}_gflops` rate rows. The shape string is in
/// (m, k, n) order; naive/seed reference timings and speedup ratios are
/// skipped — only the engine the measured simulator actually runs
/// calibrates the planner.
fn translate_kernel_records(records: &[(String, f64)]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (key, ns) in records {
        let Some(shape) = key.strip_prefix("gemm_").and_then(|s| s.strip_suffix("_ns")) else {
            continue;
        };
        if shape.contains('_') {
            continue; // gemm_naive_* / gemm_seed_* reference engines
        }
        let dims: Vec<usize> = shape.split('x').filter_map(|t| t.parse().ok()).collect();
        if let [m, k, n] = dims.as_slice() {
            if *ns > 0.0 && *m > 0 && *k > 0 && *n > 0 {
                // gflops = flops / ns: 2mkn flops / (ns * 1e-9) s / 1e9.
                let gflops = 2.0 * (*m * *k * *n) as f64 / ns;
                out.push((format!("gemm_m{m}_n{n}_k{k}_gflops"), gflops));
            }
        }
    }
    out
}

/// Translate the hybrid smoke's per-mode Eqn. 1 summaries
/// (`hybrid_{tag}_{busy_s, comm_s, dp_comm_s, energy_train_j}`) into the
/// power fitter's `run{I}_busy_s/_stall_s/_energy_j` triples, with stall
/// time the sum of boundary and data-parallel communication. Incomplete
/// groups are dropped.
fn translate_hybrid_records(records: &[(String, f64)]) -> Vec<(String, f64)> {
    // tag -> (busy, comm, dp_comm, energy)
    let mut runs: BTreeMap<String, [Option<f64>; 4]> = BTreeMap::new();
    for (key, v) in records {
        let Some(rest) = key.strip_prefix("hybrid_") else { continue };
        // Longest suffix first: `_dp_comm_s` also ends with `_comm_s`.
        let (tag, slot) = if let Some(t) = rest.strip_suffix("_dp_comm_s") {
            (t, 2)
        } else if let Some(t) = rest.strip_suffix("_comm_s") {
            (t, 1)
        } else if let Some(t) = rest.strip_suffix("_busy_s") {
            (t, 0)
        } else if let Some(t) = rest.strip_suffix("_energy_train_j") {
            (t, 3)
        } else {
            continue;
        };
        runs.entry(tag.to_string()).or_default()[slot] = Some(*v);
    }
    let mut out = Vec::new();
    for (i, vals) in runs.values().enumerate() {
        if let [Some(busy), Some(comm), Some(dp_comm), Some(energy)] = vals {
            out.push((format!("run{i}_busy_s"), *busy));
            out.push((format!("run{i}_stall_s"), comm + dp_comm));
            out.push((format!("run{i}_energy_j"), *energy));
        }
    }
    out
}

/// Parse `prefix{num}` into num, e.g. field("m256", "m") == Some(256).
fn field(tok: &str, prefix: &str) -> Option<usize> {
    tok.strip_prefix(prefix)?.parse().ok()
}

fn fit_gemm(records: &[(String, f64)], c: &mut Calibration) {
    // gemm_m{M}_n{N}_k{K}_gflops -> (m, n, k, flops_per_s)
    let mut points: Vec<(usize, usize, usize, f64)> = Vec::new();
    for (key, v) in records {
        let toks: Vec<&str> = key.split('_').collect();
        if let ["gemm", m, n, k, "gflops"] = toks.as_slice() {
            if let (Some(m), Some(n), Some(k)) = (field(m, "m"), field(n, "n"), field(k, "k")) {
                if *v > 0.0 && m > 0 && n > 0 && k > 0 {
                    points.push((m, n, k, v * 1e9));
                }
            }
        }
    }
    for (key, v) in records {
        match key.as_str() {
            "gemm_launch_overhead_s" => c.gemm.launch_overhead_s = v.max(0.0),
            "gemm_host_float_s" => c.gemm.host_float_s = v.max(0.0),
            "gemm_peer_quad_s" => c.gemm.peer_quad_s = v.max(0.0),
            _ => {}
        }
    }
    if points.len() < 3 {
        c.warnings.push(format!(
            "gemm: {} measured rate(s), need >= 3; keeping Frontier GEMM curve",
            points.len()
        ));
        return;
    }
    // The model is rate = peak * clamp(min_dim / full_eff_dim, min_eff, 1):
    // peak comes from the saturated shapes, the knee from the unsaturated
    // ones (est = min_dim * peak / rate), the floor from the slowest shape.
    let peak = points.iter().map(|p| p.3).fold(0.0f64, f64::max);
    let mut knees: Vec<f64> = points
        .iter()
        .filter(|&&(_, _, _, rate)| rate < 0.95 * peak)
        .map(|&(m, n, k, rate)| m.min(n).min(k) as f64 * peak / rate)
        .collect();
    c.gemm.peak_flops = peak;
    if knees.is_empty() {
        c.warnings.push(
            "gemm: all measured shapes saturated; keeping Frontier efficiency knee".to_string(),
        );
    } else {
        knees.sort_by(|a, b| a.total_cmp(b));
        c.gemm.full_eff_dim = knees[knees.len() / 2].clamp(1.0, 65_536.0);
    }
    let slowest = points.iter().map(|p| p.3).fold(f64::INFINITY, f64::min);
    c.gemm.min_eff = (slowest / peak).clamp(1e-3, 0.5);
}

fn fit_net(records: &[(String, f64)], c: &mut Calibration) {
    let mut obs: BTreeMap<&'static str, Vec<Observation>> = BTreeMap::new();
    for (key, v) in records {
        let toks: Vec<&str> = key.split('_').collect();
        if let ["comm", coll, m, p, "us"] = toks.as_slice() {
            if let (Some(m), Some(p)) = (field(m, "m"), field(p, "p")) {
                if *v > 0.0 && p >= 2 {
                    if let Some(name) = collective_key(coll) {
                        obs.entry(name)
                            .or_default()
                            .push(Observation { msg_floats: m, p, time_us: *v });
                    }
                }
            }
        }
    }
    for coll in Collective::ALL {
        let key = collective_key_of(coll);
        let rows = obs.get(key).map(|v| v.as_slice()).unwrap_or(&[]);
        match simnet::fit(rows) {
            Some(fitted) => *model_slot(&mut c.net, coll) = fitted.model,
            None => c.warnings.push(format!(
                "net: {} timing row(s) for {key}, need >= 3; keeping Table III {}",
                rows.len(),
                coll.name()
            )),
        }
    }
}

fn fit_power_group(records: &[(String, f64)], c: &mut Calibration) {
    let direct_busy = records.iter().find(|(k, _)| k == "power_busy_w").map(|(_, v)| *v);
    let direct_idle = records.iter().find(|(k, _)| k == "power_idle_w").map(|(_, v)| *v);
    if let (Some(busy_w), Some(idle_w)) = (direct_busy, direct_idle) {
        if busy_w > idle_w && idle_w >= 0.0 {
            c.power = PowerModel { busy_w, idle_w };
            return;
        }
        c.warnings.push(format!(
            "power: direct override busy={busy_w} idle={idle_w} is unphysical; ignoring it"
        ));
    }
    // run{I}_busy_s / _stall_s / _energy_j triples.
    let mut runs: BTreeMap<usize, (Option<f64>, Option<f64>, Option<f64>)> = BTreeMap::new();
    for (key, v) in records {
        let toks: Vec<&str> = key.split('_').collect();
        if let [run, a, b] = toks.as_slice() {
            if let Some(i) = field(run, "run") {
                let slot = runs.entry(i).or_default();
                match (*a, *b) {
                    ("busy", "s") => slot.0 = Some(*v),
                    ("stall", "s") => slot.1 = Some(*v),
                    ("energy", "j") => slot.2 = Some(*v),
                    _ => {}
                }
            }
        }
    }
    let rows: Vec<(f64, f64, f64)> = runs
        .values()
        .filter_map(|&(b, s, e)| Some((b?, s?, e?)))
        .collect();
    match fit_power(&rows) {
        Some(p) => c.power = p,
        None => c.warnings.push(format!(
            "power: {} usable run summar(ies), fit under-determined; keeping Frontier 560/90 W",
            rows.len()
        )),
    }
}

fn collective_key(s: &str) -> Option<&'static str> {
    match s {
        "bcast" => Some("bcast"),
        "allreduce" => Some("allreduce"),
        "allgather" => Some("allgather"),
        "reducescatter" => Some("reducescatter"),
        _ => None,
    }
}

fn collective_key_of(c: Collective) -> &'static str {
    match c {
        Collective::Broadcast => "bcast",
        Collective::AllReduce => "allreduce",
        Collective::AllGather => "allgather",
        Collective::ReduceScatter => "reducescatter",
    }
}

fn model_slot(net: &mut NetworkProfile, c: Collective) -> &mut simnet::CollectiveModel {
    match c {
        Collective::Broadcast => &mut net.broadcast,
        Collective::AllReduce => &mut net.all_reduce,
        Collective::AllGather => &mut net.all_gather,
        Collective::ReduceScatter => &mut net.reduce_scatter,
    }
}

// ---------------------------------------------------------------------------
// Record generation: measuring this machine, and synthesizing fixtures
// ---------------------------------------------------------------------------

/// GEMM shape grid for calibration measurements: saturated squares plus
/// skinny shapes whose smallest dimension walks the efficiency knee.
pub const CALIB_GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (512, 512, 512),
    (384, 384, 384),
    (256, 256, 256),
    (128, 128, 128),
    (64, 64, 64),
    (32, 32, 32),
    (16, 16, 16),
    (8, 8, 8),
    (8, 256, 256),
    (32, 512, 512),
    (64, 256, 256),
];

/// Measure real GEMM rates on THIS machine through the native tensor
/// substrate (wall clock). These are the honest `gemm_*` rows of a
/// calibration file: the measured simulator runs the same kernels, so a
/// planner calibrated on them prices compute at the scale the validator
/// will actually measure.
pub fn measure_gemm_records(
    shapes: &[(usize, usize, usize)],
    iters: usize,
) -> Vec<(String, f64)> {
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;
    let mut rng = Prng::new(0xCA11B);
    let iters = iters.max(1);
    let mut out = Vec::new();
    for &(m, n, k) in shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut ct = Tensor::zeros(&[m, n]);
        a.matmul_into(&b, &mut ct).expect("calib shapes are valid");
        let start = std::time::Instant::now();
        for _ in 0..iters {
            a.matmul_into(&b, &mut ct).expect("calib shapes are valid");
        }
        let per_call = start.elapsed().as_secs_f64() / iters as f64;
        let rate = 2.0 * (m * n * k) as f64 / per_call.max(1e-9);
        out.push((format!("gemm_m{m}_n{n}_k{k}_gflops"), rate / 1e9));
    }
    out
}

/// Synthesize a full record set from known-truth constants (no noise).
/// Used by the calibration round-trip tests, and to stamp the collective
/// and power rows of the committed fixture: the simulator's virtual fabric
/// advances clocks by exactly `net`'s model and charges exactly `power`,
/// so for those two groups the model IS the measurement.
pub fn synthesize_records(
    g: &GemmModel,
    net: &NetworkProfile,
    power: &PowerModel,
) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    // GEMM rows: ideal rate = peak * efficiency (no launch overhead — it is
    // carried as a direct override row instead).
    for &(m, n, k) in CALIB_GEMM_SHAPES {
        let rate = g.peak_flops * g.efficiency(m, n, k);
        out.push((format!("gemm_m{m}_n{n}_k{k}_gflops"), rate / 1e9));
    }
    out.push(("gemm_launch_overhead_s".to_string(), g.launch_overhead_s));
    out.push(("gemm_host_float_s".to_string(), g.host_float_s));
    out.push(("gemm_peer_quad_s".to_string(), g.peer_quad_s));
    for coll in Collective::ALL {
        let key = collective_key_of(coll);
        for &p in &[2usize, 8, 64] {
            for &m in &[4_096usize, 65_536, 1 << 20] {
                let us = net.time(coll, m, p) * 1e6;
                out.push((format!("comm_{key}_m{m}_p{p}_us"), us));
            }
        }
    }
    for (i, &(busy, stall)) in [(2.0, 0.5), (1.0, 3.0), (4.0, 1.0)].iter().enumerate() {
        out.push((format!("run{i}_busy_s"), busy));
        out.push((format!("run{i}_stall_s"), stall));
        out.push((format!("run{i}_energy_j"), power.energy(busy, stall)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_have_no_warnings_and_table3_constants() {
        let c = Calibration::frontier_defaults();
        assert!(c.warnings.is_empty());
        assert_eq!(c.source, CalibSource::Defaults);
        assert_eq!(c.net.all_gather.c1, 149.94);
        assert_eq!(c.power.busy_w, 560.0);
    }

    #[test]
    fn round_trip_recovers_known_constants() {
        // Synthesize from non-default truth, fit, compare within tolerance.
        let truth_g = GemmModel {
            peak_flops: 3.0e11,
            min_eff: 0.04,
            full_eff_dim: 96.0,
            launch_overhead_s: 2e-6,
            host_float_s: 3e-9,
            peer_quad_s: 0.2e-6,
        };
        let truth_net = NetworkProfile {
            broadcast: simnet::CollectiveModel { c1: 50.0, c2: 1.5e-3, c3: 0.0 },
            all_reduce: simnet::CollectiveModel { c1: 40.0, c2: 2.0e-3, c3: 0.0 },
            all_gather: simnet::CollectiveModel { c1: 120.0, c2: 2.5e-3, c3: 0.0 },
            reduce_scatter: simnet::CollectiveModel { c1: 110.0, c2: 2.2e-3, c3: 0.0 },
        };
        let truth_p = PowerModel { busy_w: 300.0, idle_w: 40.0 };
        let records = synthesize_records(&truth_g, &truth_net, &truth_p);
        let c = Calibration::from_records(&records);
        assert!(c.warnings.is_empty(), "full record set must fit cleanly: {:?}", c.warnings);
        // GEMM: peak exact (saturated shapes present), knee within 15%
        // (floor interactions make it approximate), overheads exact.
        assert!((c.gemm.peak_flops - truth_g.peak_flops).abs() / truth_g.peak_flops < 0.01);
        assert!(
            (c.gemm.full_eff_dim - truth_g.full_eff_dim).abs() / truth_g.full_eff_dim < 0.15,
            "knee {} vs {}",
            c.gemm.full_eff_dim,
            truth_g.full_eff_dim
        );
        assert!((c.gemm.launch_overhead_s - truth_g.launch_overhead_s).abs() < 1e-12);
        assert!((c.gemm.host_float_s - truth_g.host_float_s).abs() < 1e-15);
        // Network: noiseless rows, constants recovered to high precision.
        for (got, want) in [
            (c.net.broadcast, truth_net.broadcast),
            (c.net.all_reduce, truth_net.all_reduce),
            (c.net.all_gather, truth_net.all_gather),
            (c.net.reduce_scatter, truth_net.reduce_scatter),
        ] {
            assert!((got.c1 - want.c1).abs() / want.c1 < 0.01, "{got:?} vs {want:?}");
            assert!((got.c2 - want.c2).abs() / want.c2 < 0.01, "{got:?} vs {want:?}");
        }
        // Power: exact (noiseless linear system).
        assert!((c.power.busy_w - truth_p.busy_w).abs() < 1e-6);
        assert!((c.power.idle_w - truth_p.idle_w).abs() < 1e-6);
    }

    #[test]
    fn missing_file_falls_back_with_warning() {
        let c = Calibration::load_or_default(Path::new("/nonexistent/BENCH_calib.json"));
        assert_eq!(c.gemm.peak_flops, GemmModel::frontier().peak_flops);
        assert_eq!(c.power, PowerModel::frontier());
        assert_eq!(c.warnings.len(), 1);
        assert!(c.warnings[0].contains("defaults"), "{}", c.warnings[0]);
    }

    #[test]
    fn partial_records_fall_back_per_group() {
        // Only power rows: gemm and all four collectives warn, power fits.
        let truth_p = PowerModel { busy_w: 200.0, idle_w: 25.0 };
        let mut records = Vec::new();
        for (i, &(busy, stall)) in [(2.0, 0.5), (1.0, 3.0), (4.0, 1.0)].iter().enumerate() {
            records.push((format!("run{i}_busy_s"), busy));
            records.push((format!("run{i}_stall_s"), stall));
            records.push((format!("run{i}_energy_j"), truth_p.energy(busy, stall)));
        }
        // plus an unknown record that must be ignored
        records.push(("serve_pp_energy_per_kq_j".to_string(), 12.5));
        let c = Calibration::from_records(&records);
        assert!((c.power.busy_w - 200.0).abs() < 1e-6);
        assert_eq!(c.gemm.peak_flops, GemmModel::frontier().peak_flops);
        assert_eq!(c.warnings.len(), 5, "gemm + 4 collectives: {:?}", c.warnings);
    }

    #[test]
    fn kernel_records_translate_shapes_and_skip_reference_engines() {
        let records = vec![
            // 64x64x64 GEMM in 524288 ns: 2*64^3 / 524288 = 1.0 gflops.
            ("gemm_64x64x64_ns".to_string(), 524_288.0),
            // (m, k, n) = (8, 256, 32): keys come out as m8_n32_k256.
            ("gemm_8x256x32_ns".to_string(), 131_072.0),
            ("gemm_naive_64x64x64_ns".to_string(), 9e9),
            ("gemm_seed_64x64x64_ns".to_string(), 9e9),
            ("speedup_vs_naive_64x64x64".to_string(), 12.0),
            ("isa_avx2".to_string(), 1.0),
        ];
        let rows = translate_kernel_records(&records);
        assert_eq!(
            rows,
            vec![
                ("gemm_m64_n64_k64_gflops".to_string(), 1.0),
                ("gemm_m8_n32_k256_gflops".to_string(), 1.0),
            ]
        );
        assert!(rows.iter().all(|(k, _)| is_gemm_point_row(k)));
    }

    #[test]
    fn hybrid_records_translate_to_run_triples() {
        let records = vec![
            ("hybrid_pp_dp2_busy_s".to_string(), 2.0),
            ("hybrid_pp_dp2_comm_s".to_string(), 0.25),
            ("hybrid_pp_dp2_dp_comm_s".to_string(), 0.25),
            ("hybrid_pp_dp2_energy_train_j".to_string(), 1165.0),
            ("hybrid_pp_dp2_final_loss".to_string(), 0.01),
            // Incomplete group (no energy row) must be dropped.
            ("hybrid_tp_dp2_busy_s".to_string(), 1.0),
            ("hybrid_tp_dp2_comm_s".to_string(), 0.5),
        ];
        let rows = translate_hybrid_records(&records);
        assert_eq!(
            rows,
            vec![
                ("run0_busy_s".to_string(), 2.0),
                ("run0_stall_s".to_string(), 0.5),
                ("run0_energy_j".to_string(), 1165.0),
            ]
        );
        assert!(rows.iter().all(|(k, _)| is_power_row(k)));
    }

    #[test]
    fn auto_load_falls_back_to_seed_then_merges_measured_trajectories() {
        use crate::util::json::write_records_json;
        let dir = std::env::temp_dir()
            .join(format!("phantom-calib-auto-{}", std::process::id()));
        std::fs::create_dir_all(dir.join("ci/bench_seed")).unwrap();
        // Seed fixture with distinctive truth so we can tell groups apart.
        let seed_p = PowerModel { busy_w: 200.0, idle_w: 25.0 };
        let seed = synthesize_records(&GemmModel::frontier(), &NetworkProfile::frontier(), &seed_p);
        write_records_json(&dir.join(DEFAULT_CALIB_PATH), &seed).unwrap();

        // No BENCH files: seed fixture calibrates everything.
        let c = Calibration::auto_load(&dir);
        assert!(matches!(&c.source, CalibSource::Measured(s) if s.contains("bench_seed")));
        assert!(c.warnings[0].contains("seed fixture"), "{:?}", c.warnings);
        assert!((c.power.busy_w - 200.0).abs() < 1e-6);

        // Real kernel + hybrid trajectories: their groups displace the
        // seed's, the seed's collective rows survive.
        let truth_p = PowerModel { busy_w: 320.0, idle_w: 45.0 };
        let kernels: Vec<(String, f64)> = [(256usize, 256usize, 256usize), (64, 64, 64), (8, 256, 256), (512, 512, 512)]
            .iter()
            .map(|&(m, k, n)| {
                // Rate shaped like a real knee: big shapes fast, small slow.
                let gflops = if m.min(k).min(n) >= 128 { 80.0 } else { 20.0 };
                let ns = 2.0 * (m * k * n) as f64 / gflops;
                (format!("gemm_{m}x{k}x{n}_ns"), ns)
            })
            .collect();
        write_records_json(&dir.join("BENCH_kernels.json"), &kernels).unwrap();
        let mut hybrid = Vec::new();
        for (tag, busy, stall) in [("pp_dp2", 2.0, 0.5), ("tp_dp2", 1.0, 3.0)] {
            hybrid.push((format!("hybrid_{tag}_busy_s"), busy));
            hybrid.push((format!("hybrid_{tag}_comm_s"), stall / 2.0));
            hybrid.push((format!("hybrid_{tag}_dp_comm_s"), stall / 2.0));
            hybrid.push((format!("hybrid_{tag}_energy_train_j"), truth_p.energy(busy, stall)));
        }
        write_records_json(&dir.join("BENCH_hybrid.json"), &hybrid).unwrap();

        let c = Calibration::auto_load(&dir);
        match &c.source {
            CalibSource::Measured(s) => {
                assert!(s.contains("BENCH_kernels.json") && s.contains("BENCH_hybrid.json"), "{s}");
            }
            other => panic!("expected measured source, got {other:?}"),
        }
        // Power fitted from the hybrid triples, not the seed's 200/25 W.
        assert!((c.power.busy_w - 320.0).abs() < 1e-6, "busy {}", c.power.busy_w);
        assert!((c.power.idle_w - 45.0).abs() < 1e-6, "idle {}", c.power.idle_w);
        // GEMM peak from the kernel rows (80 gflops), not Frontier's.
        assert!((c.gemm.peak_flops - 80.0e9).abs() / 80.0e9 < 0.01, "{}", c.gemm.peak_flops);
        // Collectives still come from the seed (no real collective bench).
        assert!((c.net.all_gather.c1 - NetworkProfile::frontier().all_gather.c1).abs() < 1.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn measured_gemm_records_are_positive_and_parse_back() {
        let records = measure_gemm_records(&[(64, 64, 64), (16, 16, 16), (128, 64, 32)], 2);
        assert_eq!(records.len(), 3);
        for (k, v) in &records {
            assert!(*v > 0.0, "{k}: {v}");
        }
        // 3 points are enough for the GEMM group to fit without warning.
        let c = Calibration::from_records(&records);
        assert!(!c.warnings.iter().any(|w| w.starts_with("gemm:")), "{:?}", c.warnings);
        assert!(c.gemm.peak_flops > 0.0);
    }
}
