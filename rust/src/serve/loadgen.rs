//! Open-loop load generator: Poisson-ish arrivals from the deterministic
//! PRNG, driven through the serving front-end, summarized as the paper-style
//! serving report (p50/p95 latency, throughput, energy per 1k queries).
//!
//! Determinism: the whole arrival stream (timestamps AND query payloads) is
//! a pure function of `seed`, so PP and TP runs serve bit-identical traffic
//! and the BENCH_serve.json trajectory is reproducible.

use anyhow::{bail, Result};

use crate::comm::CommStats;
use crate::config::{Parallelism, RunConfig, ServeConfig};
use crate::energy::PowerModel;
use crate::obs::MetricsSnapshot;
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use crate::util::stats::{summarize, Summary};

use super::batcher::{Admission, Server, ServerStats};
use super::pool::PoolRankReport;

/// Load-generator knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadGenConfig {
    /// Total queries in the arrival stream.
    pub queries: usize,
    /// Mean arrival rate in queries per virtual second (exponential gaps).
    pub rate_qps: f64,
    /// Seed for arrival gaps and query payloads.
    pub seed: u64,
    /// Open loop: shed on a full queue (rejections count as drops).
    /// Closed loop (default): block the stream until a slot frees.
    pub open_loop: bool,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig { queries: 512, rate_qps: 2_000.0, seed: 0x5E47E, open_loop: false }
    }
}

/// One serving run's summary — the row the CLI table and BENCH_serve.json
/// are built from.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub mode: Parallelism,
    pub queries: usize,
    /// Offered arrival rate of the run (LoadGenConfig::rate_qps).
    pub rate_qps: f64,
    /// Admission-queue bound of the run (ServeConfig::queue_depth).
    pub queue_depth: usize,
    pub completed: usize,
    /// Shed by admission control (open-loop only; 0 under blocking).
    pub rejected: usize,
    /// Submissions that stalled on backpressure (blocking mode).
    pub blocked: usize,
    /// Responses whose id regressed — structurally 0, asserted anyway.
    pub misordered: usize,
    /// Latency (done - original client intent, blocking delay included)
    /// over completed queries, seconds.
    pub latency: Summary,
    /// Post-admission queue wait (dispatch - admission) summary, seconds —
    /// the server-side slice of `latency`.
    pub queue_wait: Summary,
    /// Completed queries per virtual second, over [0, last completion].
    pub throughput_qps: f64,
    /// Cluster energy over the whole run, Joules (all ranks, Eqn. 1).
    pub energy_j: f64,
    pub energy_per_kq_j: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub max_queue_seen: usize,
    /// Aggregated wire traffic across all rank endpoints.
    pub comm: CommStats,
    pub per_rank: Vec<PoolRankReport>,
    /// The server's own live-metrics snapshot, taken after the drain —
    /// the same surface `Server::metrics()` exposes mid-run. Its
    /// `latency_s_p50`/`latency_s_p99` must agree with `latency` (both are
    /// client-intent based; the regression suite asserts it).
    pub live: MetricsSnapshot,
}

/// Bursty, diurnal, heavy-tailed arrival model — the fleet's replacement
/// for the single-rate Poisson stream. Three effects compose, all drawn
/// from the deterministic PRNG so one seed defines one reproducible trace
/// that every router policy and replica count can be measured against:
///
/// * **diurnal**: the base rate is modulated by a sinusoid (amplitude
///   `diurnal_amp`, period `diurnal_period_s`) — the slow day/night swing
///   the autoscaler should track by draining replicas;
/// * **bursts**: with probability `burst_prob` per arrival, the next
///   `burst_len` arrivals come at `burst_mult` times the current rate —
///   the flash crowds that force scale-up and shedding;
/// * **lulls**: with probability `lull_prob`, a Pareto-distributed quiet
///   gap (tail index `lull_alpha`, scale `lull_scale_s`) is inserted —
///   the heavy-tailed silences that leave lingering batches to flush.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurstModel {
    /// Mean arrival rate before modulation, queries per virtual second.
    pub base_qps: f64,
    /// Sinusoid amplitude on the rate, in [0, 1).
    pub diurnal_amp: f64,
    /// Sinusoid period, virtual seconds.
    pub diurnal_period_s: f64,
    /// Per-arrival probability of entering a burst.
    pub burst_prob: f64,
    /// Rate multiplier while inside a burst (> 1).
    pub burst_mult: f64,
    /// Arrivals per burst.
    pub burst_len: usize,
    /// Per-arrival probability of a heavy-tailed lull (outside bursts).
    pub lull_prob: f64,
    /// Pareto tail index of the lull length (smaller = heavier tail).
    pub lull_alpha: f64,
    /// Pareto scale (minimum lull), virtual seconds.
    pub lull_scale_s: f64,
}

impl Default for BurstModel {
    fn default() -> Self {
        BurstModel {
            base_qps: 2_000.0,
            diurnal_amp: 0.6,
            diurnal_period_s: 0.25,
            burst_prob: 0.02,
            burst_mult: 8.0,
            burst_len: 24,
            lull_prob: 0.01,
            lull_alpha: 1.5,
            lull_scale_s: 5e-3,
        }
    }
}

impl BurstModel {
    pub fn validate(&self) -> Result<()> {
        if self.base_qps <= 0.0 || !self.base_qps.is_finite() {
            bail!("burst model needs a positive finite base rate");
        }
        if !(0.0..1.0).contains(&self.diurnal_amp) || self.diurnal_period_s <= 0.0 {
            bail!("diurnal amplitude must be in [0, 1) with a positive period");
        }
        if !(0.0..=1.0).contains(&self.burst_prob) || !(0.0..=1.0).contains(&self.lull_prob) {
            bail!("burst/lull probabilities must be in [0, 1]");
        }
        if self.burst_mult < 1.0 || self.lull_alpha <= 0.0 || self.lull_scale_s < 0.0 {
            bail!("burst multiplier must be >= 1 and the lull tail well-formed");
        }
        Ok(())
    }

    /// Materialize `queries` arrival timestamps (nondecreasing, starting
    /// after 0). The whole trace is a pure function of `seed`.
    pub fn trace(&self, seed: u64, queries: usize) -> Vec<f64> {
        let mut rng = Prng::new(seed);
        let mut t = 0.0f64;
        let mut in_burst = 0usize;
        let mut out = Vec::with_capacity(queries);
        for _ in 0..queries {
            let phase = std::f64::consts::TAU * t / self.diurnal_period_s;
            // Clamp away from zero so a deep trough never stalls the trace.
            let diurnal = (1.0 + self.diurnal_amp * phase.sin()).max(0.05);
            let mut rate = self.base_qps * diurnal;
            if in_burst > 0 {
                in_burst -= 1;
                rate *= self.burst_mult;
            } else if rng.next_f64() < self.burst_prob {
                in_burst = self.burst_len;
                rate *= self.burst_mult;
            }
            let mut gap = -(1.0 - rng.next_f64()).ln() / rate;
            if in_burst == 0 && rng.next_f64() < self.lull_prob {
                // Pareto(alpha, scale) quiet period: u^(-1/alpha) has a
                // heavy tail, so a few lulls dominate the idle time.
                let u = 1.0 - rng.next_f64(); // (0, 1]
                gap += self.lull_scale_s * u.powf(-1.0 / self.lull_alpha);
            }
            t += gap;
            out.push(t);
        }
        out
    }
}

/// Drive one full load-generator run through a fresh serving stack.
pub fn run_load(
    run: &RunConfig,
    scfg: &ServeConfig,
    lcfg: &LoadGenConfig,
    exec: &ExecServer,
) -> Result<LoadReport> {
    if lcfg.queries == 0 || lcfg.rate_qps <= 0.0 || !lcfg.rate_qps.is_finite() {
        bail!("load generator needs queries >= 1 and a positive finite rate");
    }
    let n = run.model.n;
    let mut server = Server::start(run, *scfg, exec)?;

    let mut rng = Prng::new(lcfg.seed);
    let mut t = 0.0f64;
    let mut admitted = 0u64;
    let mut responses = Vec::with_capacity(lcfg.queries);
    for _ in 0..lcfg.queries {
        // Exponential inter-arrival gap (1 - u in (0, 1] avoids ln 0).
        t += -(1.0 - rng.next_f64()).ln() / lcfg.rate_qps;
        let x = Tensor::randn(&[n], 1.0, &mut rng);
        if lcfg.open_loop {
            // Open loop: shed clients never delay the stream.
            match server.try_submit(t, x)? {
                Admission::Accepted(id) => {
                    debug_assert_eq!(id, admitted);
                    admitted += 1;
                }
                Admission::Rejected => {}
            }
        } else {
            // A blocked stream delays every later delivery past the block,
            // but the intent clock keeps running at the offered rate: the
            // server clamps the effective admission itself and the
            // Response carries both instants, so latency is measured from
            // the client's intent on every surface.
            let (id, _effective) = server.submit_blocking(t, x)?;
            debug_assert_eq!(id, admitted);
            admitted += 1;
        }
        responses.append(&mut server.take_responses());
    }
    server.drain()?;
    // Snapshot the live metrics after the drain, before teardown: this is
    // the surface a router or dashboard would read mid-run.
    let live = server.metrics();
    let (mut tail, stats, per_rank) = server.finish()?;
    responses.append(&mut tail);

    summarize_run(run, lcfg, scfg, stats, per_rank, live, responses)
}

fn summarize_run(
    run: &RunConfig,
    lcfg: &LoadGenConfig,
    scfg: &ServeConfig,
    stats: ServerStats,
    per_rank: Vec<PoolRankReport>,
    live: MetricsSnapshot,
    responses: Vec<super::batcher::Response>,
) -> Result<LoadReport> {
    let completed = responses.len();
    if completed == 0 {
        bail!("no queries completed — the load generator shed everything");
    }
    let mut misordered = 0usize;
    let mut last_id: Option<u64> = None;
    let mut latencies = Vec::with_capacity(completed);
    let mut queue_waits = Vec::with_capacity(completed);
    let mut last_done = 0.0f64;
    for r in &responses {
        if let Some(prev) = last_id {
            if r.id <= prev {
                misordered += 1;
            }
        }
        last_id = Some(r.id);
        latencies.push(r.latency_s());
        queue_waits.push(r.queue_wait_s());
        last_done = last_done.max(r.done_s);
    }

    let power: PowerModel = run.hardware.power;
    let mut energy_j = 0.0;
    let mut comm = CommStats::default();
    for r in &per_rank {
        energy_j += r.ledger.energy_j(&power);
        comm.accumulate(&r.stats);
    }

    Ok(LoadReport {
        mode: scfg.mode,
        queries: lcfg.queries,
        rate_qps: lcfg.rate_qps,
        queue_depth: scfg.queue_depth,
        completed,
        rejected: stats.rejected as usize,
        blocked: stats.blocked as usize,
        misordered,
        latency: summarize(&latencies),
        queue_wait: summarize(&queue_waits),
        throughput_qps: completed as f64 / last_done.max(1e-12),
        energy_j,
        energy_per_kq_j: energy_j / completed as f64 * 1_000.0,
        batches: stats.batches,
        mean_batch: stats.dispatched as f64 / stats.batches.max(1) as f64,
        max_queue_seen: stats.max_queue_seen,
        comm,
        per_rank,
        live,
    })
}

/// Combine per-mode records and, when both PP and TP reports are present,
/// append the `pp_over_tp_energy` headline ratio. The single source of the
/// BENCH_serve.json schema for the CLI, the serve bench, and the CI smoke
/// test.
///
/// When a mode contributed several reports (a replica fleet produces one
/// per replica), they are aggregated rather than silently dropped: counts
/// and energy sum exactly, latency percentiles are completed-weighted, and
/// `energy_per_kq_j` is recomputed from the total energy over the total
/// completions (i.e. energy-weighted, not a mean of per-replica ratios).
/// `{mode}_reports` records how many reports fed each mode's row, so the
/// `pp_over_tp_energy` headline stays honest at any replica count.
pub fn combined_records(reports: &[LoadReport]) -> Vec<(String, f64)> {
    let mut records: Vec<(String, f64)> = Vec::new();
    // Group by mode preserving first-seen order (at most a handful of
    // modes, so the quadratic scan is fine).
    let mut groups: Vec<(Parallelism, Vec<&LoadReport>)> = Vec::new();
    for r in reports {
        match groups.iter_mut().find(|(m, _)| *m == r.mode) {
            Some((_, g)) => g.push(r),
            None => groups.push((r.mode, vec![r])),
        }
    }
    for (mode, group) in &groups {
        records.extend(aggregate_records(*mode, group));
        records.push((format!("{}_reports", mode.name()), group.len() as f64));
    }
    let energy = |mode: Parallelism| {
        groups.iter().find(|(m, _)| *m == mode).map(|(_, g)| {
            let e: f64 = g.iter().map(|r| r.energy_j).sum();
            let c: f64 = g.iter().map(|r| r.completed as f64).sum();
            e / c.max(1.0) * 1_000.0
        })
    };
    if let (Some(pp), Some(tp)) = (energy(Parallelism::Phantom), energy(Parallelism::Tensor)) {
        records.push(("pp_over_tp_energy".to_string(), pp / tp));
    }
    records
}

/// Aggregate one mode's reports into the flat record schema. A single
/// report reduces exactly to `bench_records`.
fn aggregate_records(mode: Parallelism, group: &[&LoadReport]) -> Vec<(String, f64)> {
    let m = mode.name();
    let sum = |f: &dyn Fn(&LoadReport) -> f64| group.iter().map(|r| f(r)).sum::<f64>();
    let completed = sum(&|r| r.completed as f64);
    let batches = sum(&|r| r.batches as f64);
    // Completed-weighted latency percentiles: each replica's percentile
    // contributes in proportion to the queries it actually answered.
    let wlat = |f: &dyn Fn(&LoadReport) -> f64| {
        sum(&|r| f(r) * r.completed as f64) / completed.max(1.0)
    };
    vec![
        (format!("{m}_queries"), sum(&|r| r.queries as f64)),
        (format!("{m}_rate_qps"), sum(&|r| r.rate_qps)),
        (format!("{m}_queue_depth"), sum(&|r| r.queue_depth as f64)),
        (format!("{m}_completed"), completed),
        (format!("{m}_rejected"), sum(&|r| r.rejected as f64)),
        (format!("{m}_blocked"), sum(&|r| r.blocked as f64)),
        (format!("{m}_misordered"), sum(&|r| r.misordered as f64)),
        (format!("{m}_p50_latency_s"), wlat(&|r| r.latency.p50)),
        (format!("{m}_p95_latency_s"), wlat(&|r| r.latency.p95)),
        (format!("{m}_p99_latency_s"), wlat(&|r| r.latency.p99)),
        (format!("{m}_p50_queue_wait_s"), wlat(&|r| r.queue_wait.p50)),
        (format!("{m}_throughput_qps"), sum(&|r| r.throughput_qps)),
        (format!("{m}_energy_per_kq_j"), sum(&|r| r.energy_j) / completed.max(1.0) * 1_000.0),
        (format!("{m}_batches"), batches),
        (format!("{m}_mean_batch"), sum(&|r| r.mean_batch * r.batches as f64) / batches.max(1.0)),
        (format!("{m}_floats_moved"), sum(&|r| r.comm.floats_moved as f64)),
    ]
}

/// Flat (key, value) records for one mode's run, prefixed by the mode name
/// ("pp_p50_latency_s", ...).
pub fn bench_records(r: &LoadReport) -> Vec<(String, f64)> {
    aggregate_records(r.mode, &[r])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(mode: Parallelism, completed: usize, energy_j: f64, p50: f64) -> LoadReport {
        let lat = summarize(&[p50]);
        LoadReport {
            mode,
            queries: completed,
            rate_qps: 100.0,
            queue_depth: 8,
            completed,
            rejected: 0,
            blocked: 0,
            misordered: 0,
            latency: lat,
            queue_wait: lat,
            throughput_qps: 10.0,
            energy_j,
            energy_per_kq_j: energy_j / completed as f64 * 1_000.0,
            batches: 4,
            mean_batch: completed as f64 / 4.0,
            max_queue_seen: 8,
            comm: CommStats::default(),
            per_rank: Vec::new(),
            live: MetricsSnapshot::default(),
        }
    }

    #[test]
    fn combined_records_aggregates_all_reports_per_mode() {
        // Regression: the old `find` kept only the first report per mode,
        // so a fleet's later replicas silently vanished from the headline.
        let pp_a = report(Parallelism::Phantom, 100, 50.0, 0.010);
        let pp_b = report(Parallelism::Phantom, 300, 90.0, 0.030);
        let tp = report(Parallelism::Tensor, 400, 280.0, 0.020);
        let recs = combined_records(&[pp_a, pp_b, tp]);
        let get = |k: &str| {
            recs.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap_or_else(|| {
                panic!("missing record {k}")
            })
        };
        assert_eq!(get("pp_reports"), 2.0);
        assert_eq!(get("tp_reports"), 1.0);
        assert_eq!(get("pp_completed"), 400.0);
        // Energy per 1k queries from totals: (50 + 90) / 400 * 1000.
        assert!((get("pp_energy_per_kq_j") - 350.0).abs() < 1e-9);
        // Completed-weighted p50: (0.010*100 + 0.030*300) / 400.
        assert!((get("pp_p50_latency_s") - 0.025).abs() < 1e-12);
        // Headline uses the aggregate, not the first pp report:
        // 350 / (280/400*1000) = 350 / 700 = 0.5.
        assert!((get("pp_over_tp_energy") - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bench_records_matches_single_report_aggregate() {
        let r = report(Parallelism::Phantom, 64, 32.0, 0.005);
        let solo = bench_records(&r);
        let combined = combined_records(std::slice::from_ref(&r));
        for (k, v) in &solo {
            let c = combined.iter().find(|(n, _)| n == k).map(|(_, x)| *x);
            assert_eq!(c, Some(*v), "record {k} diverged");
        }
        assert!((r.energy_per_kq_j - 500.0).abs() < 1e-9);
    }
}
