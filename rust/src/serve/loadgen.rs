//! Open-loop load generator: Poisson-ish arrivals from the deterministic
//! PRNG, driven through the serving front-end, summarized as the paper-style
//! serving report (p50/p95 latency, throughput, energy per 1k queries).
//!
//! Determinism: the whole arrival stream (timestamps AND query payloads) is
//! a pure function of `seed`, so PP and TP runs serve bit-identical traffic
//! and the BENCH_serve.json trajectory is reproducible.

use anyhow::{bail, Result};

use crate::comm::CommStats;
use crate::config::{Parallelism, RunConfig, ServeConfig};
use crate::energy::PowerModel;
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use crate::util::stats::{summarize, Summary};

use super::batcher::{Admission, Server, ServerStats};
use super::pool::PoolRankReport;

/// Load-generator knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadGenConfig {
    /// Total queries in the arrival stream.
    pub queries: usize,
    /// Mean arrival rate in queries per virtual second (exponential gaps).
    pub rate_qps: f64,
    /// Seed for arrival gaps and query payloads.
    pub seed: u64,
    /// Open loop: shed on a full queue (rejections count as drops).
    /// Closed loop (default): block the stream until a slot frees.
    pub open_loop: bool,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        LoadGenConfig { queries: 512, rate_qps: 2_000.0, seed: 0x5E47E, open_loop: false }
    }
}

/// One serving run's summary — the row the CLI table and BENCH_serve.json
/// are built from.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub mode: Parallelism,
    pub queries: usize,
    /// Offered arrival rate of the run (LoadGenConfig::rate_qps).
    pub rate_qps: f64,
    /// Admission-queue bound of the run (ServeConfig::queue_depth).
    pub queue_depth: usize,
    pub completed: usize,
    /// Shed by admission control (open-loop only; 0 under blocking).
    pub rejected: usize,
    /// Submissions that stalled on backpressure (blocking mode).
    pub blocked: usize,
    /// Responses whose id regressed — structurally 0, asserted anyway.
    pub misordered: usize,
    /// Latency (done - original arrival) over completed queries, seconds.
    pub latency: Summary,
    /// Completed queries per virtual second, over [0, last completion].
    pub throughput_qps: f64,
    /// Cluster energy over the whole run, Joules (all ranks, Eqn. 1).
    pub energy_j: f64,
    pub energy_per_kq_j: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub max_queue_seen: usize,
    /// Aggregated wire traffic across all rank endpoints.
    pub comm: CommStats,
    pub per_rank: Vec<PoolRankReport>,
}

/// Drive one full load-generator run through a fresh serving stack.
pub fn run_load(
    run: &RunConfig,
    scfg: &ServeConfig,
    lcfg: &LoadGenConfig,
    exec: &ExecServer,
) -> Result<LoadReport> {
    if lcfg.queries == 0 || lcfg.rate_qps <= 0.0 || !lcfg.rate_qps.is_finite() {
        bail!("load generator needs queries >= 1 and a positive finite rate");
    }
    let n = run.model.n;
    let mut server = Server::start(run, *scfg, exec)?;

    let mut rng = Prng::new(lcfg.seed);
    let mut t = 0.0f64;
    // Original (pre-backpressure) arrival time per query id, for honest
    // client-side latency accounting.
    let mut arrivals: Vec<f64> = Vec::with_capacity(lcfg.queries);
    let mut last_effective = 0.0f64;
    let mut responses = Vec::with_capacity(lcfg.queries);
    for _ in 0..lcfg.queries {
        // Exponential inter-arrival gap (1 - u in (0, 1] avoids ln 0).
        t += -(1.0 - rng.next_f64()).ln() / lcfg.rate_qps;
        let x = Tensor::randn(&[n], 1.0, &mut rng);
        if lcfg.open_loop {
            // Open loop: shed clients never delay the stream.
            match server.try_submit(t, x)? {
                Admission::Accepted(id) => {
                    debug_assert_eq!(id as usize, arrivals.len());
                    arrivals.push(t);
                }
                Admission::Rejected => {}
            }
        } else {
            // A blocked stream delays every later arrival past the block.
            let (id, effective) = server.submit_blocking(t.max(last_effective), x)?;
            debug_assert_eq!(id as usize, arrivals.len());
            arrivals.push(t); // latency is measured from the client's intent
            last_effective = effective;
        }
        responses.append(&mut server.take_responses());
    }
    let (mut tail, stats, per_rank) = server.finish()?;
    responses.append(&mut tail);

    summarize_run(run, lcfg, scfg, stats, per_rank, &arrivals, responses)
}

fn summarize_run(
    run: &RunConfig,
    lcfg: &LoadGenConfig,
    scfg: &ServeConfig,
    stats: ServerStats,
    per_rank: Vec<PoolRankReport>,
    arrivals: &[f64],
    responses: Vec<super::batcher::Response>,
) -> Result<LoadReport> {
    let completed = responses.len();
    if completed == 0 {
        bail!("no queries completed — the load generator shed everything");
    }
    let mut misordered = 0usize;
    let mut last_id: Option<u64> = None;
    let mut latencies = Vec::with_capacity(completed);
    let mut last_done = 0.0f64;
    for r in &responses {
        if let Some(prev) = last_id {
            if r.id <= prev {
                misordered += 1;
            }
        }
        last_id = Some(r.id);
        let orig = arrivals.get(r.id as usize).copied().unwrap_or(r.arrival_s);
        latencies.push(r.done_s - orig);
        last_done = last_done.max(r.done_s);
    }

    let power: PowerModel = run.hardware.power;
    let mut energy_j = 0.0;
    let mut comm = CommStats::default();
    for r in &per_rank {
        energy_j += r.ledger.energy_j(&power);
        comm.accumulate(&r.stats);
    }

    Ok(LoadReport {
        mode: scfg.mode,
        queries: lcfg.queries,
        rate_qps: lcfg.rate_qps,
        queue_depth: scfg.queue_depth,
        completed,
        rejected: stats.rejected as usize,
        blocked: stats.blocked as usize,
        misordered,
        latency: summarize(&latencies),
        throughput_qps: completed as f64 / last_done.max(1e-12),
        energy_j,
        energy_per_kq_j: energy_j / completed as f64 * 1_000.0,
        batches: stats.batches,
        mean_batch: stats.dispatched as f64 / stats.batches.max(1) as f64,
        max_queue_seen: stats.max_queue_seen,
        comm,
        per_rank,
    })
}

/// Combine per-mode records and, when both PP and TP reports are present,
/// append the `pp_over_tp_energy` headline ratio. The single source of the
/// BENCH_serve.json schema for the CLI, the serve bench, and the CI smoke
/// test.
pub fn combined_records(reports: &[LoadReport]) -> Vec<(String, f64)> {
    let mut records: Vec<(String, f64)> = Vec::new();
    for r in reports {
        records.extend(bench_records(r));
    }
    let energy =
        |mode: Parallelism| reports.iter().find(|r| r.mode == mode).map(|r| r.energy_per_kq_j);
    if let (Some(pp), Some(tp)) = (energy(Parallelism::Phantom), energy(Parallelism::Tensor)) {
        records.push(("pp_over_tp_energy".to_string(), pp / tp));
    }
    records
}

/// Flat (key, value) records for one mode's run, prefixed by the mode name
/// ("pp_p50_latency_s", ...).
pub fn bench_records(r: &LoadReport) -> Vec<(String, f64)> {
    let m = r.mode.name();
    vec![
        (format!("{m}_queries"), r.queries as f64),
        (format!("{m}_rate_qps"), r.rate_qps),
        (format!("{m}_queue_depth"), r.queue_depth as f64),
        (format!("{m}_completed"), r.completed as f64),
        (format!("{m}_rejected"), r.rejected as f64),
        (format!("{m}_misordered"), r.misordered as f64),
        (format!("{m}_p50_latency_s"), r.latency.p50),
        (format!("{m}_p95_latency_s"), r.latency.p95),
        (format!("{m}_throughput_qps"), r.throughput_qps),
        (format!("{m}_energy_per_kq_j"), r.energy_per_kq_j),
        (format!("{m}_batches"), r.batches as f64),
        (format!("{m}_mean_batch"), r.mean_batch),
        (format!("{m}_floats_moved"), r.comm.floats_moved as f64),
    ]
}
