//! Persistent phantom-parallel serving subsystem (DESIGN.md §7): the
//! "inferencing" half of the paper's title as a long-running system rather
//! than a one-shot example.
//!
//! Three layers:
//!
//! * `pool`    — one long-lived thread per rank holding its weight shards
//!   and `Fabric` endpoint across requests; ranks outlive any single
//!   pipeline invocation and idle (static draw B) between batches.
//! * `batcher` — bounded admission queue with backpressure plus a dynamic
//!   micro-batcher (fill up to `max_batch`, or linger `linger_s` past
//!   pool-ready, whichever closes the batch first).
//! * `loadgen` — open-loop Poisson-ish load harness over the deterministic
//!   PRNG; reports p50/p99 latency, throughput and energy per 1k queries,
//!   and emits the BENCH_serve.json perf-trajectory records. Its
//!   `BurstModel` adds bursty/diurnal/heavy-tailed traces for fleet runs.
//!
//! On top of the single-replica stack sits the DP fleet (DESIGN.md §14):
//!
//! * `router`    — per-query replica choice from live queue depth and the
//!   J/query EWMA (round-robin / least-queue / energy-aware policies);
//! * `autoscale` — occupancy-watermark scaler with patience + cooldown
//!   hysteresis;
//! * `fleet`     — the event-driven front-end holding N replicas on
//!   independent communicator groups, advancing all virtual clocks
//!   coherently, spinning replicas up from snapshots and draining them
//!   down; reports fleet p50/p99, shed rate, occupancy and J/1k-queries
//!   into BENCH_fleet.json.
//!
//! PP's forward path saves the same All-Gather traffic per query as per
//! training step (paper Table II), so the serving comparison mirrors the
//! training one: same fabric, same energy ledger, same Eqn. 26 wire model.

pub mod autoscale;
pub mod batcher;
pub mod fleet;
pub mod loadgen;
pub mod pool;
pub mod router;

pub use autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
pub use batcher::{Admission, Response, Server, ServerStats};
pub use fleet::{fleet_records, run_fleet, FleetConfig, FleetReport};
pub use loadgen::{
    bench_records, combined_records, run_load, BurstModel, LoadGenConfig, LoadReport,
};
pub use pool::{PoolOptions, PoolRankReport, RankPool};
pub use router::{ReplicaStatus, RoutePolicy, Router};

use anyhow::{Context, Result};

/// Write flat (key, value) records as the BENCH_serve.json trajectory file.
/// Thin Result-typed wrapper over the shared perf-record serializer
/// (util::json::write_records_json).
pub fn write_records_json(path: &std::path::Path, records: &[(String, f64)]) -> Result<()> {
    crate::util::json::write_records_json(path, records)
        .with_context(|| format!("writing {}", path.display()))
}

/// `write_records_json` with the shared BENCH provenance header stamped
/// under the reserved `meta` key.
pub fn write_records_json_with_meta(
    path: &std::path::Path,
    records: &[(String, f64)],
    meta: &crate::util::json::BenchMeta,
) -> Result<()> {
    crate::util::json::write_records_json_with_meta(path, records, meta)
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, Parallelism, ServeConfig};
    use crate::runtime::ExecServer;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    fn tiny_cfg() -> (crate::config::RunConfig, ExecServer) {
        let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
        let server = ExecServer::for_run(&cfg).unwrap();
        (cfg, server)
    }

    #[test]
    fn fill_and_linger_rules_batch_deterministically() {
        let (cfg, exec) = tiny_cfg();
        let scfg = ServeConfig {
            queue_depth: 8,
            max_batch: 4,
            linger_s: 1e-3,
            mode: Parallelism::Phantom,
        };
        let mut server = Server::start(&cfg, scfg, &exec).unwrap();
        let mut rng = Prng::new(7);
        let n = server.n();

        // Four queries in a tight burst: the fill rule closes the batch at
        // the fourth arrival (1e-4 * 4), not at the linger deadline.
        for i in 1..=4u64 {
            let x = Tensor::randn(&[n], 1.0, &mut rng);
            let a = server.try_submit(1e-4 * i as f64, x).unwrap();
            assert!(matches!(a, Admission::Accepted(_)));
        }
        // A straggler far in the future flushes the first batch...
        let x = Tensor::randn(&[n], 1.0, &mut rng);
        server.try_submit(10.0, x).unwrap();
        let first: Vec<Response> = server.take_responses();
        assert_eq!(first.len(), 4);
        for (i, r) in first.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.batch_size, 4);
            assert!((r.dispatch_s - 4e-4).abs() < 1e-12, "fill rule: {}", r.dispatch_s);
            assert!(r.done_s > r.dispatch_s);
            assert!(r.latency_s() > 0.0);
        }
        // ...and itself dispatches alone at its linger deadline on drain.
        let (tail, stats, per_rank) = server.finish().unwrap();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].id, 4);
        assert_eq!(tail[0].batch_size, 1);
        assert!(
            (tail[0].dispatch_s - 10.001).abs() < 1e-9,
            "linger rule: {}",
            tail[0].dispatch_s
        );
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.dispatched, 5);
        assert_eq!(stats.rejected, 0);
        assert_eq!(per_rank.len(), cfg.p);
        for r in &per_rank {
            // Ranks idled between the two widely spaced batches.
            assert!(r.ledger.idle_s > 9.0, "rank {} idle {}", r.rank, r.ledger.idle_s);
            assert!(r.stats.all_gathers > 0);
        }
    }

    #[test]
    fn burst_overload_sheds_open_loop_and_blocks_closed_loop() {
        let (cfg, exec) = tiny_cfg();
        let scfg = ServeConfig {
            queue_depth: 4,
            max_batch: 4,
            linger_s: 1e-3,
            mode: Parallelism::Phantom,
        };
        // 64 queries arriving essentially at once (rate 1e12 qps): far more
        // than one batch can absorb before the pool goes busy.
        let lcfg = LoadGenConfig { queries: 64, rate_qps: 1e12, seed: 42, open_loop: true };
        let shed = run_load(&cfg, &scfg, &lcfg, &exec).unwrap();
        assert!(shed.rejected > 0, "open loop must shed under burst overload");
        assert_eq!(shed.completed + shed.rejected, 64);
        assert_eq!(shed.misordered, 0);

        let lcfg = LoadGenConfig { open_loop: false, ..lcfg };
        let blocked = run_load(&cfg, &scfg, &lcfg, &exec).unwrap();
        assert_eq!(blocked.completed, 64, "blocking backpressure drops nothing");
        assert_eq!(blocked.rejected, 0);
        assert!(blocked.blocked > 0, "the stream must have stalled at least once");
        assert_eq!(blocked.misordered, 0);
        assert!(blocked.latency.p95 >= blocked.latency.p50);
        assert!(blocked.energy_j > 0.0);
    }

    #[test]
    fn rejection_advances_the_arrival_frontier() {
        let (cfg, exec) = tiny_cfg();
        let scfg = ServeConfig {
            queue_depth: 1,
            max_batch: 1,
            linger_s: 2e-3,
            mode: Parallelism::Phantom,
        };
        let mut server = Server::start(&cfg, scfg, &exec).unwrap();
        let n = server.n();
        let mut rng = Prng::new(11);
        let mut q = || Tensor::randn(&[n], 1.0, &mut rng);
        // q1 queues; q2's arrival dispatches q1 and queues itself; q3 finds
        // the pool busy (virtual service >> the 1 us arrival gaps) with the
        // one-slot queue held by q2 -> rejected.
        assert!(matches!(server.try_submit(1.0, q()).unwrap(), Admission::Accepted(_)));
        assert!(matches!(server.try_submit(1.000001, q()).unwrap(), Admission::Accepted(_)));
        assert!(matches!(server.try_submit(1.000002, q()).unwrap(), Admission::Rejected));
        // The rejected arrival still advanced the frontier: time cannot
        // rewind behind an observed (even shed) arrival.
        assert!(server.try_submit(1.0000015, q()).is_err());
        let (resp, stats, _) = server.finish().unwrap();
        assert_eq!(resp.len(), 2);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn submissions_must_be_monotone_and_well_shaped() {
        let (cfg, exec) = tiny_cfg();
        let mut server = Server::start(&cfg, ServeConfig::default(), &exec).unwrap();
        let n = server.n();
        let mut rng = Prng::new(3);
        server.try_submit(1.0, Tensor::randn(&[n], 1.0, &mut rng)).unwrap();
        // time going backwards is a caller bug
        assert!(server.try_submit(0.5, Tensor::randn(&[n], 1.0, &mut rng)).is_err());
        // wrong query shape
        assert!(server.try_submit(2.0, Tensor::randn(&[n + 1], 1.0, &mut rng)).is_err());
        assert!(server.try_submit(2.0, Tensor::randn(&[1, n], 1.0, &mut rng)).is_err());
        let (resp, _, _) = server.finish().unwrap();
        assert_eq!(resp.len(), 1);
    }
}
