//! Replica-choice policies for the serving fleet (DESIGN.md §14).
//!
//! The router sees only what a real front-end would: each active
//! replica's live queue depth and its J/query EWMA from the replica's own
//! `Server::metrics()` — the PIE-P-style predicted-energy signal
//! (PAPERS.md) that the metrics registry has exported since PR 8 but
//! nothing consumed until now. Routing is deterministic: the same policy
//! over the same replica statuses always picks the same replica, so fleet
//! runs replay bit-identically under a fixed seed.

use anyhow::{bail, Result};

/// Which replica gets the next query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate over the active replicas, blind to their state. Sheds when
    /// the chosen replica is full even if a peer has room — the classic
    /// stateless load balancer, kept naive on purpose as the baseline.
    RoundRobin,
    /// Pick the active replica with the shortest queue (ties to the
    /// lowest replica id).
    LeastQueue,
    /// Pick the non-full replica with the lowest live J/query EWMA;
    /// replicas that have not yet dispatched a batch (no EWMA) rank after
    /// warm ones, and ties break toward the *most* queued candidate so
    /// queries pack into fuller batches — amortizing per-batch collective
    /// and idle energy is exactly how serving energy is won (Huber et
    /// al.). Falls back to least-queue when every active replica is full.
    EnergyAware,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutePolicy::RoundRobin,
            "least" | "least-queue" => RoutePolicy::LeastQueue,
            "energy" | "energy-aware" => RoutePolicy::EnergyAware,
            other => bail!("unknown route policy '{other}' (rr | least | energy)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastQueue => "least",
            RoutePolicy::EnergyAware => "energy",
        }
    }

    /// Every policy, baseline first — the order the fleet CLI reports.
    pub fn all() -> [RoutePolicy; 3] {
        [RoutePolicy::RoundRobin, RoutePolicy::LeastQueue, RoutePolicy::EnergyAware]
    }
}

/// One active replica's live state as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaStatus {
    /// Fleet-wide replica id (stable across scale events).
    pub id: usize,
    /// Queries admitted but not yet dispatched.
    pub queued: usize,
    /// The replica's admission bound.
    pub queue_depth: usize,
    /// Live J/query EWMA from the replica's metrics; `None` until its
    /// first batch completes.
    pub j_per_query: Option<f64>,
}

impl ReplicaStatus {
    fn full(&self) -> bool {
        self.queued >= self.queue_depth
    }
}

/// Stateful router: owns the round-robin cursor.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, rr_next: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Choose a replica for the next query among `statuses` (the active
    /// replicas, in stable id order). Returns an index into `statuses`,
    /// or `None` when the slice is empty. The router never refuses a full
    /// replica outright — admission control (shed/block) stays with the
    /// replica's own server.
    pub fn pick(&mut self, statuses: &[ReplicaStatus]) -> Option<usize> {
        if statuses.is_empty() {
            return None;
        }
        Some(match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next % statuses.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                i
            }
            RoutePolicy::LeastQueue => least_queue(statuses),
            RoutePolicy::EnergyAware => {
                let mut best: Option<usize> = None;
                for (i, s) in statuses.iter().enumerate() {
                    if s.full() {
                        continue;
                    }
                    best = Some(match best {
                        None => i,
                        Some(b) => {
                            if energy_pref(s, &statuses[b]) {
                                i
                            } else {
                                b
                            }
                        }
                    });
                }
                // Everyone full: the least-loaded replica sheds/blocks
                // least badly.
                best.unwrap_or_else(|| least_queue(statuses))
            }
        })
    }
}

fn least_queue(statuses: &[ReplicaStatus]) -> usize {
    let mut best = 0usize;
    for (i, s) in statuses.iter().enumerate().skip(1) {
        if s.queued < statuses[best].queued {
            best = i;
        }
    }
    best
}

/// Does candidate `a` beat incumbent `b` under the energy-aware order?
/// Lower EWMA wins; a known EWMA beats an unknown one; otherwise prefer
/// the fuller queue (batch packing), then the lower id.
fn energy_pref(a: &ReplicaStatus, b: &ReplicaStatus) -> bool {
    match (a.j_per_query, b.j_per_query) {
        (Some(ja), Some(jb)) if ja != jb => ja < jb,
        (Some(_), None) => true,
        (None, Some(_)) => false,
        _ => a.queued > b.queued, // equal-energy or both cold: pack
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(id: usize, queued: usize, j: Option<f64>) -> ReplicaStatus {
        ReplicaStatus { id, queued, queue_depth: 8, j_per_query: j }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let s = [st(0, 0, None), st(1, 5, None), st(2, 8, None)];
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&s).unwrap()).collect();
        // Blind rotation — even onto the full replica 2.
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_queue_prefers_shortest_with_low_id_ties() {
        let mut r = Router::new(RoutePolicy::LeastQueue);
        assert_eq!(r.pick(&[st(0, 3, None), st(1, 1, None), st(2, 1, None)]), Some(1));
    }

    #[test]
    fn energy_aware_prefers_low_ewma_then_packs() {
        let mut r = Router::new(RoutePolicy::EnergyAware);
        // Warm cheap replica beats warm expensive and cold ones.
        assert_eq!(
            r.pick(&[st(0, 2, Some(9.0)), st(1, 2, Some(3.0)), st(2, 7, None)]),
            Some(1)
        );
        // Cold fleet: pack the fullest non-full queue.
        assert_eq!(r.pick(&[st(0, 2, None), st(1, 6, None), st(2, 8, None)]), Some(1));
        // Cheapest is full: spill to the next-cheapest with room.
        assert_eq!(r.pick(&[st(0, 8, Some(1.0)), st(1, 3, Some(5.0))]), Some(1));
        // Everyone full: fall back to least-queue.
        assert_eq!(r.pick(&[st(0, 9, Some(1.0)), st(1, 8, Some(5.0))]), Some(1));
        assert_eq!(r.pick(&[]), None);
    }
}
