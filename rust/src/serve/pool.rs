//! Persistent rank worker pool: one long-lived thread per rank, holding its
//! weight shards and its `Fabric` endpoint across requests.
//!
//! This is the first subsystem where ranks outlive a single pipeline
//! invocation (DESIGN.md §7): `RankPool::start` materializes parameters and
//! endpoints once; every dispatched batch reuses them. Between batches each
//! rank's virtual clock idles (`sync_to(dispatch_s)` charges the gap at the
//! static draw B), so serving energy accounts for the duty cycle, not just
//! the busy bursts.
//!
//! `load_weights` hot-swaps the pool onto a checkpoint snapshot
//! (DESIGN.md §8) between batches: the swap message rides the same
//! per-rank channel as jobs, so per-rank ordering guarantees every query
//! dispatched before the swap is served by the old weights and everything
//! after — including queries already queued in the batcher — by the new,
//! with nothing dropped.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::{RankParams, Snapshot};
use crate::comm::{join_rank_threads, CommStats, Fabric, InjectorFactory};
use crate::config::{Parallelism, RunConfig, ServeConfig};
use crate::coordinator::{pp_forward_shard, tp_forward_shard};
use crate::energy::{EnergyLedger, LedgerSummary};
use crate::model::{PhantomRankParams, TpRankParams};
use crate::runtime::ExecServer;
use crate::tensor::Tensor;

struct Job {
    seq: u64,
    /// Virtual time at which the batch leaves the queue; each rank idles up
    /// to this instant before computing.
    dispatch_s: f64,
    x_shard: Tensor,
}

/// What a pool rank receives: a forward job, or a weight swap that takes
/// effect for every subsequent job on that rank.
enum RankMsg {
    Job(Job),
    Swap(Box<Worker>),
}

enum Worker {
    Pp(PhantomRankParams),
    Tp(TpRankParams),
}

struct Done {
    seq: u64,
    rank: usize,
    y_shard: Tensor,
    now_s: f64,
    /// Energy this rank spent since its previous completion (idle gap +
    /// batch), Joules — the pool sums it per batch for J/query metrics.
    energy_j: f64,
}

/// Final accounting for one pool rank, returned at shutdown.
#[derive(Debug, Clone)]
pub struct PoolRankReport {
    pub rank: usize,
    pub ledger: LedgerSummary,
    pub stats: CommStats,
    /// Span timeline + interval snapshot when the pool was traced
    /// (`PoolOptions::trace`); `None` otherwise.
    pub trace: Option<crate::obs::TraceCapture>,
}

/// The long-lived worker pool. Batches go in via `execute`; per-rank
/// ledgers come out via `shutdown`.
pub struct RankPool {
    p: usize,
    n: usize,
    mode: Parallelism,
    job_txs: Vec<mpsc::Sender<RankMsg>>,
    done_rx: mpsc::Receiver<Result<Done>>,
    handles: Vec<thread::JoinHandle<PoolRankReport>>,
    next_seq: u64,
    free_s: f64,
    last_batch_j: f64,
}

/// Optional pool wiring for chaos/conformance testing (DESIGN.md §9).
#[derive(Debug, Clone, Default)]
pub struct PoolOptions {
    /// Deterministic fault injection: each rank endpoint is armed with
    /// `faults.for_rank(rank)` before serving. `None` = fault-free.
    pub faults: Option<InjectorFactory>,
    /// Override the fabric rendezvous timeout (chaos tests shrink it so
    /// injected drops surface in milliseconds). `None` = production 60 s.
    pub rendezvous_timeout: Option<std::time::Duration>,
    /// Arm every rank's span recorder (obs): each `PoolRankReport` then
    /// carries a `TraceCapture`.
    pub trace: bool,
}

impl RankPool {
    /// Spawn the p rank threads. `scfg.mode` selects the serving pipeline;
    /// `run` supplies geometry, seed, and hardware. Each rank initializes
    /// its parameter shards deterministically from (seed, mode, rank) —
    /// identical to the training-side initialization.
    pub fn start(run: &RunConfig, scfg: &ServeConfig, exec: &ExecServer) -> Result<RankPool> {
        Self::start_with(run, scfg, exec, PoolOptions::default())
    }

    /// `start` with fault-injection / timeout options.
    pub fn start_with(
        run: &RunConfig,
        scfg: &ServeConfig,
        exec: &ExecServer,
        opts: PoolOptions,
    ) -> Result<RankPool> {
        let endpoints = match opts.rendezvous_timeout {
            Some(t) => Fabric::with_timeout(run.p, run.hardware.net, t),
            None => Fabric::new(run.p, run.hardware.net),
        };
        Self::start_on(run, scfg, exec, opts, endpoints)
    }

    /// Spawn the rank threads onto caller-provided fabric endpoints. The
    /// fleet front-end builds one independent communicator group per
    /// replica (`Fabric::replica_groups`) and starts each replica's pool
    /// on its own group; fault arming and thread names use the endpoint's
    /// `world_rank`, so every rank in a fleet keeps a globally unique
    /// identity (`world_rank == rank` for the single-pool path).
    pub fn start_on(
        run: &RunConfig,
        scfg: &ServeConfig,
        exec: &ExecServer,
        opts: PoolOptions,
        endpoints: Vec<crate::comm::Endpoint>,
    ) -> Result<RankPool> {
        run.validate()?;
        scfg.validate()?;
        let artifact = run
            .artifact
            .clone()
            .ok_or_else(|| anyhow!("serving needs an artifact config name"))?;
        let mcfg = exec.manifest.config(&artifact)?;
        if mcfg.p != run.p || mcfg.n != run.model.n {
            bail!(
                "artifact '{}' geometry (p={}, n={}) does not match serve run (p={}, n={})",
                artifact,
                mcfg.p,
                mcfg.n,
                run.p,
                run.model.n
            );
        }

        let p = run.p;
        if endpoints.len() != p {
            bail!("pool needs {p} endpoints, got {}", endpoints.len());
        }
        let (done_tx, done_rx) = mpsc::channel::<Result<Done>>();
        let mut job_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for (rank, mut ep) in endpoints.into_iter().enumerate() {
            let world = ep.world_rank;
            if let Some(factory) = &opts.faults {
                if let Some(injector) = factory.for_rank(world) {
                    ep.arm_faults(injector);
                }
            }
            let (job_tx, job_rx) = mpsc::channel::<RankMsg>();
            job_txs.push(job_tx);
            let done_tx = done_tx.clone();
            let handle = exec.handle();
            let artifact = artifact.clone();
            let model = run.model;
            let seed = run.train.seed;
            let mode = scfg.mode;
            let power = run.hardware.power;
            let trace = opts.trace;
            handles.push(
                thread::Builder::new()
                    .name(format!("serve-rank-{world}"))
                    .spawn(move || {
                        rank_loop(
                            rank, p, mode, model, seed, artifact, handle, ep, job_rx, done_tx,
                            power, trace,
                        )
                    })
                    .context("spawning serve rank thread")?,
            );
        }
        drop(done_tx);

        Ok(RankPool {
            p,
            n: run.model.n,
            mode: scfg.mode,
            job_txs,
            done_rx,
            handles,
            next_seq: 0,
            free_s: 0.0,
            last_batch_j: 0.0,
        })
    }

    /// Virtual time at which the pool finished its last batch (0 before the
    /// first dispatch). The batcher never dispatches earlier than this.
    pub fn free_s(&self) -> f64 {
        self.free_s
    }

    /// Cluster energy (all ranks, idle gap + compute) spent on the last
    /// `execute` call, Joules. 0 before the first batch.
    pub fn last_batch_energy_j(&self) -> f64 {
        self.last_batch_j
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mode(&self) -> Parallelism {
        self.mode
    }

    /// Hot-swap every rank's weights to a (possibly re-sharded) snapshot.
    /// The swap rides the per-rank job channels, so it lands between
    /// batches on every rank: queries already dispatched are answered by
    /// the old weights, every later dispatch by the new — no query is
    /// dropped and no batch sees a torn mix of layouts. The snapshot's
    /// parallelism mode may differ from the pool's starting mode (the
    /// collective schedule follows the weights). A hybrid (dp > 1)
    /// snapshot is collapsed first — its DP replicas are verified
    /// bitwise-identical, then replica 0 serves (serving is
    /// model-parallel; replicas carry no extra weights).
    pub fn load_weights(&mut self, snap: &Snapshot) -> Result<()> {
        snap.validate()?;
        if snap.dp() > 1 {
            let collapsed = crate::ckpt::collapse_dp(snap)
                .context("collapsing hybrid snapshot for serving")?;
            return self.load_weights(&collapsed);
        }
        if snap.p() != self.p || snap.n() != self.n {
            bail!(
                "snapshot geometry (p={}, n={}) does not match pool (p={}, n={})",
                snap.p(),
                snap.n(),
                self.p,
                self.n
            );
        }
        for (rank, tx) in self.job_txs.iter().enumerate() {
            let worker = match &snap.shards[rank].params {
                RankParams::Phantom(params) => Worker::Pp(params.clone()),
                RankParams::Tensor(params) => Worker::Tp(params.clone()),
            };
            tx.send(RankMsg::Swap(Box::new(worker)))
                .map_err(|_| anyhow!("a serve rank died"))?;
        }
        self.mode = snap.mode();
        Ok(())
    }

    /// Run one batched forward pass at virtual time `dispatch_s` over
    /// `x_full` [B, n]. Blocks until every rank finishes; returns the
    /// assembled output [B, n] and the batch completion time (max rank
    /// clock).
    pub fn execute(&mut self, dispatch_s: f64, x_full: &Tensor) -> Result<(Tensor, f64)> {
        if dispatch_s < self.free_s {
            bail!(
                "dispatch at t={dispatch_s} precedes pool-free time {} (batcher bug)",
                self.free_s
            );
        }
        let shards = x_full.col_shards(self.p)?;
        let seq = self.next_seq;
        self.next_seq += 1;
        for (tx, shard) in self.job_txs.iter().zip(shards) {
            tx.send(RankMsg::Job(Job { seq, dispatch_s, x_shard: shard }))
                .map_err(|_| anyhow!("a serve rank died"))?;
        }
        let mut outs: Vec<Option<Tensor>> = (0..self.p).map(|_| None).collect();
        let mut done_s = dispatch_s;
        let mut batch_j = 0.0;
        for _ in 0..self.p {
            let d = self
                .done_rx
                .recv()
                .map_err(|_| anyhow!("serve rank pool died mid-batch"))??;
            if d.seq != seq {
                bail!("out-of-sequence completion: got {} want {seq}", d.seq);
            }
            done_s = done_s.max(d.now_s);
            batch_j += d.energy_j;
            outs[d.rank] = Some(d.y_shard);
        }
        self.last_batch_j = batch_j;
        let shards: Vec<Tensor> =
            outs.into_iter().map(|o| o.expect("every rank reported")).collect();
        let y_full = Tensor::from_col_shards(&shards)?;
        self.free_s = done_s;
        Ok((y_full, done_s))
    }

    /// Tear the pool down and collect per-rank ledgers/stats (rank order).
    /// A panicked rank surfaces as a structured error (rank id + payload)
    /// after every surviving thread has been joined.
    pub fn shutdown(self) -> Result<Vec<PoolRankReport>> {
        let RankPool { job_txs, done_rx, handles, .. } = self;
        drop(job_txs);
        drop(done_rx);
        let (joined, panic) = join_rank_threads(handles);
        if let Some(p) = panic {
            return Err(anyhow!("serve rank {} panicked: {}", p.rank, p.payload));
        }
        let mut reports: Vec<PoolRankReport> = joined.into_iter().map(|(_, r)| r).collect();
        reports.sort_by_key(|r| r.rank);
        Ok(reports)
    }
}

#[allow(clippy::too_many_arguments)]
fn rank_loop(
    rank: usize,
    p: usize,
    mode: Parallelism,
    model: crate::config::ModelConfig,
    seed: u64,
    artifact: String,
    exec: crate::runtime::ExecHandle,
    mut ep: crate::comm::Endpoint,
    job_rx: mpsc::Receiver<RankMsg>,
    done_tx: mpsc::Sender<Result<Done>>,
    power: crate::energy::PowerModel,
    trace: bool,
) -> PoolRankReport {
    crate::obs::log::set_rank(rank);
    let mut ledger = EnergyLedger::new();
    if trace {
        ledger.arm_tracing(rank);
    }
    // Per-batch energy deltas for the Done reports (J/query metrics).
    let mut prev_j = 0.0;
    let worker = match mode {
        Parallelism::Phantom => PhantomRankParams::init(&model, p, rank, seed).map(Worker::Pp),
        Parallelism::Tensor => TpRankParams::init(&model, p, rank, seed).map(Worker::Tp),
    };
    match worker {
        Ok(mut worker) => {
            while let Ok(msg) = job_rx.recv() {
                let job = match msg {
                    RankMsg::Swap(new_worker) => {
                        // Host-side weight adoption between batches: not
                        // charged to the device ledger (like loading a
                        // snapshot off the host filesystem).
                        worker = *new_worker;
                        continue;
                    }
                    RankMsg::Job(job) => job,
                };
                if ledger.traced() && job.dispatch_s > ledger.now_s {
                    ledger.span_begin("pool.idle", "idle");
                    ledger.sync_to(job.dispatch_s);
                    ledger.span_end();
                } else {
                    ledger.sync_to(job.dispatch_s);
                }
                let rows = job.x_shard.shape()[0];
                if ledger.traced() {
                    let name = format!("batch {}", job.seq);
                    ledger.span_begin("batch", &name);
                }
                let res = match &worker {
                    Worker::Pp(params) => pp_forward_shard(
                        &exec, &artifact, params, &mut ep, &mut ledger, job.x_shard,
                    ),
                    Worker::Tp(params) => tp_forward_shard(
                        &exec, &artifact, params, &mut ep, &mut ledger, job.x_shard, true,
                    ),
                };
                let total_j = ledger.energy_j(&power);
                let energy_j = total_j - prev_j;
                prev_j = total_j;
                let seq = job.seq;
                ledger.span_end_with(|| {
                    vec![
                        ("seq", crate::obs::Arg::I(seq as i64)),
                        ("rows", crate::obs::Arg::I(rows as i64)),
                        ("energy_j", crate::obs::Arg::F(energy_j)),
                    ]
                });
                // Long-lived thread: keep the ledger O(1) across batches
                // (no-op while traced — attribution needs the intervals).
                ledger.compact();
                match res {
                    Ok(y_shard) => {
                        let done =
                            Done { seq: job.seq, rank, y_shard, now_s: ledger.now_s, energy_j };
                        if done_tx.send(Ok(done)).is_err() {
                            break; // leader gone: drain and report
                        }
                    }
                    Err(e) => {
                        // Wake peers blocked in the rendezvous promptly
                        // instead of leaving them to the 60 s timeout.
                        ep.poison();
                        let _ = done_tx.send(Err(e.context(format!("serve rank {rank}"))));
                        break;
                    }
                }
            }
        }
        Err(e) => {
            ep.poison();
            let _ = done_tx.send(Err(e.context(format!("serve rank {rank} init"))));
        }
    }
    let trace = ledger.take_trace();
    PoolRankReport { rank, ledger: ledger.summary(), stats: ep.stats, trace }
}
