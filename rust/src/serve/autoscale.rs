//! Occupancy-driven replica autoscaling with hysteresis (DESIGN.md §14).
//!
//! The fleet samples mean active-replica occupancy (queued / queue_depth)
//! at every arrival and feeds it here. Two guards stop flapping under the
//! bursty load model: a scale decision needs `patience` *consecutive*
//! samples beyond the watermark, and after acting the scaler holds still
//! for `cooldown_s` of virtual time. Everything is pure state over the
//! fed samples, so autoscaling replays deterministically with the trace.

use anyhow::{bail, Result};

/// Autoscaler policy knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleConfig {
    /// Never drain below this many active replicas (also the fleet's
    /// starting active count).
    pub min_replicas: usize,
    /// Never activate more than this many (the fleet pre-spawns exactly
    /// this many pools; standby replicas cost no energy).
    pub max_replicas: usize,
    /// Mean occupancy at/above which the fleet wants another replica.
    pub high_water: f64,
    /// Mean occupancy at/below which a replica should drain.
    pub low_water: f64,
    /// Consecutive beyond-watermark samples required before acting.
    pub patience: u32,
    /// Virtual seconds to hold still after a scale action.
    pub cooldown_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 2,
            high_water: 0.75,
            low_water: 0.15,
            patience: 8,
            cooldown_s: 0.05,
        }
    }
}

impl AutoscaleConfig {
    pub fn validate(&self) -> Result<()> {
        if self.min_replicas < 1 || self.max_replicas < self.min_replicas {
            bail!(
                "autoscale needs 1 <= min_replicas <= max_replicas, got {}..{}",
                self.min_replicas,
                self.max_replicas
            );
        }
        if !(0.0..=1.0).contains(&self.low_water)
            || !(0.0..=1.0).contains(&self.high_water)
            || self.low_water >= self.high_water
        {
            bail!(
                "watermarks need 0 <= low < high <= 1, got {}..{}",
                self.low_water,
                self.high_water
            );
        }
        if self.patience == 0 || self.cooldown_s < 0.0 {
            bail!("patience must be >= 1 and cooldown nonnegative");
        }
        Ok(())
    }
}

/// A scale decision for the fleet to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Activate one standby replica (snapshot spin-up).
    Up,
    /// Start draining one active replica.
    Down,
}

/// Hysteresis state machine over occupancy samples.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    above: u32,
    below: u32,
    cooldown_until: f64,
    ups: usize,
    downs: usize,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler { cfg, above: 0, below: 0, cooldown_until: 0.0, ups: 0, downs: 0 }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Scale-up / scale-down actions taken so far.
    pub fn actions(&self) -> (usize, usize) {
        (self.ups, self.downs)
    }

    /// Feed one occupancy sample at virtual time `now_s` with `active`
    /// replicas currently active (Draining replicas excluded). Returns the
    /// action the fleet must apply, if any.
    pub fn observe(&mut self, now_s: f64, occupancy: f64, active: usize) -> Option<ScaleAction> {
        if occupancy >= self.cfg.high_water {
            self.above += 1;
            self.below = 0;
        } else if occupancy <= self.cfg.low_water {
            self.below += 1;
            self.above = 0;
        } else {
            self.above = 0;
            self.below = 0;
        }
        if now_s < self.cooldown_until {
            return None;
        }
        if self.above >= self.cfg.patience && active < self.cfg.max_replicas {
            self.above = 0;
            self.below = 0;
            self.cooldown_until = now_s + self.cfg.cooldown_s;
            self.ups += 1;
            return Some(ScaleAction::Up);
        }
        if self.below >= self.cfg.patience && active > self.cfg.min_replicas {
            self.above = 0;
            self.below = 0;
            self.cooldown_until = now_s + self.cfg.cooldown_s;
            self.downs += 1;
            return Some(ScaleAction::Down);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 3,
            high_water: 0.8,
            low_water: 0.2,
            patience: 3,
            cooldown_s: 1.0,
        }
    }

    #[test]
    fn patience_gates_scale_up_and_cooldown_holds() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(0.0, 1.0, 1), None);
        assert_eq!(a.observe(0.1, 1.0, 1), None);
        // Third consecutive high sample: act.
        assert_eq!(a.observe(0.2, 1.0, 1), Some(ScaleAction::Up));
        // Saturated again immediately — cooldown holds until t = 1.2.
        assert_eq!(a.observe(0.3, 1.0, 2), None);
        assert_eq!(a.observe(0.4, 1.0, 2), None);
        assert_eq!(a.observe(1.3, 1.0, 2), Some(ScaleAction::Up));
        // At max: no further up.
        for i in 0..5 {
            assert_eq!(a.observe(3.0 + i as f64, 1.0, 3), None);
        }
        assert_eq!(a.actions(), (2, 0));
    }

    #[test]
    fn mid_band_samples_reset_streaks() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.observe(0.0, 1.0, 1), None);
        assert_eq!(a.observe(0.1, 1.0, 1), None);
        assert_eq!(a.observe(0.2, 0.5, 1), None); // streak broken
        assert_eq!(a.observe(0.3, 1.0, 1), None);
        assert_eq!(a.observe(0.4, 1.0, 1), None);
        assert_eq!(a.observe(0.5, 1.0, 1), Some(ScaleAction::Up));
    }

    #[test]
    fn scale_down_respects_min() {
        let mut a = Autoscaler::new(cfg());
        for i in 0..3 {
            let want = if i == 2 { Some(ScaleAction::Down) } else { None };
            assert_eq!(a.observe(2.0 + i as f64, 0.0, 2), want);
        }
        // Already at min: low occupancy never drains the last replica.
        for i in 0..5 {
            assert_eq!(a.observe(10.0 + i as f64, 0.0, 1), None);
        }
        assert_eq!(a.actions(), (0, 1));
    }

    #[test]
    fn config_validation_rejects_inverted_watermarks() {
        let mut c = cfg();
        assert!(c.validate().is_ok());
        c.low_water = 0.9;
        assert!(c.validate().is_err());
        c.low_water = 0.2;
        c.max_replicas = 0;
        assert!(c.validate().is_err());
    }
}
