//! Bounded admission queue + dynamic micro-batcher in front of the rank
//! pool.
//!
//! Queries arrive with nondecreasing *virtual* timestamps (one open-loop
//! client stream). The batcher coalesces queued queries into a forward
//! batch under two rules (DESIGN.md §7):
//!
//! * **fill**: as soon as `max_batch` queries are queued and the pool is
//!   free, dispatch a full batch (no lingering);
//! * **linger**: otherwise a forming batch waits at most `linger_s` past
//!   pool-ready for stragglers, then dispatches whatever arrived.
//!
//! The queue is bounded at `queue_depth` *in virtual time*: a query whose
//! arrival finds `queue_depth` queries still waiting is either shed
//! (`try_submit` → `Admission::Rejected`, the open-loop client walks away)
//! or blocked (`submit_blocking`: the client stalls until a dispatch frees
//! a slot, and is admitted at that instant — backpressure propagates to
//! the arrival stream).
//!
//! Dispatch simulation is lazy: a batch is only executed once its virtual
//! dispatch time is certain AND has been passed by the arrival frontier,
//! so queue occupancy seen by admission control matches what a real
//! concurrent queue would hold at that instant. Responses therefore come
//! back in strict query-id order — misordering is structurally impossible
//! and the load harness asserts it anyway.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::config::{RunConfig, ServeConfig};
use crate::obs::{Arg, MetricsRegistry, MetricsSnapshot, SpanRecorder};
use crate::runtime::ExecServer;
use crate::tensor::Tensor;

use super::pool::{PoolOptions, PoolRankReport, RankPool};

/// One served query's outcome.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// The client's original (pre-backpressure) arrival intent. Equals
    /// `arrival_s` unless the submission blocked for a queue slot.
    pub intent_s: f64,
    /// Effective admission time (after any backpressure blocking).
    pub arrival_s: f64,
    /// When its batch left the queue.
    pub dispatch_s: f64,
    /// When its batch completed (max rank clock).
    pub done_s: f64,
    /// Size of the batch it rode in.
    pub batch_size: usize,
    /// The output row [n].
    pub y: Tensor,
}

impl Response {
    /// End-to-end latency as the client experienced it: completion minus
    /// the original intent time, blocking delay included. This is the
    /// number both `Server::metrics()` and `LoadReport` quote — under
    /// backpressure the old admission-based accounting under-reported and
    /// the two surfaces disagreed.
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.intent_s
    }

    /// Time spent queued after admission, before the batch dispatched —
    /// the server-side component of `latency_s`, kept as its own metric.
    pub fn queue_wait_s(&self) -> f64 {
        self.dispatch_s - self.arrival_s
    }
}

/// Admission verdict of `try_submit`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Accepted(u64),
    /// Queue full at the arrival instant: backpressure, query shed.
    Rejected,
}

/// Counters the server keeps while running.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServerStats {
    pub admitted: u64,
    pub rejected: u64,
    /// Submissions that had to block for a queue slot.
    pub blocked: u64,
    pub batches: u64,
    pub max_queue_seen: usize,
    /// Sum of dispatched batch sizes (mean = / batches).
    pub dispatched: u64,
}

struct Pending {
    id: u64,
    /// Original client intent time (latency accounting).
    intent_s: f64,
    /// Effective admission time (batch-composition rules).
    arrival_s: f64,
    x: Tensor, // [n]
}

/// The serving front-end: admission queue + batcher + rank pool.
pub struct Server {
    pool: RankPool,
    scfg: ServeConfig,
    pending: VecDeque<Pending>,
    completed: Vec<Response>,
    next_id: u64,
    last_arrival_s: f64,
    /// Latest client intent observed (blocking submissions): intents must
    /// themselves be nondecreasing even when backpressure pushes the
    /// effective admissions past them.
    last_intent_s: f64,
    pub stats: ServerStats,
    /// Rolling live metrics (queue depth, shed/admit counters, latency
    /// p50/p99, J/query EWMA) — always on; snapshot via [`Server::metrics`].
    metrics: MetricsRegistry,
    /// Batcher decision timeline (admit/shed/batch/swap instants, stamped
    /// in virtual time) when the serve run is traced; `None` otherwise.
    events: Option<SpanRecorder>,
}

impl Server {
    pub fn start(run: &RunConfig, scfg: ServeConfig, exec: &ExecServer) -> Result<Server> {
        Self::start_with(run, scfg, exec, PoolOptions::default())
    }

    /// `start` with fault-injection / timeout / tracing options.
    pub fn start_with(
        run: &RunConfig,
        scfg: ServeConfig,
        exec: &ExecServer,
        opts: PoolOptions,
    ) -> Result<Server> {
        let trace = opts.trace;
        let pool = RankPool::start_with(run, &scfg, exec, opts)?;
        Ok(Self::from_pool(run, scfg, pool, trace))
    }

    /// Start a server whose pool runs on caller-provided fabric endpoints
    /// (the fleet gives each replica its own communicator group from
    /// `Fabric::replica_groups`).
    pub fn start_on(
        run: &RunConfig,
        scfg: ServeConfig,
        exec: &ExecServer,
        opts: PoolOptions,
        endpoints: Vec<crate::comm::Endpoint>,
    ) -> Result<Server> {
        let trace = opts.trace;
        let pool = RankPool::start_on(run, &scfg, exec, opts, endpoints)?;
        Ok(Self::from_pool(run, scfg, pool, trace))
    }

    fn from_pool(run: &RunConfig, scfg: ServeConfig, pool: RankPool, trace: bool) -> Server {
        Server {
            pool,
            scfg,
            pending: VecDeque::new(),
            completed: Vec::new(),
            next_id: 0,
            last_arrival_s: 0.0,
            last_intent_s: 0.0,
            stats: ServerStats::default(),
            metrics: MetricsRegistry::default(),
            events: trace.then(|| SpanRecorder::new(run.p)),
        }
    }

    pub fn n(&self) -> usize {
        self.pool.n()
    }

    /// Queries admitted but not yet dispatched.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// Hot-swap the pool onto a (possibly re-sharded) checkpoint snapshot.
    /// Takes effect between batches: queries still queued at the swap —
    /// and everything submitted later — are served by the new weights;
    /// nothing queued is dropped or reordered.
    pub fn hot_swap(&mut self, snap: &crate::ckpt::Snapshot) -> Result<()> {
        self.pool.load_weights(snap)?;
        self.metrics.inc("swaps");
        if let Some(rec) = self.events.as_mut() {
            rec.event("serve.swap", "hot swap", self.last_arrival_s, vec![]);
        }
        Ok(())
    }

    /// Point-in-time snapshot of the live serve metrics: counters
    /// (admitted/shed/blocked/batches/swaps), the queue-depth gauge,
    /// latency and batch-size histograms (`*_p50`/`*_p99`/`*_count`), and
    /// the J/query EWMA (`j_per_query_ewma`).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Take the batcher's virtual-time decision timeline (traced serve
    /// runs only; `None` otherwise or if already taken).
    pub fn take_host_events(&mut self) -> Option<SpanRecorder> {
        self.events.take()
    }

    /// Open-loop submission at virtual time `arrival_s` (must be
    /// nondecreasing across calls). Returns `Rejected` when the queue is
    /// full at that instant.
    pub fn try_submit(&mut self, arrival_s: f64, x: Tensor) -> Result<Admission> {
        self.check_arrival(arrival_s, &x)?;
        // Every observed arrival advances the frontier, rejected or not —
        // a later submission must never precede a rejection it witnessed.
        self.last_arrival_s = arrival_s;
        self.last_intent_s = self.last_intent_s.max(arrival_s);
        self.advance_to(arrival_s)?;
        if self.pending.len() >= self.scfg.queue_depth {
            self.stats.rejected += 1;
            self.metrics.inc("shed");
            if let Some(rec) = self.events.as_mut() {
                rec.event("serve.shed", "shed", arrival_s, vec![]);
            }
            return Ok(Admission::Rejected);
        }
        Ok(Admission::Accepted(self.enqueue(arrival_s, arrival_s, x)))
    }

    /// Closed-loop submission at the client's intent time: when the stream
    /// is stalled (an earlier submission blocked past `intent_s`) or the
    /// queue is full, the client blocks until a dispatch frees a slot and
    /// is admitted at that instant. The query's latency clock starts at
    /// `intent_s` regardless — both the live histogram and the Response
    /// report client-intent latency. Intents must be nondecreasing across
    /// calls (they may lag the effective-admission frontier). Returns
    /// (query id, effective arrival time).
    pub fn submit_blocking(&mut self, intent_s: f64, x: Tensor) -> Result<(u64, f64)> {
        if !intent_s.is_finite() || intent_s < self.last_intent_s {
            bail!(
                "intents must be finite and nondecreasing: got {intent_s} after {}",
                self.last_intent_s
            );
        }
        if x.shape() != &[self.pool.n()] {
            bail!("query must be a [n]={} row, got {:?}", self.pool.n(), x.shape());
        }
        self.last_intent_s = intent_s;
        // A single closed-loop stream cannot deliver before its previous
        // admission: the wire arrival starts at the later of the intent
        // and the current frontier.
        let mut effective_s = intent_s.max(self.last_arrival_s);
        self.advance_to(effective_s)?;
        let mut was_blocked = false;
        while self.pending.len() >= self.scfg.queue_depth {
            // The blocked client is the next event in the stream, so no
            // other arrival can precede the freeing dispatch: force it.
            let (dispatch_s, count) = self
                .next_dispatch(f64::INFINITY)
                .expect("a full queue always contains a dispatchable batch");
            self.dispatch(dispatch_s, count)?;
            effective_s = effective_s.max(dispatch_s);
            was_blocked = true;
        }
        if was_blocked {
            self.stats.blocked += 1;
            self.metrics.inc("blocked");
        }
        self.last_arrival_s = effective_s;
        Ok((self.enqueue(intent_s, effective_s, x), effective_s))
    }

    /// Advance the server's virtual clock to `now_s` without submitting:
    /// dispatch every batch whose timing is certain by that instant. The
    /// fleet front-end calls this on every global arrival so all replicas'
    /// clocks move coherently — a replica receiving no traffic still
    /// flushes its lingering batches while its peers are being fed.
    pub fn advance_clock(&mut self, now_s: f64) -> Result<()> {
        if !now_s.is_finite() || now_s < self.last_arrival_s {
            bail!(
                "clock must advance monotonically: got {now_s} after {}",
                self.last_arrival_s
            );
        }
        self.last_arrival_s = now_s;
        self.advance_to(now_s)
    }

    /// Dispatch everything still queued (the arrival stream has ended).
    pub fn drain(&mut self) -> Result<()> {
        self.advance_to(f64::INFINITY)
    }

    /// Pop the responses completed so far, in query-id order.
    pub fn take_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.completed)
    }

    /// Drain, then shut the pool down. Returns any not-yet-taken responses
    /// plus the final stats and per-rank accounting.
    pub fn finish(mut self) -> Result<(Vec<Response>, ServerStats, Vec<PoolRankReport>)> {
        self.drain()?;
        let responses = self.take_responses();
        let stats = self.stats;
        let per_rank = self.pool.shutdown()?;
        Ok((responses, stats, per_rank))
    }

    // -- internals ---------------------------------------------------------

    fn check_arrival(&self, arrival_s: f64, x: &Tensor) -> Result<()> {
        if !arrival_s.is_finite() || arrival_s < self.last_arrival_s {
            bail!(
                "arrivals must be finite and nondecreasing: got {arrival_s} after {}",
                self.last_arrival_s
            );
        }
        if x.shape() != &[self.pool.n()] {
            bail!("query must be a [n]={} row, got {:?}", self.pool.n(), x.shape());
        }
        Ok(())
    }

    fn enqueue(&mut self, intent_s: f64, arrival_s: f64, x: Tensor) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.last_arrival_s = self.last_arrival_s.max(arrival_s);
        self.pending.push_back(Pending { id, intent_s, arrival_s, x });
        self.stats.admitted += 1;
        self.stats.max_queue_seen = self.stats.max_queue_seen.max(self.pending.len());
        self.metrics.inc("admitted");
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
        if let Some(rec) = self.events.as_mut() {
            rec.event("serve.admit", "admit", arrival_s, vec![("id", Arg::I(id as i64))]);
        }
        id
    }

    /// Decide the next batch, given that no arrival can occur before
    /// `now_s`. Returns (dispatch time, query count), or None if the batch
    /// composition or timing is not yet certain.
    fn next_dispatch(&self, now_s: f64) -> Option<(f64, usize)> {
        let head = self.pending.front()?;
        let t_ready = self.pool.free_s().max(head.arrival_s);
        let deadline = t_ready + self.scfg.linger_s;
        if self.pending.len() >= self.scfg.max_batch {
            let t_full = self.pending[self.scfg.max_batch - 1].arrival_s;
            if t_full <= deadline {
                // Fill rule: the batch is full before the linger expires.
                let t = t_ready.max(t_full);
                return if t <= now_s { Some((t, self.scfg.max_batch)) } else { None };
            }
            // The linger closes first; later-queued arrivals prove nothing
            // more can join, so fall through to the linger rule (its
            // composition is already certain regardless of `now_s`, but
            // dispatch still waits for the frontier to pass the deadline).
        }
        if deadline <= now_s {
            let count = self
                .pending
                .iter()
                .take_while(|q| q.arrival_s <= deadline)
                .count()
                .min(self.scfg.max_batch);
            debug_assert!(count >= 1, "head arrived by t_ready <= deadline");
            return Some((deadline, count));
        }
        None
    }

    /// Dispatch every batch that is due before the arrival frontier.
    fn advance_to(&mut self, now_s: f64) -> Result<()> {
        while let Some((dispatch_s, count)) = self.next_dispatch(now_s) {
            self.dispatch(dispatch_s, count)?;
        }
        Ok(())
    }

    fn dispatch(&mut self, dispatch_s: f64, count: usize) -> Result<()> {
        debug_assert!(count >= 1 && count <= self.pending.len());
        let n = self.pool.n();
        let queries: Vec<Pending> = self.pending.drain(..count).collect();
        let mut flat = Vec::with_capacity(count * n);
        for q in &queries {
            flat.extend_from_slice(q.x.data());
        }
        let x_full = Tensor::from_vec(&[count, n], flat)?;
        let (y_full, done_s) = self.pool.execute(dispatch_s, &x_full)?;
        if y_full.shape() != &[count, n] {
            bail!("pool returned {:?}, want [{count}, {n}]", y_full.shape());
        }
        for (i, q) in queries.into_iter().enumerate() {
            let y = Tensor::from_vec(&[n], y_full.data()[i * n..(i + 1) * n].to_vec())?;
            // Client-intent latency: blocking delay included. The old
            // `done_s - q.arrival_s` measured from the post-backpressure
            // admission instant, silently under-reporting p50/p99 whenever
            // submissions blocked. Queue wait (admission -> dispatch) stays
            // observable as its own histogram.
            self.metrics.observe("latency_s", done_s - q.intent_s);
            self.metrics.observe("queue_wait_s", dispatch_s - q.arrival_s);
            self.completed.push(Response {
                id: q.id,
                intent_s: q.intent_s,
                arrival_s: q.arrival_s,
                dispatch_s,
                done_s,
                batch_size: count,
                y,
            });
        }
        self.stats.batches += 1;
        self.stats.dispatched += count as u64;
        let batch_j = self.pool.last_batch_energy_j();
        self.metrics.inc("batches");
        self.metrics.observe("batch_size", count as f64);
        self.metrics.ewma("j_per_query", batch_j / count as f64, 0.2);
        self.metrics.set_gauge("queue_depth", self.pending.len() as f64);
        if let Some(rec) = self.events.as_mut() {
            let args = vec![
                ("queries", Arg::I(count as i64)),
                ("done_s", Arg::F(done_s)),
                ("energy_j", Arg::F(batch_j)),
            ];
            rec.event("serve.batch", "dispatch", dispatch_s, args);
        }
        Ok(())
    }
}
