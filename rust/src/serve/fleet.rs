//! Energy-routed replica fleet: N independent `RankPool` replicas behind
//! a router and an occupancy autoscaler (DESIGN.md §14, ROADMAP item 3).
//!
//! Each replica is a full serving stack (admission queue + batcher + rank
//! pool) on its own communicator group from `Fabric::replica_groups` —
//! replicas never exchange traffic, so the fleet scales the paper's
//! model-parallel serving story to DP width without new collectives. The
//! front-end is event-driven in virtual time: every global arrival first
//! advances *all* non-standby replicas' clocks coherently (a replica
//! receiving no traffic still flushes its lingering batches while peers
//! are fed), then samples the autoscaler, then routes the query.
//!
//! Scale-up spins a standby replica onto a snapshot via the existing
//! `Server::hot_swap` path; scale-down marks a replica Draining — the
//! router stops feeding it, it flushes naturally with the shared clock,
//! and it parks as Standby once empty. Standby replicas dispatch nothing,
//! so their ledgers never advance: an idle replica costs no energy, which
//! is exactly why packing queries onto few warm replicas (the
//! energy-aware policy) beats spreading them round-robin.

use anyhow::{anyhow, bail, Result};

use crate::ckpt::Snapshot;
use crate::comm::{Fabric, RENDEZVOUS_TIMEOUT};
use crate::config::{RunConfig, ServeConfig};
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use crate::util::stats::{summarize, Summary};

use super::autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
use super::batcher::{Admission, Server};
use super::pool::PoolOptions;
use super::router::{ReplicaStatus, RoutePolicy, Router};

/// Fleet-level knobs: routing policy plus autoscaler envelope. The fleet
/// pre-spawns `autoscale.max_replicas` pools and starts
/// `autoscale.min_replicas` of them Active.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetConfig {
    pub policy: RoutePolicy,
    pub autoscale: AutoscaleConfig,
}

/// One fleet run's summary — deterministic (bit-identical under a fixed
/// trace and seed), which the replay property test asserts via `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub policy: RoutePolicy,
    /// Pre-spawned replica pools (`autoscale.max_replicas`).
    pub replicas: usize,
    pub queries: usize,
    pub completed: usize,
    /// Shed by the routed replica's admission control (open-loop).
    pub shed: usize,
    /// Per-replica response-order violations — structurally 0.
    pub misordered: usize,
    /// Client-intent latency over completed queries, seconds.
    pub latency: Summary,
    /// Post-admission queue wait, seconds.
    pub queue_wait: Summary,
    pub throughput_qps: f64,
    /// Whole-fleet energy (every rank of every replica), Joules.
    pub energy_j: f64,
    pub energy_per_kq_j: f64,
    /// Mean Active-replica count over arrival samples.
    pub mean_active: f64,
    /// Mean Active-replica occupancy (queued / queue_depth) over samples.
    pub mean_occupancy: f64,
    pub scale_ups: usize,
    pub scale_downs: usize,
    pub per_replica_completed: Vec<usize>,
    /// Virtual end time (max rank-ledger clock across the fleet).
    pub virtual_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplicaState {
    /// Routable.
    Active,
    /// Flushing its queue; the router skips it.
    Draining,
    /// Empty and parked; costs no energy until spun up.
    Standby,
}

struct Replica {
    server: Server,
    state: ReplicaState,
    /// Local query id -> global query id (admission order).
    assigned: Vec<u64>,
    /// Next expected local response id (per-replica order check).
    collected: u64,
    completed: usize,
}

impl Replica {
    /// Pull completed responses, verifying per-replica id order and
    /// recording fleet-level latency samples.
    fn collect(
        &mut self,
        misordered: &mut usize,
        latencies: &mut Vec<f64>,
        queue_waits: &mut Vec<f64>,
        last_done: &mut f64,
    ) {
        for r in self.server.take_responses() {
            if r.id != self.collected {
                *misordered += 1;
            }
            self.collected = r.id + 1;
            self.completed += 1;
            latencies.push(r.latency_s());
            queue_waits.push(r.queue_wait_s());
            *last_done = last_done.max(r.done_s);
        }
    }
}

/// Run one fleet over an explicit arrival trace (`BurstModel::trace`
/// materializes one; tests hand-craft saturate/lull phases). Query
/// payloads are a pure function of `payload_seed` and the arrival index,
/// independent of routing — every policy and replica count serves
/// bit-identical traffic.
pub fn run_fleet(
    run: &RunConfig,
    scfg: &ServeConfig,
    fcfg: &FleetConfig,
    arrivals: &[f64],
    payload_seed: u64,
    exec: &ExecServer,
) -> Result<FleetReport> {
    run.validate()?;
    scfg.validate()?;
    fcfg.autoscale.validate()?;
    if arrivals.is_empty() {
        bail!("fleet needs at least one arrival");
    }
    let mut prev = 0.0f64;
    for &t in arrivals {
        if !t.is_finite() || t < prev {
            bail!("fleet arrivals must be finite and nondecreasing");
        }
        prev = t;
    }

    let max_r = fcfg.autoscale.max_replicas;
    let n = run.model.n;
    // One independent communicator group per replica; globally unique
    // world ranks (replica * p + rank) name the threads.
    let groups = Fabric::replica_groups(run.p, max_r, run.hardware.net, RENDEZVOUS_TIMEOUT);
    let mut reps: Vec<Replica> = Vec::with_capacity(max_r);
    for (i, eps) in groups.into_iter().enumerate() {
        let server = Server::start_on(run, *scfg, exec, PoolOptions::default(), eps)?;
        let state = if i < fcfg.autoscale.min_replicas {
            ReplicaState::Active
        } else {
            ReplicaState::Standby
        };
        reps.push(Replica { server, state, assigned: Vec::new(), collected: 0, completed: 0 });
    }
    // Spin-up weights: the deterministic init snapshot (identical to what
    // every pool already holds — the swap exercises the snapshot path).
    let snap = Snapshot::init(run)?;

    let mut router = Router::new(fcfg.policy);
    let mut scaler = Autoscaler::new(fcfg.autoscale);
    let mut rng = Prng::new(payload_seed);

    let mut shed = 0usize;
    let mut misordered = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut queue_waits: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut last_done = 0.0f64;
    let mut occupancy_sum = 0.0f64;
    let mut active_sum = 0usize;

    for (gid, &t) in arrivals.iter().enumerate() {
        // Payload drawn before routing: the PRNG stream never depends on
        // policy or fleet state.
        let x = Tensor::randn(&[n], 1.0, &mut rng);

        // 1. Advance every non-standby replica's clock coherently and
        //    harvest what completed.
        for rep in reps.iter_mut() {
            if rep.state != ReplicaState::Standby {
                rep.server.advance_clock(t)?;
            }
            rep.collect(&mut misordered, &mut latencies, &mut queue_waits, &mut last_done);
            if rep.state == ReplicaState::Draining && rep.server.queued() == 0 {
                rep.state = ReplicaState::Standby;
            }
        }

        // 2. Sample occupancy over Active replicas and autoscale.
        let active: Vec<usize> = (0..reps.len())
            .filter(|&i| reps[i].state == ReplicaState::Active)
            .collect();
        let occ = active
            .iter()
            .map(|&i| reps[i].server.queued() as f64 / scfg.queue_depth as f64)
            .sum::<f64>()
            / active.len() as f64;
        occupancy_sum += occ;
        active_sum += active.len();
        match scaler.observe(t, occ, active.len()) {
            Some(ScaleAction::Up) => {
                // Prefer a parked standby (snapshot spin-up); else cancel
                // a drain in progress — it still holds weights and queue.
                if let Some(i) = reps.iter().position(|r| r.state == ReplicaState::Standby) {
                    reps[i].server.advance_clock(t)?;
                    reps[i].server.hot_swap(&snap)?;
                    reps[i].state = ReplicaState::Active;
                } else if let Some(i) =
                    reps.iter().position(|r| r.state == ReplicaState::Draining)
                {
                    reps[i].state = ReplicaState::Active;
                }
            }
            Some(ScaleAction::Down) => {
                // Drain the emptiest Active replica (ties to the highest
                // id, keeping low ids warm for the router).
                let victim = active
                    .iter()
                    .copied()
                    .min_by_key(|&i| (reps[i].server.queued(), usize::MAX - i))
                    .expect("scale-down only fires with active > min >= 1");
                reps[victim].state = ReplicaState::Draining;
            }
            None => {}
        }

        // 3. Route among the (possibly just-changed) Active replicas.
        let statuses: Vec<ReplicaStatus> = (0..reps.len())
            .filter(|&i| reps[i].state == ReplicaState::Active)
            .map(|i| ReplicaStatus {
                id: i,
                queued: reps[i].server.queued(),
                queue_depth: scfg.queue_depth,
                j_per_query: reps[i].server.metrics().get("j_per_query_ewma"),
            })
            .collect();
        let pick = router
            .pick(&statuses)
            .ok_or_else(|| anyhow!("fleet has no active replica (autoscaler bug)"))?;
        let rid = statuses[pick].id;
        match reps[rid].server.try_submit(t, x)? {
            Admission::Accepted(local) => {
                debug_assert_eq!(local as usize, reps[rid].assigned.len());
                reps[rid].assigned.push(gid as u64);
            }
            Admission::Rejected => shed += 1,
        }
    }

    // The stream ended: flush everything still queued, everywhere.
    for rep in reps.iter_mut() {
        rep.server.drain()?;
        rep.collect(&mut misordered, &mut latencies, &mut queue_waits, &mut last_done);
    }

    let completed = latencies.len();
    if completed + shed != arrivals.len() {
        bail!(
            "fleet dropped queries: {} completed + {} shed != {} offered",
            completed,
            shed,
            arrivals.len()
        );
    }
    if completed == 0 {
        bail!("fleet shed every query — raise queue_depth or lower the offered rate");
    }

    let mut energy_j = 0.0f64;
    let mut virtual_s = 0.0f64;
    let mut per_replica_completed = Vec::with_capacity(reps.len());
    for rep in reps {
        debug_assert_eq!(rep.completed, rep.assigned.len(), "every admitted query completed");
        per_replica_completed.push(rep.completed);
        let (tail, _stats, per_rank) = rep.server.finish()?;
        debug_assert!(tail.is_empty(), "drain + collect already took every response");
        for pr in &per_rank {
            energy_j += pr.ledger.energy_j(&run.hardware.power);
            virtual_s = virtual_s.max(pr.ledger.end_s);
        }
    }

    let samples = arrivals.len() as f64;
    let (scale_ups, scale_downs) = scaler.actions();
    Ok(FleetReport {
        policy: fcfg.policy,
        replicas: max_r,
        queries: arrivals.len(),
        completed,
        shed,
        misordered,
        latency: summarize(&latencies),
        queue_wait: summarize(&queue_waits),
        throughput_qps: completed as f64 / last_done.max(1e-12),
        energy_j,
        energy_per_kq_j: energy_j / completed as f64 * 1_000.0,
        mean_active: active_sum as f64 / samples,
        mean_occupancy: occupancy_sum / samples,
        scale_ups,
        scale_downs,
        per_replica_completed,
        virtual_s,
    })
}

/// Flat (key, value) records for one fleet run, prefixed
/// `r{replicas}_{policy}_` — the BENCH_fleet.json rows.
pub fn fleet_records(r: &FleetReport) -> Vec<(String, f64)> {
    let pre = format!("r{}_{}", r.replicas, r.policy.name());
    vec![
        (format!("{pre}_queries"), r.queries as f64),
        (format!("{pre}_completed"), r.completed as f64),
        (format!("{pre}_shed"), r.shed as f64),
        (format!("{pre}_shed_rate"), r.shed as f64 / r.queries as f64),
        (format!("{pre}_misordered"), r.misordered as f64),
        (format!("{pre}_p50_latency_s"), r.latency.p50),
        (format!("{pre}_p99_latency_s"), r.latency.p99),
        (format!("{pre}_p50_queue_wait_s"), r.queue_wait.p50),
        (format!("{pre}_throughput_qps"), r.throughput_qps),
        (format!("{pre}_energy_per_kq_j"), r.energy_per_kq_j),
        (format!("{pre}_mean_active"), r.mean_active),
        (format!("{pre}_occupancy"), r.mean_occupancy),
        (format!("{pre}_scale_ups"), r.scale_ups as f64),
        (format!("{pre}_scale_downs"), r.scale_downs as f64),
    ]
}
