//! In-memory collective communication fabric (the RCCL substitute).
//!
//! `Fabric::new(p, profile)` hands out one `Endpoint` per rank thread.
//! Collectives rendezvous in shared memory with synchronous semantics: all
//! ranks must call the same collective in the same order (SPMD), the last
//! arriver computes the combined result, and every participant's virtual
//! clock advances to
//!
//! ```text
//! t_after = max_i(t_arrive_i) + comm_time(m, p)
//! ```
//!
//! where `comm_time` is the paper's Eqn. (26) model with Table III constants
//! (`simnet`). The wait until the slowest peer arrives is charged as Idle
//! (static power B); driving the collective is charged as Communicate (also
//! B — the paper folds communication into the static-draw coefficient).
//!
//! Message-size accounting follows Table II: the `m` fed to the model is the
//! per-rank payload in floats (All-Gather: contribution size; Reduce-Scatter:
//! slot size; All-Reduce / Broadcast: full tensor size).
//!
//! **Fault hooks** (DESIGN.md §9): every endpoint can carry a
//! `FaultInjector` consulted once per rendezvous collective. The injector
//! sees `(rank, seq, op)` — `seq` is this endpoint's collective counter —
//! and answers with a `FaultAction`: proceed, stall the virtual clock
//! (straggler), drop the message (peers hit the rendezvous timeout),
//! poison the fabric, or crash the rank (panic after poisoning so peers
//! surface errors promptly instead of hanging). Faults are charged to the
//! *virtual* clock, never to wall-clock sleeps, so an injected schedule is
//! bit-reproducible; `testkit::FaultPlan` builds seeded schedules on top
//! of this hook.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::energy::{Activity, EnergyLedger};
use crate::simnet::{Collective, NetworkProfile};
use crate::tensor::Tensor;

/// Default rendezvous timeout: a mis-sequenced collective (deadlock) fails
/// loudly instead of hanging the test suite. `Fabric::with_timeout` lets
/// deadlock tests shrink this to milliseconds.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(60);

struct ExchangeState {
    gen: u64,
    deposits: Vec<Option<(Tensor, f64)>>,
    count: usize,
    ready: bool,
    results: Vec<Option<Tensor>>,
    max_clock: f64,
    pickups: usize,
    /// Set by the first rank of a round; all others must match (SPMD check).
    op: Option<&'static str>,
    poisoned: bool,
}

struct Shared {
    state: Mutex<ExchangeState>,
    cv: Condvar,
    p: usize,
    timeout: Duration,
}

/// Per-endpoint traffic statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub all_gathers: u64,
    pub reduce_scatters: u64,
    pub all_reduces: u64,
    pub broadcasts: u64,
    pub barriers: u64,
    /// Total floats counted as message size m across collectives.
    pub floats_moved: u64,
    /// Total modeled communication seconds.
    pub comm_s: f64,
}

impl CommStats {
    pub fn collectives(&self) -> u64 {
        self.all_gathers + self.reduce_scatters + self.all_reduces + self.broadcasts
    }

    /// Merge another endpoint's counters into this one (cluster totals).
    pub fn accumulate(&mut self, other: &CommStats) {
        self.all_gathers += other.all_gathers;
        self.reduce_scatters += other.reduce_scatters;
        self.all_reduces += other.all_reduces;
        self.broadcasts += other.broadcasts;
        self.barriers += other.barriers;
        self.floats_moved += other.floats_moved;
        self.comm_s += other.comm_s;
    }
}

/// What an armed `FaultInjector` tells an endpoint to do at a collective.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// No fault: run the collective normally.
    Proceed,
    /// Straggle: stall this rank's virtual clock by `seconds` (charged as
    /// Idle) before entering the rendezvous. Peers absorb the stall as
    /// rendezvous wait via the max-arrival rule.
    Delay { seconds: f64 },
    /// Lose the message: this rank never deposits and errors out; peers
    /// blocked in the rendezvous surface the configured timeout.
    Drop,
    /// Poison the fabric out-of-band, then error. Peers wake promptly.
    Poison,
    /// Kill the rank: poison the fabric (so peers surface errors instead
    /// of hanging) and panic with a recognizable payload. Propagated as a
    /// structured error by `Fabric::run_ranks` and the coordinator driver.
    Crash,
}

/// Per-endpoint fault hook, consulted once per rendezvous collective.
/// `seq` counts this endpoint's collectives from 0 (`charge_modeled` and
/// the internal delegation of `all_reduce_scalar` do not tick it).
pub trait FaultInjector: Send {
    fn on_collective(&mut self, rank: usize, seq: u64, op: &'static str) -> FaultAction;
}

/// Cloneable per-rank injector source: drivers that own fabric construction
/// (`coordinator::train_with`, `serve::RankPool`) accept one of these and
/// arm each endpoint at spawn time, so rank workers run unmodified.
#[derive(Clone)]
pub struct InjectorFactory(Arc<dyn Fn(usize) -> Option<Box<dyn FaultInjector>> + Send + Sync>);

impl InjectorFactory {
    pub fn new(
        f: impl Fn(usize) -> Option<Box<dyn FaultInjector>> + Send + Sync + 'static,
    ) -> InjectorFactory {
        InjectorFactory(Arc::new(f))
    }

    /// The injector for one rank (`None` = that rank runs fault-free).
    pub fn for_rank(&self, rank: usize) -> Option<Box<dyn FaultInjector>> {
        (self.0)(rank)
    }
}

impl std::fmt::Debug for InjectorFactory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("InjectorFactory(..)")
    }
}

/// One rank's handle onto the fabric. Moves into the rank's thread.
pub struct Endpoint {
    /// Group-local rank: position inside this endpoint's communicator.
    pub rank: usize,
    /// Group size: how many peers rendezvous on this communicator.
    pub p: usize,
    /// Global identity for fault hooks and diagnostics. Equals `rank` for
    /// ungrouped fabrics; grouped fabrics (`Fabric::new_grouped`) stamp the
    /// owning world rank so fault schedules and crash reports keep naming
    /// one global rank even when it holds several endpoints.
    pub world_rank: usize,
    shared: Arc<Shared>,
    profile: NetworkProfile,
    pub stats: CommStats,
    injector: Option<Box<dyn FaultInjector>>,
    /// Rendezvous collectives issued by this endpoint (fault-hook clock).
    collective_seq: u64,
    /// Ledger bucket this endpoint's wire time is charged to: Communicate
    /// for model-parallel groups, DpComm for data-parallel groups.
    comm_activity: Activity,
}

/// A world rank's coordinates in a hybrid DP × model-parallel grid.
/// World rank `w` = `dp_rank * p_model + model_rank`: consecutive world
/// ranks form a model-parallel group (one DP replica), and the ranks with
/// equal `model_rank` across replicas form a data-parallel group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupLayout {
    /// Model-parallel group size (the paper's p).
    pub p_model: usize,
    /// Data-parallel replica count.
    pub dp: usize,
}

impl GroupLayout {
    pub fn world(&self) -> usize {
        self.p_model * self.dp
    }

    pub fn model_rank(&self, world: usize) -> usize {
        world % self.p_model
    }

    pub fn dp_rank(&self, world: usize) -> usize {
        world / self.p_model
    }

    pub fn world_rank(&self, dp_rank: usize, model_rank: usize) -> usize {
        dp_rank * self.p_model + model_rank
    }
}

/// One world rank's endpoints in a hybrid grid: a model-parallel endpoint
/// (peers = same replica) and a data-parallel endpoint (peers = same model
/// rank across replicas). The two communicators rendezvous independently
/// and keep independent collective sequence numbers; the DP endpoint's
/// wire time is charged to the ledger's DpComm bucket.
pub struct HybridEndpoint {
    pub world: usize,
    pub model: Endpoint,
    pub dp: Endpoint,
}

/// The fabric constructor.
pub struct Fabric;

impl Fabric {
    pub fn new(p: usize, profile: NetworkProfile) -> Vec<Endpoint> {
        Self::with_timeout(p, profile, RENDEZVOUS_TIMEOUT)
    }

    /// Like `new`, with an explicit rendezvous timeout. Production callers
    /// keep the 60 s default; deadlock/poisoning tests pass milliseconds so
    /// a mis-sequenced collective surfaces as a prompt error.
    pub fn with_timeout(p: usize, profile: NetworkProfile, timeout: Duration) -> Vec<Endpoint> {
        assert!(p >= 1);
        let shared = Arc::new(Shared {
            state: Mutex::new(ExchangeState {
                gen: 0,
                deposits: (0..p).map(|_| None).collect(),
                count: 0,
                ready: false,
                results: (0..p).map(|_| None).collect(),
                max_clock: 0.0,
                pickups: 0,
                op: None,
                poisoned: false,
            }),
            cv: Condvar::new(),
            p,
            timeout,
        });
        (0..p)
            .map(|rank| Endpoint {
                rank,
                p,
                world_rank: rank,
                shared: shared.clone(),
                profile,
                stats: CommStats::default(),
                injector: None,
                collective_seq: 0,
                comm_activity: Activity::Communicate,
            })
            .collect()
    }

    /// Build the communicators of a hybrid DP × model grid: `layout.dp`
    /// model-parallel groups of size `p_model` plus `p_model` data-parallel
    /// groups of size `dp`, returned as one `HybridEndpoint` per world rank
    /// in world-rank order. Every group is an independent rendezvous fabric
    /// with its own SPMD check, poison domain and collective sequence
    /// numbers; the DP endpoints charge their wire time to the DpComm
    /// ledger bucket so the gradient all-reduce is accounted separately.
    pub fn new_grouped(
        layout: GroupLayout,
        profile: NetworkProfile,
        timeout: Duration,
    ) -> Vec<HybridEndpoint> {
        assert!(layout.p_model >= 1 && layout.dp >= 1);
        let mut model_groups: Vec<std::collections::VecDeque<Endpoint>> = (0..layout.dp)
            .map(|_| Fabric::with_timeout(layout.p_model, profile, timeout).into())
            .collect();
        let mut dp_groups: Vec<std::collections::VecDeque<Endpoint>> = (0..layout.p_model)
            .map(|_| Fabric::with_timeout(layout.dp, profile, timeout).into())
            .collect();
        (0..layout.world())
            .map(|world| {
                let r = layout.model_rank(world);
                let d = layout.dp_rank(world);
                let mut model = model_groups[d].pop_front().expect("one endpoint per rank");
                debug_assert_eq!(model.rank, r);
                model.world_rank = world;
                let mut dp = dp_groups[r].pop_front().expect("one endpoint per replica");
                debug_assert_eq!(dp.rank, d);
                dp.world_rank = world;
                dp.comm_activity = Activity::DpComm;
                HybridEndpoint { world, model, dp }
            })
            .collect()
    }

    /// Replica fabrics for a serving fleet: `replicas` independent
    /// model-parallel groups of size `p`, one `Vec<Endpoint>` per replica
    /// in replica order (group-local rank order within each). Built on
    /// `new_grouped`, so each endpoint's `world_rank` is its global
    /// identity (`replica * p + rank`) for fault schedules and thread
    /// names. The cross-replica data-parallel endpoints are dropped:
    /// serving replicas are fully independent and never issue a DP
    /// collective, and an endpoint that never rendezvouses blocks nobody.
    pub fn replica_groups(
        p: usize,
        replicas: usize,
        profile: NetworkProfile,
        timeout: Duration,
    ) -> Vec<Vec<Endpoint>> {
        let layout = GroupLayout { p_model: p, dp: replicas };
        let mut groups: Vec<Vec<Endpoint>> =
            (0..replicas).map(|_| Vec::with_capacity(p)).collect();
        for he in Self::new_grouped(layout, profile, timeout) {
            groups[layout.dp_rank(he.world)].push(he.model);
        }
        groups
    }

    /// Run a closure on p fabric ranks, one OS thread each, and return the
    /// per-rank results in rank order. A panicking rank is propagated as a
    /// structured `RankPanic` (rank id + panic payload + the offending
    /// collective context embedded in the payload) instead of a bare
    /// join-handle unwrap, so chaos tests can assert on the failure shape.
    pub fn run_ranks<T: Send + 'static>(
        p: usize,
        profile: NetworkProfile,
        timeout: Duration,
        f: impl Fn(Endpoint, EnergyLedger) -> T + Send + Sync + 'static,
    ) -> Result<Vec<T>, RankPanic> {
        let endpoints = Fabric::with_timeout(p, profile, timeout);
        let f = Arc::new(f);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                let rank = ep.rank;
                std::thread::Builder::new()
                    .name(format!("fabric-rank-{rank}"))
                    .spawn(move || f(ep, EnergyLedger::new()))
                    .expect("spawning fabric rank thread")
            })
            .collect();
        let (ok, panic) = join_rank_threads(handles);
        match panic {
            None => Ok(ok.into_iter().map(|(_, v)| v).collect()),
            Some(p) => Err(p),
        }
    }
}

/// Join rank-indexed thread handles (index = rank), separating successful
/// results from panics. The single place crash-surfacing join semantics
/// live: `Fabric::run_ranks`, the training driver, and the serve pool all
/// report panicking ranks through this.
pub fn join_rank_threads<T>(
    handles: Vec<std::thread::JoinHandle<T>>,
) -> (Vec<(usize, T)>, Option<RankPanic>) {
    let mut out = Vec::with_capacity(handles.len());
    let mut failures: Vec<(usize, String)> = Vec::new();
    for (rank, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(v) => out.push((rank, v)),
            Err(payload) => failures.push((rank, panic_payload(payload))),
        }
    }
    let panic = if failures.is_empty() { None } else { Some(RankPanic::new(failures)) };
    (out, panic)
}

/// Structured failure from `Fabric::run_ranks`: which rank(s) panicked and
/// with what payload, in rank order.
#[derive(Debug)]
pub struct RankPanic {
    /// Lowest-numbered panicking rank.
    pub rank: usize,
    /// That rank's panic payload.
    pub payload: String,
    /// Every panicking rank with its payload, in rank order.
    pub all: Vec<(usize, String)>,
}

impl RankPanic {
    fn new(all: Vec<(usize, String)>) -> RankPanic {
        let (rank, payload) = all.first().cloned().expect("at least one failure");
        RankPanic { rank, payload, all }
    }
}

impl std::fmt::Display for RankPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank {} panicked: {}", self.rank, self.payload)?;
        if self.all.len() > 1 {
            write!(f, " ({} ranks panicked in total)", self.all.len())?;
        }
        Ok(())
    }
}

impl std::error::Error for RankPanic {}

/// Best-effort extraction of a panic payload into a printable string.
pub fn panic_payload(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Endpoint {
    /// Install a fault injector on this endpoint. Subsequent rendezvous
    /// collectives consult it before depositing.
    pub fn arm_faults(&mut self, injector: Box<dyn FaultInjector>) {
        self.injector = Some(injector);
    }

    /// Rendezvous collectives issued so far (the fault-hook sequence clock).
    pub fn collective_seq(&self) -> u64 {
        self.collective_seq
    }

    /// Consult the armed injector (if any) before a rendezvous collective.
    /// Ticks the per-endpoint sequence counter exactly once per collective.
    /// The injector sees the endpoint's `world_rank` (= `rank` on ungrouped
    /// fabrics), so hybrid fault schedules key on one global identity.
    fn fault_gate(&mut self, op: &'static str, ledger: &mut EnergyLedger) -> Result<()> {
        let seq = self.collective_seq;
        self.collective_seq += 1;
        let Some(inj) = self.injector.as_mut() else {
            return Ok(());
        };
        match inj.on_collective(self.world_rank, seq, op) {
            FaultAction::Proceed => Ok(()),
            FaultAction::Delay { seconds } => {
                // Straggler: virtual-clock stall only — never a real sleep,
                // so the injected schedule stays bit-reproducible.
                ledger.advance(seconds, Activity::Idle);
                Ok(())
            }
            FaultAction::Drop => Err(anyhow!(
                "injected fault: rank {} dropped '{op}' (collective #{seq}); \
                 peers will surface the rendezvous timeout",
                self.world_rank
            )),
            FaultAction::Poison => {
                self.poison();
                Err(anyhow!(
                    "injected fault: rank {} poisoned the fabric at '{op}' (collective #{seq})",
                    self.world_rank
                ))
            }
            FaultAction::Crash => {
                // Poison first so peers blocked in the rendezvous wake with
                // an error instead of waiting out the timeout: a crash must
                // surface, never hang.
                self.poison();
                panic!(
                    "injected fault: rank {} crashed at '{op}' (collective #{seq})",
                    self.world_rank
                );
            }
        }
    }

    /// Generic rendezvous: deposit `t`, let the last arriver run `combine`
    /// over all deposits (ordered by rank) producing per-rank results, and
    /// return this rank's result plus the max arrival clock.
    fn exchange(
        &mut self,
        op: &'static str,
        t: Tensor,
        now_s: f64,
        combine: impl FnOnce(Vec<Tensor>) -> Result<Vec<Tensor>>,
    ) -> Result<(Tensor, f64)> {
        if self.p == 1 {
            let mut r = combine(vec![t])?;
            return Ok((r.pop().unwrap(), now_s));
        }
        let sh = &self.shared;
        let mut s = sh.state.lock().map_err(|_| anyhow!("fabric mutex poisoned"))?;

        // Wait for the previous round to fully drain before depositing.
        while s.ready && !s.poisoned {
            let (ns, to) = sh
                .cv
                .wait_timeout(s, sh.timeout)
                .map_err(|_| anyhow!("fabric mutex poisoned"))?;
            s = ns;
            if to.timed_out() {
                s.poisoned = true;
                sh.cv.notify_all();
                return Err(anyhow!(
                    "rank {}: rendezvous timeout waiting to enter '{op}'",
                    self.rank
                ));
            }
        }
        if s.poisoned {
            return Err(anyhow!("fabric poisoned by a peer failure"));
        }

        // SPMD check: every rank of a round must run the same collective.
        match s.op {
            None => s.op = Some(op),
            Some(prev) if prev != op => {
                s.poisoned = true;
                sh.cv.notify_all();
                return Err(anyhow!(
                    "collective mismatch: rank {} called '{op}' while round is '{prev}'",
                    self.rank
                ));
            }
            _ => {}
        }

        let my_gen = s.gen;
        assert!(s.deposits[self.rank].is_none(), "double deposit by rank {}", self.rank);
        s.deposits[self.rank] = Some((t, now_s));
        s.count += 1;

        if s.count == sh.p {
            // Last arriver: combine.
            let mut parts = Vec::with_capacity(sh.p);
            let mut max_clock = f64::NEG_INFINITY;
            for d in s.deposits.iter_mut() {
                let (tensor, clk) = d.take().unwrap();
                max_clock = max_clock.max(clk);
                parts.push(tensor);
            }
            match combine(parts) {
                Ok(results) => {
                    debug_assert_eq!(results.len(), sh.p);
                    for (slot, r) in s.results.iter_mut().zip(results) {
                        *slot = Some(r);
                    }
                    s.max_clock = max_clock;
                    s.ready = true;
                    s.pickups = sh.p;
                    sh.cv.notify_all();
                }
                Err(e) => {
                    s.poisoned = true;
                    sh.cv.notify_all();
                    return Err(e);
                }
            }
        } else {
            // Wait for the round to complete.
            while !(s.ready && s.gen == my_gen) && !s.poisoned {
                let (ns, to) = sh
                    .cv
                    .wait_timeout(s, sh.timeout)
                    .map_err(|_| anyhow!("fabric mutex poisoned"))?;
                s = ns;
                if to.timed_out() {
                    s.poisoned = true;
                    sh.cv.notify_all();
                    return Err(anyhow!(
                        "rank {}: rendezvous timeout inside '{op}' \
                         (a peer likely died or diverged)",
                        self.rank
                    ));
                }
            }
            if s.poisoned {
                return Err(anyhow!("fabric poisoned by a peer failure"));
            }
        }

        let result = s.results[self.rank].take().expect("result already taken");
        let max_clock = s.max_clock;
        s.pickups -= 1;
        if s.pickups == 0 {
            s.ready = false;
            s.count = 0;
            s.gen += 1;
            s.op = None;
            sh.cv.notify_all();
        }
        Ok((result, max_clock))
    }

    /// Poison the fabric, waking any peers blocked in a rendezvous with a
    /// prompt error instead of leaving them to the rendezvous timeout.
    /// Long-lived consumers (the serve pool) call this when a rank fails
    /// outside a collective so its peers never hang waiting for it.
    pub fn poison(&self) {
        if let Ok(mut s) = self.shared.state.lock() {
            s.poisoned = true;
            self.shared.cv.notify_all();
        }
    }

    /// A detached poisoner for this endpoint's group, usable after the
    /// endpoint itself has moved into a worker. The hybrid driver holds
    /// one per DP endpoint so a rank that dies in its MODEL group (whose
    /// fabric the fault path poisons directly) also wakes its DP-group
    /// peers promptly instead of leaving them to the wall-clock
    /// rendezvous timeout.
    pub fn poisoner(&self) -> FabricPoisoner {
        FabricPoisoner { shared: self.shared.clone() }
    }

    /// Span categories for this endpoint's traffic: the DP gradient sync
    /// gets its own attribution buckets, mirroring the DpComm ledger
    /// bucket.
    fn span_cats(&self) -> (&'static str, &'static str) {
        if self.comm_activity == Activity::DpComm {
            ("dp.wait", "dp.wire")
        } else {
            ("comm.wait", "comm.wire")
        }
    }

    /// Charge the ledger for a collective: idle until the slowest peer
    /// arrived, then the modeled wire time. On traced ledgers the
    /// rendezvous wait and the wire time become separate spans tagged with
    /// the op, this endpoint's collective seq, and the message size.
    fn charge(
        &mut self,
        ledger: &mut EnergyLedger,
        op: &'static str,
        collective: Collective,
        msg_floats: usize,
        max_arrival: f64,
    ) {
        let wire_s = self.profile.time(collective, msg_floats, self.p);
        if ledger.defer_armed() {
            // Overlapped (1F1B) collective: the rendezvous wait is real —
            // peers must still arrive, so clocks stay aligned — but the
            // wire time is parked on the ledger's overlap register, where
            // subsequent compute drains it concurrently and the scheduler
            // charges only the un-hidden remainder (`drain_deferred`).
            // Traffic stats below still record the full wire time: the
            // bytes move either way, hidden or not.
            if ledger.traced() {
                let seq = self.collective_seq.wrapping_sub(1) as i64;
                let (wait_cat, wire_cat) = self.span_cats();
                if max_arrival > ledger.now_s {
                    ledger.span_begin(wait_cat, op);
                    ledger.sync_to(max_arrival);
                    ledger.span_end_with(|| vec![("seq", crate::obs::Arg::I(seq))]);
                }
                ledger.trace_event(wire_cat, op, || {
                    vec![
                        ("seq", crate::obs::Arg::I(seq)),
                        ("deferred_wire_s", crate::obs::Arg::F(wire_s)),
                        ("floats", crate::obs::Arg::I(msg_floats as i64)),
                    ]
                });
            } else {
                ledger.sync_to(max_arrival);
            }
            ledger.defer_comm(wire_s);
            self.stats.floats_moved += msg_floats as u64;
            self.stats.comm_s += wire_s;
            return;
        }
        if ledger.traced() {
            // fault_gate already ticked the counter for this collective.
            let seq = self.collective_seq.wrapping_sub(1) as i64;
            let (group_rank, world_rank, p) =
                (self.rank as i64, self.world_rank as i64, self.p as i64);
            let (wait_cat, wire_cat) = self.span_cats();
            if max_arrival > ledger.now_s {
                ledger.span_begin(wait_cat, op);
                ledger.sync_to(max_arrival);
                ledger.span_end_with(|| vec![("seq", crate::obs::Arg::I(seq))]);
            }
            ledger.span_begin(wire_cat, op);
            ledger.advance(wire_s, self.comm_activity);
            ledger.span_end_with(|| {
                vec![
                    ("seq", crate::obs::Arg::I(seq)),
                    ("floats", crate::obs::Arg::I(msg_floats as i64)),
                    ("bytes", crate::obs::Arg::I(msg_floats as i64 * 4)),
                    ("group_size", crate::obs::Arg::I(p)),
                    ("rank", crate::obs::Arg::I(group_rank)),
                    ("world_rank", crate::obs::Arg::I(world_rank)),
                ]
            });
        } else {
            ledger.sync_to(max_arrival);
            ledger.advance(wire_s, self.comm_activity);
        }
        self.stats.floats_moved += msg_floats as u64;
        self.stats.comm_s += wire_s;
    }

    /// All-Gather: every rank contributes `t`; every rank receives the
    /// rank-ordered stack `[p, ...t.shape]`. Message size m = numel(t).
    pub fn all_gather(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.all_gather_op("all_gather", t, ledger)
    }

    /// The ZeRO parameter All-Gather on a data-parallel group: identical
    /// rendezvous and stacking semantics to `all_gather`, under a distinct
    /// op tag (like `dp_all_reduce`) so SPMD mismatch checks and fault
    /// schedules can tell the sharded-optimizer traffic apart from
    /// model-parallel collectives. Wire time lands in the DpComm bucket
    /// when used on a DP-group endpoint.
    pub fn dp_all_gather(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.all_gather_op("dp_all_gather", t, ledger)
    }

    fn all_gather_op(
        &mut self,
        op: &'static str,
        t: Tensor,
        ledger: &mut EnergyLedger,
    ) -> Result<Tensor> {
        self.fault_gate(op, ledger)?;
        let m = t.numel();
        let (result, max_arrival) = self.exchange(op, t, ledger.now_s, |parts| {
            let stacked = Tensor::stack(&parts)?;
            Ok(vec![stacked; parts_len(&parts)])
        })?;
        self.charge(ledger, op, Collective::AllGather, m, max_arrival);
        self.stats.all_gathers += 1;
        Ok(result)
    }

    /// Reduce-Scatter: every rank contributes `[p, ...]`; slot j is summed
    /// across ranks and delivered to rank j. Message size m = slot numel.
    pub fn reduce_scatter(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.reduce_scatter_op("reduce_scatter", t, ledger)
    }

    /// The ZeRO gradient Reduce-Scatter on a data-parallel group: identical
    /// rendezvous and rank-ordered summation semantics to `reduce_scatter`
    /// — and therefore the same bitwise fold order as `dp_all_reduce`,
    /// which is what makes the sharded optimizer update bit-identical to
    /// the flat path — under a distinct op tag for SPMD checks and fault
    /// schedules.
    pub fn dp_reduce_scatter(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.reduce_scatter_op("dp_reduce_scatter", t, ledger)
    }

    fn reduce_scatter_op(
        &mut self,
        op: &'static str,
        t: Tensor,
        ledger: &mut EnergyLedger,
    ) -> Result<Tensor> {
        self.fault_gate(op, ledger)?;
        let p = self.p;
        if t.shape().first() != Some(&p) {
            return Err(anyhow!(
                "{op} input must have leading dim p={p}, got {:?}",
                t.shape()
            ));
        }
        let m = t.numel() / p;
        let (result, max_arrival) = self.exchange(op, t, ledger.now_s, |parts| {
            let mut out = Vec::with_capacity(p);
            for j in 0..p {
                let mut acc = parts[0].unstack_at(j);
                for part in &parts[1..] {
                    acc.add_assign(&part.unstack_at(j));
                }
                out.push(acc);
            }
            Ok(out)
        })?;
        self.charge(ledger, op, Collective::ReduceScatter, m, max_arrival);
        self.stats.reduce_scatters += 1;
        Ok(result)
    }

    /// All-Reduce (sum): every rank contributes `t` and receives the
    /// elementwise sum. Message size m = numel(t).
    pub fn all_reduce(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.all_reduce_op("all_reduce", t, ledger)
    }

    /// The data-parallel gradient All-Reduce: identical rendezvous and
    /// summation semantics to `all_reduce`, under a distinct op tag so the
    /// SPMD mismatch check and fault schedules can tell the DP gradient
    /// sync apart from model-parallel traffic. Meant for endpoints of a DP
    /// group (`Fabric::new_grouped`), whose wire time lands in the DpComm
    /// ledger bucket.
    pub fn dp_all_reduce(&mut self, t: Tensor, ledger: &mut EnergyLedger) -> Result<Tensor> {
        self.all_reduce_op("dp_all_reduce", t, ledger)
    }

    fn all_reduce_op(
        &mut self,
        op: &'static str,
        t: Tensor,
        ledger: &mut EnergyLedger,
    ) -> Result<Tensor> {
        self.fault_gate(op, ledger)?;
        let m = t.numel();
        let (result, max_arrival) = self.exchange(op, t, ledger.now_s, |parts| {
            let mut acc = parts[0].clone();
            for part in &parts[1..] {
                acc.add_assign(part);
            }
            Ok(vec![acc; parts.len()])
        })?;
        self.charge(ledger, op, Collective::AllReduce, m, max_arrival);
        self.stats.all_reduces += 1;
        Ok(result)
    }

    /// Broadcast from `root`: non-root contributions are ignored (they pass
    /// an empty tensor by convention). Message size m = numel(root tensor).
    pub fn broadcast(
        &mut self,
        root: usize,
        t: Tensor,
        ledger: &mut EnergyLedger,
    ) -> Result<Tensor> {
        self.fault_gate("broadcast", ledger)?;
        let (result, max_arrival) = self.exchange("broadcast", t, ledger.now_s, move |parts| {
            let chosen = parts[root].clone();
            Ok(vec![chosen; parts.len()])
        })?;
        let m = result.numel();
        self.charge(ledger, "broadcast", Collective::Broadcast, m, max_arrival);
        self.stats.broadcasts += 1;
        Ok(result)
    }

    /// Barrier: pure synchronization (idle charge only, no wire time).
    pub fn barrier(&mut self, ledger: &mut EnergyLedger) -> Result<()> {
        self.fault_gate("barrier", ledger)?;
        let (_, max_arrival) =
            self.exchange("barrier", Tensor::zeros(&[0]), ledger.now_s, |parts| {
                Ok(vec![Tensor::zeros(&[0]); parts.len()])
            })?;
        if ledger.traced() && max_arrival > ledger.now_s {
            let seq = self.collective_seq.wrapping_sub(1) as i64;
            let (wait_cat, _) = self.span_cats();
            ledger.span_begin(wait_cat, "barrier");
            ledger.sync_to(max_arrival);
            ledger.span_end_with(|| vec![("seq", crate::obs::Arg::I(seq))]);
        } else {
            ledger.sync_to(max_arrival);
        }
        self.stats.barriers += 1;
        Ok(())
    }

    /// Charge the time of a collective WITHOUT moving data.
    ///
    /// The paper's TP pipeline issues Broadcast (forward) and an extra
    /// synchronization collective (backward) beyond the functionally
    /// necessary All-Gather/All-Reduce (Appendix, Table II). Our functional
    /// implementation assembles the same values with one collective; this
    /// method charges the wire time of the *paper's* schedule so beta_tau
    /// is reproduced faithfully. Callers must already be clock-synchronized
    /// (i.e. immediately after a functional collective), which keeps the
    /// virtual clocks aligned without a rendezvous.
    pub fn charge_modeled(
        &mut self,
        collective: Collective,
        msg_floats: usize,
        ledger: &mut EnergyLedger,
    ) {
        let wire_s = self.profile.time(collective, msg_floats, self.p);
        if ledger.traced() {
            let (_, wire_cat) = self.span_cats();
            let name = match collective {
                Collective::Broadcast => "modeled broadcast",
                Collective::AllReduce => "modeled all_reduce",
                Collective::AllGather => "modeled all_gather",
                Collective::ReduceScatter => "modeled reduce_scatter",
            };
            ledger.span_begin(wire_cat, name);
            ledger.advance(wire_s, self.comm_activity);
            ledger.span_end_with(|| {
                vec![
                    ("floats", crate::obs::Arg::I(msg_floats as i64)),
                    ("bytes", crate::obs::Arg::I(msg_floats as i64 * 4)),
                ]
            });
        } else {
            ledger.advance(wire_s, self.comm_activity);
        }
        self.stats.floats_moved += msg_floats as u64;
        self.stats.comm_s += wire_s;
        match collective {
            Collective::Broadcast => self.stats.broadcasts += 1,
            Collective::AllReduce => self.stats.all_reduces += 1,
            Collective::AllGather => self.stats.all_gathers += 1,
            Collective::ReduceScatter => self.stats.reduce_scatters += 1,
        }
    }

    /// Scalar All-Reduce convenience (loss aggregation).
    pub fn all_reduce_scalar(&mut self, v: f32, ledger: &mut EnergyLedger) -> Result<f32> {
        let t = Tensor::from_vec(&[1], vec![v])?;
        let r = self.all_reduce(t, ledger)?;
        Ok(r.data()[0])
    }
}

/// Detached handle onto one group fabric's poison flag (`Endpoint::poisoner`).
pub struct FabricPoisoner {
    shared: Arc<Shared>,
}

impl FabricPoisoner {
    /// Poison the group, waking any blocked peers promptly.
    pub fn poison(&self) {
        if let Ok(mut s) = self.shared.state.lock() {
            s.poisoned = true;
            self.shared.cv.notify_all();
        }
    }
}

fn parts_len(parts: &[Tensor]) -> usize {
    parts.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::NetworkProfile;
    use std::thread;

    /// Test shorthand: `Fabric::run_ranks` at the frontier profile and the
    /// production rendezvous timeout, expecting no rank to panic.
    pub fn run_ranks<T: Send + 'static>(
        p: usize,
        f: impl Fn(Endpoint, EnergyLedger) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        Fabric::run_ranks(p, NetworkProfile::frontier(), RENDEZVOUS_TIMEOUT, f)
            .expect("no rank panicked")
    }

    #[test]
    fn all_gather_stacks_in_rank_order() {
        let out = run_ranks(4, |mut ep, mut led| {
            let t = Tensor::filled(&[2], ep.rank as f32);
            ep.all_gather(t, &mut led).unwrap()
        });
        for g in out {
            assert_eq!(g.shape(), &[4, 2]);
            assert_eq!(g.data(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        }
    }

    #[test]
    fn reduce_scatter_sums_and_scatters() {
        let out = run_ranks(3, |mut ep, mut led| {
            // rank r contributes [p, 1] tensor with slot j = r*10 + j
            let data: Vec<f32> = (0..3).map(|j| (ep.rank * 10 + j) as f32).collect();
            let t = Tensor::from_vec(&[3, 1], data).unwrap();
            (ep.rank, ep.reduce_scatter(t, &mut led).unwrap())
        });
        for (rank, r) in out {
            // slot j = sum_r (r*10 + j) = 30 + 3j
            assert_eq!(r.shape(), &[1]);
            assert_eq!(r.data()[0], 30.0 + 3.0 * rank as f32);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let out = run_ranks(4, |mut ep, mut led| {
            let t = Tensor::filled(&[3], (ep.rank + 1) as f32);
            ep.all_reduce(t, &mut led).unwrap()
        });
        for r in out {
            assert_eq!(r.data(), &[10.0, 10.0, 10.0]);
        }
    }

    #[test]
    fn broadcast_takes_root() {
        let out = run_ranks(3, |mut ep, mut led| {
            let t = if ep.rank == 1 {
                Tensor::filled(&[2], 7.0)
            } else {
                Tensor::zeros(&[2])
            };
            ep.broadcast(1, t, &mut led).unwrap()
        });
        for r in out {
            assert_eq!(r.data(), &[7.0, 7.0]);
        }
    }

    #[test]
    fn virtual_clocks_synchronize() {
        let out = run_ranks(2, |mut ep, mut led| {
            // rank 1 computes longer before the collective
            let work = if ep.rank == 1 { 2.0 } else { 0.5 };
            led.advance(work, Activity::Compute);
            ep.all_reduce(Tensor::filled(&[4], 1.0), &mut led).unwrap();
            (ep.rank, led)
        });
        let wire = NetworkProfile::frontier().time(Collective::AllReduce, 4, 2);
        for (rank, led) in out {
            // both clocks end at max(2.0, 0.5) + wire
            assert!((led.now_s - (2.0 + wire)).abs() < 1e-12, "rank {rank}: {}", led.now_s);
            if rank == 0 {
                assert!((led.idle_s() - 1.5).abs() < 1e-12);
            } else {
                assert_eq!(led.idle_s(), 0.0);
            }
            assert!((led.comm_s() - wire).abs() < 1e-15);
        }
    }

    #[test]
    fn repeated_rounds_reuse_fabric() {
        let out = run_ranks(3, |mut ep, mut led| {
            let mut acc = 0.0;
            for round in 0..50 {
                let t = Tensor::filled(&[1], (ep.rank + round) as f32);
                acc += ep.all_reduce(t, &mut led).unwrap().data()[0];
            }
            acc
        });
        // round r: sum = (0 + 1 + 2) + 3r = 3 + 3r; total = sum_{0..50} = 150 + 3*1225
        for r in out {
            assert_eq!(r, (150 + 3 * 1225) as f32);
        }
    }

    #[test]
    fn single_rank_collectives_are_local() {
        let eps = Fabric::new(1, NetworkProfile::frontier());
        let mut ep = eps.into_iter().next().unwrap();
        let mut led = EnergyLedger::new();
        let g = ep.all_gather(Tensor::filled(&[2], 3.0), &mut led).unwrap();
        assert_eq!(g.shape(), &[1, 2]);
        let r = ep.all_reduce(Tensor::filled(&[2], 3.0), &mut led).unwrap();
        assert_eq!(r.data(), &[3.0, 3.0]);
        assert_eq!(led.comm_s(), 0.0, "p=1 must be communication-free");
    }

    #[test]
    fn mismatched_collectives_poison_not_hang() {
        let out = run_ranks(2, |mut ep, mut led| {
            let t = Tensor::filled(&[1], 1.0);
            if ep.rank == 0 {
                ep.all_reduce(t, &mut led).map(|_| ())
            } else {
                ep.all_gather(t, &mut led).map(|_| ())
            }
        });
        assert!(out.iter().any(|r| r.is_err()), "mismatch must surface as an error");
    }

    #[test]
    fn poison_wakes_blocked_peers_promptly() {
        // Default 60 s timeout: the blocked rank must wake via the poison
        // signal, not the timeout — the elapsed-time bound proves it.
        let t0 = std::time::Instant::now();
        let out = run_ranks(2, |mut ep, mut led| {
            if ep.rank == 0 {
                ep.all_reduce(Tensor::filled(&[4], 1.0), &mut led).map(|_| ())
            } else {
                // Give rank 0 a moment to enter the rendezvous, then fail
                // out-of-band (what a dying serve rank does).
                thread::sleep(Duration::from_millis(50));
                ep.poison();
                Ok(())
            }
        });
        assert!(out[0].is_err(), "blocked rank must surface the poisoning");
        assert!(t0.elapsed() < Duration::from_secs(10), "woke by signal, not timeout");
    }

    #[test]
    fn reduce_scatter_validates_leading_dim() {
        let out = run_ranks(2, |mut ep, mut led| {
            if ep.rank == 0 {
                // wrong leading dim on rank 0 -> local error, rank 1 must not hang
                let bad = Tensor::zeros(&[3, 1]);
                let e = ep.reduce_scatter(bad, &mut led);
                assert!(e.is_err());
                // recover by sending the right shape
                let good = Tensor::zeros(&[2, 1]);
                ep.reduce_scatter(good, &mut led).map(|_| ())
            } else {
                let good = Tensor::zeros(&[2, 1]);
                ep.reduce_scatter(good, &mut led).map(|_| ())
            }
        });
        assert!(out.into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn stats_merge_across_endpoints() {
        let mut total = CommStats::default();
        let a = CommStats { all_gathers: 2, floats_moved: 100, comm_s: 0.5, ..Default::default() };
        let b = CommStats { reduce_scatters: 3, floats_moved: 50, comm_s: 0.25, ..Default::default() };
        total.accumulate(&a);
        total.accumulate(&b);
        assert_eq!(total.all_gathers, 2);
        assert_eq!(total.reduce_scatters, 3);
        assert_eq!(total.collectives(), 5);
        assert_eq!(total.floats_moved, 150);
        assert!((total.comm_s - 0.75).abs() < 1e-15);
    }

    /// A one-off injector for hook tests: fire `action` on `(rank, seq)`.
    struct OneShot {
        rank: usize,
        seq: u64,
        action: FaultAction,
    }

    impl FaultInjector for OneShot {
        fn on_collective(&mut self, rank: usize, seq: u64, _op: &'static str) -> FaultAction {
            if rank == self.rank && seq == self.seq {
                self.action.clone()
            } else {
                FaultAction::Proceed
            }
        }
    }

    #[test]
    fn run_ranks_propagates_panic_as_structured_error() {
        let err = Fabric::run_ranks(
            3,
            NetworkProfile::frontier(),
            Duration::from_millis(200),
            |ep, _led| {
                if ep.rank == 1 {
                    panic!("boom from rank {}", ep.rank);
                }
                ep.rank
            },
        )
        .expect_err("rank 1 panicked");
        assert_eq!(err.rank, 1);
        assert!(err.payload.contains("boom from rank 1"), "{}", err.payload);
        assert_eq!(err.all.len(), 1);
        let msg = err.to_string();
        assert!(msg.contains("rank 1 panicked"), "{msg}");
    }

    #[test]
    fn injected_delay_stalls_straggler_and_peers_absorb_it() {
        let delay = 3.0f64;
        let out = run_ranks(2, move |mut ep, mut led| {
            if ep.rank == 1 {
                ep.arm_faults(Box::new(OneShot {
                    rank: 1,
                    seq: 0,
                    action: FaultAction::Delay { seconds: delay },
                }));
            }
            ep.all_reduce(Tensor::filled(&[4], 1.0), &mut led).unwrap();
            led
        });
        let wire = NetworkProfile::frontier().time(Collective::AllReduce, 4, 2);
        for led in &out {
            // Both clocks end at the injected stall + wire time.
            assert!((led.now_s - (delay + wire)).abs() < 1e-12, "{}", led.now_s);
        }
        // Rank 0 waited the stall out at the rendezvous; rank 1 idled
        // through its own injected stall. Either way the stall is Idle.
        assert!((out[0].idle_s() - delay).abs() < 1e-12);
        assert!((out[1].idle_s() - delay).abs() < 1e-12);
    }

    #[test]
    fn injected_crash_poisons_peers_and_surfaces_rank_id() {
        let err = Fabric::run_ranks(
            2,
            NetworkProfile::frontier(),
            Duration::from_secs(60),
            |mut ep, mut led| {
                if ep.rank == 0 {
                    let f = OneShot { rank: 0, seq: 1, action: FaultAction::Crash };
                    ep.arm_faults(Box::new(f));
                }
                ep.all_reduce(Tensor::filled(&[2], 1.0), &mut led).unwrap();
                // Second collective: rank 0 crashes; rank 1 must error
                // promptly via the poison signal, not the 60 s timeout.
                let t0 = std::time::Instant::now();
                let r = ep.all_reduce(Tensor::filled(&[2], 1.0), &mut led);
                if ep.rank == 1 {
                    assert!(r.is_err(), "peer of a crashed rank must error");
                    assert!(t0.elapsed() < Duration::from_secs(10), "woke by poison");
                }
            },
        )
        .expect_err("rank 0 crashed");
        assert_eq!(err.rank, 0);
        assert!(err.payload.contains("injected fault"), "{}", err.payload);
        assert!(err.payload.contains("collective #1"), "{}", err.payload);
    }

    #[test]
    fn injected_drop_errors_the_dropping_rank() {
        // The peer-side timeout path is covered with a short timeout in
        // tests/chaos_integration.rs; here only the dropping rank runs the
        // collective so the production 60 s fabric never has to wait.
        let out = run_ranks(2, |mut ep, mut led| {
            if ep.rank == 1 {
                ep.arm_faults(Box::new(OneShot { rank: 1, seq: 0, action: FaultAction::Drop }));
                let e = ep.all_reduce(Tensor::filled(&[2], 1.0), &mut led).unwrap_err();
                assert!(e.to_string().contains("dropped"), "{e}");
            }
            ep.rank
        });
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn fault_seq_counts_rendezvous_collectives_only() {
        let out = run_ranks(2, |mut ep, mut led| {
            ep.all_gather(Tensor::zeros(&[2]), &mut led).unwrap();
            ep.all_reduce_scalar(1.0, &mut led).unwrap();
            ep.charge_modeled(Collective::Broadcast, 8, &mut led);
            ep.barrier(&mut led).unwrap();
            ep.collective_seq()
        });
        for seq in out {
            // all_gather + (scalar -> all_reduce) + barrier = 3 ticks;
            // charge_modeled is not a rendezvous and must not tick.
            assert_eq!(seq, 3);
        }
    }

    #[test]
    fn stats_accumulate() {
        let out = run_ranks(2, |mut ep, mut led| {
            ep.all_gather(Tensor::zeros(&[8]), &mut led).unwrap();
            ep.reduce_scatter(Tensor::zeros(&[2, 8]), &mut led).unwrap();
            ep.barrier(&mut led).unwrap();
            ep.stats
        });
        for s in out {
            assert_eq!(s.all_gathers, 1);
            assert_eq!(s.reduce_scatters, 1);
            assert_eq!(s.barriers, 1);
            assert_eq!(s.floats_moved, 8 + 8);
            assert!(s.comm_s > 0.0);
        }
    }

    #[test]
    fn group_layout_maps_world_ranks() {
        let l = GroupLayout { p_model: 3, dp: 2 };
        assert_eq!(l.world(), 6);
        for w in 0..l.world() {
            assert_eq!(l.world_rank(l.dp_rank(w), l.model_rank(w)), w);
        }
        assert_eq!(l.model_rank(4), 1);
        assert_eq!(l.dp_rank(4), 1);
    }

    #[test]
    fn grouped_fabric_scopes_collectives_seqs_and_buckets() {
        let layout = GroupLayout { p_model: 2, dp: 2 };
        let eps = Fabric::new_grouped(layout, NetworkProfile::frontier(), RENDEZVOUS_TIMEOUT);
        assert_eq!(eps.len(), 4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut hep| {
                thread::spawn(move || {
                    let mut led = EnergyLedger::new();
                    let w = hep.world;
                    assert_eq!(hep.model.world_rank, w);
                    assert_eq!(hep.dp.world_rank, w);
                    // Model-group collective: stacks this replica's members.
                    let g = hep.model.all_gather(Tensor::filled(&[1], w as f32), &mut led);
                    let g = g.unwrap();
                    // DP-group collective: sums across the replicas that
                    // share this model rank.
                    let s = hep.dp.dp_all_reduce(Tensor::filled(&[1], w as f32), &mut led);
                    let s = s.unwrap();
                    (w, g, s, led, hep.model.collective_seq(), hep.dp.collective_seq())
                })
            })
            .collect();
        for h in handles {
            let (w, g, s, led, mseq, dseq) = h.join().unwrap();
            let layout = GroupLayout { p_model: 2, dp: 2 };
            let (d, r) = (layout.dp_rank(w), layout.model_rank(w));
            // Model group of replica d holds world ranks {2d, 2d+1}.
            assert_eq!(g.data(), &[(2 * d) as f32, (2 * d + 1) as f32], "world {w}");
            // DP group of model rank r holds {r, r+2}: value sum = 2r + 2.
            assert_eq!(s.data(), &[(2 * r + 2) as f32], "world {w}");
            // Per-group collective sequence numbers tick independently.
            assert_eq!((mseq, dseq), (1, 1));
            // Model wire time lands in Communicate, DP in its own bucket.
            assert!(led.comm_s() > 0.0);
            assert!(led.dp_comm_s() > 0.0);
        }
    }

    #[test]
    fn dp_all_reduce_is_a_distinct_op_for_spmd_checks() {
        let out = run_ranks(2, |mut ep, mut led| {
            let t = Tensor::filled(&[1], 1.0);
            if ep.rank == 0 {
                ep.all_reduce(t, &mut led).map(|_| ())
            } else {
                ep.dp_all_reduce(t, &mut led).map(|_| ())
            }
        });
        assert!(
            out.iter().any(|r| r.is_err()),
            "mixing all_reduce with dp_all_reduce must poison the round"
        );
    }

    #[test]
    fn grouped_fault_hooks_see_world_ranks() {
        let delay = 2.0f64;
        let layout = GroupLayout { p_model: 2, dp: 2 };
        let eps = Fabric::new_grouped(layout, NetworkProfile::frontier(), RENDEZVOUS_TIMEOUT);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut hep| {
                thread::spawn(move || {
                    let mut led = EnergyLedger::new();
                    if hep.world == 3 {
                        // Fires only if the hook reports the WORLD rank (3),
                        // not the group-local rank (1).
                        hep.model.arm_faults(Box::new(OneShot {
                            rank: 3,
                            seq: 0,
                            action: FaultAction::Delay { seconds: delay },
                        }));
                    }
                    hep.model.all_gather(Tensor::filled(&[1], 1.0), &mut led).unwrap();
                    (hep.world, led)
                })
            })
            .collect();
        let wire = NetworkProfile::frontier().time(Collective::AllGather, 1, 2);
        for h in handles {
            let (w, led) = h.join().unwrap();
            let want = if w >= 2 { delay + wire } else { wire };
            assert!(
                (led.now_s - want).abs() < 1e-12,
                "world {w}: clock {} != {want}",
                led.now_s
            );
        }
    }
}
