//! Leader driver: spawns rank workers, aggregates losses out-of-band,
//! decides the stopping point (fixed-loss or iteration cap), and assembles
//! the training report (loss curve, per-rank energy/time ledgers, comm
//! statistics).
//!
//! Checkpointing (DESIGN.md §8) rides the same control plane: the
//! per-iteration continue message can additionally request a snapshot, at
//! which point every rank clones its parameters + optimizer state onto a
//! shard channel and keeps computing while the leader assembles and
//! atomically writes the `ckpt::Snapshot`. Resume replays the saved loss
//! history through the `LossTracker` and hands every rank its saved shard,
//! so the continued run is bit-identical to the uninterrupted one.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use super::rank_pp::PhantomRank;
use super::rank_tp::TensorRank;
use super::LossReport;
use crate::ckpt::{self, RankParams, RankShard, Snapshot, TrainProgress};
use crate::comm::{join_rank_threads, CommStats, Endpoint, Fabric, GroupLayout, InjectorFactory};
use crate::config::{CkptPolicy, ComputeModel, Parallelism, RunConfig};
use crate::data::{BatchCache, Teacher};
use crate::energy::LedgerSummary;
use crate::model::{pp_model_params, tp_model_params, PhantomRankParams, TpRankParams};
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::train::LossTracker;
use crate::util::prng::Prng;

/// Per-rank outcome.
#[derive(Debug, Clone)]
pub struct RankReport {
    /// World rank (= dp_rank * p + model_rank; the model rank for dp = 1).
    pub rank: usize,
    pub ledger: LedgerSummary,
    /// Model-parallel group traffic.
    pub stats: CommStats,
    /// Data-parallel group traffic (the DP gradient All-Reduce); all-zero
    /// for dp = 1 runs, which never enter the DP fabric.
    pub dp_stats: CommStats,
    /// Virtual time at which warmup ended (energy accounting boundary).
    pub warm_t: f64,
    /// Energy over the post-warmup training phase only.
    pub energy_train_j: f64,
    /// Floats of optimizer state held on this rank at the end of the run
    /// (ZeRO-1 sharding drops this to ~1/dp of the flat baseline).
    pub opt_state_floats: usize,
    /// Span timeline + interval snapshot when the run was traced
    /// (`TrainOptions::trace`); `None` otherwise.
    pub trace: Option<crate::obs::TraceCapture>,
}

/// Aggregated training report (one row of the paper's Table I, plus curves).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub mode: Parallelism,
    pub p: usize,
    /// Data-parallel replica count (1 = pure model parallelism). The run
    /// spanned `p * dp` ranks; `per_rank` lists them in world-rank order.
    pub dp: usize,
    pub n: usize,
    pub k: usize,
    pub layers: usize,
    pub batch: usize,
    /// Global loss per iteration (mean squared error over B*n).
    pub losses: Vec<f64>,
    pub iterations: usize,
    pub reached_target: bool,
    pub per_rank: Vec<RankReport>,
    /// Total model parameters across all ranks.
    pub model_params: u64,
    /// Cluster totals (all ranks, full run).
    pub energy_total_j: f64,
    /// Cluster energy excluding the warmup iterations (the paper's
    /// training-phase accounting).
    pub energy_train_j: f64,
    /// Virtual wall time (max rank clock).
    pub wall_s: f64,
    /// Virtual wall time excluding warmup.
    pub wall_train_s: f64,
    /// Leader-side (host) event timeline when the run was traced:
    /// checkpoint writes, stamped in REAL wall seconds since the run
    /// started (the leader has no virtual clock).
    pub host_trace: Option<crate::obs::SpanRecorder>,
}

impl TrainReport {
    /// Energy per post-warmup iteration in Joules (Table I column).
    pub fn energy_per_iter_j(&self) -> f64 {
        let iters = self.iterations.saturating_sub(warmup_of(&self.per_rank)) as f64;
        if iters > 0.0 {
            self.energy_train_j / iters
        } else {
            0.0
        }
    }
}

fn warmup_of(per_rank: &[RankReport]) -> usize {
    // warm_t > 0 means at least one warmup iteration was excluded; the
    // driver stores the count in the report directly, so this is only a
    // guard for empty runs.
    usize::from(per_rank.iter().any(|r| r.warm_t > 0.0))
}

/// Durability/elasticity options for a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainOptions {
    /// Periodic snapshots: every `ckpt.every` iterations into
    /// `ckpt.dir/ckpt-NNNNNN` (plus a final snapshot at the stopping
    /// point).
    pub ckpt: Option<CkptPolicy>,
    /// Continue a previous run from its snapshot. The snapshot's config
    /// must match `cfg` on everything that shapes the math (mode, p,
    /// model, batch, seed, optimizer, dataset); iteration caps and loss
    /// targets may differ.
    pub resume: Option<Snapshot>,
    /// Deterministic fault injection (testkit, DESIGN.md §9): each rank's
    /// fabric endpoint is armed with `faults.for_rank(rank)` before it
    /// starts training. `None` = fault-free.
    pub faults: Option<InjectorFactory>,
    /// Override the fabric rendezvous timeout. Chaos tests that inject
    /// message drops shrink this to milliseconds so the peers' timeout
    /// errors surface promptly; `None` keeps the production 60 s default.
    pub rendezvous_timeout: Option<std::time::Duration>,
    /// Arm every rank's span recorder (obs): each `RankReport` then
    /// carries a `TraceCapture` and the report a leader-side `host_trace`.
    pub trace: bool,
}

/// The per-iteration control message the leader sends every rank.
#[derive(Debug, Clone, Copy)]
struct RankCommand {
    /// Clone and ship this rank's shard onto the snapshot channel.
    snapshot: bool,
    /// Keep training (false = clean stop).
    go: bool,
}

/// Train one configuration end-to-end on the simulated cluster.
///
/// `server` must serve an artifact bundle matching (p, n, k, batch) of
/// `cfg` (see `RunConfig::artifact` / manifest lookup).
pub fn train(cfg: &RunConfig, server: &ExecServer) -> Result<TrainReport> {
    train_with(cfg, server, TrainOptions::default())
}

/// `train` with checkpoint/resume options.
pub fn train_with(cfg: &RunConfig, server: &ExecServer, opts: TrainOptions) -> Result<TrainReport> {
    cfg.validate()?;
    if !matches!(cfg.hardware.compute, ComputeModel::Measured) {
        bail!("coordinator::train runs measured mode; use perfmodel for analytic predictions");
    }
    let artifact = cfg
        .artifact
        .clone()
        .ok_or_else(|| anyhow!("measured run needs an artifact config name"))?;
    let mcfg = server.manifest.config(&artifact)?.clone();
    if mcfg.p != cfg.p || mcfg.n != cfg.model.n || mcfg.batch != cfg.train.batch {
        bail!(
            "artifact '{}' geometry (p={}, n={}, batch={}) does not match run \
             (p={}, n={}, batch={})",
            artifact,
            mcfg.p,
            mcfg.n,
            mcfg.batch,
            cfg.p,
            cfg.model.n,
            cfg.train.batch
        );
    }
    if cfg.mode == Parallelism::Phantom && mcfg.k != cfg.model.k {
        bail!("artifact '{}' k={} does not match run k={}", artifact, mcfg.k, cfg.model.k);
    }
    if let Some(policy) = &opts.ckpt {
        policy.validate()?;
    }

    let p = cfg.p;
    // Hybrid DP×(TP|PP): the cluster is p model ranks × dp replicas. Every
    // control-plane structure below is world-rank sized; dp = 1 collapses
    // to exactly the pre-hybrid single-group layout.
    let dp = cfg.dp;
    let world = p * dp;
    let scale = 1.0 / (cfg.train.batch as f64 * cfg.model.n as f64);

    // Resume: replay the saved loss history through a fresh tracker so the
    // stopping rule (EMA, target, cap) continues exactly, restore the
    // run-level PRNG, and stage each rank's saved shard.
    let mut tracker = LossTracker::new(cfg.train.target_loss, cfg.train.max_iters);
    let mut run_rng = ckpt::run_stream(cfg.train.seed);
    let start_iter: u64;
    let mut resume_shards: Vec<Option<RankShard>> = (0..world).map(|_| None).collect();
    if let Some(snap) = opts.resume {
        check_resume_compat(cfg, &snap)?;
        start_iter = snap.progress.iter;
        let mut replay_stop = false;
        for l in &snap.progress.losses {
            replay_stop = tracker.record(*l);
        }
        run_rng = Prng::from_state(snap.progress.prng);
        if replay_stop {
            // Nothing left to train: the snapshot already satisfies the
            // stopping rule. Report it without spawning ranks.
            return Ok(finished_report(cfg, &tracker));
        }
        for shard in snap.shards {
            let rank = shard.rank;
            resume_shards[rank] = Some(shard);
        }
    } else {
        start_iter = 0;
    }

    // dp = 1 runs the plain single-group fabric (byte-identical to the
    // pre-hybrid path); dp > 1 builds the grouped communicators. Either
    // way each world rank gets (model endpoint, optional DP endpoint).
    let timeout = opts.rendezvous_timeout.unwrap_or(crate::comm::RENDEZVOUS_TIMEOUT);
    let endpoints: Vec<(Endpoint, Option<Endpoint>)> = if dp == 1 {
        Fabric::with_timeout(p, cfg.hardware.net, timeout)
            .into_iter()
            .map(|ep| (ep, None))
            .collect()
    } else {
        Fabric::new_grouped(GroupLayout { p_model: p, dp }, cfg.hardware.net, timeout)
            .into_iter()
            .map(|hep| (hep.model, Some(hep.dp)))
            .collect()
    };
    let teacher = Teacher::new(cfg.model.n, cfg.train.seed);
    let cache = Arc::new(BatchCache::new(
        teacher,
        cfg.train.batch,
        p,
        dp,
        cfg.train.dataset_batches,
    ));

    // Control plane: rank -> leader loss reports; leader -> rank commands;
    // rank -> leader parameter shards when a snapshot is requested.
    let (loss_tx, loss_rx) = mpsc::channel::<LossReport>();
    let (shard_tx, shard_rx) = mpsc::channel::<RankShard>();
    let mut cont_txs: Vec<mpsc::Sender<RankCommand>> = Vec::with_capacity(world);

    let mut handles = Vec::with_capacity(world);
    for ((rank, (mut ep, dp_ep)), resume_shard) in
        endpoints.into_iter().enumerate().zip(resume_shards)
    {
        // Fault schedules key on world ranks and arm the model-group
        // endpoint — the one that runs the per-layer collective schedule
        // the plans' sequence arithmetic describes. The DP group stays
        // fault-free (its endpoints still poison with their group if a
        // member dies mid-all-reduce).
        if let Some(factory) = &opts.faults {
            if let Some(injector) = factory.for_rank(rank) {
                ep.arm_faults(injector);
            }
        }
        let (ct, cr) = mpsc::channel::<RankCommand>();
        cont_txs.push(ct);
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let cache = cache.clone();
        let loss_tx = loss_tx.clone();
        let shard_tx = shard_tx.clone();
        let warmup = cfg.train.warmup_iters;
        let trace = opts.trace;
        handles.push(
            thread::Builder::new()
                .name(format!("rank-{rank}"))
                .spawn(move || -> Result<RankReport> {
                    run_rank(RankCtx {
                        rank,
                        cfg: &cfg,
                        artifact,
                        exec,
                        ep,
                        dp_ep,
                        cache,
                        loss_tx,
                        cont_rx: cr,
                        shard_tx,
                        warmup,
                        start_iter,
                        resume_shard,
                        trace,
                    })
                })
                .context("spawning rank thread")?,
        );
    }
    drop(loss_tx);
    drop(shard_tx);

    // Leader loop: aggregate per-iteration losses, decide stopping, and
    // collect + write snapshots at checkpoint boundaries.
    //
    // Traced runs also keep a host timeline for leader-side work (the
    // checkpoint writes); it is stamped in REAL wall seconds since this
    // point — the leader does not participate in the virtual clock.
    let host_t0 = std::time::Instant::now();
    let mut host_rec = opts.trace.then(|| crate::obs::SpanRecorder::new(world));
    let mut pending: std::collections::HashMap<u64, Vec<(usize, f64)>> = Default::default();
    let mut next_iter: u64 = start_iter;
    let mut leader_err: Option<anyhow::Error> = None;
    let mut ckpt_err: Option<anyhow::Error> = None;
    'leader: loop {
        let report = match loss_rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all ranks done or died
        };
        pending.entry(report.iter).or_default().push((report.rank, report.loss_local));
        while pending.get(&next_iter).map(|v| v.len()) == Some(world) {
            let mut parts = pending.remove(&next_iter).expect("presence checked");
            // Sum in world-rank order, not arrival order: f64 addition is
            // not associative, and both run-to-run determinism and the
            // bit-identical resume guarantee need one canonical order.
            parts.sort_by_key(|&(rank, _)| rank);
            let global = parts.iter().map(|&(_, loss)| loss).sum::<f64>() * scale;
            let stop = tracker.record(global);
            run_rng.next_u64(); // run-level stream: one draw per iteration
            let completed = next_iter + 1;
            let snapshot = match &opts.ckpt {
                Some(policy) => stop || completed % policy.every as u64 == 0,
                None => false,
            };
            for ct in &cont_txs {
                // A rank that already exited with an error has dropped its
                // receiver; surface that instead of spinning forever.
                if ct.send(RankCommand { snapshot, go: !stop }).is_err() {
                    leader_err = Some(anyhow!("a rank died mid-iteration"));
                    break 'leader;
                }
            }
            next_iter = completed;
            if snapshot {
                let policy = opts.ckpt.as_ref().expect("snapshot implies a policy");
                if let Some(rec) = host_rec.as_mut() {
                    let name = format!("ckpt-{completed:06}");
                    rec.begin("ckpt", &name, host_t0.elapsed().as_secs_f64());
                }
                let res =
                    write_snapshot(cfg, policy, completed, &tracker, &run_rng, &shard_rx, world);
                if let Some(rec) = host_rec.as_mut() {
                    let args = vec![("iter", crate::obs::Arg::I(completed as i64))];
                    rec.end_args(host_t0.elapsed().as_secs_f64(), args);
                }
                if let Err(e) = res {
                    ckpt_err = Some(e);
                    break 'leader;
                }
            }
            if stop {
                break 'leader;
            }
        }
    }
    drop(cont_txs);

    // Structured crash surfacing (rank id + panic payload via RankPanic):
    // chaos tests assert on who died and why, not a bare "thread panicked".
    let (joined, panic) = join_rank_threads(handles);
    let mut per_rank = Vec::with_capacity(world);
    let mut rank_err: Option<anyhow::Error> = None;
    for (rank, res) in joined {
        match res {
            Ok(r) => per_rank.push(r),
            Err(e) => {
                if rank_err.is_none() {
                    rank_err = Some(e.context(format!("rank {rank} failed")));
                }
            }
        }
    }
    // A crash is the root cause of its peers' poisoned-fabric errors, so a
    // panic outranks an ordinary rank error regardless of join order.
    let rank_err = panic.map(anyhow::Error::new).or(rank_err);
    // A checkpoint-write failure is the root cause (ranks then only died of
    // the leader's disappearance), so it wins; otherwise the first rank
    // error carries the diagnosis, with the leader's observation last.
    if let Some(e) = ckpt_err {
        return Err(e);
    }
    if let Some(e) = rank_err {
        return Err(e);
    }
    if let Some(e) = leader_err {
        return Err(e);
    }
    per_rank.sort_by_key(|r| r.rank);

    let mut totals = LedgerSummary::default();
    let mut energy_total = 0.0;
    let mut energy_train = 0.0;
    let mut warm_t_max: f64 = 0.0;
    for r in &per_rank {
        totals.accumulate(&r.ledger);
        energy_train += r.energy_train_j;
        warm_t_max = warm_t_max.max(r.warm_t);
    }
    energy_total += totals.energy_j(&cfg.hardware.power);

    Ok(TrainReport {
        mode: cfg.mode,
        p,
        dp,
        n: cfg.model.n,
        k: cfg.model.k,
        layers: cfg.model.layers,
        batch: cfg.train.batch,
        iterations: tracker.history.len(),
        losses: tracker.history.clone(),
        reached_target: tracker.reached_target(),
        model_params: model_params_of(cfg),
        energy_total_j: energy_total,
        energy_train_j: energy_train,
        wall_s: totals.end_s,
        wall_train_s: (totals.end_s - warm_t_max).max(0.0),
        per_rank,
        host_trace: host_rec,
    })
}

/// Logical model size (one DP replica's parameters; replicas are copies,
/// not extra model capacity).
fn model_params_of(cfg: &RunConfig) -> u64 {
    match cfg.mode {
        Parallelism::Tensor => tp_model_params(cfg.model.n, cfg.model.layers),
        Parallelism::Phantom => pp_model_params(cfg.model.n, cfg.model.layers, cfg.p, cfg.model.k),
    }
}

/// Report for a resumed run whose snapshot already satisfies the stopping
/// rule: the full loss history, no new rank activity.
fn finished_report(cfg: &RunConfig, tracker: &LossTracker) -> TrainReport {
    TrainReport {
        mode: cfg.mode,
        p: cfg.p,
        dp: cfg.dp,
        n: cfg.model.n,
        k: cfg.model.k,
        layers: cfg.model.layers,
        batch: cfg.train.batch,
        iterations: tracker.history.len(),
        losses: tracker.history.clone(),
        reached_target: tracker.reached_target(),
        model_params: model_params_of(cfg),
        energy_total_j: 0.0,
        energy_train_j: 0.0,
        wall_s: 0.0,
        wall_train_s: 0.0,
        per_rank: Vec::new(),
        host_trace: None,
    }
}

/// Everything that shapes the training math must match for a bit-identical
/// continuation; caps/targets and hardware accounting may differ.
fn check_resume_compat(cfg: &RunConfig, snap: &Snapshot) -> Result<()> {
    snap.validate()?;
    let sc = &snap.config;
    if sc.mode != cfg.mode || sc.p != cfg.p || sc.dp != cfg.dp {
        bail!(
            "resume layout ({}, p={}, dp={}) does not match run ({}, p={}, dp={})",
            sc.mode.name(),
            sc.p,
            sc.dp,
            cfg.mode.name(),
            cfg.p,
            cfg.dp
        );
    }
    if sc.model != cfg.model {
        bail!("resume model {:?} does not match run {:?}", sc.model, cfg.model);
    }
    if sc.train.batch != cfg.train.batch
        || sc.train.seed != cfg.train.seed
        || sc.train.dataset_batches != cfg.train.dataset_batches
    {
        bail!(
            "resume data stream (batch={}, seed={}, dataset_batches={}) does not match run \
             (batch={}, seed={}, dataset_batches={})",
            sc.train.batch,
            sc.train.seed,
            sc.train.dataset_batches,
            cfg.train.batch,
            cfg.train.seed,
            cfg.train.dataset_batches
        );
    }
    if sc.train.optimizer != cfg.train.optimizer {
        bail!(
            "resume optimizer {:?} does not match run {:?}",
            sc.train.optimizer,
            cfg.train.optimizer
        );
    }
    // The schedule shapes the math (micro-batch row chunking changes the
    // f32 summation order) and sharding shapes the optimizer-state layout
    // each shard persists, so a bit-identical continuation needs all
    // three to match.
    if sc.train.micro != cfg.train.micro
        || sc.train.schedule != cfg.train.schedule
        || sc.train.sharded_state != cfg.train.sharded_state
    {
        bail!(
            "resume schedule (micro={}, schedule={}, sharded_state={}) does not match run \
             (micro={}, schedule={}, sharded_state={})",
            sc.train.micro,
            sc.train.schedule.name(),
            sc.train.sharded_state,
            cfg.train.micro,
            cfg.train.schedule.name(),
            cfg.train.sharded_state
        );
    }
    Ok(())
}

/// Collect one shard per world rank off the snapshot channel and write the
/// snapshot atomically as `dir/ckpt-NNNNNN`.
fn write_snapshot(
    cfg: &RunConfig,
    policy: &CkptPolicy,
    completed: u64,
    tracker: &LossTracker,
    run_rng: &Prng,
    shard_rx: &mpsc::Receiver<RankShard>,
    world: usize,
) -> Result<()> {
    let mut shards: Vec<Option<RankShard>> = (0..world).map(|_| None).collect();
    for _ in 0..world {
        let shard = shard_rx
            .recv()
            .map_err(|_| anyhow!("a rank died before shipping its snapshot shard"))?;
        let rank = shard.rank;
        shards[rank] = Some(shard);
    }
    let snap = Snapshot {
        config: cfg.clone(),
        progress: TrainProgress {
            iter: completed,
            losses: tracker.history.clone(),
            prng: run_rng.state(),
        },
        shards: shards.into_iter().map(|s| s.expect("every rank shipped")).collect(),
    };
    let dir = policy.dir.join(format!("ckpt-{completed:06}"));
    snap.save(&dir)
        .with_context(|| format!("writing checkpoint at iteration {completed}"))
}

/// Arguments of one rank worker thread.
struct RankCtx<'a> {
    /// World rank (= dp_rank * p + model_rank).
    rank: usize,
    cfg: &'a RunConfig,
    artifact: String,
    exec: crate::runtime::ExecHandle,
    ep: crate::comm::Endpoint,
    /// Data-parallel group endpoint; `None` for dp = 1 runs.
    dp_ep: Option<crate::comm::Endpoint>,
    cache: Arc<BatchCache>,
    loss_tx: mpsc::Sender<LossReport>,
    cont_rx: mpsc::Receiver<RankCommand>,
    shard_tx: mpsc::Sender<RankShard>,
    warmup: usize,
    start_iter: u64,
    resume_shard: Option<RankShard>,
    /// Arm this rank's ledger span recorder.
    trace: bool,
}

/// Wakes the rank's DP-group peers if the rank exits abnormally. The
/// fault path poisons the MODEL group directly (fault_gate), but a dying
/// rank's DP group would otherwise sit in `dp_all_reduce` for the full
/// wall-clock rendezvous timeout; this guard poisons it on panic or
/// error-return, and is disarmed on normal completion.
///
/// Deliberately scoped to the DP group only: an organic (non-injected)
/// failure leaving MODEL peers to the rendezvous timeout is the
/// established pre-hybrid contract — drop faults surface as "dropped"/
/// "timeout" errors (DESIGN.md §9, chaos suite) — and poisoning the
/// model group here would mask that root cause behind lower-numbered
/// peers' "fabric poisoned" errors.
struct DpPoisonGuard {
    poisoner: Option<crate::comm::FabricPoisoner>,
}

impl Drop for DpPoisonGuard {
    fn drop(&mut self) {
        if let Some(p) = &self.poisoner {
            p.poison();
        }
    }
}

fn run_rank(ctx: RankCtx<'_>) -> Result<RankReport> {
    enum Worker {
        Pp(PhantomRank),
        Tp(TensorRank),
    }
    let RankCtx {
        rank,
        cfg,
        artifact,
        exec,
        ep,
        dp_ep,
        cache,
        loss_tx,
        cont_rx,
        shard_tx,
        warmup,
        start_iter,
        resume_shard,
        trace,
    } = ctx;
    crate::obs::log::set_rank(rank);
    // The worker's shard geometry is keyed on the model rank: DP replicas
    // of one model rank initialize (and, gradients being summed, stay)
    // weight-identical.
    let model_rank = rank % cfg.p;
    let mut dp_guard = DpPoisonGuard { poisoner: dp_ep.as_ref().map(|e| e.poisoner()) };
    let (resume_params, resume_opt) = match resume_shard {
        Some(shard) => (Some(shard.params), shard.opt),
        None => (None, None),
    };
    // ZeRO-1: with sharded_state on a dp > 1 grid, each replica's
    // optimizer is laid out for its owned flat parameter slice
    // (ceil(total/dp) floats) instead of the full parameter list.
    let sharded = cfg.train.sharded_state && cfg.dp > 1;
    let mut worker = match cfg.mode {
        Parallelism::Phantom => {
            let params = match resume_params {
                Some(RankParams::Phantom(p)) => p,
                Some(RankParams::Tensor(_)) => bail!("resume shard is TP but the run is PP"),
                None => PhantomRankParams::init(&cfg.model, cfg.p, model_rank, cfg.train.seed)?,
            };
            let sharded_slot = sharded.then(|| {
                let total: usize = super::rank_pp::param_shapes(&params)
                    .iter()
                    .map(|s| s.iter().product::<usize>())
                    .sum();
                super::zero::slot_len(total, cfg.dp)
            });
            let mut w = PhantomRank::with_state(
                params,
                artifact,
                cfg.train.optimizer,
                resume_opt,
                exec,
                ep,
                sharded_slot,
            )?;
            w.set_schedule(
                cfg.train.micro,
                cfg.train.schedule == crate::config::Schedule::OneFOneB,
            );
            Worker::Pp(w)
        }
        Parallelism::Tensor => {
            let params = match resume_params {
                Some(RankParams::Tensor(t)) => t,
                Some(RankParams::Phantom(_)) => bail!("resume shard is PP but the run is TP"),
                None => TpRankParams::init(&cfg.model, cfg.p, model_rank, cfg.train.seed)?,
            };
            let sharded_slot = sharded.then(|| {
                let total: usize = params
                    .weights
                    .iter()
                    .chain(params.biases.iter())
                    .map(|t| t.numel())
                    .sum();
                super::zero::slot_len(total, cfg.dp)
            });
            Worker::Tp(TensorRank::with_state(
                params,
                artifact,
                cfg.train.optimizer,
                resume_opt,
                exec,
                ep,
                sharded_slot,
            )?)
        }
    };
    if let Some(dp) = dp_ep {
        match &mut worker {
            Worker::Pp(w) => w.arm_dp(dp),
            Worker::Tp(w) => w.arm_dp(dp),
        }
    }
    if trace {
        match &mut worker {
            Worker::Pp(w) => w.ledger.arm_tracing(rank),
            Worker::Tp(w) => w.ledger.arm_tracing(rank),
        }
    }

    let mut warm_t = 0.0;
    let mut iter: u64 = start_iter;
    loop {
        let (x, t) = cache.shard(iter, rank)?;
        let loss_local = match &mut worker {
            Worker::Pp(w) => w.iteration(&x, &t)?,
            Worker::Tp(w) => w.iteration(&x, &t)?,
        };
        if (iter + 1) as usize == warmup {
            warm_t = match &worker {
                Worker::Pp(w) => w.ledger.now_s,
                Worker::Tp(w) => w.ledger.now_s,
            };
        }
        loss_tx
            .send(LossReport { rank, iter, loss_local })
            .map_err(|_| anyhow!("leader is gone"))?;
        match cont_rx.recv() {
            Ok(cmd) => {
                if cmd.snapshot {
                    // Clone-and-ship is host-side control plane (like the
                    // loss report): not charged to the device ledger. The
                    // rank keeps training while the leader writes.
                    let shard = match &worker {
                        Worker::Pp(w) => RankShard {
                            rank,
                            params: RankParams::Phantom(w.params.clone()),
                            opt: Some(w.opt_state()),
                        },
                        Worker::Tp(w) => RankShard {
                            rank,
                            params: RankParams::Tensor(w.params.clone()),
                            opt: Some(w.opt_state()),
                        },
                    };
                    if shard_tx.send(shard).is_err() {
                        bail!("leader dropped the snapshot channel");
                    }
                }
                if cmd.go {
                    iter += 1;
                } else {
                    break;
                }
            }
            Err(_) => bail!("leader dropped the control channel"),
        }
    }

    // Normal completion: nothing to wake — every DP peer stops too.
    dp_guard.poisoner = None;
    let opt_state_floats = match &worker {
        Worker::Pp(w) => w.opt_state_floats(),
        Worker::Tp(w) => w.opt_state_floats(),
    };
    let (mut ledger, stats, dp_stats) = match worker {
        Worker::Pp(w) => (w.ledger, w.ep.stats, w.dp_ep.map(|e| e.stats).unwrap_or_default()),
        Worker::Tp(w) => (w.ledger, w.ep.stats, w.dp_ep.map(|e| e.stats).unwrap_or_default()),
    };
    let trace = ledger.take_trace();
    let energy_train_j =
        ledger.energy_j_between(&cfg.hardware.power, warm_t, ledger.now_s);
    Ok(RankReport {
        rank,
        ledger: ledger.summary(),
        stats,
        dp_stats,
        warm_t,
        energy_train_j,
        opt_state_floats,
        trace,
    })
}

/// Inference report: forward-only serving statistics (the "inferencing"
/// half of the paper's title — PP's forward path saves the same
/// communication per query as per training iteration).
#[derive(Debug, Clone)]
pub struct InferReport {
    pub mode: Parallelism,
    pub batches: usize,
    /// Virtual latency per batch, seconds (post-warmup).
    pub latencies_s: Vec<f64>,
    /// Cluster energy over the serving phase (post-warmup), Joules.
    pub energy_j: f64,
    /// Samples served per virtual second (post-warmup).
    pub throughput: f64,
}

/// Serve `batches` forward-only batches and report latency/energy.
pub fn infer(cfg: &RunConfig, server: &ExecServer, batches: usize) -> Result<InferReport> {
    cfg.validate()?;
    let artifact = cfg.artifact.clone().ok_or_else(|| anyhow!("needs artifact"))?;
    let p = cfg.p;
    let endpoints = Fabric::new(p, cfg.hardware.net);
    let teacher = Teacher::new(cfg.model.n, cfg.train.seed);
    // Forward-only serving is model-parallel: DP replicas would only
    // duplicate the stream, so inference always runs one model group.
    let cache = Arc::new(BatchCache::new(
        teacher,
        cfg.train.batch,
        p,
        1,
        cfg.train.dataset_batches,
    ));

    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let cache = cache.clone();
        handles.push(thread::spawn(move || -> Result<(Vec<f64>, crate::energy::EnergyLedger)> {
            let mut ledger = crate::energy::EnergyLedger::new();
            let mut ep = ep;
            let mut marks = vec![0.0f64];
            match cfg.mode {
                Parallelism::Phantom => {
                    let params =
                        PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    for b in 0..batches as u64 {
                        let (x, _) = cache.shard(b, rank)?;
                        super::pp_forward_shard(
                            &exec, &artifact, &params, &mut ep, &mut ledger, x,
                        )?;
                        marks.push(ledger.now_s);
                    }
                }
                Parallelism::Tensor => {
                    let params = TpRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    for b in 0..batches as u64 {
                        let (x, _) = cache.shard(b, rank)?;
                        super::tp_forward_shard(
                            &exec, &artifact, &params, &mut ep, &mut ledger, x, true,
                        )?;
                        marks.push(ledger.now_s);
                    }
                }
            }
            Ok((marks, ledger))
        }));
    }

    let mut all_marks: Vec<Vec<f64>> = Vec::new();
    let mut energy = 0.0;
    let mut warm_t: f64 = 0.0;
    let mut end_t: f64 = 0.0;
    for h in handles {
        let (marks, ledger) = h.join().map_err(|_| anyhow!("rank panicked"))??;
        // warmup = first batch (PJRT compile)
        warm_t = warm_t.max(marks.get(1).copied().unwrap_or(0.0));
        end_t = end_t.max(ledger.now_s);
        energy += ledger.energy_j_between(&cfg.hardware.power, marks[1], ledger.now_s);
        all_marks.push(marks);
    }
    // Virtual latencies are identical across ranks (synchronous collectives);
    // use rank 0's marks, skipping the warmup batch.
    let marks = &all_marks[0];
    let latencies: Vec<f64> = marks.windows(2).skip(1).map(|w| w[1] - w[0]).collect();
    let serving_time = (end_t - warm_t).max(1e-12);
    let throughput = ((batches - 1) * cfg.train.batch) as f64 / serving_time;
    Ok(InferReport {
        mode: cfg.mode,
        batches,
        latencies_s: latencies,
        energy_j: energy,
        throughput,
    })
}

/// Convenience for tests/examples: evaluate the sharded PP forward once
/// (no training) and return the assembled output. Drives the same phase
/// schedule as training.
pub fn pp_forward_once(
    cfg: &RunConfig,
    server: &ExecServer,
    x_full: &Tensor,
) -> Result<Tensor> {
    let artifact = cfg.artifact.clone().ok_or_else(|| anyhow!("needs artifact"))?;
    let p = cfg.p;
    let endpoints = Fabric::new(p, cfg.hardware.net);
    let x_shards = x_full.col_shards(p)?;
    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let x = x_shards[rank].clone();
        handles.push(thread::spawn(move || -> Result<Tensor> {
            let params = PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
            let mut ep = ep;
            let mut ledger = crate::energy::EnergyLedger::new();
            super::pp_forward_shard(&exec, &artifact, &params, &mut ep, &mut ledger, x)
        }));
    }
    let mut shards = Vec::new();
    for h in handles {
        shards.push(h.join().map_err(|_| anyhow!("rank panicked"))??);
    }
    Tensor::from_col_shards(&shards)
}
