//! Leader driver: spawns rank workers, aggregates losses out-of-band,
//! decides the stopping point (fixed-loss or iteration cap), and assembles
//! the training report (loss curve, per-rank energy/time ledgers, comm
//! statistics).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use super::rank_pp::PhantomRank;
use super::rank_tp::TensorRank;
use super::LossReport;
use crate::comm::{CommStats, Fabric};
use crate::config::{ComputeModel, Parallelism, RunConfig};
use crate::data::{BatchCache, Teacher};
use crate::energy::LedgerSummary;
use crate::model::{pp_model_params, tp_model_params, PhantomRankParams, TpRankParams};
use crate::runtime::ExecServer;
use crate::tensor::Tensor;
use crate::train::LossTracker;

/// Per-rank outcome.
#[derive(Debug, Clone)]
pub struct RankReport {
    pub rank: usize,
    pub ledger: LedgerSummary,
    pub stats: CommStats,
    /// Virtual time at which warmup ended (energy accounting boundary).
    pub warm_t: f64,
    /// Energy over the post-warmup training phase only.
    pub energy_train_j: f64,
}

/// Aggregated training report (one row of the paper's Table I, plus curves).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub mode: Parallelism,
    pub p: usize,
    pub n: usize,
    pub k: usize,
    pub layers: usize,
    pub batch: usize,
    /// Global loss per iteration (mean squared error over B*n).
    pub losses: Vec<f64>,
    pub iterations: usize,
    pub reached_target: bool,
    pub per_rank: Vec<RankReport>,
    /// Total model parameters across all ranks.
    pub model_params: u64,
    /// Cluster totals (all ranks, full run).
    pub energy_total_j: f64,
    /// Cluster energy excluding the warmup iterations (the paper's
    /// training-phase accounting).
    pub energy_train_j: f64,
    /// Virtual wall time (max rank clock).
    pub wall_s: f64,
    /// Virtual wall time excluding warmup.
    pub wall_train_s: f64,
}

impl TrainReport {
    /// Energy per post-warmup iteration in Joules (Table I column).
    pub fn energy_per_iter_j(&self) -> f64 {
        let iters = self.iterations.saturating_sub(warmup_of(&self.per_rank)) as f64;
        if iters > 0.0 {
            self.energy_train_j / iters
        } else {
            0.0
        }
    }
}

fn warmup_of(per_rank: &[RankReport]) -> usize {
    // warm_t > 0 means at least one warmup iteration was excluded; the
    // driver stores the count in the report directly, so this is only a
    // guard for empty runs.
    usize::from(per_rank.iter().any(|r| r.warm_t > 0.0))
}

/// Train one configuration end-to-end on the simulated cluster.
///
/// `server` must serve an artifact bundle matching (p, n, k, batch) of
/// `cfg` (see `RunConfig::artifact` / manifest lookup).
pub fn train(cfg: &RunConfig, server: &ExecServer) -> Result<TrainReport> {
    cfg.validate()?;
    if !matches!(cfg.hardware.compute, ComputeModel::Measured) {
        bail!("coordinator::train runs measured mode; use perfmodel for analytic predictions");
    }
    let artifact = cfg
        .artifact
        .clone()
        .ok_or_else(|| anyhow!("measured run needs an artifact config name"))?;
    let mcfg = server.manifest.config(&artifact)?.clone();
    if mcfg.p != cfg.p || mcfg.n != cfg.model.n || mcfg.batch != cfg.train.batch {
        bail!(
            "artifact '{}' geometry (p={}, n={}, batch={}) does not match run \
             (p={}, n={}, batch={})",
            artifact,
            mcfg.p,
            mcfg.n,
            mcfg.batch,
            cfg.p,
            cfg.model.n,
            cfg.train.batch
        );
    }
    if cfg.mode == Parallelism::Phantom && mcfg.k != cfg.model.k {
        bail!("artifact '{}' k={} does not match run k={}", artifact, mcfg.k, cfg.model.k);
    }

    let p = cfg.p;
    let scale = 1.0 / (cfg.train.batch as f64 * cfg.model.n as f64);
    let endpoints = Fabric::new(p, cfg.hardware.net);
    let teacher = Teacher::new(cfg.model.n, cfg.train.seed);
    let cache = Arc::new(BatchCache::new(
        teacher,
        cfg.train.batch,
        p,
        cfg.train.dataset_batches,
    ));

    // Control plane: rank -> leader loss reports; leader -> rank continue.
    let (loss_tx, loss_rx) = mpsc::channel::<LossReport>();
    let mut cont_txs: Vec<mpsc::Sender<bool>> = Vec::with_capacity(p);

    let mut handles = Vec::with_capacity(p);
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let (ct, cr) = mpsc::channel::<bool>();
        cont_txs.push(ct);
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let cache = cache.clone();
        let loss_tx = loss_tx.clone();
        let warmup = cfg.train.warmup_iters;
        handles.push(
            thread::Builder::new()
                .name(format!("rank-{rank}"))
                .spawn(move || -> Result<RankReport> {
                    run_rank(rank, &cfg, artifact, exec, ep, cache, loss_tx, cr, warmup)
                })
                .context("spawning rank thread")?,
        );
    }
    drop(loss_tx);

    // Leader loop: aggregate per-iteration losses, decide stopping.
    let mut tracker = LossTracker::new(cfg.train.target_loss, cfg.train.max_iters);
    let mut losses = Vec::new();
    let mut pending: std::collections::HashMap<u64, (f64, usize)> = Default::default();
    let mut next_iter: u64 = 0;
    let mut leader_err: Option<anyhow::Error> = None;
    'leader: loop {
        let report = match loss_rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all ranks done or died
        };
        let e = pending.entry(report.iter).or_insert((0.0, 0));
        e.0 += report.loss_local;
        e.1 += 1;
        while let Some(&(sum, cnt)) = pending.get(&next_iter) {
            if cnt < p {
                break;
            }
            pending.remove(&next_iter);
            let global = sum * scale;
            losses.push(global);
            let stop = {
                let mut t = tracker.clone();
                let s = t.record(global);
                tracker = t;
                s
            };
            for ct in &cont_txs {
                // A rank that already exited with an error has dropped its
                // receiver; surface that instead of spinning forever.
                if ct.send(!stop).is_err() {
                    leader_err = Some(anyhow!("a rank died mid-iteration"));
                    break 'leader;
                }
            }
            next_iter += 1;
            if stop {
                break 'leader;
            }
        }
    }
    drop(cont_txs);

    let mut per_rank = Vec::with_capacity(p);
    for h in handles {
        match h.join() {
            Ok(Ok(r)) => per_rank.push(r),
            Ok(Err(e)) => return Err(e.context("rank failed")),
            Err(_) => bail!("rank thread panicked"),
        }
    }
    if let Some(e) = leader_err {
        return Err(e);
    }
    per_rank.sort_by_key(|r| r.rank);

    let mut totals = LedgerSummary::default();
    let mut energy_total = 0.0;
    let mut energy_train = 0.0;
    let mut warm_t_max: f64 = 0.0;
    for r in &per_rank {
        totals.accumulate(&r.ledger);
        energy_train += r.energy_train_j;
        warm_t_max = warm_t_max.max(r.warm_t);
    }
    energy_total += totals.energy_j(&cfg.hardware.power);

    let model_params = match cfg.mode {
        Parallelism::Tensor => tp_model_params(cfg.model.n, cfg.model.layers),
        Parallelism::Phantom => {
            pp_model_params(cfg.model.n, cfg.model.layers, p, cfg.model.k)
        }
    };

    Ok(TrainReport {
        mode: cfg.mode,
        p,
        n: cfg.model.n,
        k: cfg.model.k,
        layers: cfg.model.layers,
        batch: cfg.train.batch,
        iterations: losses.len(),
        losses,
        reached_target: tracker.reached_target(),
        model_params,
        energy_total_j: energy_total,
        energy_train_j: energy_train,
        wall_s: totals.end_s,
        wall_train_s: (totals.end_s - warm_t_max).max(0.0),
        per_rank,
    })
}

#[allow(clippy::too_many_arguments)]
fn run_rank(
    rank: usize,
    cfg: &RunConfig,
    artifact: String,
    exec: crate::runtime::ExecHandle,
    ep: crate::comm::Endpoint,
    cache: Arc<BatchCache>,
    loss_tx: mpsc::Sender<LossReport>,
    cont_rx: mpsc::Receiver<bool>,
    warmup: usize,
) -> Result<RankReport> {
    enum Worker {
        Pp(PhantomRank),
        Tp(TensorRank),
    }
    let mut worker = match cfg.mode {
        Parallelism::Phantom => Worker::Pp(PhantomRank::new(
            PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?,
            artifact,
            cfg.train.optimizer,
            exec,
            ep,
        )),
        Parallelism::Tensor => Worker::Tp(TensorRank::new(
            TpRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?,
            artifact,
            cfg.train.optimizer,
            exec,
            ep,
        )),
    };

    let mut warm_t = 0.0;
    let mut iter: u64 = 0;
    loop {
        let (x, t) = cache.shard(iter, rank)?;
        let loss_local = match &mut worker {
            Worker::Pp(w) => w.iteration(&x, &t)?,
            Worker::Tp(w) => w.iteration(&x, &t)?,
        };
        if (iter + 1) as usize == warmup {
            warm_t = match &worker {
                Worker::Pp(w) => w.ledger.now_s,
                Worker::Tp(w) => w.ledger.now_s,
            };
        }
        loss_tx
            .send(LossReport { rank, iter, loss_local })
            .map_err(|_| anyhow!("leader is gone"))?;
        match cont_rx.recv() {
            Ok(true) => iter += 1,
            Ok(false) => break,
            Err(_) => bail!("leader dropped the control channel"),
        }
    }

    let (ledger, stats) = match worker {
        Worker::Pp(w) => (w.ledger, w.ep.stats),
        Worker::Tp(w) => (w.ledger, w.ep.stats),
    };
    let energy_train_j =
        ledger.energy_j_between(&cfg.hardware.power, warm_t, ledger.now_s);
    Ok(RankReport {
        rank,
        ledger: ledger.summary(),
        stats,
        warm_t,
        energy_train_j,
    })
}

/// Inference report: forward-only serving statistics (the "inferencing"
/// half of the paper's title — PP's forward path saves the same
/// communication per query as per training iteration).
#[derive(Debug, Clone)]
pub struct InferReport {
    pub mode: Parallelism,
    pub batches: usize,
    /// Virtual latency per batch, seconds (post-warmup).
    pub latencies_s: Vec<f64>,
    /// Cluster energy over the serving phase (post-warmup), Joules.
    pub energy_j: f64,
    /// Samples served per virtual second (post-warmup).
    pub throughput: f64,
}

/// Serve `batches` forward-only batches and report latency/energy.
pub fn infer(cfg: &RunConfig, server: &ExecServer, batches: usize) -> Result<InferReport> {
    cfg.validate()?;
    let artifact = cfg.artifact.clone().ok_or_else(|| anyhow!("needs artifact"))?;
    let p = cfg.p;
    let endpoints = Fabric::new(p, cfg.hardware.net);
    let teacher = Teacher::new(cfg.model.n, cfg.train.seed);
    let cache = Arc::new(BatchCache::new(
        teacher,
        cfg.train.batch,
        p,
        cfg.train.dataset_batches,
    ));

    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let cache = cache.clone();
        handles.push(thread::spawn(move || -> Result<(Vec<f64>, crate::energy::EnergyLedger)> {
            let mut ledger = crate::energy::EnergyLedger::new();
            let mut ep = ep;
            let mut marks = vec![0.0f64];
            match cfg.mode {
                Parallelism::Phantom => {
                    let params =
                        PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    for b in 0..batches as u64 {
                        let (x, _) = cache.shard(b, rank)?;
                        super::pp_forward_shard(
                            &exec, &artifact, &params, &mut ep, &mut ledger, x,
                        )?;
                        marks.push(ledger.now_s);
                    }
                }
                Parallelism::Tensor => {
                    let params = TpRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
                    for b in 0..batches as u64 {
                        let (x, _) = cache.shard(b, rank)?;
                        super::tp_forward_shard(
                            &exec, &artifact, &params, &mut ep, &mut ledger, x, true,
                        )?;
                        marks.push(ledger.now_s);
                    }
                }
            }
            Ok((marks, ledger))
        }));
    }

    let mut all_marks: Vec<Vec<f64>> = Vec::new();
    let mut energy = 0.0;
    let mut warm_t: f64 = 0.0;
    let mut end_t: f64 = 0.0;
    for h in handles {
        let (marks, ledger) = h.join().map_err(|_| anyhow!("rank panicked"))??;
        // warmup = first batch (PJRT compile)
        warm_t = warm_t.max(marks.get(1).copied().unwrap_or(0.0));
        end_t = end_t.max(ledger.now_s);
        energy += ledger.energy_j_between(&cfg.hardware.power, marks[1], ledger.now_s);
        all_marks.push(marks);
    }
    // Virtual latencies are identical across ranks (synchronous collectives);
    // use rank 0's marks, skipping the warmup batch.
    let marks = &all_marks[0];
    let latencies: Vec<f64> = marks.windows(2).skip(1).map(|w| w[1] - w[0]).collect();
    let serving_time = (end_t - warm_t).max(1e-12);
    let throughput = ((batches - 1) * cfg.train.batch) as f64 / serving_time;
    Ok(InferReport {
        mode: cfg.mode,
        batches,
        latencies_s: latencies,
        energy_j: energy,
        throughput,
    })
}

/// Convenience for tests/examples: evaluate the sharded PP forward once
/// (no training) and return the assembled output. Drives the same phase
/// schedule as training.
pub fn pp_forward_once(
    cfg: &RunConfig,
    server: &ExecServer,
    x_full: &Tensor,
) -> Result<Tensor> {
    let artifact = cfg.artifact.clone().ok_or_else(|| anyhow!("needs artifact"))?;
    let p = cfg.p;
    let endpoints = Fabric::new(p, cfg.hardware.net);
    let x_shards = x_full.col_shards(p)?;
    let mut handles = Vec::new();
    for (rank, ep) in endpoints.into_iter().enumerate() {
        let cfg = cfg.clone();
        let artifact = artifact.clone();
        let exec = server.handle();
        let x = x_shards[rank].clone();
        handles.push(thread::spawn(move || -> Result<Tensor> {
            let params = PhantomRankParams::init(&cfg.model, cfg.p, rank, cfg.train.seed)?;
            let mut ep = ep;
            let mut ledger = crate::energy::EnergyLedger::new();
            super::pp_forward_shard(&exec, &artifact, &params, &mut ep, &mut ledger, x)
        }));
    }
    let mut shards = Vec::new();
    for h in handles {
        shards.push(h.join().map_err(|_| anyhow!("rank panicked"))??);
    }
    Tensor::from_col_shards(&shards)
}
