//! Tensor-parallel rank worker: the paper's baseline pipeline.
//!
//! Functionally the forward assembles the full activation with one
//! All-Gather and the backward sums partial input-gradients with one
//! All-Reduce. The paper's implementation additionally issues a Broadcast
//! (forward) and a Reduce-Scatter (backward) per layer (Table II); those are
//! charged to the virtual clock via `Endpoint::charge_modeled` so beta_tau
//! matches the paper's schedule (see comm::charge_modeled docs).

use anyhow::Result;

use super::exec_charged;
use super::rank_pp::unpack;
use crate::comm::Endpoint;
use crate::config::OptimizerConfig;
use crate::energy::EnergyLedger;
use crate::model::TpRankParams;
use crate::runtime::ExecHandle;
use crate::simnet::Collective;
use crate::tensor::Tensor;
use crate::train::{Optimizer, OptimizerState};

/// Per-rank tensor-parallel worker state.
pub struct TensorRank {
    pub params: TpRankParams,
    pub artifact: String,
    opt: Optimizer,
    pub exec: ExecHandle,
    pub ep: Endpoint,
    /// Data-parallel group endpoint (hybrid DP×TP): armed via `arm_dp`
    /// when the run has dp > 1; `None` = pure tensor parallelism, whose
    /// iteration is byte-identical to the pre-hybrid schedule.
    pub dp_ep: Option<Endpoint>,
    pub ledger: EnergyLedger,
    /// Charge the paper's full Table II schedule (Broadcast + extra
    /// Reduce-Scatter). On by default; ablation benches switch it off.
    pub paper_schedule: bool,
    /// ZeRO-1: `Some(slot)` = the optimizer holds state only for this
    /// replica's owned flat parameter slice of `slot` floats.
    sharded_slot: Option<usize>,
    /// Iterations completed (names the per-iteration trace spans).
    iter_no: u64,
}

impl TensorRank {
    pub fn new(
        params: TpRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        exec: ExecHandle,
        ep: Endpoint,
    ) -> TensorRank {
        Self::with_state(params, artifact, opt_cfg, None, exec, ep, None)
            .expect("a fresh optimizer always matches its own shapes")
    }

    /// Build with a restored optimizer state (checkpoint resume); `None`
    /// starts a fresh optimizer, identical to `new`. With
    /// `sharded_slot = Some(slot)` the optimizer is laid out for the
    /// replica's owned flat parameter slice (ZeRO-1); any restored state
    /// must match that layout.
    pub fn with_state(
        params: TpRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        opt_state: Option<OptimizerState>,
        exec: ExecHandle,
        ep: Endpoint,
        sharded_slot: Option<usize>,
    ) -> Result<TensorRank> {
        let shapes: Vec<Vec<usize>> = match sharded_slot {
            Some(slot) => vec![vec![slot]],
            None => params
                .weights
                .iter()
                .map(|t| t.shape().to_vec())
                .chain(params.biases.iter().map(|t| t.shape().to_vec()))
                .collect(),
        };
        let opt = Optimizer::with_state(opt_cfg, &shapes, opt_state)?;
        Ok(TensorRank {
            params,
            artifact,
            opt,
            exec,
            ep,
            dp_ep: None,
            ledger: EnergyLedger::new(),
            paper_schedule: true,
            sharded_slot,
            iter_no: 0,
        })
    }

    /// Join a data-parallel group: every subsequent iteration ends with
    /// the DP gradient All-Reduce over `dp_ep` before the optimizer step.
    pub fn arm_dp(&mut self, dp_ep: Endpoint) {
        self.dp_ep = Some(dp_ep);
    }

    /// Export the optimizer's accumulated state for checkpointing.
    pub fn opt_state(&self) -> OptimizerState {
        self.opt.state()
    }

    /// Floats of optimizer state held on this rank (sharded: ~1/dp flat).
    pub fn opt_state_floats(&self) -> usize {
        self.opt.state_floats()
    }

    /// One forward+backward+update iteration. Returns the rank-local sum of
    /// squared errors (pre-scale).
    ///
    /// Zero-clone hot path: every backend call borrows its inputs; the one
    /// remaining copy is the input batch shard handed to the first
    /// All-Gather (collectives take owned payloads — that copy IS the
    /// modeled data movement).
    pub fn iteration(&mut self, x_shard: &Tensor, t_shard: &Tensor) -> Result<f64> {
        let layers = self.params.layers();
        let rank = self.params.rank;
        let m = self.params.m;
        let p = self.params.p;
        let n = m * p;
        let batch = x_shard.shape()[0];

        if self.ledger.traced() {
            let name = format!("iter {}", self.iter_no);
            self.ledger.span_begin("iter", &name);
        }

        // ---- forward ----
        self.ledger.span_begin("phase", "forward");
        let mut y_shard = x_shard.clone();
        let mut y_fulls: Vec<Tensor> = Vec::with_capacity(layers);
        let mut zs: Vec<Tensor> = Vec::with_capacity(layers);
        for l in 0..layers {
            // All-Gather the activation shards: message (n/p)*batch.
            let gathered = self.ep.all_gather(y_shard, &mut self.ledger)?;
            let y_full = gathered.concat_shards_stacked()?;
            if self.paper_schedule {
                // Paper Table II: Broadcast of the n*batch global layer.
                self.ep.charge_modeled(Collective::Broadcast, n * batch, &mut self.ledger);
            }
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "tp_fwd",
                &[&y_full, &self.params.weights[l], &self.params.biases[l]],
            )?;
            let [y_out, z]: [Tensor; 2] = unpack(r.outputs, "tp_fwd")?;
            y_fulls.push(y_full);
            zs.push(z);
            y_shard = y_out;
        }

        // ---- loss ----
        self.ledger.span_end(); // forward
        self.ledger.span_begin("phase", "loss");
        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &self.artifact,
            "mse_delta",
            &[&y_shard, &zs[layers - 1], t_shard],
        )?;
        let [loss_t, delta0]: [Tensor; 2] = unpack(r.outputs, "mse_delta")?;
        let loss_local = loss_t.data()[0] as f64;
        let mut delta = delta0;

        // ---- backward ----
        self.ledger.span_end(); // loss
        self.ledger.span_begin("phase", "backward");
        // Top layer's gradients, then for each lower layer the fused
        // tp_bwd_step (finish + grads) after the All-Reduce — one backend
        // call per inter-collective segment (EXPERIMENTS.md §Perf).
        let mut grads: Vec<Option<[Tensor; 2]>> = (0..layers).map(|_| None).collect();
        {
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "tp_grads",
                &[&y_fulls[layers - 1], &delta],
            )?;
            let [dw, db]: [Tensor; 2] = unpack(r.outputs, "tp_grads")?;
            grads[layers - 1] = Some([dw, db]);
        }
        for l in (1..layers).rev() {
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "tp_bwd_partial",
                &[&delta, &self.params.weights[l]],
            )?;
            let [dy_partial]: [Tensor; 1] = unpack(r.outputs, "tp_bwd_partial")?;

            // All-Reduce the n*batch input-gradient (paper Table II).
            let dy_full = self.ep.all_reduce(dy_partial, &mut self.ledger)?;
            if self.paper_schedule {
                // Paper Table II: Reduce-Scatter of the (n/p)*batch shard.
                self.ep.charge_modeled(
                    Collective::ReduceScatter,
                    m * batch,
                    &mut self.ledger,
                );
            }
            let dy_shard = dy_full.col_slice(rank * m, m)?;
            // fused: finish(l-1) + grads(l-1)
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "tp_bwd_step",
                &[&dy_shard, &zs[l - 1], &y_fulls[l - 1]],
            )?;
            let [d, dw, db]: [Tensor; 3] = unpack(r.outputs, "tp_bwd_step")?;
            std::mem::replace(&mut delta, d).recycle();
            grads[l - 1] = Some([dw, db]);
        }

        self.ledger.span_end(); // backward
        // Dead error/activation tensors fold back into the bounded band
        // pool so the next iteration's kernels reuse their allocations.
        delta.recycle();
        for t in y_fulls.into_iter().chain(zs) {
            t.recycle();
        }

        // ---- DP gradient sync + optimizer step ----
        // Order must match named_tensors: W*, b*; arrays moved, not cloned.
        let mut dws = Vec::with_capacity(layers);
        let mut dbs = Vec::with_capacity(layers);
        for g in grads.into_iter() {
            let [dw, db] = g.expect("every layer produced grads");
            dws.push(dw);
            dbs.push(db);
        }
        let mut grad_list = dws;
        grad_list.append(&mut dbs);
        // Hybrid DP×TP: synchronize gradients across the data-parallel
        // replicas before the optimizer step — one flat All-Reduce then
        // the full step on every replica, or the ZeRO-1 Reduce-Scatter →
        // slice step → All-Gather cycle when the state is sharded. Comm
        // lands in the DpComm bucket; rendezvous wait is never charged as
        // compute.
        {
            let mut tensors = self.params.named_tensors();
            let mut refs: Vec<&mut Tensor> =
                tensors.iter_mut().map(|(_, t)| &mut **t).collect();
            super::dp_sync_and_step(
                &mut self.dp_ep,
                self.sharded_slot,
                &mut self.opt,
                &mut refs,
                grad_list,
                &mut self.ledger,
            )?;
        }

        self.ledger.span_end_with(|| vec![("loss_local", crate::obs::Arg::F(loss_local))]);
        self.iter_no += 1;
        Ok(loss_local)
    }
}
