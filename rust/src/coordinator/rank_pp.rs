//! Phantom-parallel rank worker: one training iteration's phase schedule.

use anyhow::{bail, Result};

use super::exec_charged;
use crate::comm::Endpoint;
use crate::config::OptimizerConfig;
use crate::energy::{Activity, EnergyLedger};
use crate::model::PhantomRankParams;
use crate::runtime::ExecHandle;
use crate::tensor::Tensor;
use crate::train::Optimizer;

/// Per-rank phantom-parallel worker state.
pub struct PhantomRank {
    pub params: PhantomRankParams,
    pub artifact: String,
    opt: Optimizer,
    pub exec: ExecHandle,
    pub ep: Endpoint,
    pub ledger: EnergyLedger,
}

impl PhantomRank {
    pub fn new(
        params: PhantomRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        exec: ExecHandle,
        ep: Endpoint,
    ) -> PhantomRank {
        let shapes = param_shapes(&params);
        PhantomRank {
            params,
            artifact,
            opt: Optimizer::new(opt_cfg, &shapes),
            exec,
            ep,
            ledger: EnergyLedger::new(),
        }
    }

    /// One forward+backward+update iteration over the local shard.
    /// Returns the rank-local sum of squared errors (pre-scale).
    ///
    /// Uses the FUSED inter-collective segments (pp_fwd_step / pp_loss_step
    /// / pp_bwd_step): every stretch of compute between two collectives is
    /// one PJRT execution — 7 calls per 2-layer iteration instead of 10
    /// (EXPERIMENTS.md §Perf). The collective schedule is unchanged from
    /// the paper's Table II: one k*batch All-Gather per layer forward, one
    /// k*batch Reduce-Scatter per layer backward.
    pub fn iteration(&mut self, x_shard: &Tensor, t_shard: &Tensor) -> Result<f64> {
        let layers = self.params.layers();
        let rank = self.params.rank;
        let art = self.artifact.clone();

        // ---- forward ----
        let mut ys: Vec<Tensor> = vec![x_shard.clone()];
        let mut zs: Vec<Tensor> = Vec::with_capacity(layers);
        let mut g_alls: Vec<Tensor> = Vec::with_capacity(layers);

        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &art,
            "pp_fwd_local",
            vec![
                ys[0].clone(),
                self.params.locals[0].clone(),
                self.params.compressors[0].clone(),
            ],
        )?;
        let [mut z_loc, mut g]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_local")?;

        for l in 0..layers {
            // The ONLY forward collective (paper Table II, PP row).
            let mut g_all = self.ep.all_gather(g.clone(), &mut self.ledger)?;
            g_all.zero_slot(rank);

            if l + 1 < layers {
                // fused: combine(l) + local(l+1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &art,
                    "pp_fwd_step",
                    vec![
                        z_loc,
                        g_all.clone(),
                        self.params.decompressors[l].clone(),
                        self.params.biases[l].clone(),
                        self.params.locals[l + 1].clone(),
                        self.params.compressors[l + 1].clone(),
                    ],
                )?;
                let [y_out, z, z_loc_next, g_next]: [Tensor; 4] =
                    unpack(r.outputs, "pp_fwd_step")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
                z_loc = z_loc_next;
                g = g_next;
            } else {
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &art,
                    "pp_fwd_combine",
                    vec![
                        z_loc.clone(),
                        g_all.clone(),
                        self.params.decompressors[l].clone(),
                        self.params.biases[l].clone(),
                    ],
                )?;
                let [y_out, z]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_combine")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
            }
        }

        // ---- loss + top-layer error compression (fused) ----
        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &art,
            "pp_loss_step",
            vec![
                ys[layers].clone(),
                zs[layers - 1].clone(),
                t_shard.clone(),
                self.params.decompressors[layers - 1].clone(),
            ],
        )?;
        let [loss_t, delta0, h_out]: [Tensor; 3] = unpack(r.outputs, "pp_loss_step")?;
        let loss_local = loss_t.data()[0] as f64;
        let mut delta = delta0;
        // The ONLY backward collective (paper Table II, PP row).
        let mut h_sum = self.ep.reduce_scatter(h_out, &mut self.ledger)?;

        // ---- backward ----
        let mut grads: Vec<Option<[Tensor; 4]>> = (0..layers).map(|_| None).collect();
        for l in (0..layers).rev() {
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &art,
                "pp_grads",
                vec![ys[l].clone(), delta.clone(), h_sum.clone(), g_alls[l].clone()],
            )?;
            let [dl, dc, dd, db]: [Tensor; 4] = unpack(r.outputs, "pp_grads")?;
            grads[l] = Some([dl, dc, dd, db]);

            if l > 0 {
                // fused: combine(l) + compress(l-1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &art,
                    "pp_bwd_step",
                    vec![
                        delta,
                        h_sum,
                        self.params.locals[l].clone(),
                        self.params.compressors[l].clone(),
                        zs[l - 1].clone(),
                        self.params.decompressors[l - 1].clone(),
                    ],
                )?;
                let [d, h_out_prev]: [Tensor; 2] = unpack(r.outputs, "pp_bwd_step")?;
                delta = d;
                h_sum = self.ep.reduce_scatter(h_out_prev, &mut self.ledger)?;
            }
        }

        // ---- optimizer step (rank-local compute) ----
        let t0 = std::time::Instant::now();
        let mut grad_list = Vec::with_capacity(4 * layers);
        // Order must match `param_shapes`/`named_tensors`: L*, C*, D*, b*.
        for g in grads.iter().flatten() {
            grad_list.push(g[0].clone());
        }
        for g in grads.iter().flatten() {
            grad_list.push(g[1].clone());
        }
        for g in grads.iter().flatten() {
            grad_list.push(g[2].clone());
        }
        for g in grads.iter().flatten() {
            grad_list.push(g[3].clone());
        }
        {
            let mut tensors = self.params.named_tensors();
            let mut refs: Vec<&mut Tensor> =
                tensors.iter_mut().map(|(_, t)| &mut **t).collect();
            self.opt.step(&mut refs, &grad_list);
        }
        self.ledger.advance(t0.elapsed().as_secs_f64(), Activity::Compute);

        Ok(loss_local)
    }
}

pub(crate) fn param_shapes(params: &PhantomRankParams) -> Vec<Vec<usize>> {
    let mut shapes = Vec::new();
    for t in &params.locals {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.compressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.decompressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.biases {
        shapes.push(t.shape().to_vec());
    }
    shapes
}

/// Unpack a fixed-arity executable result.
pub(crate) fn unpack<const N: usize>(outputs: Vec<Tensor>, entry: &str) -> Result<[Tensor; N]> {
    if outputs.len() != N {
        bail!("{entry}: expected {N} outputs, got {}", outputs.len());
    }
    Ok(outputs.try_into().map_err(|_| ()).expect("length checked"))
}
