//! Phantom-parallel rank worker: one training iteration's phase schedule,
//! generalized over micro-batches and the interleaved 1F1B pipeline
//! schedule (DESIGN.md §15).
//!
//! The batch shard is split into `micro` contiguous row chunks (same
//! remainder tiling as the DP row split). Two schedules drive the chunks:
//!
//! * `sync` (GPipe-style): all forwards in micro order, then all
//!   backwards in micro order. Every collective is exposed — priced
//!   exactly like the pre-pipeline schedule.
//! * `1f1b`: `W = min(p-1, micro)` warmup forwards, then a steady state
//!   alternating backward(i) / forward(W+i), then cooldown backwards.
//!   Interior collectives are *overlapped*: their wire time is parked on
//!   the ledger's deferral register and drained at zero cost by
//!   subsequent micro-batch compute; only micro 0's forward and the last
//!   micro's backward collectives (the pipeline-fill/drain boundary,
//!   which has no compute to hide under) stay exposed, plus whatever
//!   remainder compute could not cover.
//!
//! Both schedules run every forward in micro order and every backward in
//! micro order with gradient accumulation and the f64 loss sum in micro
//! order, so they are bitwise identical to each other at equal `micro`.
//! `micro = 1` is byte-identical to the historical synchronous path (one
//! chunk = the whole shard, nothing deferred).

use anyhow::{bail, Result};

use super::exec_charged;
use crate::comm::Endpoint;
use crate::config::OptimizerConfig;
use crate::energy::{Activity, EnergyLedger};
use crate::model::PhantomRankParams;
use crate::runtime::ExecHandle;
use crate::tensor::Tensor;
use crate::train::{Optimizer, OptimizerState};

/// Per-rank phantom-parallel worker state.
pub struct PhantomRank {
    pub params: PhantomRankParams,
    pub artifact: String,
    opt: Optimizer,
    pub exec: ExecHandle,
    pub ep: Endpoint,
    /// Data-parallel group endpoint (hybrid DP×PP): armed via `arm_dp`
    /// when the run has dp > 1; `None` = pure phantom parallelism, whose
    /// iteration is byte-identical to the pre-hybrid schedule.
    pub dp_ep: Option<Endpoint>,
    pub ledger: EnergyLedger,
    /// Micro-batches per iteration (1 = the historical whole-shard path).
    micro: usize,
    /// Run the interleaved 1F1B schedule with comm/compute overlap.
    one_f_one_b: bool,
    /// ZeRO-1: `Some(slot)` = the optimizer holds state only for this
    /// replica's owned flat parameter slice of `slot` floats.
    sharded_slot: Option<usize>,
    /// Iterations completed (names the per-iteration trace spans).
    iter_no: u64,
}

/// Retained per-micro-batch forward state consumed by its backward.
struct MicroStash {
    ys: Vec<Tensor>,
    zs: Vec<Tensor>,
    g_alls: Vec<Tensor>,
}

impl PhantomRank {
    pub fn new(
        params: PhantomRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        exec: ExecHandle,
        ep: Endpoint,
    ) -> PhantomRank {
        Self::with_state(params, artifact, opt_cfg, None, exec, ep, None)
            .expect("a fresh optimizer always matches its own shapes")
    }

    /// Build with a restored optimizer state (checkpoint resume); `None`
    /// starts a fresh optimizer, identical to `new`. With
    /// `sharded_slot = Some(slot)` the optimizer is laid out for the
    /// replica's owned flat parameter slice (one `[slot]` moment per
    /// tensor) instead of the full parameter list — the ZeRO-1 mode; any
    /// restored state must match that layout.
    pub fn with_state(
        params: PhantomRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        opt_state: Option<OptimizerState>,
        exec: ExecHandle,
        ep: Endpoint,
        sharded_slot: Option<usize>,
    ) -> Result<PhantomRank> {
        let shapes = match sharded_slot {
            Some(slot) => vec![vec![slot]],
            None => param_shapes(&params),
        };
        let opt = Optimizer::with_state(opt_cfg, &shapes, opt_state)?;
        let ledger = EnergyLedger::new();
        Ok(PhantomRank {
            params,
            artifact,
            opt,
            exec,
            ep,
            dp_ep: None,
            ledger,
            micro: 1,
            one_f_one_b: false,
            sharded_slot,
            iter_no: 0,
        })
    }

    /// Join a data-parallel group: every subsequent iteration ends with
    /// the DP gradient synchronization over `dp_ep` (flat All-Reduce, or
    /// the ZeRO Reduce-Scatter/All-Gather pair when sharded) before the
    /// optimizer step.
    pub fn arm_dp(&mut self, dp_ep: Endpoint) {
        self.dp_ep = Some(dp_ep);
    }

    /// Configure the micro-batch count and pipeline schedule for all
    /// subsequent iterations. `micro = 1, one_f_one_b = false` (the
    /// default) is the historical synchronous whole-shard path.
    pub fn set_schedule(&mut self, micro: usize, one_f_one_b: bool) {
        assert!(micro >= 1, "micro-batch count must be at least 1");
        self.micro = micro;
        self.one_f_one_b = one_f_one_b;
    }

    /// Export the optimizer's accumulated state for checkpointing.
    pub fn opt_state(&self) -> OptimizerState {
        self.opt.state()
    }

    /// Floats of optimizer state held on this rank (sharded: ~1/dp flat).
    pub fn opt_state_floats(&self) -> usize {
        self.opt.state_floats()
    }

    /// One forward+backward+update iteration over the local shard.
    /// Returns the rank-local sum of squared errors (pre-scale).
    ///
    /// Uses the FUSED inter-collective segments (pp_fwd_step / pp_loss_step
    /// / pp_bwd_step): every stretch of compute between two collectives is
    /// one backend execution — 7 calls per 2-layer iteration instead of 10
    /// (EXPERIMENTS.md §Perf). The collective schedule is unchanged from
    /// the paper's Table II: one k*batch All-Gather per layer forward, one
    /// k*batch Reduce-Scatter per layer backward — per micro-batch.
    ///
    /// Zero-clone hot path: every backend call borrows its inputs, so no
    /// weight, decompressor, bias or retained activation is copied — only
    /// the collectives take (and must take) owned payloads, and a
    /// micro > 1 run copies the row chunks out of the shard once.
    pub fn iteration(&mut self, x_shard: &Tensor, t_shard: &Tensor) -> Result<f64> {
        if self.ledger.traced() {
            let name = format!("iter {}", self.iter_no);
            self.ledger.span_begin("iter", &name);
        }

        let rows = x_shard.shape()[0];
        let micro = self.micro.min(rows).max(1);
        let overlap = self.one_f_one_b && micro > 1;

        // Row chunks: same remainder tiling as the DP row split, so every
        // chunk is non-empty and they tile the shard exactly. The loss
        // kernels scale by the config's global 1/(batch*n) constant, not
        // the chunk row count, so per-chunk losses and gradients sum to
        // the whole-shard values exactly.
        let chunks: Vec<(Tensor, Tensor)> = if micro == 1 {
            Vec::new() // borrow x_shard/t_shard directly, no copy
        } else {
            (0..micro)
                .map(|i| {
                    let (start, len) = crate::data::dp_row_range(rows, micro, i);
                    Ok((
                        crate::data::row_slice(x_shard, start, len)?,
                        crate::data::row_slice(t_shard, start, len)?,
                    ))
                })
                .collect::<Result<_>>()?
        };
        let mb = |i: usize| -> (&Tensor, &Tensor) {
            if micro == 1 {
                (x_shard, t_shard)
            } else {
                (&chunks[i].0, &chunks[i].1)
            }
        };

        let mut loss_local = 0.0f64;
        let mut grad_acc: Option<Vec<Tensor>> = None;
        let mut bwd =
            |rank: &mut Self, stash: MicroStash, i: usize, expose: bool| -> Result<()> {
                let (x_mb, t_mb) = if micro == 1 {
                    (x_shard, t_shard)
                } else {
                    (&chunks[i].0, &chunks[i].1)
                };
                let (loss, grads) = rank.backward_micro(stash, x_mb, t_mb, expose)?;
                loss_local += loss;
                match grad_acc.as_mut() {
                    None => grad_acc = Some(grads),
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(grads) {
                            a.add_assign(&g);
                            g.recycle(); // back to the band pool for micro i+1
                        }
                    }
                }
                Ok(())
            };

        if !overlap {
            // Synchronous (GPipe-style): all forwards in micro order, then
            // all backwards in micro order, every collective exposed.
            // micro = 1 is byte-identical to the historical path.
            let mut stashes: Vec<MicroStash> = Vec::with_capacity(micro);
            for i in 0..micro {
                stashes.push(self.forward_micro(mb(i).0, true)?);
            }
            for (i, stash) in stashes.into_iter().enumerate() {
                bwd(self, stash, i, true)?;
            }
        } else {
            // 1F1B: warmup fills the pipeline with W forwards, the steady
            // state drains one backward per new forward, cooldown drains
            // the rest. Interior collectives defer their wire time onto
            // the overlap register (micro 0's forward and the last
            // micro's backward stay exposed — fill and drain have no
            // neighboring compute to hide under).
            let w = (self.ep.p - 1).clamp(1, micro);
            let mut in_flight: std::collections::VecDeque<MicroStash> =
                std::collections::VecDeque::with_capacity(w);
            for i in 0..w {
                in_flight.push_back(self.forward_micro(mb(i).0, i == 0)?);
            }
            for i in 0..micro - w {
                let stash = in_flight.pop_front().expect("warmup filled the queue");
                bwd(self, stash, i, i == micro - 1)?;
                in_flight.push_back(self.forward_micro(mb(w + i).0, false)?);
            }
            for i in micro - w..micro {
                let stash = in_flight.pop_front().expect("one stash per micro");
                bwd(self, stash, i, i == micro - 1)?;
            }
            // Un-hidden overlapped wire time: charge the remainder before
            // the DP sync so the deferral register never leaks across
            // iterations (and the buckets keep partitioning the clock).
            self.ledger.drain_deferred(Activity::Communicate);
        }
        drop(bwd);

        let grad_list = grad_acc.expect("at least one micro-batch ran");

        // ---- DP gradient sync + optimizer step (rank-local compute) ----
        // Flat: one All-Reduce then the full step on every replica.
        // Sharded (ZeRO-1): Reduce-Scatter -> slice step -> All-Gather.
        {
            let mut tensors = self.params.named_tensors();
            let mut refs: Vec<&mut Tensor> =
                tensors.iter_mut().map(|(_, t)| &mut **t).collect();
            super::dp_sync_and_step(
                &mut self.dp_ep,
                self.sharded_slot,
                &mut self.opt,
                &mut refs,
                grad_list,
                &mut self.ledger,
            )?;
        }

        self.ledger.span_end_with(|| vec![("loss_local", crate::obs::Arg::F(loss_local))]);
        self.iter_no += 1;
        Ok(loss_local)
    }

    /// Forward pass over one micro-batch: pp_fwd_local, then per layer the
    /// All-Gather + fused combine/local step, stashing the retained
    /// activations for the matching backward. `expose = false` parks the
    /// collectives' wire time on the ledger's overlap register.
    fn forward_micro(&mut self, x_mb: &Tensor, expose: bool) -> Result<MicroStash> {
        self.ledger.set_defer(!expose);
        let r = self.forward_micro_inner(x_mb);
        self.ledger.set_defer(false);
        r
    }

    fn forward_micro_inner(&mut self, x_mb: &Tensor) -> Result<MicroStash> {
        let layers = self.params.layers();
        let rank = self.params.rank;
        self.ledger.span_begin("phase", "forward");
        // ys[l] = post-activation output of layer l; the layer-l input is
        // x_mb for l == 0, else ys[l - 1].
        let mut ys: Vec<Tensor> = Vec::with_capacity(layers);
        let mut zs: Vec<Tensor> = Vec::with_capacity(layers);
        let mut g_alls: Vec<Tensor> = Vec::with_capacity(layers);

        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &self.artifact,
            "pp_fwd_local",
            &[x_mb, &self.params.locals[0], &self.params.compressors[0]],
        )?;
        let [mut z_loc, g]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_local")?;
        let mut g = Some(g);

        for l in 0..layers {
            // The ONLY forward collective (paper Table II, PP row); it
            // consumes g, which the next fused step replaces.
            let mut g_all =
                self.ep.all_gather(g.take().expect("g set each layer"), &mut self.ledger)?;
            g_all.zero_slot(rank);

            if l + 1 < layers {
                // fused: combine(l) + local(l+1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_fwd_step",
                    &[
                        &z_loc,
                        &g_all,
                        &self.params.decompressors[l],
                        &self.params.biases[l],
                        &self.params.locals[l + 1],
                        &self.params.compressors[l + 1],
                    ],
                )?;
                let [y_out, z, z_loc_next, g_next]: [Tensor; 4] =
                    unpack(r.outputs, "pp_fwd_step")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
                z_loc = z_loc_next;
                g = Some(g_next);
            } else {
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_fwd_combine",
                    &[
                        &z_loc,
                        &g_all,
                        &self.params.decompressors[l],
                        &self.params.biases[l],
                    ],
                )?;
                let [y_out, z]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_combine")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
            }
        }
        self.ledger.span_end(); // forward
        Ok(MicroStash { ys, zs, g_alls })
    }

    /// Loss + backward pass over one micro-batch, consuming its forward
    /// stash. Returns the micro-batch's local loss and its gradient list
    /// in `param_shapes` order (L*, C*, D*, b*). `expose = false` parks
    /// the collectives' wire time on the ledger's overlap register.
    fn backward_micro(
        &mut self,
        stash: MicroStash,
        x_mb: &Tensor,
        t_mb: &Tensor,
        expose: bool,
    ) -> Result<(f64, Vec<Tensor>)> {
        self.ledger.set_defer(!expose);
        let r = self.backward_micro_inner(stash, x_mb, t_mb);
        self.ledger.set_defer(false);
        r
    }

    fn backward_micro_inner(
        &mut self,
        stash: MicroStash,
        x_mb: &Tensor,
        t_mb: &Tensor,
    ) -> Result<(f64, Vec<Tensor>)> {
        let layers = self.params.layers();
        let MicroStash { ys, zs, g_alls } = stash;

        // ---- loss + top-layer error compression (fused) ----
        self.ledger.span_begin("phase", "loss");
        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &self.artifact,
            "pp_loss_step",
            &[
                &ys[layers - 1],
                &zs[layers - 1],
                t_mb,
                &self.params.decompressors[layers - 1],
            ],
        )?;
        let [loss_t, delta0, h_out]: [Tensor; 3] = unpack(r.outputs, "pp_loss_step")?;
        let loss_local = loss_t.data()[0] as f64;
        let mut delta = delta0;
        // The ONLY backward collective (paper Table II, PP row).
        let mut h_sum = self.ep.reduce_scatter(h_out, &mut self.ledger)?;

        // ---- backward ----
        self.ledger.span_end(); // loss
        self.ledger.span_begin("phase", "backward");
        let mut grads: Vec<Option<[Tensor; 4]>> = (0..layers).map(|_| None).collect();
        for l in (0..layers).rev() {
            // The layer-l input activation, borrowed (not cloned).
            let y_prev: &Tensor = if l == 0 { x_mb } else { &ys[l - 1] };
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "pp_grads",
                &[y_prev, &delta, &h_sum, &g_alls[l]],
            )?;
            let [dl, dc, dd, db]: [Tensor; 4] = unpack(r.outputs, "pp_grads")?;
            grads[l] = Some([dl, dc, dd, db]);

            if l > 0 {
                // fused: combine(l) + compress(l-1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_bwd_step",
                    &[
                        &delta,
                        &h_sum,
                        &self.params.locals[l],
                        &self.params.compressors[l],
                        &zs[l - 1],
                        &self.params.decompressors[l - 1],
                    ],
                )?;
                let [d, h_out_prev]: [Tensor; 2] = unpack(r.outputs, "pp_bwd_step")?;
                std::mem::replace(&mut delta, d).recycle();
                let h_next = self.ep.reduce_scatter(h_out_prev, &mut self.ledger)?;
                std::mem::replace(&mut h_sum, h_next).recycle();
            }
        }
        self.ledger.span_end(); // backward
        // The micro-batch's error/activation tensors are dead: fold their
        // allocations back into the bounded band pool so the next
        // micro-batch's kernels reuse them instead of re-allocating.
        delta.recycle();
        h_sum.recycle();
        for t in ys.into_iter().chain(zs).chain(g_alls) {
            t.recycle();
        }

        // Order must match `param_shapes`/`named_tensors`: L*, C*, D*, b*.
        // The per-layer arrays are moved out, never cloned.
        let mut dls = Vec::with_capacity(layers);
        let mut dcs = Vec::with_capacity(layers);
        let mut dds = Vec::with_capacity(layers);
        let mut dbs = Vec::with_capacity(layers);
        for g in grads.into_iter() {
            let [dl, dc, dd, db] = g.expect("every layer produced grads");
            dls.push(dl);
            dcs.push(dc);
            dds.push(dd);
            dbs.push(db);
        }
        let mut grad_list = dls;
        grad_list.append(&mut dcs);
        grad_list.append(&mut dds);
        grad_list.append(&mut dbs);
        Ok((loss_local, grad_list))
    }
}

pub(crate) fn param_shapes(params: &PhantomRankParams) -> Vec<Vec<usize>> {
    let mut shapes = Vec::new();
    for t in &params.locals {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.compressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.decompressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.biases {
        shapes.push(t.shape().to_vec());
    }
    shapes
}

/// Unpack a fixed-arity executable result.
pub(crate) fn unpack<const N: usize>(outputs: Vec<Tensor>, entry: &str) -> Result<[Tensor; N]> {
    if outputs.len() != N {
        bail!("{entry}: expected {N} outputs, got {}", outputs.len());
    }
    Ok(outputs.try_into().map_err(|_| ()).expect("length checked"))
}
