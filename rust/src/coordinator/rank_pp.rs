//! Phantom-parallel rank worker: one training iteration's phase schedule.

use anyhow::{bail, Result};

use super::exec_charged;
use crate::comm::Endpoint;
use crate::config::OptimizerConfig;
use crate::energy::{Activity, EnergyLedger};
use crate::model::PhantomRankParams;
use crate::runtime::ExecHandle;
use crate::tensor::Tensor;
use crate::train::{Optimizer, OptimizerState};

/// Per-rank phantom-parallel worker state.
pub struct PhantomRank {
    pub params: PhantomRankParams,
    pub artifact: String,
    opt: Optimizer,
    pub exec: ExecHandle,
    pub ep: Endpoint,
    /// Data-parallel group endpoint (hybrid DP×PP): armed via `arm_dp`
    /// when the run has dp > 1; `None` = pure phantom parallelism, whose
    /// iteration is byte-identical to the pre-hybrid schedule.
    pub dp_ep: Option<Endpoint>,
    pub ledger: EnergyLedger,
    /// Iterations completed (names the per-iteration trace spans).
    iter_no: u64,
}

impl PhantomRank {
    pub fn new(
        params: PhantomRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        exec: ExecHandle,
        ep: Endpoint,
    ) -> PhantomRank {
        Self::with_state(params, artifact, opt_cfg, None, exec, ep)
            .expect("a fresh optimizer always matches its own shapes")
    }

    /// Build with a restored optimizer state (checkpoint resume); `None`
    /// starts a fresh optimizer, identical to `new`.
    pub fn with_state(
        params: PhantomRankParams,
        artifact: String,
        opt_cfg: OptimizerConfig,
        opt_state: Option<OptimizerState>,
        exec: ExecHandle,
        ep: Endpoint,
    ) -> Result<PhantomRank> {
        let shapes = param_shapes(&params);
        let opt = Optimizer::with_state(opt_cfg, &shapes, opt_state)?;
        let ledger = EnergyLedger::new();
        Ok(PhantomRank { params, artifact, opt, exec, ep, dp_ep: None, ledger, iter_no: 0 })
    }

    /// Join a data-parallel group: every subsequent iteration ends with
    /// the DP gradient All-Reduce over `dp_ep` before the optimizer step.
    pub fn arm_dp(&mut self, dp_ep: Endpoint) {
        self.dp_ep = Some(dp_ep);
    }

    /// Export the optimizer's accumulated state for checkpointing.
    pub fn opt_state(&self) -> OptimizerState {
        self.opt.state()
    }

    /// One forward+backward+update iteration over the local shard.
    /// Returns the rank-local sum of squared errors (pre-scale).
    ///
    /// Uses the FUSED inter-collective segments (pp_fwd_step / pp_loss_step
    /// / pp_bwd_step): every stretch of compute between two collectives is
    /// one backend execution — 7 calls per 2-layer iteration instead of 10
    /// (EXPERIMENTS.md §Perf). The collective schedule is unchanged from
    /// the paper's Table II: one k*batch All-Gather per layer forward, one
    /// k*batch Reduce-Scatter per layer backward.
    ///
    /// Zero-clone hot path: every backend call borrows its inputs, so no
    /// weight, decompressor, bias or retained activation is copied — only
    /// the collectives take (and must take) owned payloads.
    pub fn iteration(&mut self, x_shard: &Tensor, t_shard: &Tensor) -> Result<f64> {
        let layers = self.params.layers();
        let rank = self.params.rank;

        if self.ledger.traced() {
            let name = format!("iter {}", self.iter_no);
            self.ledger.span_begin("iter", &name);
        }

        // ---- forward ----
        self.ledger.span_begin("phase", "forward");
        // ys[l] = post-activation output of layer l; the layer-l input is
        // x_shard for l == 0, else ys[l - 1].
        let mut ys: Vec<Tensor> = Vec::with_capacity(layers);
        let mut zs: Vec<Tensor> = Vec::with_capacity(layers);
        let mut g_alls: Vec<Tensor> = Vec::with_capacity(layers);

        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &self.artifact,
            "pp_fwd_local",
            &[x_shard, &self.params.locals[0], &self.params.compressors[0]],
        )?;
        let [mut z_loc, g]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_local")?;
        let mut g = Some(g);

        for l in 0..layers {
            // The ONLY forward collective (paper Table II, PP row); it
            // consumes g, which the next fused step replaces.
            let mut g_all =
                self.ep.all_gather(g.take().expect("g set each layer"), &mut self.ledger)?;
            g_all.zero_slot(rank);

            if l + 1 < layers {
                // fused: combine(l) + local(l+1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_fwd_step",
                    &[
                        &z_loc,
                        &g_all,
                        &self.params.decompressors[l],
                        &self.params.biases[l],
                        &self.params.locals[l + 1],
                        &self.params.compressors[l + 1],
                    ],
                )?;
                let [y_out, z, z_loc_next, g_next]: [Tensor; 4] =
                    unpack(r.outputs, "pp_fwd_step")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
                z_loc = z_loc_next;
                g = Some(g_next);
            } else {
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_fwd_combine",
                    &[
                        &z_loc,
                        &g_all,
                        &self.params.decompressors[l],
                        &self.params.biases[l],
                    ],
                )?;
                let [y_out, z]: [Tensor; 2] = unpack(r.outputs, "pp_fwd_combine")?;
                ys.push(y_out);
                zs.push(z);
                g_alls.push(g_all);
            }
        }

        // ---- loss + top-layer error compression (fused) ----
        self.ledger.span_end(); // forward
        self.ledger.span_begin("phase", "loss");
        let r = exec_charged(
            &self.exec,
            &mut self.ledger,
            &self.artifact,
            "pp_loss_step",
            &[
                &ys[layers - 1],
                &zs[layers - 1],
                t_shard,
                &self.params.decompressors[layers - 1],
            ],
        )?;
        let [loss_t, delta0, h_out]: [Tensor; 3] = unpack(r.outputs, "pp_loss_step")?;
        let loss_local = loss_t.data()[0] as f64;
        let mut delta = delta0;
        // The ONLY backward collective (paper Table II, PP row).
        let mut h_sum = self.ep.reduce_scatter(h_out, &mut self.ledger)?;

        // ---- backward ----
        self.ledger.span_end(); // loss
        self.ledger.span_begin("phase", "backward");
        let mut grads: Vec<Option<[Tensor; 4]>> = (0..layers).map(|_| None).collect();
        for l in (0..layers).rev() {
            // The layer-l input activation, borrowed (not cloned).
            let y_prev: &Tensor = if l == 0 { x_shard } else { &ys[l - 1] };
            let r = exec_charged(
                &self.exec,
                &mut self.ledger,
                &self.artifact,
                "pp_grads",
                &[y_prev, &delta, &h_sum, &g_alls[l]],
            )?;
            let [dl, dc, dd, db]: [Tensor; 4] = unpack(r.outputs, "pp_grads")?;
            grads[l] = Some([dl, dc, dd, db]);

            if l > 0 {
                // fused: combine(l) + compress(l-1)
                let r = exec_charged(
                    &self.exec,
                    &mut self.ledger,
                    &self.artifact,
                    "pp_bwd_step",
                    &[
                        &delta,
                        &h_sum,
                        &self.params.locals[l],
                        &self.params.compressors[l],
                        &zs[l - 1],
                        &self.params.decompressors[l - 1],
                    ],
                )?;
                let [d, h_out_prev]: [Tensor; 2] = unpack(r.outputs, "pp_bwd_step")?;
                delta = d;
                h_sum = self.ep.reduce_scatter(h_out_prev, &mut self.ledger)?;
            }
        }

        self.ledger.span_end(); // backward

        // ---- DP gradient sync + optimizer step (rank-local compute) ----
        // Order must match `param_shapes`/`named_tensors`: L*, C*, D*, b*.
        // The per-layer arrays are moved out, never cloned.
        let mut dls = Vec::with_capacity(layers);
        let mut dcs = Vec::with_capacity(layers);
        let mut dds = Vec::with_capacity(layers);
        let mut dbs = Vec::with_capacity(layers);
        for g in grads.into_iter() {
            let [dl, dc, dd, db] = g.expect("every layer produced grads");
            dls.push(dl);
            dcs.push(dc);
            dds.push(dd);
            dbs.push(db);
        }
        let mut grad_list = dls;
        grad_list.append(&mut dcs);
        grad_list.append(&mut dds);
        grad_list.append(&mut dbs);
        // Hybrid DP×PP: sum gradients across the data-parallel replicas
        // (one flat All-Reduce, charged to the DpComm bucket) before the
        // identical optimizer step runs on every replica. Outside the
        // optimizer's wall-time window: rendezvous wait must never be
        // charged as compute.
        if let Some(dp) = self.dp_ep.as_mut() {
            super::dp_all_reduce_grads(dp, &mut grad_list, &mut self.ledger)?;
        }
        self.ledger.span_begin("opt", "opt step");
        let t0 = std::time::Instant::now();
        {
            let mut tensors = self.params.named_tensors();
            let mut refs: Vec<&mut Tensor> =
                tensors.iter_mut().map(|(_, t)| &mut **t).collect();
            self.opt.step(&mut refs, &grad_list);
        }
        let opt_s = t0.elapsed().as_secs_f64();
        self.ledger.advance(opt_s, Activity::Compute);
        self.ledger.span_end_with(|| vec![("wall_s", crate::obs::Arg::F(opt_s))]);

        self.ledger.span_end_with(|| vec![("loss_local", crate::obs::Arg::F(loss_local))]);
        self.iter_no += 1;
        Ok(loss_local)
    }
}

pub(crate) fn param_shapes(params: &PhantomRankParams) -> Vec<Vec<usize>> {
    let mut shapes = Vec::new();
    for t in &params.locals {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.compressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.decompressors {
        shapes.push(t.shape().to_vec());
    }
    for t in &params.biases {
        shapes.push(t.shape().to_vec());
    }
    shapes
}

/// Unpack a fixed-arity executable result.
pub(crate) fn unpack<const N: usize>(outputs: Vec<Tensor>, entry: &str) -> Result<[Tensor; N]> {
    if outputs.len() != N {
        bail!("{entry}: expected {N} outputs, got {}", outputs.len());
    }
    Ok(outputs.try_into().map_err(|_| ()).expect("length checked"))
}
