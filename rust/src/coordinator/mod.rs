//! L3 coordinator: the paper's system contribution.
//!
//! A leader (`driver`) spawns one OS thread per rank. Each rank runs the
//! per-iteration phase schedule of its parallelism mode, executing the
//! collective-free compute segments through the configured backend
//! (`runtime::ExecHandle` — native fused kernels by default, PJRT behind
//! the `xla` feature) and the collectives through the in-memory fabric
//! (`comm`), with virtual time / energy tracked by its `EnergyLedger`.
//!
//! Phase schedule per iteration (paper Secs. IV–V, Table II):
//!
//! Phantom (PP), per layer l forward:
//!   1. exec pp_fwd_local        (z_loc = y·L, g = y·C — the L1 hot-spot)
//!   2. All-Gather(g)            message k·batch      <- the only fwd comm
//!   3. zero own slot of g_all   (own-slot convention)
//!   4. exec pp_fwd_combine      (decompress + bias + relu)
//! loss: exec mse_delta (local shard, no collective — loss reporting goes
//! out-of-band to the leader, matching the paper's external monitoring).
//! backward, per layer l (L..1):
//!   5. exec pp_bwd_compress     (h_out[i] = delta·D[i]^T)
//!   6. Reduce-Scatter(h_out)    message k·batch      <- the only bwd comm
//!   7. exec pp_grads            (Eqns. 18-21)
//!   8. exec pp_bwd_combine      (Eqn. 17, skipped below layer 1)
//! optimizer step rank-locally.
//!
//! Tensor-parallel (TP) baseline, per layer l forward:
//!   1. All-Gather(y_shard)      message (n/p)·batch
//!   2. charge Broadcast(n·batch)         (paper's schedule, Table II)
//!   3. exec tp_fwd
//! backward:
//!   4. exec tp_grads
//!   5. exec tp_bwd_partial; All-Reduce(dy_full) message n·batch
//!   6. charge Reduce-Scatter((n/p)·batch) (paper's schedule)
//!   7. slice own shard; exec tp_bwd_finish

pub mod driver;
pub mod rank_pp;
pub mod rank_tp;

pub use driver::{train, train_with, RankReport, TrainOptions, TrainReport};

use crate::comm::Endpoint;
use crate::energy::{Activity, EnergyLedger};
use crate::model::{PhantomRankParams, TpRankParams};
use crate::runtime::{ExecHandle, ExecReply};
use crate::simnet::Collective;
use crate::tensor::Tensor;
use anyhow::Result;

/// The hybrid DP gradient synchronization (DESIGN.md §10): flatten the
/// rank's gradient list into one contiguous payload, All-Reduce it across
/// the data-parallel group (elementwise sum in replica order — the same
/// canonical order every fabric combine uses), and scatter the summed
/// values back into the per-tensor gradients in place. One collective per
/// iteration, message size = the rank's full parameter count, charged to
/// the ledger's DpComm bucket by the DP endpoint. A size-1 group is a
/// no-op: pure model-parallel runs never enter the DP fabric.
///
/// No averaging happens here: every replica computes its gradients with
/// the *global* batch's loss scale baked into the kernels, so the replica
/// sum IS the full-batch gradient.
pub(crate) fn dp_all_reduce_grads(
    dp_ep: &mut Endpoint,
    grads: &mut [Tensor],
    ledger: &mut EnergyLedger,
) -> Result<()> {
    if dp_ep.p == 1 {
        return Ok(());
    }
    let total: usize = grads.iter().map(|g| g.numel()).sum();
    let mut flat = Tensor::zeros(&[total]);
    let mut off = 0;
    for g in grads.iter() {
        flat.data_mut()[off..off + g.numel()].copy_from_slice(g.data());
        off += g.numel();
    }
    let summed = dp_ep.dp_all_reduce(flat, ledger)?;
    let mut off = 0;
    for g in grads.iter_mut() {
        let n = g.numel();
        g.data_mut().copy_from_slice(&summed.data()[off..off + n]);
        off += n;
    }
    Ok(())
}

/// Shared helper: execute a compute segment and charge its wall time to the
/// rank's virtual clock as busy (dynamic-power) time. Inputs are borrowed —
/// weights and activations are never cloned for a call.
pub(crate) fn exec_charged(
    exec: &ExecHandle,
    ledger: &mut EnergyLedger,
    artifact: &str,
    entry: &str,
    inputs: &[&crate::tensor::Tensor],
) -> Result<ExecReply> {
    if !ledger.traced() {
        let reply = exec.execute(artifact, entry, inputs)?;
        ledger.advance(reply.wall_s, Activity::Compute);
        return Ok(reply);
    }
    // Traced: wrap the busy charge in an "exec" span annotated with the
    // GEMM work the native backend did on this thread (tally drained
    // around the call so concurrent ranks can't mix counts).
    let _ = crate::tensor::gemm::tally_take();
    let reply = exec.execute(artifact, entry, inputs)?;
    let tally = crate::tensor::gemm::tally_take();
    let wall_s = reply.wall_s;
    ledger.span_begin("exec", entry);
    ledger.advance(wall_s, Activity::Compute);
    ledger.span_end_with(|| {
        use crate::obs::Arg;
        let mut args = vec![
            ("wall_s", Arg::F(wall_s)),
            ("gemm_calls", Arg::I(tally.calls as i64)),
            ("gemm_flops", Arg::I(tally.flops.min(i64::MAX as u64) as i64)),
            ("max_bands", Arg::I(tally.max_bands as i64)),
            ("isa", Arg::S(crate::tensor::simd::active().name().to_string())),
        ];
        if tally.calls > 0 {
            args.push(("shapes", Arg::S(tally.shape_names().join(","))));
        }
        args
    });
    Ok(reply)
}

/// One phantom-parallel forward pass over this rank's column shard: the
/// training schedule's forward phases only (pp_fwd_local → All-Gather →
/// zero own slot → pp_fwd_combine, per layer). Shared by `driver::infer`,
/// `driver::pp_forward_once`, and the persistent serving pool
/// (`serve::pool`), so every forward consumer drives the identical
/// collective schedule and energy accounting.
pub fn pp_forward_shard(
    exec: &ExecHandle,
    artifact: &str,
    params: &PhantomRankParams,
    ep: &mut Endpoint,
    ledger: &mut EnergyLedger,
    x_shard: Tensor,
) -> Result<Tensor> {
    let mut y = x_shard;
    for l in 0..params.layers() {
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "pp_fwd_local",
            &[&y, &params.locals[l], &params.compressors[l]],
        )?;
        let [z_loc, g]: [Tensor; 2] = rank_pp::unpack(r.outputs, "pp_fwd_local")?;
        // The ONLY forward collective (paper Table II, PP row).
        let mut g_all = ep.all_gather(g, ledger)?;
        g_all.zero_slot(params.rank);
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "pp_fwd_combine",
            &[&z_loc, &g_all, &params.decompressors[l], &params.biases[l]],
        )?;
        let [y_out, _z]: [Tensor; 2] = rank_pp::unpack(r.outputs, "pp_fwd_combine")?;
        y = y_out;
    }
    Ok(y)
}

/// One tensor-parallel forward pass over this rank's column shard
/// (All-Gather → optional paper-schedule Broadcast charge → tp_fwd, per
/// layer). `paper_schedule` charges the Broadcast of the full n·batch
/// activation the paper's TP pipeline issues (Table II).
pub fn tp_forward_shard(
    exec: &ExecHandle,
    artifact: &str,
    params: &TpRankParams,
    ep: &mut Endpoint,
    ledger: &mut EnergyLedger,
    x_shard: Tensor,
    paper_schedule: bool,
) -> Result<Tensor> {
    let n = params.m * params.p;
    let mut y_shard = x_shard;
    for l in 0..params.layers() {
        let batch = y_shard.shape()[0];
        let gathered = ep.all_gather(y_shard, ledger)?;
        let y_full = gathered.concat_shards_stacked()?;
        if paper_schedule {
            ep.charge_modeled(Collective::Broadcast, n * batch, ledger);
        }
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "tp_fwd",
            &[&y_full, &params.weights[l], &params.biases[l]],
        )?;
        let [y_out, _z]: [Tensor; 2] = rank_pp::unpack(r.outputs, "tp_fwd")?;
        y_shard = y_out;
    }
    Ok(y_shard)
}

/// Control-plane messages between ranks and the leader. The loss report /
/// continue-decision travel out-of-band (host-side), mirroring the paper's
/// external monitoring script; they are not charged to the device ledgers.
#[derive(Debug)]
pub(crate) struct LossReport {
    pub rank: usize,
    pub iter: u64,
    /// Rank-local sum of squared errors (pre-scale).
    pub loss_local: f64,
}
