//! L3 coordinator: the paper's system contribution.
//!
//! A leader (`driver`) spawns one OS thread per rank. Each rank runs the
//! per-iteration phase schedule of its parallelism mode, executing the
//! collective-free compute segments through the configured backend
//! (`runtime::ExecHandle` — native fused kernels by default, PJRT behind
//! the `xla` feature) and the collectives through the in-memory fabric
//! (`comm`), with virtual time / energy tracked by its `EnergyLedger`.
//!
//! Phase schedule per iteration (paper Secs. IV–V, Table II):
//!
//! Phantom (PP), per layer l forward:
//!   1. exec pp_fwd_local        (z_loc = y·L, g = y·C — the L1 hot-spot)
//!   2. All-Gather(g)            message k·batch      <- the only fwd comm
//!   3. zero own slot of g_all   (own-slot convention)
//!   4. exec pp_fwd_combine      (decompress + bias + relu)
//! loss: exec mse_delta (local shard, no collective — loss reporting goes
//! out-of-band to the leader, matching the paper's external monitoring).
//! backward, per layer l (L..1):
//!   5. exec pp_bwd_compress     (h_out[i] = delta·D[i]^T)
//!   6. Reduce-Scatter(h_out)    message k·batch      <- the only bwd comm
//!   7. exec pp_grads            (Eqns. 18-21)
//!   8. exec pp_bwd_combine      (Eqn. 17, skipped below layer 1)
//! optimizer step rank-locally.
//!
//! Tensor-parallel (TP) baseline, per layer l forward:
//!   1. All-Gather(y_shard)      message (n/p)·batch
//!   2. charge Broadcast(n·batch)         (paper's schedule, Table II)
//!   3. exec tp_fwd
//! backward:
//!   4. exec tp_grads
//!   5. exec tp_bwd_partial; All-Reduce(dy_full) message n·batch
//!   6. charge Reduce-Scatter((n/p)·batch) (paper's schedule)
//!   7. slice own shard; exec tp_bwd_finish

pub mod driver;
pub mod rank_pp;
pub mod rank_tp;

pub use driver::{train, train_with, RankReport, TrainOptions, TrainReport};

use crate::comm::Endpoint;
use crate::energy::{Activity, EnergyLedger};
use crate::model::{PhantomRankParams, TpRankParams};
use crate::runtime::{ExecHandle, ExecReply};
use crate::simnet::Collective;
use crate::tensor::Tensor;
use anyhow::Result;

/// The hybrid DP gradient synchronization (DESIGN.md §10): flatten the
/// rank's gradient list into one contiguous payload, All-Reduce it across
/// the data-parallel group (elementwise sum in replica order — the same
/// canonical order every fabric combine uses), and scatter the summed
/// values back into the per-tensor gradients in place. One collective per
/// iteration, message size = the rank's full parameter count, charged to
/// the ledger's DpComm bucket by the DP endpoint. A size-1 group is a
/// no-op: pure model-parallel runs never enter the DP fabric.
///
/// No averaging happens here: every replica computes its gradients with
/// the *global* batch's loss scale baked into the kernels, so the replica
/// sum IS the full-batch gradient.
pub(crate) fn dp_all_reduce_grads(
    dp_ep: &mut Endpoint,
    grads: &mut [Tensor],
    ledger: &mut EnergyLedger,
) -> Result<()> {
    if dp_ep.p == 1 {
        return Ok(());
    }
    let total: usize = grads.iter().map(|g| g.numel()).sum();
    let mut flat = Tensor::zeros(&[total]);
    let mut off = 0;
    for g in grads.iter() {
        flat.data_mut()[off..off + g.numel()].copy_from_slice(g.data());
        off += g.numel();
    }
    let summed = dp_ep.dp_all_reduce(flat, ledger)?;
    let mut off = 0;
    for g in grads.iter_mut() {
        let n = g.numel();
        g.data_mut().copy_from_slice(&summed.data()[off..off + n]);
        off += n;
    }
    Ok(())
}

/// ZeRO-1 flat-slice algebra (DESIGN.md §15): the helpers that carve a
/// rank's parameter/gradient list into the dp-rank-owned slices the
/// sharded optimizer path reduce-scatters, updates, and all-gathers.
///
/// The layout is the same flat concatenation `dp_all_reduce_grads` uses,
/// zero-padded to `dp * slot` and viewed as `[dp, slot]`: replica d owns
/// flat range `[d*slot, (d+1)*slot)`. Because the fabric's Reduce-Scatter
/// sums slot j across ranks in the SAME rank order as All-Reduce sums the
/// whole payload, the owned slice of a reduce-scattered gradient is
/// bitwise equal to the matching slice of the all-reduced gradient — which
/// is what makes the sharded optimizer update bit-identical to the flat
/// path (the optimizers are elementwise with a fixed scalar order, and the
/// zero pad is inert under all of them).
///
/// Public (not pub(crate)) so `tests/fabric_props.rs` can drive the ragged
/// tiling and round-trip properties directly.
pub mod zero {
    use crate::tensor::Tensor;

    /// Owned slice length per replica: `ceil(total / dp)` (the last
    /// replica's tail is zero padding when `dp` does not divide `total`).
    pub fn slot_len(total: usize, dp: usize) -> usize {
        assert!(dp >= 1);
        total.div_ceil(dp)
    }

    /// Flatten a tensor list into one contiguous `[total]` payload
    /// (the `dp_all_reduce_grads` concatenation order).
    pub fn flatten(tensors: &[Tensor]) -> Tensor {
        let total: usize = tensors.iter().map(|t| t.numel()).sum();
        let mut flat = Tensor::zeros(&[total]);
        let mut off = 0;
        for t in tensors {
            flat.data_mut()[off..off + t.numel()].copy_from_slice(t.data());
            off += t.numel();
        }
        flat
    }

    /// View a flat `[total]` payload as the `[dp, slot]` stack the fabric's
    /// Reduce-Scatter consumes, zero-padding the tail.
    pub fn pad_stack(flat: &Tensor, dp: usize) -> Tensor {
        let total = flat.numel();
        let slot = slot_len(total, dp);
        let mut stacked = Tensor::zeros(&[dp, slot]);
        stacked.data_mut()[..total].copy_from_slice(flat.data());
        stacked
    }

    /// Scatter a flat payload back into the tensor list it was flattened
    /// from (inverse of `flatten`; `flat` may carry trailing padding).
    pub fn unflatten_into(flat: &Tensor, tensors: &mut [&mut Tensor]) {
        let mut off = 0;
        for t in tensors.iter_mut() {
            let n = t.numel();
            t.data_mut().copy_from_slice(&flat.data()[off..off + n]);
            off += n;
        }
        debug_assert!(off <= flat.numel());
    }

    /// Copy the `[start, start+len)` window of the flat view of `tensors`
    /// into an owned `[len]` tensor, zero-padding past the end — the
    /// replica's owned parameter slice the sharded optimizer steps on.
    pub fn read_slice(tensors: &[&mut Tensor], start: usize, len: usize) -> Tensor {
        let mut out = Tensor::zeros(&[len]);
        let mut off = 0usize; // flat offset of the current tensor
        for t in tensors.iter() {
            let n = t.numel();
            let lo = start.max(off);
            let hi = (start + len).min(off + n);
            if lo < hi {
                out.data_mut()[lo - start..hi - start]
                    .copy_from_slice(&t.data()[lo - off..hi - off]);
            }
            off += n;
        }
        out
    }
}

/// The end-of-iteration tail shared by both rank loops: DP gradient
/// synchronization followed by the optimizer step, with the step's real
/// wall time charged to the virtual clock as busy compute.
///
/// * Flat path (`sharded_slot == None`, or no DP group): the PR 5
///   schedule, byte-identical — one flat `dp_all_reduce`, then the full
///   optimizer step on every replica.
/// * ZeRO-1 path (`sharded_slot == Some(slot)`, DP group of size > 1):
///   Reduce-Scatter the flat gradient (each replica receives the summed
///   gradient for its owned slice only), step a slice-sized optimizer on
///   an owned copy of the parameter slice, then All-Gather the updated
///   slices and scatter the full parameter vector back. Optimizer moments
///   exist only for the owned slice (~1/dp of the flat footprint); both
///   collectives are charged to the DpComm bucket by the DP endpoint.
pub(crate) fn dp_sync_and_step(
    dp_ep: &mut Option<Endpoint>,
    sharded_slot: Option<usize>,
    opt: &mut crate::train::Optimizer,
    params: &mut [&mut Tensor],
    mut grad_list: Vec<Tensor>,
    ledger: &mut EnergyLedger,
) -> Result<()> {
    let sharded = match (dp_ep.as_ref(), sharded_slot) {
        (Some(dp), Some(_)) if dp.p > 1 => true,
        _ => false,
    };
    if !sharded {
        if let Some(dp) = dp_ep.as_mut() {
            dp_all_reduce_grads(dp, &mut grad_list, ledger)?;
        }
        ledger.span_begin("opt", "opt step");
        let t0 = std::time::Instant::now();
        opt.step(params, &grad_list);
        let opt_s = t0.elapsed().as_secs_f64();
        ledger.advance(opt_s, Activity::Compute);
        ledger.span_end_with(|| vec![("wall_s", crate::obs::Arg::F(opt_s))]);
        for g in grad_list {
            g.recycle(); // dead gradients feed the next iteration's kernels
        }
        return Ok(());
    }
    let dp = dp_ep.as_mut().expect("sharded implies a DP group");
    let slot = sharded_slot.expect("sharded implies a slot length");
    let d = dp.rank;
    debug_assert_eq!(slot, zero::slot_len(params.iter().map(|t| t.numel()).sum(), dp.p));

    // Reduce-Scatter the flat gradient: replica d receives the summed
    // gradient for its owned slice, in the all-reduce fold order.
    let flat = zero::flatten(&grad_list);
    for g in grad_list {
        g.recycle();
    }
    let total = flat.numel();
    let own_grad = dp.dp_reduce_scatter(zero::pad_stack(&flat, dp.p), ledger)?;
    flat.recycle();

    // Slice-local optimizer step on an owned copy of the parameter slice.
    let mut own_params = zero::read_slice(params, d * slot, slot);
    ledger.span_begin("opt", "opt step");
    let t0 = std::time::Instant::now();
    opt.step(&mut [&mut own_params], std::slice::from_ref(&own_grad));
    let opt_s = t0.elapsed().as_secs_f64();
    ledger.advance(opt_s, Activity::Compute);
    ledger.span_end_with(|| vec![("wall_s", crate::obs::Arg::F(opt_s))]);

    // All-Gather the updated slices and write the full vector back.
    let gathered = dp.dp_all_gather(own_params, ledger)?;
    debug_assert_eq!(gathered.numel(), dp.p * slot);
    debug_assert!(gathered.numel() >= total);
    zero::unflatten_into(&gathered, params);
    own_grad.recycle();
    gathered.recycle();
    Ok(())
}

/// Shared helper: execute a compute segment and charge its wall time to the
/// rank's virtual clock as busy (dynamic-power) time. Inputs are borrowed —
/// weights and activations are never cloned for a call.
pub(crate) fn exec_charged(
    exec: &ExecHandle,
    ledger: &mut EnergyLedger,
    artifact: &str,
    entry: &str,
    inputs: &[&crate::tensor::Tensor],
) -> Result<ExecReply> {
    if !ledger.traced() {
        let reply = exec.execute(artifact, entry, inputs)?;
        ledger.advance(reply.wall_s, Activity::Compute);
        return Ok(reply);
    }
    // Traced: wrap the busy charge in an "exec" span annotated with the
    // GEMM work the native backend did on this thread (tally drained
    // around the call so concurrent ranks can't mix counts).
    let _ = crate::tensor::gemm::tally_take();
    let reply = exec.execute(artifact, entry, inputs)?;
    let tally = crate::tensor::gemm::tally_take();
    let wall_s = reply.wall_s;
    ledger.span_begin("exec", entry);
    ledger.advance(wall_s, Activity::Compute);
    ledger.span_end_with(|| {
        use crate::obs::Arg;
        let mut args = vec![
            ("wall_s", Arg::F(wall_s)),
            ("gemm_calls", Arg::I(tally.calls as i64)),
            ("gemm_flops", Arg::I(tally.flops.min(i64::MAX as u64) as i64)),
            ("max_bands", Arg::I(tally.max_bands as i64)),
            ("isa", Arg::S(crate::tensor::simd::active().name().to_string())),
        ];
        if tally.calls > 0 {
            args.push(("shapes", Arg::S(tally.shape_names().join(","))));
        }
        args
    });
    Ok(reply)
}

/// One phantom-parallel forward pass over this rank's column shard: the
/// training schedule's forward phases only (pp_fwd_local → All-Gather →
/// zero own slot → pp_fwd_combine, per layer). Shared by `driver::infer`,
/// `driver::pp_forward_once`, and the persistent serving pool
/// (`serve::pool`), so every forward consumer drives the identical
/// collective schedule and energy accounting.
pub fn pp_forward_shard(
    exec: &ExecHandle,
    artifact: &str,
    params: &PhantomRankParams,
    ep: &mut Endpoint,
    ledger: &mut EnergyLedger,
    x_shard: Tensor,
) -> Result<Tensor> {
    let mut y = x_shard;
    for l in 0..params.layers() {
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "pp_fwd_local",
            &[&y, &params.locals[l], &params.compressors[l]],
        )?;
        let [z_loc, g]: [Tensor; 2] = rank_pp::unpack(r.outputs, "pp_fwd_local")?;
        // The ONLY forward collective (paper Table II, PP row).
        let mut g_all = ep.all_gather(g, ledger)?;
        g_all.zero_slot(params.rank);
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "pp_fwd_combine",
            &[&z_loc, &g_all, &params.decompressors[l], &params.biases[l]],
        )?;
        let [y_out, _z]: [Tensor; 2] = rank_pp::unpack(r.outputs, "pp_fwd_combine")?;
        y = y_out;
    }
    Ok(y)
}

/// One tensor-parallel forward pass over this rank's column shard
/// (All-Gather → optional paper-schedule Broadcast charge → tp_fwd, per
/// layer). `paper_schedule` charges the Broadcast of the full n·batch
/// activation the paper's TP pipeline issues (Table II).
pub fn tp_forward_shard(
    exec: &ExecHandle,
    artifact: &str,
    params: &TpRankParams,
    ep: &mut Endpoint,
    ledger: &mut EnergyLedger,
    x_shard: Tensor,
    paper_schedule: bool,
) -> Result<Tensor> {
    let n = params.m * params.p;
    let mut y_shard = x_shard;
    for l in 0..params.layers() {
        let batch = y_shard.shape()[0];
        let gathered = ep.all_gather(y_shard, ledger)?;
        let y_full = gathered.concat_shards_stacked()?;
        if paper_schedule {
            ep.charge_modeled(Collective::Broadcast, n * batch, ledger);
        }
        let r = exec_charged(
            exec,
            ledger,
            artifact,
            "tp_fwd",
            &[&y_full, &params.weights[l], &params.biases[l]],
        )?;
        let [y_out, _z]: [Tensor; 2] = rank_pp::unpack(r.outputs, "tp_fwd")?;
        y_shard = y_out;
    }
    Ok(y_shard)
}

/// Control-plane messages between ranks and the leader. The loss report /
/// continue-decision travel out-of-band (host-side), mirroring the paper's
/// external monitoring script; they are not charged to the device ledgers.
#[derive(Debug)]
pub(crate) struct LossReport {
    pub rank: usize,
    pub iter: u64,
    /// Rank-local sum of squared errors (pre-scale).
    pub loss_local: f64,
}
