//! Elastic checkpoint & recovery subsystem (DESIGN.md §8).
//!
//! Three layers:
//!
//! * **Format + I/O** (`io`, this module): a versioned on-disk snapshot —
//!   `manifest.json` + one framed, checksummed binary shard file per rank —
//!   capturing model weights, optimizer moments + step count, train
//!   progress (iteration, loss history, run-level PRNG state) and the
//!   `RunConfig` that produced it. Writes are atomic (temp dir + rename);
//!   loads verify whole-file and per-record checksums. Round-trips at both
//!   rank (`load_rank`) and whole-model (`load`) granularity.
//! * **Re-sharding** (`reshard`): gather the logical parameters out of any
//!   (p, TP|PP) layout and re-slice them into any other — TP column
//!   re-sharding, exact PP block-merge down-scaling, and TP→PP
//!   dense-phantom conversion. See reshard.rs for the algebra.
//! * **Integration**: `coordinator::driver::train_with` writes periodic
//!   snapshots and resumes bit-identically; `serve::RankPool::load_weights`
//!   hot-swaps a running pool onto a snapshot between batches; the
//!   `phantom ckpt` CLI exposes inspect/reshard/verify.
//!
//! Snapshotting is host-side control plane (like loss aggregation): it is
//! not charged to the device ledgers.

pub mod io;
pub mod reshard;

pub use reshard::{collapse_dp, reshard};

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Parallelism, RunConfig};
use crate::model::{
    assemble_tp_dense, tp_dense_forward, DensePhantomOracle, PhantomRankParams, TpRankParams,
};
use crate::tensor::Tensor;
use crate::train::OptimizerState;
use crate::util::json::{read_json, Json};
use crate::util::prng::{Prng, PrngState};

/// On-disk format version (manifest `version` field).
pub const VERSION: i64 = 1;

/// The run-level PRNG stream: advanced once per training iteration by the
/// driver and captured in every snapshot, so any future run-level
/// stochasticity resumes bit-identically. `Prng::from_state` restores it.
pub fn run_stream(seed: u64) -> Prng {
    Prng::new(seed ^ 0x52554E) // "RUN"
}

/// One rank's model parameters, either parallelism mode.
#[derive(Debug, Clone)]
pub enum RankParams {
    Phantom(PhantomRankParams),
    Tensor(TpRankParams),
}

impl RankParams {
    pub fn mode(&self) -> Parallelism {
        match self {
            RankParams::Phantom(_) => Parallelism::Phantom,
            RankParams::Tensor(_) => Parallelism::Tensor,
        }
    }

    /// Named tensors in the canonical serialization order (matches
    /// `named_tensors` / the optimizer's parameter order).
    fn named(&self) -> Vec<(String, &Tensor)> {
        let mut out = Vec::new();
        match self {
            RankParams::Phantom(p) => {
                for (i, t) in p.locals.iter().enumerate() {
                    out.push((format!("L{i}"), t));
                }
                for (i, t) in p.compressors.iter().enumerate() {
                    out.push((format!("C{i}"), t));
                }
                for (i, t) in p.decompressors.iter().enumerate() {
                    out.push((format!("D{i}"), t));
                }
                for (i, t) in p.biases.iter().enumerate() {
                    out.push((format!("b{i}"), t));
                }
            }
            RankParams::Tensor(p) => {
                for (i, t) in p.weights.iter().enumerate() {
                    out.push((format!("W{i}"), t));
                }
                for (i, t) in p.biases.iter().enumerate() {
                    out.push((format!("b{i}"), t));
                }
            }
        }
        out
    }
}

/// One rank's complete checkpointable state.
#[derive(Debug, Clone)]
pub struct RankShard {
    pub rank: usize,
    pub params: RankParams,
    /// `None` = fresh optimizer on restore. Re-sharding drops moments (they
    /// have no meaning across a layout change).
    pub opt: Option<OptimizerState>,
}

/// Where training stood when the snapshot was taken.
#[derive(Debug, Clone)]
pub struct TrainProgress {
    /// Completed iterations (also the length of `losses`).
    pub iter: u64,
    /// Full global-loss history from iteration 0 — replayed through the
    /// `LossTracker` on resume so the stopping rule continues exactly.
    pub losses: Vec<f64>,
    /// Run-level PRNG state (see `run_stream`).
    pub prng: PrngState,
}

impl TrainProgress {
    /// Progress of a never-trained snapshot for `seed`.
    pub fn fresh(seed: u64) -> TrainProgress {
        TrainProgress { iter: 0, losses: Vec::new(), prng: run_stream(seed).state() }
    }
}

/// A complete model snapshot: config + progress + one shard per rank.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub config: RunConfig,
    pub progress: TrainProgress,
    pub shards: Vec<RankShard>,
}

impl Snapshot {
    pub fn mode(&self) -> Parallelism {
        self.config.mode
    }

    pub fn p(&self) -> usize {
        self.config.p
    }

    pub fn n(&self) -> usize {
        self.config.model.n
    }

    pub fn k(&self) -> usize {
        self.config.model.k
    }

    pub fn layers(&self) -> usize {
        self.config.model.layers
    }

    /// Data-parallel replica count of this snapshot (1 for pure layouts;
    /// hybrid snapshots carry p * dp shards in world-rank order).
    pub fn dp(&self) -> usize {
        self.config.dp.max(1)
    }

    /// Build the snapshot of a freshly initialized (untrained) model —
    /// deterministic from the config, exactly the state training starts
    /// from. Useful for re-sharding demos and tests without a train run.
    /// Hybrid configs (dp > 1) produce one shard per world rank; replicas
    /// of a model rank are identical, as training keeps them.
    pub fn init(config: &RunConfig) -> Result<Snapshot> {
        let world = config.p * config.dp.max(1);
        let mut shards = Vec::with_capacity(world);
        for rank in 0..world {
            let model_rank = rank % config.p;
            let params = match config.mode {
                Parallelism::Phantom => RankParams::Phantom(PhantomRankParams::init(
                    &config.model,
                    config.p,
                    model_rank,
                    config.train.seed,
                )?),
                Parallelism::Tensor => RankParams::Tensor(TpRankParams::init(
                    &config.model,
                    config.p,
                    model_rank,
                    config.train.seed,
                )?),
            };
            shards.push(RankShard { rank, params, opt: None });
        }
        let snap = Snapshot {
            config: config.clone(),
            progress: TrainProgress::fresh(config.train.seed),
            shards,
        };
        snap.validate()?;
        Ok(snap)
    }

    /// Structural validation: one shard per world rank in order, every
    /// tensor shaped for this (p, n, k, layers), own decompressor slots
    /// zero (at the shard's MODEL rank — hybrid shards repeat the model
    /// geometry once per DP replica). Deliberately more permissive than
    /// `RunConfig::validate` in exactly one place: phantom k may equal n/p
    /// (the dense-phantom layout that TP→PP re-sharding produces).
    pub fn validate(&self) -> Result<()> {
        let (p, n, layers) = (self.config.p, self.config.model.n, self.config.model.layers);
        let dp = self.config.dp;
        if p == 0 || dp == 0 || n == 0 || layers == 0 {
            bail!("snapshot geometry must be positive (p={p}, dp={dp}, n={n}, layers={layers})");
        }
        if n % p != 0 {
            bail!("n={n} not divisible by p={p}");
        }
        let m = n / p;
        let world = p * dp;
        if self.shards.len() != world {
            bail!("{} shards for p={p} x dp={dp}", self.shards.len());
        }
        if self.progress.losses.len() as u64 != self.progress.iter {
            bail!(
                "progress: {} losses for {} completed iterations",
                self.progress.losses.len(),
                self.progress.iter
            );
        }
        for (i, s) in self.shards.iter().enumerate() {
            let model_rank = i % p;
            if s.rank != i {
                bail!("shard {i} claims rank {}", s.rank);
            }
            if s.params.mode() != self.config.mode {
                bail!("shard {i} mode {:?} vs config {:?}", s.params.mode(), self.config.mode);
            }
            if let Some(opt) = &s.opt {
                if opt.kind() != self.config.train.optimizer.name() {
                    bail!(
                        "shard {i} optimizer state '{}' vs config '{}'",
                        opt.kind(),
                        self.config.train.optimizer.name()
                    );
                }
            }
            match &s.params {
                RankParams::Phantom(ps) => {
                    let k = self.config.model.k;
                    if k == 0 || k > m {
                        bail!("phantom k={k} outside 1..={m}");
                    }
                    if ps.p != p || ps.m != m || ps.k != k || ps.layers() != layers {
                        bail!("shard {i}: phantom geometry mismatch");
                    }
                    if ps.rank != model_rank {
                        bail!("shard {i}: params claim model rank {} (want {model_rank})", ps.rank);
                    }
                    for l in 0..layers {
                        check_shape("L", i, l, &ps.locals[l], &[m, m])?;
                        check_shape("C", i, l, &ps.compressors[l], &[m, k])?;
                        check_shape("D", i, l, &ps.decompressors[l], &[p, k, m])?;
                        check_shape("b", i, l, &ps.biases[l], &[m])?;
                        let own = ps.decompressors[l].unstack_at(model_rank);
                        if own.data().iter().any(|&x| x != 0.0) {
                            bail!("shard {i} layer {l}: frozen own decompressor slot is nonzero");
                        }
                    }
                }
                RankParams::Tensor(ts) => {
                    if ts.p != p || ts.m != m || ts.layers() != layers {
                        bail!("shard {i}: tp geometry mismatch");
                    }
                    if ts.rank != model_rank {
                        bail!("shard {i}: params claim model rank {} (want {model_rank})", ts.rank);
                    }
                    for l in 0..layers {
                        check_shape("W", i, l, &ts.weights[l], &[n, m])?;
                        check_shape("b", i, l, &ts.biases[l], &[m])?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Host-side forward of the whole snapshot on `x` [B, n] — the
    /// backend-free reference used by `phantom ckpt verify` and the
    /// re-sharding equivalence proofs. Hybrid snapshots forward replica 0
    /// (DP replicas are weight-identical copies of the same model).
    pub fn forward_host(&self, x: &Tensor) -> Result<Tensor> {
        self.validate()?;
        let replica0 = &self.shards[..self.config.p];
        match self.config.mode {
            Parallelism::Phantom => {
                let ranks: Vec<PhantomRankParams> = replica0
                    .iter()
                    .map(|s| match &s.params {
                        RankParams::Phantom(p) => p.clone(),
                        RankParams::Tensor(_) => unreachable!("validated phantom"),
                    })
                    .collect();
                DensePhantomOracle::from_ranks(ranks)?.forward(x)
            }
            Parallelism::Tensor => {
                let shards: Vec<TpRankParams> = replica0
                    .iter()
                    .map(|s| match &s.params {
                        RankParams::Tensor(t) => t.clone(),
                        RankParams::Phantom(_) => unreachable!("validated tp"),
                    })
                    .collect();
                let (weights, biases) = assemble_tp_dense(&shards)?;
                tp_dense_forward(&weights, &biases, x)
            }
        }
    }

    // -- persistence -------------------------------------------------------

    /// Write the snapshot atomically into `dir` (created; an existing
    /// snapshot of the same name is replaced only after the new one is
    /// fully on disk).
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.validate()?;
        io::atomic_write_dir(dir, |tmp| {
            let mut shard_entries = Vec::with_capacity(self.shards.len());
            for shard in &self.shards {
                let file = shard_file_name(shard.rank);
                let mut records = shard.params.named();
                let opt_meta = append_opt_records(&mut records, &shard.opt);
                let bytes = io::encode_records(&records);
                std::fs::write(tmp.join(&file), &bytes)
                    .with_context(|| format!("writing shard {file}"))?;
                let mut entry = vec![
                    ("rank", Json::int(shard.rank as i64)),
                    ("file", Json::str(file.clone())),
                    ("bytes", Json::int(bytes.len() as i64)),
                    ("fnv", Json::str(io::u64_to_hex(io::fnv1a64(&bytes)))),
                    ("tensors", Json::int(records.len() as i64)),
                ];
                entry.extend(opt_meta);
                shard_entries.push(Json::obj(entry));
            }
            let manifest = Json::obj(vec![
                ("version", Json::int(VERSION)),
                ("kind", Json::str("phantom-snapshot")),
                ("config", self.config.to_json()),
                (
                    "progress",
                    Json::obj(vec![
                        ("iter", Json::int(self.progress.iter as i64)),
                        (
                            "losses",
                            Json::arr(self.progress.losses.iter().map(|&l| Json::num(l)).collect()),
                        ),
                        ("prng_state", Json::str(io::u64_to_hex(self.progress.prng.state))),
                        (
                            "prng_spare",
                            self.progress.prng.spare_normal.map(Json::num).unwrap_or(Json::Null),
                        ),
                    ]),
                ),
                ("shards", Json::arr(shard_entries)),
            ]);
            std::fs::write(tmp.join("manifest.json"), manifest.pretty())
                .context("writing manifest.json")?;
            Ok(())
        })
        .with_context(|| format!("saving snapshot to {}", dir.display()))
    }

    /// Load a full snapshot, verifying every checksum.
    pub fn load(dir: &Path) -> Result<Snapshot> {
        let (config, progress, entries) = load_manifest(dir)?;
        let mut shards = Vec::with_capacity(entries.len());
        for e in &entries {
            shards.push(load_shard(dir, &config, e)?);
        }
        let snap = Snapshot { config, progress, shards };
        snap.validate().with_context(|| format!("snapshot {} is invalid", dir.display()))?;
        Ok(snap)
    }

    /// Load a single rank's shard (manifest + that rank's file only) — the
    /// rank-granular half of the round-trip contract, for workers that must
    /// not materialize the whole model.
    pub fn load_rank(dir: &Path, rank: usize) -> Result<RankShard> {
        let (config, _, entries) = load_manifest(dir)?;
        let e = entries
            .iter()
            .find(|e| e.rank == rank)
            .ok_or_else(|| anyhow!("snapshot has no shard for rank {rank}"))?;
        load_shard(dir, &config, e)
    }
}

fn check_shape(name: &str, rank: usize, layer: usize, t: &Tensor, want: &[usize]) -> Result<()> {
    if t.shape() != want {
        bail!("shard {rank} layer {layer}: {name} shaped {:?}, want {:?}", t.shape(), want);
    }
    Ok(())
}

fn shard_file_name(rank: usize) -> String {
    format!("rank-{rank:04}.bin")
}

/// Append the optimizer moments as `opt.*` records; returns the manifest
/// metadata fields describing the state.
fn append_opt_records<'a>(
    records: &mut Vec<(String, &'a Tensor)>,
    opt: &'a Option<OptimizerState>,
) -> Vec<(&'static str, Json)> {
    match opt {
        None => vec![("opt", Json::str("none"))],
        Some(OptimizerState::Sgd) => vec![("opt", Json::str("sgd"))],
        Some(OptimizerState::Momentum { velocity }) => {
            for (i, t) in velocity.iter().enumerate() {
                records.push((format!("opt.v.{i}"), t));
            }
            vec![
                ("opt", Json::str("momentum")),
                ("opt_params", Json::int(velocity.len() as i64)),
            ]
        }
        Some(OptimizerState::Adam { t, m, v }) => {
            for (i, x) in m.iter().enumerate() {
                records.push((format!("opt.m.{i}"), x));
            }
            for (i, x) in v.iter().enumerate() {
                records.push((format!("opt.v.{i}"), x));
            }
            vec![
                ("opt", Json::str("adam")),
                ("opt_t", Json::int(*t as i64)),
                ("opt_params", Json::int(m.len() as i64)),
            ]
        }
    }
}

/// A parsed manifest shard entry.
struct ShardEntry {
    rank: usize,
    file: String,
    bytes: u64,
    fnv: u64,
    opt: String,
    /// Adam step count; required (not defaulted) when `opt == "adam"` so a
    /// damaged manifest fails the load instead of silently resetting t.
    opt_t: Option<u64>,
    /// Moment tensors per moment array. Defaults to the mode-derived
    /// per-parameter count (4·layers PP / 2·layers TP) when absent —
    /// ZeRO-sharded states carry exactly 1 flat slice tensor instead.
    opt_params: Option<usize>,
}

fn load_manifest(dir: &Path) -> Result<(RunConfig, TrainProgress, Vec<ShardEntry>)> {
    let path = dir.join("manifest.json");
    let j = read_json(&path).with_context(|| format!("reading {}", path.display()))?;
    let version = j.get("version").as_i64().unwrap_or(0);
    if version != VERSION {
        bail!("unsupported snapshot version {version} (want {VERSION})");
    }
    if j.get("kind").as_str() != Some("phantom-snapshot") {
        bail!("{} is not a phantom snapshot manifest", path.display());
    }
    let config = RunConfig::from_json_unchecked(j.get("config")).context("manifest config")?;
    let pj = j.get("progress");
    let losses: Vec<f64> = pj
        .get("losses")
        .as_arr()
        .context("manifest progress.losses")?
        .iter()
        .map(|l| l.as_f64().context("loss entry"))
        .collect::<Result<_>>()?;
    let prng_state = pj.get("prng_state").as_str().context("progress.prng_state")?;
    let progress = TrainProgress {
        iter: pj.get("iter").as_i64().context("progress.iter")? as u64,
        losses,
        prng: PrngState {
            state: io::u64_from_hex(prng_state)?,
            spare_normal: pj.get("prng_spare").as_f64(),
        },
    };
    let mut entries = Vec::new();
    for e in j.get("shards").as_arr().context("manifest shards[]")?.iter() {
        entries.push(ShardEntry {
            rank: e.get("rank").as_usize().context("shard rank")?,
            file: e.get("file").as_str().context("shard file")?.to_string(),
            bytes: e.get("bytes").as_i64().context("shard bytes")? as u64,
            fnv: io::u64_from_hex(e.get("fnv").as_str().context("shard fnv")?)?,
            opt: e.get("opt").as_str().unwrap_or("none").to_string(),
            opt_t: e.get("opt_t").as_i64().map(|v| v as u64),
            opt_params: e.get("opt_params").as_i64().map(|v| v as usize),
        });
    }
    Ok((config, progress, entries))
}

fn load_shard(dir: &Path, config: &RunConfig, e: &ShardEntry) -> Result<RankShard> {
    if e.file.contains('/') || e.file.contains("..") {
        bail!("shard file name '{}' escapes the snapshot directory", e.file);
    }
    // Param structs carry the MODEL rank (hybrid world ranks repeat the
    // model geometry once per DP replica; for dp = 1 they coincide).
    let model_rank = e.rank % config.p.max(1);
    let records = io::read_shard_file(&dir.join(&e.file), e.bytes, e.fnv)?;
    let mut map: std::collections::BTreeMap<String, Tensor> = records.into_iter().collect();
    let mut take = |name: &str| -> Result<Tensor> {
        map.remove(name).ok_or_else(|| anyhow!("shard {}: missing tensor '{name}'", e.rank))
    };
    let layers = config.model.layers;
    let params = match config.mode {
        Parallelism::Phantom => {
            let mut locals = Vec::with_capacity(layers);
            let mut compressors = Vec::with_capacity(layers);
            let mut decompressors = Vec::with_capacity(layers);
            let mut biases = Vec::with_capacity(layers);
            for l in 0..layers {
                locals.push(take(&format!("L{l}"))?);
                compressors.push(take(&format!("C{l}"))?);
                decompressors.push(take(&format!("D{l}"))?);
                biases.push(take(&format!("b{l}"))?);
            }
            RankParams::Phantom(PhantomRankParams {
                rank: model_rank,
                p: config.p,
                m: config.model.n / config.p,
                k: config.model.k,
                locals,
                compressors,
                decompressors,
                biases,
            })
        }
        Parallelism::Tensor => {
            let mut weights = Vec::with_capacity(layers);
            let mut biases = Vec::with_capacity(layers);
            for l in 0..layers {
                weights.push(take(&format!("W{l}"))?);
                biases.push(take(&format!("b{l}"))?);
            }
            RankParams::Tensor(TpRankParams {
                rank: model_rank,
                p: config.p,
                m: config.model.n / config.p,
                weights,
                biases,
            })
        }
    };
    let n_params = e.opt_params.unwrap_or(match &params {
        RankParams::Phantom(_) => 4 * layers,
        RankParams::Tensor(_) => 2 * layers,
    });
    let opt = match e.opt.as_str() {
        "none" => None,
        "sgd" => Some(OptimizerState::Sgd),
        "momentum" => {
            let mut velocity = Vec::with_capacity(n_params);
            for i in 0..n_params {
                velocity.push(take(&format!("opt.v.{i}"))?);
            }
            Some(OptimizerState::Momentum { velocity })
        }
        "adam" => {
            let t = e
                .opt_t
                .ok_or_else(|| anyhow!("shard {}: adam state is missing opt_t", e.rank))?;
            let mut m = Vec::with_capacity(n_params);
            let mut v = Vec::with_capacity(n_params);
            for i in 0..n_params {
                m.push(take(&format!("opt.m.{i}"))?);
            }
            for i in 0..n_params {
                v.push(take(&format!("opt.v.{i}"))?);
            }
            Some(OptimizerState::Adam { t, m, v })
        }
        other => bail!("shard {}: unknown optimizer state kind '{other}'", e.rank),
    };
    if let Some((name, _)) = map.into_iter().next() {
        bail!("shard {}: unexpected tensor '{name}' in file", e.rank);
    }
    Ok(RankShard { rank: e.rank, params, opt })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, OptimizerConfig};
    use crate::train::Optimizer;
    use crate::util::proptest::assert_close;
    use std::path::PathBuf;

    fn tdir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("phantom-ckpt-mod-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn pp_snapshot() -> Snapshot {
        let cfg = preset("tiny", Parallelism::Phantom).unwrap();
        Snapshot::init(&cfg).unwrap()
    }

    fn tensors_equal(a: &Tensor, b: &Tensor) -> bool {
        a.shape() == b.shape()
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn save_load_roundtrips_bitwise_both_modes() {
        let root = tdir("roundtrip");
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.train.optimizer =
                OptimizerConfig::Adam { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
            let mut snap = Snapshot::init(&cfg).unwrap();
            // attach a non-trivial optimizer state + progress
            for shard in &mut snap.shards {
                let shapes: Vec<Vec<usize>> =
                    shard.params.named().iter().map(|(_, t)| t.shape().to_vec()).collect();
                let mut opt = Optimizer::new(cfg.train.optimizer, &shapes);
                let grads: Vec<Tensor> =
                    shapes.iter().map(|s| Tensor::filled(s, 0.25)).collect();
                let mut params: Vec<Tensor> =
                    shapes.iter().map(|s| Tensor::filled(s, 1.0)).collect();
                let mut refs: Vec<&mut Tensor> = params.iter_mut().collect();
                opt.step(&mut refs, &grads);
                shard.opt = Some(opt.state());
            }
            snap.progress = TrainProgress {
                iter: 3,
                losses: vec![1.5, 0.75, 0.25],
                prng: run_stream(7).state(),
            };

            let dir = root.join(mode.name());
            snap.save(&dir).unwrap();
            let back = Snapshot::load(&dir).unwrap();
            assert_eq!(back.config, snap.config);
            assert_eq!(back.progress.iter, 3);
            assert_eq!(back.progress.losses, snap.progress.losses);
            assert_eq!(back.progress.prng, snap.progress.prng);
            for (a, b) in snap.shards.iter().zip(&back.shards) {
                let (na, nb) = (a.params.named(), b.params.named());
                assert_eq!(na.len(), nb.len());
                for ((n1, t1), (n2, t2)) in na.iter().zip(&nb) {
                    assert_eq!(n1, n2);
                    assert!(tensors_equal(t1, t2), "{} {n1}", mode.name());
                }
                assert_eq!(a.opt, b.opt, "optimizer state must round-trip");
            }
            // rank granularity
            let shard1 = Snapshot::load_rank(&dir, 1).unwrap();
            assert_eq!(shard1.rank, 1);
            let want = snap.shards[1].params.named();
            let got = shard1.params.named();
            for ((n1, t1), (_, t2)) in want.iter().zip(&got) {
                assert!(tensors_equal(t1, t2), "rank shard {n1}");
            }
            assert!(Snapshot::load_rank(&dir, 99).is_err());

            // a lost adam step count must fail the load, not default to 0
            let mpath = dir.join("manifest.json");
            let text = std::fs::read_to_string(&mpath).unwrap();
            let stripped = text.replacen("\"opt_t\": 1,", "", 1);
            assert_ne!(stripped, text, "manifest must carry opt_t for adam");
            std::fs::write(&mpath, stripped).unwrap();
            assert!(Snapshot::load(&dir).is_err(), "missing opt_t must fail the load");
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn tampering_is_rejected() {
        let root = tdir("tamper");
        let snap = pp_snapshot();
        let dir = root.join("snap");
        snap.save(&dir).unwrap();

        // flip one byte in a shard payload
        let shard_path = dir.join(shard_file_name(0));
        let mut bytes = std::fs::read(&shard_path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&shard_path, &bytes).unwrap();
        assert!(Snapshot::load(&dir).is_err(), "payload tamper must fail the load");
        assert!(Snapshot::load_rank(&dir, 0).is_err());
        // ...but other ranks stay individually loadable
        assert!(Snapshot::load_rank(&dir, 1).is_ok());

        // manifest pointing at a wrong length
        snap.save(&dir).unwrap();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let j = Json::parse(&text).unwrap();
        let bytes0 = j.get("shards").as_arr().unwrap()[0].get("bytes").as_i64().unwrap();
        let text = text.replacen(
            &format!("\"bytes\": {bytes0}"),
            &format!("\"bytes\": {}", bytes0 + 1),
            1,
        );
        std::fs::write(&mpath, text).unwrap();
        assert!(Snapshot::load(&dir).is_err(), "manifest length tamper must fail");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn torn_write_surfaces_checksum_error_naming_the_file() {
        // Regression (ISSUE 5): a shard truncated mid-record and a shard
        // with one corrupt payload byte must both surface as checksum
        // errors that NAME the rank file — never a panic, and never a
        // silently loaded half-model.
        let root = tdir("torn");
        let snap = pp_snapshot();
        let dir = root.join("snap");

        // Truncation mid-record (manifest byte count now disagrees).
        snap.save(&dir).unwrap();
        let path = dir.join("rank-0002.bin");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = Snapshot::load(&dir).expect_err("truncated shard must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank-0002.bin"), "error must name the file: {msg}");

        // Truncation that a doctored manifest agrees with (byte count AND
        // whole-file checksum recomputed for the truncated file): the
        // record-level decode is the last line of defense and must still
        // reject the torn record, naming the file. Rank 0 here because its
        // "bytes" entry is the manifest's first (all shards are equal-sized
        // at this geometry, so a plain replacen would hit rank 0 anyway).
        snap.save(&dir).unwrap();
        let path0 = dir.join("rank-0000.bin");
        let bytes = std::fs::read(&path0).unwrap();
        let cut = bytes.len() - 5; // mid-record: inside the last checksum
        std::fs::write(&path0, &bytes[..cut]).unwrap();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let fixed = text
            .replacen(
                &format!("\"bytes\": {}", bytes.len()),
                &format!("\"bytes\": {cut}"),
                1,
            )
            .replacen(
                &io::u64_to_hex(io::fnv1a64(&bytes)),
                &io::u64_to_hex(io::fnv1a64(&bytes[..cut])),
                1,
            );
        assert_ne!(fixed, text, "manifest must carry the shard byte count");
        std::fs::write(&mpath, fixed).unwrap();
        let err = Snapshot::load(&dir).expect_err("mid-record truncation must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank-0000.bin"), "error must name the file: {msg}");
        assert!(msg.contains("truncated"), "error must name the truncation: {msg}");

        // One corrupt payload byte: whole-file checksum catches it, and
        // the error names the file; sibling ranks stay loadable.
        snap.save(&dir).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = Snapshot::load(&dir).expect_err("corrupt byte must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank-0002.bin"), "error must name the file: {msg}");
        assert!(msg.contains("checksum"), "error must name the checksum: {msg}");
        assert!(Snapshot::load_rank(&dir, 2).is_err());
        assert!(Snapshot::load_rank(&dir, 0).is_ok(), "other ranks stay loadable");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn hybrid_snapshot_roundtrips_and_validates() {
        let root = tdir("hybrid");
        for mode in [Parallelism::Phantom, Parallelism::Tensor] {
            let mut cfg = preset("tiny", mode).unwrap();
            cfg.dp = 2;
            let snap = Snapshot::init(&cfg).unwrap();
            assert_eq!(snap.dp(), 2);
            assert_eq!(snap.shards.len(), cfg.p * 2);
            snap.validate().unwrap();

            let dir = root.join(mode.name());
            snap.save(&dir).unwrap();
            let back = Snapshot::load(&dir).unwrap();
            assert_eq!(back.config.dp, 2);
            assert_eq!(back.shards.len(), cfg.p * 2);
            for (a, b) in snap.shards.iter().zip(&back.shards) {
                for ((n1, t1), (_, t2)) in a.params.named().iter().zip(&b.params.named()) {
                    assert!(tensors_equal(t1, t2), "{} {n1}", mode.name());
                }
            }
            // Replica shards load at world-rank granularity, carrying the
            // MODEL rank in their params.
            let w = cfg.p + 1; // replica 1 of model rank 1
            let shard = Snapshot::load_rank(&dir, w).unwrap();
            assert_eq!(shard.rank, w);
            match &shard.params {
                RankParams::Phantom(ps) => assert_eq!(ps.rank, 1),
                RankParams::Tensor(ts) => assert_eq!(ts.rank, 1),
            }
            // forward_host (replica 0) equals the pure dp=1 snapshot's.
            let mut pure_cfg = cfg.clone();
            pure_cfg.dp = 1;
            let pure = Snapshot::init(&pure_cfg).unwrap();
            let mut rng = Prng::new(21);
            let x = Tensor::randn(&[3, snap.n()], 1.0, &mut rng);
            assert_eq!(
                snap.forward_host(&x).unwrap(),
                pure.forward_host(&x).unwrap(),
                "hybrid forward must equal the single-replica forward"
            );
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn validate_catches_structural_damage() {
        let mut snap = pp_snapshot();
        snap.shards.swap(0, 1);
        assert!(snap.validate().is_err(), "out-of-order ranks");

        let mut snap = pp_snapshot();
        snap.shards.pop();
        assert!(snap.validate().is_err(), "missing shard");

        let mut snap = pp_snapshot();
        if let RankParams::Phantom(p) = &mut snap.shards[2].params {
            // poke the frozen own slot
            let off = 2 * p.k * p.m;
            p.decompressors[0].data_mut()[off] = 1.0;
        }
        assert!(snap.validate().is_err(), "nonzero frozen slot");

        let mut snap = pp_snapshot();
        snap.progress.iter = 5; // losses is empty
        assert!(snap.validate().is_err(), "iter/losses mismatch");
    }

    #[test]
    fn forward_host_matches_dense_oracle() {
        let snap = pp_snapshot();
        let model = snap.config.model;
        let oracle = DensePhantomOracle::init(&model, snap.p(), snap.config.train.seed).unwrap();
        let mut rng = Prng::new(11);
        let x = Tensor::randn(&[3, snap.n()], 1.0, &mut rng);
        let a = snap.forward_host(&x).unwrap();
        let b = oracle.forward(&x).unwrap();
        assert_close(a.data(), b.data(), 1e-6, 1e-7).unwrap();
    }

    #[test]
    fn init_snapshot_matches_training_init_tp() {
        let cfg = preset("tiny", Parallelism::Tensor).unwrap();
        let snap = Snapshot::init(&cfg).unwrap();
        let direct = TpRankParams::init(&cfg.model, cfg.p, 2, cfg.train.seed).unwrap();
        match &snap.shards[2].params {
            RankParams::Tensor(t) => {
                assert!(tensors_equal(&t.weights[0], &direct.weights[0]));
                assert!(tensors_equal(&t.biases[1], &direct.biases[1]));
            }
            RankParams::Phantom(_) => panic!("mode"),
        }
    }
}
