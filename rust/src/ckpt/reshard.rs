//! Re-sharding algebra: move a snapshot between (p, TP|PP) layouts.
//!
//! Every layout computes `y_out = relu(y_full W + b)` for some logical
//! [n, n] matrix per layer, so re-sharding is gather-then-reslice on that
//! logical model (DESIGN.md §8):
//!
//! * **TP gather** — the column shards tile W exactly.
//! * **PP gather (densify)** — block (src, dst) of W is `L_dst` on the
//!   diagonal and the rank-k product `C_src · D_dst[src]` off it (the
//!   dense-equivalent oracle's matrix).
//! * **TP reslice** — cut columns; exact for any p' dividing n.
//! * **PP reslice (dense-phantom)** — from a dense W there is no exact
//!   rank-k factorization for k < n/p', so conversion targets k' = n/p'
//!   with the identity compressor: `C = I`, `D_dst[src] = W[src, dst]`
//!   block, `L_dst = W[dst, dst]`, own decompressor slot frozen at zero.
//!   `y · I` is exact in floating point, so the converted model is
//!   forward-equivalent to the source up to summation order.
//! * **PP merge (elastic down-scaling)** — the special case PP p → p'
//!   where p' divides p keeps the compression structure instead of
//!   densifying: merging r = p/p' ranks concatenates their shards with
//!   k' = r·k. Intra-group phantom paths become part of the merged local
//!   matrix (`L'` absorbs `C_a · D_b[a]` for a, b in the same group),
//!   the merged compressor is block-diagonal, and remote decompressors
//!   stack blockwise. Since k < n/p implies r·k < n/p', the merged model
//!   always satisfies the phantom size constraint — down-scaling is
//!   closed under the paper's Eqn. 8 regime.
//!
//! Optimizer moments do not survive a layout change (their axes are tied
//! to the shard geometry), so re-sharded shards carry `opt: None`; loss
//! history, iteration count and PRNG state are preserved.

use anyhow::{bail, Context, Result};

use crate::config::Parallelism;
use crate::model::{assemble_tp_dense, PhantomRankParams, TpRankParams};
use crate::tensor::Tensor;
use crate::train::OptimizerState;

use super::{RankParams, RankShard, Snapshot};

/// Re-shard `src` into `target_p` ranks in `target_mode`. The result is
/// forward-equivalent to the source (within floating-point summation
/// order) and carries the source's training progress with a fresh
/// optimizer. Hybrid sources (dp > 1) are first collapsed to one replica
/// — DP replicas must be weight-identical, which is verified bitwise —
/// and the result is always a pure (dp = 1) layout.
pub fn reshard(src: &Snapshot, target_p: usize, target_mode: Parallelism) -> Result<Snapshot> {
    src.validate()?;
    // Collapse hybrid sources in place (no recursion): `src` is already
    // validated, and the collapsed subset is valid by construction, so
    // the O(total-params) validation walk runs once, not four times.
    let collapsed;
    let src = if src.config.dp > 1 {
        collapsed = collapse_validated(src)?;
        &collapsed
    } else {
        src
    };
    let n = src.n();
    if target_p == 0 || n % target_p != 0 {
        bail!("target p={target_p} must divide n={n}");
    }
    if target_mode == Parallelism::Phantom && target_p < 2 {
        bail!("phantom layouts need p >= 2 (p=1 has no remote ranks)");
    }

    let shards = match (src.mode(), target_mode) {
        (Parallelism::Phantom, Parallelism::Phantom)
            if src.p() % target_p == 0 && target_p < src.p() =>
        {
            merge_phantom(src, target_p)?
        }
        _ => {
            let (weights, biases) = gather_dense(src)?;
            match target_mode {
                Parallelism::Tensor => slice_tp(&weights, &biases, target_p)?,
                Parallelism::Phantom => slice_dense_phantom(&weights, &biases, target_p)?,
            }
        }
    };

    let mut config = src.config.clone();
    config.mode = target_mode;
    config.p = target_p;
    if target_mode == Parallelism::Phantom {
        config.model.k = match &shards[0] {
            RankParams::Phantom(p) => p.k,
            RankParams::Tensor(_) => unreachable!("phantom target"),
        };
    }
    // The source's artifact name described the old geometry; consumers of
    // a re-sharded snapshot (serve hot-swap, host-side forward) bring
    // their own execution context.
    config.artifact = None;

    let out = Snapshot {
        config,
        progress: src.progress.clone(),
        shards: shards
            .into_iter()
            .enumerate()
            .map(|(rank, params)| RankShard { rank, params, opt: None })
            .collect(),
    };
    out.validate()?;
    Ok(out)
}

/// Collapse a hybrid (dp > 1) snapshot to its replica-0 model-parallel
/// group. The DP training invariant says replicas of one model rank are
/// weight-identical (same init, gradients summed by one All-Reduce, same
/// optimizer step); this is verified BITWISE against replica 0 before any
/// replica is dropped, so a torn or diverged hybrid snapshot is rejected
/// instead of silently resharding one replica's view. Optimizer moments of
/// replica 0 are kept — the collapse does not change the shard geometry.
/// ZeRO-sharded snapshots (`train.sharded_state`) hold each replica's
/// owned optimizer slice only; the collapse concatenates the slices in
/// DP-rank order and unflattens them back to full per-parameter moments,
/// so the collapsed (dp = 1) snapshot resumes bit-identically as a flat
/// run.
pub fn collapse_dp(src: &Snapshot) -> Result<Snapshot> {
    src.validate()?;
    collapse_validated(src)
}

/// `collapse_dp` minus the input validation pass — for callers that have
/// already validated `src`. The output is a subset of the validated
/// input (replica-0 shards, dp set to 1), so it is valid by construction
/// and is not re-walked either.
fn collapse_validated(src: &Snapshot) -> Result<Snapshot> {
    let (p, dp) = (src.p(), src.config.dp);
    if dp <= 1 {
        return Ok(src.clone());
    }
    for w in p..p * dp {
        let reference = &src.shards[w % p].params;
        if !params_bitwise_eq(reference, &src.shards[w].params) {
            bail!(
                "hybrid snapshot: DP replica {} of model rank {} diverged from replica 0 \
                 (replicas must be weight-identical; the snapshot is torn or corrupt)",
                w / p,
                w % p
            );
        }
    }
    let mut config = src.config.clone();
    config.dp = 1;
    let mut shards = src.shards[..p].to_vec();
    // ZeRO-1: each replica's shard holds only its owned flat optimizer
    // slice. Gather the slices of every model rank in DP-rank order and
    // unflatten them back to full per-parameter moments, so the collapsed
    // snapshot carries exactly the state a flat dp=1 run would have.
    if config.train.sharded_state {
        for (r, shard) in shards.iter_mut().enumerate() {
            let parts: Vec<&OptimizerState> = (0..dp)
                .filter_map(|d| src.shards[d * p + r].opt.as_ref())
                .collect();
            if parts.is_empty() {
                continue; // fresh optimizer everywhere: nothing to merge
            }
            if parts.len() != dp {
                bail!(
                    "hybrid snapshot: model rank {r} has {} of {dp} sharded optimizer \
                     slices (the snapshot is torn)",
                    parts.len()
                );
            }
            let shapes: Vec<Vec<usize>> =
                shard.params.named().iter().map(|(_, t)| t.shape().to_vec()).collect();
            shard.opt = Some(
                OptimizerState::concat_sharded(&parts, &shapes)
                    .with_context(|| format!("merging model rank {r}'s optimizer slices"))?,
            );
        }
    }
    Ok(Snapshot { config, progress: src.progress.clone(), shards })
}

/// Bitwise tensor-by-tensor equality of two rank param sets (f32 compared
/// as bits: NaN-safe, -0.0 != 0.0 — exactly what "same bytes" means).
fn params_bitwise_eq(a: &RankParams, b: &RankParams) -> bool {
    let (na, nb) = (a.named(), b.named());
    na.len() == nb.len()
        && na.iter().zip(&nb).all(|((name_a, ta), (name_b, tb))| {
            name_a == name_b
                && ta.shape() == tb.shape()
                && ta
                    .data()
                    .iter()
                    .zip(tb.data())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        })
}

/// Gather the logical dense weights [n, n] and biases [n] per layer.
fn gather_dense(src: &Snapshot) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    match src.mode() {
        Parallelism::Tensor => {
            let shards: Vec<TpRankParams> = src
                .shards
                .iter()
                .map(|s| match &s.params {
                    RankParams::Tensor(t) => t.clone(),
                    RankParams::Phantom(_) => unreachable!("validated tp"),
                })
                .collect();
            assemble_tp_dense(&shards)
        }
        Parallelism::Phantom => {
            let (p, n, layers) = (src.p(), src.n(), src.layers());
            let m = n / p;
            let mut weights = Vec::with_capacity(layers);
            let mut biases = Vec::with_capacity(layers);
            for l in 0..layers {
                let mut w = Tensor::zeros(&[n, n]);
                let mut b = Tensor::zeros(&[n]);
                for dst in 0..p {
                    let ps = phantom(&src.shards[dst].params);
                    paste(&mut w, n, dst * m, dst * m, &ps.locals[l]);
                    b.data_mut()[dst * m..(dst + 1) * m].copy_from_slice(ps.biases[l].data());
                    for s in 0..p {
                        if s == dst {
                            continue;
                        }
                        let c = &phantom(&src.shards[s].params).compressors[l];
                        let block = c.matmul(&ps.decompressors[l].unstack_at(s))?;
                        paste(&mut w, n, s * m, dst * m, &block);
                    }
                }
                weights.push(w);
                biases.push(b);
            }
            Ok((weights, biases))
        }
    }
}

/// Cut the dense model into TP column shards.
fn slice_tp(weights: &[Tensor], biases: &[Tensor], p: usize) -> Result<Vec<RankParams>> {
    let n = biases[0].numel();
    let m = n / p;
    let mut out = Vec::with_capacity(p);
    for rank in 0..p {
        let mut ws = Vec::with_capacity(weights.len());
        let mut bs = Vec::with_capacity(weights.len());
        for (w, b) in weights.iter().zip(biases) {
            ws.push(w.col_slice(rank * m, m)?);
            bs.push(Tensor::from_vec(&[m], b.data()[rank * m..(rank + 1) * m].to_vec())?);
        }
        out.push(RankParams::Tensor(TpRankParams { rank, p, m, weights: ws, biases: bs }));
    }
    Ok(out)
}

/// Cut the dense model into the dense-phantom layout: k = m with identity
/// compressors, diagonal blocks as locals, off-diagonal blocks as
/// decompressors (own slot zero).
fn slice_dense_phantom(weights: &[Tensor], biases: &[Tensor], p: usize) -> Result<Vec<RankParams>> {
    let n = biases[0].numel();
    let m = n / p;
    let layers = weights.len();
    let mut ident = Tensor::zeros(&[m, m]);
    for i in 0..m {
        ident.data_mut()[i * m + i] = 1.0;
    }
    let mut out = Vec::with_capacity(p);
    for rank in 0..p {
        let mut locals = Vec::with_capacity(layers);
        let mut compressors = Vec::with_capacity(layers);
        let mut decompressors = Vec::with_capacity(layers);
        let mut bs = Vec::with_capacity(layers);
        for (w, b) in weights.iter().zip(biases) {
            locals.push(block(w, n, rank * m, rank * m, m, m));
            compressors.push(ident.clone());
            let mut d = Tensor::zeros(&[p, m, m]);
            for s in 0..p {
                if s == rank {
                    continue;
                }
                let blk = block(w, n, s * m, rank * m, m, m);
                d.data_mut()[s * m * m..(s + 1) * m * m].copy_from_slice(blk.data());
            }
            decompressors.push(d);
            bs.push(Tensor::from_vec(&[m], b.data()[rank * m..(rank + 1) * m].to_vec())?);
        }
        out.push(RankParams::Phantom(PhantomRankParams {
            rank,
            p,
            m,
            k: m,
            locals,
            compressors,
            decompressors,
            biases: bs,
        }));
    }
    Ok(out)
}

/// Elastic PP down-scaling: merge groups of r = p/p' consecutive ranks,
/// keeping the compression structure with k' = r·k.
fn merge_phantom(src: &Snapshot, target_p: usize) -> Result<Vec<RankParams>> {
    let (p, n, layers, k) = (src.p(), src.n(), src.layers(), src.k());
    let m = n / p;
    let r = p / target_p;
    let (m2, k2) = (r * m, r * k);
    let old = |i: usize| phantom(&src.shards[i].params);

    let mut out = Vec::with_capacity(target_p);
    for big in 0..target_p {
        let group = |a: usize| big * r + a; // old rank index of sub-block a
        let mut locals = Vec::with_capacity(layers);
        let mut compressors = Vec::with_capacity(layers);
        let mut decompressors = Vec::with_capacity(layers);
        let mut biases = Vec::with_capacity(layers);
        for l in 0..layers {
            // L': diagonal sub-blocks are the old locals; intra-group
            // phantom paths C_a · D_b[a] become ordinary local weight.
            let mut lw = Tensor::zeros(&[m2, m2]);
            for a in 0..r {
                for bsub in 0..r {
                    if a == bsub {
                        paste(&mut lw, m2, a * m, bsub * m, &old(group(a)).locals[l]);
                    } else {
                        let blk = old(group(a)).compressors[l]
                            .matmul(&old(group(bsub)).decompressors[l].unstack_at(group(a)))?;
                        paste(&mut lw, m2, a * m, bsub * m, &blk);
                    }
                }
            }
            locals.push(lw);

            // C': block-diagonal stack of the old compressors.
            let mut cw = Tensor::zeros(&[m2, k2]);
            for a in 0..r {
                paste(&mut cw, k2, a * m, a * k, &old(group(a)).compressors[l]);
            }
            compressors.push(cw);

            // D'[src_big]: old D_{dst}[src] blocks, rows by source
            // sub-block (g layout), columns by destination sub-block.
            let mut d = Tensor::zeros(&[target_p, k2, m2]);
            for src_big in 0..target_p {
                if src_big == big {
                    continue; // own slot stays zero
                }
                let base = src_big * k2 * m2;
                for a in 0..r {
                    for bsub in 0..r {
                        let blk = old(group(bsub)).decompressors[l].unstack_at(src_big * r + a);
                        for row in 0..k {
                            let dst_off = base + (a * k + row) * m2 + bsub * m;
                            d.data_mut()[dst_off..dst_off + m]
                                .copy_from_slice(&blk.data()[row * m..(row + 1) * m]);
                        }
                    }
                }
            }
            decompressors.push(d);

            let mut bv = Tensor::zeros(&[m2]);
            for a in 0..r {
                bv.data_mut()[a * m..(a + 1) * m]
                    .copy_from_slice(old(group(a)).biases[l].data());
            }
            biases.push(bv);
        }
        out.push(RankParams::Phantom(PhantomRankParams {
            rank: big,
            p: target_p,
            m: m2,
            k: k2,
            locals,
            compressors,
            decompressors,
            biases,
        }));
    }
    Ok(out)
}

fn phantom(p: &RankParams) -> &PhantomRankParams {
    match p {
        RankParams::Phantom(x) => x,
        RankParams::Tensor(_) => unreachable!("caller checked the mode"),
    }
}

/// Copy `src` [h, w] into the matrix `dst` (row stride `dst_cols`) at
/// (row0, col0).
fn paste(dst: &mut Tensor, dst_cols: usize, row0: usize, col0: usize, src: &Tensor) {
    let (h, w) = (src.shape()[0], src.shape()[1]);
    for row in 0..h {
        let off = (row0 + row) * dst_cols + col0;
        dst.data_mut()[off..off + w].copy_from_slice(&src.data()[row * w..(row + 1) * w]);
    }
}

/// Extract the [h, w] block of the matrix `src` (row stride `src_cols`)
/// at (row0, col0).
fn block(src: &Tensor, src_cols: usize, row0: usize, col0: usize, h: usize, w: usize) -> Tensor {
    let mut out = Tensor::zeros(&[h, w]);
    for row in 0..h {
        let off = (row0 + row) * src_cols + col0;
        out.data_mut()[row * w..(row + 1) * w].copy_from_slice(&src.data()[off..off + w]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::prng::Prng;
    use crate::util::proptest::assert_close;

    fn snap(mode: Parallelism, p: usize, n: usize, k: usize) -> Snapshot {
        let mut cfg = crate::config::preset("tiny", mode).unwrap();
        cfg.p = p;
        cfg.model = ModelConfig { n, layers: 2, k };
        cfg.artifact = Some("custom".to_string());
        Snapshot::init(&cfg).unwrap()
    }

    fn batch(n: usize, seed: u64) -> Tensor {
        let mut rng = Prng::new(seed);
        Tensor::randn(&[5, n], 1.0, &mut rng)
    }

    fn assert_forward_equiv(a: &Snapshot, b: &Snapshot, tag: &str) {
        let x = batch(a.n(), 0xE0);
        let ya = a.forward_host(&x).unwrap();
        let yb = b.forward_host(&x).unwrap();
        assert_close(ya.data(), yb.data(), 1e-4, 1e-5).unwrap_or_else(|e| panic!("{tag}: {e}"));
    }

    #[test]
    fn tp_resharding_is_exact_any_p() {
        let src = snap(Parallelism::Tensor, 8, 64, 0);
        for p2 in [1usize, 2, 4, 8, 16] {
            let re = reshard(&src, p2, Parallelism::Tensor).unwrap();
            assert_eq!(re.p(), p2);
            assert_eq!(re.mode(), Parallelism::Tensor);
            assert_eq!(re.config.artifact, None);
            assert_forward_equiv(&src, &re, &format!("tp->tp p={p2}"));
        }
    }

    #[test]
    fn tp_to_dense_phantom_is_forward_equivalent() {
        // The acceptance-criteria scenario: TP p=8 -> PP p=2.
        let src = snap(Parallelism::Tensor, 8, 64, 0);
        let re = reshard(&src, 2, Parallelism::Phantom).unwrap();
        assert_eq!(re.mode(), Parallelism::Phantom);
        assert_eq!(re.k(), 32, "dense-phantom conversion uses k = n/p");
        // frozen own slots survived the conversion
        re.validate().unwrap();
        assert_forward_equiv(&src, &re, "tp p=8 -> pp p=2");
        // and the round trip back to TP still matches
        let back = reshard(&re, 4, Parallelism::Tensor).unwrap();
        assert_forward_equiv(&src, &back, "pp p=2 -> tp p=4");
    }

    #[test]
    fn pp_merge_down_scaling_is_forward_equivalent_and_keeps_k_small() {
        let src = snap(Parallelism::Phantom, 8, 64, 3);
        let p4 = reshard(&src, 4, Parallelism::Phantom).unwrap();
        assert_eq!(p4.k(), 6, "merge doubles k, not densify");
        assert_forward_equiv(&src, &p4, "pp p=8 -> p=4");
        // elastic chain p=8 -> p=4 -> p=2
        let p2 = reshard(&p4, 2, Parallelism::Phantom).unwrap();
        assert_eq!(p2.k(), 12);
        assert_forward_equiv(&src, &p2, "pp p=8 -> p=4 -> p=2");
        // merged models keep k' < m' (Eqn. 8 regime closed under merging)
        assert!(p2.k() < p2.n() / p2.p());
    }

    #[test]
    fn pp_up_scaling_densifies() {
        let src = snap(Parallelism::Phantom, 2, 32, 4);
        let up = reshard(&src, 4, Parallelism::Phantom).unwrap();
        assert_eq!(up.k(), 8, "up-scaling has no exact factorization: k = n/p");
        assert_forward_equiv(&src, &up, "pp p=2 -> p=4");
    }

    #[test]
    fn pp_to_tp_round_trips_progress() {
        let mut src = snap(Parallelism::Phantom, 4, 32, 3);
        src.progress.losses = vec![2.0, 1.0];
        src.progress.iter = 2;
        let re = reshard(&src, 2, Parallelism::Tensor).unwrap();
        assert_eq!(re.progress.losses, src.progress.losses);
        assert_eq!(re.progress.iter, 2);
        assert!(re.shards.iter().all(|s| s.opt.is_none()), "moments dropped");
        assert_forward_equiv(&src, &re, "pp p=4 -> tp p=2");
    }

    #[test]
    fn reshard_rejects_bad_targets() {
        let src = snap(Parallelism::Tensor, 4, 32, 0);
        assert!(reshard(&src, 0, Parallelism::Tensor).is_err());
        assert!(reshard(&src, 3, Parallelism::Tensor).is_err(), "3 does not divide 32");
        assert!(reshard(&src, 1, Parallelism::Phantom).is_err(), "phantom needs p >= 2");
    }

    #[test]
    fn p1_tp_round_trip_is_identity() {
        // Edge case: gather everything onto a single rank and re-shard
        // back out. p=1 is a legal TP layout (the dense model itself);
        // the round trip must be an exact copy, not just close.
        let src = snap(Parallelism::Tensor, 4, 32, 0);
        let dense = reshard(&src, 1, Parallelism::Tensor).unwrap();
        assert_eq!(dense.p(), 1);
        assert_eq!(dense.shards.len(), 1);
        assert_forward_equiv(&src, &dense, "tp p=4 -> p=1");
        let back = reshard(&dense, 4, Parallelism::Tensor).unwrap();
        for (a, b) in src.shards.iter().zip(&back.shards) {
            match (&a.params, &b.params) {
                (RankParams::Tensor(x), RankParams::Tensor(y)) => {
                    assert_eq!(x.weights, y.weights, "p=1 round trip must be bitwise");
                    assert_eq!(x.biases, y.biases);
                }
                _ => panic!("mode"),
            }
        }
        // PP cannot target p=1 (no remote ranks to hold phantom layers),
        // but a PP source can collapse to the dense p=1 TP layout.
        let pp = snap(Parallelism::Phantom, 4, 32, 3);
        assert!(reshard(&pp, 1, Parallelism::Phantom).is_err());
        let collapsed = reshard(&pp, 1, Parallelism::Tensor).unwrap();
        assert_forward_equiv(&pp, &collapsed, "pp p=4 -> dense p=1");
    }

    #[test]
    fn non_divisor_targets_error_cleanly_in_both_modes() {
        // p' must divide n: n=32 rejects p'=3, 5, 7, 12, 33 for TP and PP
        // alike, with an error that names the constraint instead of
        // slicing garbage.
        let tp = snap(Parallelism::Tensor, 4, 32, 0);
        let pp = snap(Parallelism::Phantom, 4, 32, 3);
        for bad_p in [3usize, 5, 7, 12, 33] {
            for (src, mode) in [(&tp, Parallelism::Tensor), (&pp, Parallelism::Phantom)] {
                let err = reshard(src, bad_p, mode)
                    .expect_err(&format!("p={bad_p} must be rejected"));
                let msg = err.to_string();
                assert!(msg.contains("divide"), "{mode:?} p={bad_p}: {msg}");
            }
        }
    }

    #[test]
    fn reshard_then_reshard_back_is_exact_tp() {
        // TP column cuts are pure copies, so p=4 -> p=8 -> p=4 must
        // restore every shard bitwise (not merely forward-equivalent).
        let src = snap(Parallelism::Tensor, 4, 64, 0);
        let wide = reshard(&src, 8, Parallelism::Tensor).unwrap();
        let back = reshard(&wide, 4, Parallelism::Tensor).unwrap();
        for (a, b) in src.shards.iter().zip(&back.shards) {
            match (&a.params, &b.params) {
                (RankParams::Tensor(x), RankParams::Tensor(y)) => {
                    assert_eq!(x.weights, y.weights, "reshard-back must be bitwise");
                    assert_eq!(x.biases, y.biases);
                }
                _ => panic!("mode"),
            }
        }
    }

    #[test]
    fn reshard_then_reshard_back_stays_forward_equivalent_pp() {
        // PP round trips are not bitwise (densify/merge change the
        // factorization) but must stay forward-equivalent and structurally
        // valid through a full cycle: merge down, densify up, and a
        // cross-mode PP -> TP -> PP loop.
        let src = snap(Parallelism::Phantom, 8, 64, 3);
        let down = reshard(&src, 2, Parallelism::Phantom).unwrap();
        let up = reshard(&down, 8, Parallelism::Phantom).unwrap();
        up.validate().unwrap();
        assert_forward_equiv(&src, &up, "pp p=8 -> p=2 -> p=8");

        let as_tp = reshard(&src, 4, Parallelism::Tensor).unwrap();
        let back_pp = reshard(&as_tp, 8, Parallelism::Phantom).unwrap();
        back_pp.validate().unwrap();
        assert_eq!(back_pp.k(), 8, "dense-phantom conversion uses k = n/p");
        assert_forward_equiv(&src, &back_pp, "pp -> tp -> pp");
    }

    #[test]
    fn hybrid_snapshot_collapses_verified_and_reshards() {
        // A hybrid DP×PP snapshot: 2 replicas × p=4. Collapse keeps one
        // replica after verifying the others bitwise; reshard goes through
        // the same collapse transparently.
        let mut cfg = crate::config::preset("tiny", Parallelism::Phantom).unwrap();
        cfg.p = 4;
        cfg.dp = 2;
        cfg.model = ModelConfig { n: 32, layers: 2, k: 3 };
        cfg.artifact = Some("custom".to_string());
        let hybrid = Snapshot::init(&cfg).unwrap();
        assert_eq!(hybrid.shards.len(), 8);

        let pure = collapse_dp(&hybrid).unwrap();
        assert_eq!(pure.config.dp, 1);
        assert_eq!(pure.shards.len(), 4);
        assert_forward_equiv(&hybrid, &pure, "hybrid collapse");

        // reshard(hybrid) == reshard(collapse(hybrid)), and the result is
        // always a pure layout.
        let re = reshard(&hybrid, 2, Parallelism::Tensor).unwrap();
        assert_eq!(re.config.dp, 1);
        assert_forward_equiv(&hybrid, &re, "hybrid -> tp p=2");

        // A diverged replica is rejected, naming the replica and rank.
        let mut torn = hybrid.clone();
        if let RankParams::Phantom(ps) = &mut torn.shards[6].params {
            ps.locals[0].data_mut()[0] += 1.0;
        }
        let err = collapse_dp(&torn).expect_err("diverged replica must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("replica 1"), "{msg}");
        assert!(msg.contains("model rank 2"), "{msg}");
        assert!(reshard(&torn, 2, Parallelism::Tensor).is_err());
    }

    #[test]
    fn identity_reshard_preserves_weights_bitwise() {
        let src = snap(Parallelism::Tensor, 4, 32, 0);
        let re = reshard(&src, 4, Parallelism::Tensor).unwrap();
        for (a, b) in src.shards.iter().zip(&re.shards) {
            match (&a.params, &b.params) {
                (RankParams::Tensor(x), RankParams::Tensor(y)) => {
                    // gather + reslice at the same p is an exact copy
                    assert_eq!(x.weights, y.weights);
                    assert_eq!(x.biases, y.biases);
                }
                _ => panic!("mode"),
            }
        }
    }
}
