//! Checkpoint binary shard format + atomic directory writes.
//!
//! A snapshot directory holds one `manifest.json` (util::json) and one
//! framed binary file per rank. Shard files are self-describing and
//! self-checking:
//!
//! ```text
//! magic "PHCKPT01"
//! u32   record count
//! per record:
//!   u32  name length, name bytes (UTF-8)
//!   u32  ndim, ndim x u64 dims
//!   u64  payload length in bytes (= numel * 4)
//!   f32  payload, little-endian
//!   u64  FNV-1a 64 checksum of the payload bytes
//! ```
//!
//! The manifest additionally records every shard file's byte length and
//! whole-file FNV-1a checksum, so corruption is caught at both the file
//! and the record level before any tensor reaches the model.
//!
//! Crash consistency: `atomic_write_dir` materializes the whole snapshot
//! in a sibling `.tmp` directory and `rename`s it into place as the last
//! step. A reader never observes a half-written snapshot directory under
//! the final name; an orphaned `.tmp` from a crash is inert and simply
//! overwritten by the next save. Replacing an existing snapshot moves the
//! old copy aside before the rename and deletes it after, so at least one
//! complete copy survives any crash point.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

pub const MAGIC: &[u8; 8] = b"PHCKPT01";

/// FNV-1a 64-bit: tiny, dependency-free integrity hash (not cryptographic —
/// this guards against torn writes and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Encode named tensors into the framed shard format.
pub fn encode_records(records: &[(String, &Tensor)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for (name, t) in records {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        let payload_len = t.numel() * 4;
        out.extend_from_slice(&(payload_len as u64).to_le_bytes());
        let start = out.len();
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let checksum = fnv1a64(&out[start..]);
        out.extend_from_slice(&checksum.to_le_bytes());
    }
    out
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!(
                "truncated shard file: need {n} bytes at offset {}, have {}",
                self.pos,
                self.bytes.len() - self.pos
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// Decode a framed shard file, verifying per-record checksums and that the
/// file is consumed exactly.
pub fn decode_records(bytes: &[u8]) -> Result<Vec<(String, Tensor)>> {
    let mut c = Cursor { bytes, pos: 0 };
    if c.take(8)? != MAGIC {
        bail!("bad shard magic (not a PHCKPT01 file)");
    }
    let count = c.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let name_len = c.u32()? as usize;
        let name = std::str::from_utf8(c.take(name_len)?)
            .with_context(|| format!("record {i}: name is not UTF-8"))?
            .to_string();
        let ndim = c.u32()? as usize;
        if ndim > 8 {
            bail!("record '{name}': implausible ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        let payload_len = c.u64()? as usize;
        if payload_len != numel * 4 {
            bail!(
                "record '{name}': payload length {payload_len} does not match shape \
                 {shape:?} ({} floats)",
                numel
            );
        }
        let payload = c.take(payload_len)?;
        let want = c.u64()?;
        let got = fnv1a64(payload);
        if got != want {
            bail!("record '{name}': checksum mismatch ({got:#018x} vs {want:#018x})");
        }
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().expect("4 bytes")))
            .collect();
        out.push((name.clone(), Tensor::from_vec(&shape, data)?));
    }
    if c.pos != bytes.len() {
        bail!("trailing garbage after the last record ({} bytes)", bytes.len() - c.pos);
    }
    Ok(out)
}

/// Read a shard file, verifying its byte length and whole-file checksum
/// against the manifest's expectations before decoding.
pub fn read_shard_file(
    path: &Path,
    want_bytes: u64,
    want_fnv: u64,
) -> Result<Vec<(String, Tensor)>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading shard {}", path.display()))?;
    if bytes.len() as u64 != want_bytes {
        bail!("{}: {} bytes on disk, manifest says {want_bytes}", path.display(), bytes.len());
    }
    let got = fnv1a64(&bytes);
    if got != want_fnv {
        bail!("{}: file checksum {got:#018x}, manifest says {want_fnv:#018x}", path.display());
    }
    decode_records(&bytes).with_context(|| format!("decoding {}", path.display()))
}

/// Materialize a directory atomically: `build` populates a sibling temp
/// directory, which is renamed to `final_dir` only after it is complete.
/// An existing `final_dir` is replaced by first moving it aside and only
/// removing it once the new directory is in place — at every instant at
/// least one complete copy exists on disk (a crash mid-replace can at
/// worst leave the old copy under its `.old` aside name).
pub fn atomic_write_dir(final_dir: &Path, build: impl FnOnce(&Path) -> Result<()>) -> Result<()> {
    let name = final_dir
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("snapshot path {:?} has no final component", final_dir))?
        .to_string_lossy()
        .to_string();
    let parent: PathBuf = match final_dir.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    std::fs::create_dir_all(&parent)
        .with_context(|| format!("creating {}", parent.display()))?;
    let tmp = parent.join(format!(".{name}.tmp-{}", std::process::id()));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp)
            .with_context(|| format!("clearing stale {}", tmp.display()))?;
    }
    std::fs::create_dir_all(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    match build(&tmp) {
        Ok(()) => {}
        Err(e) => {
            std::fs::remove_dir_all(&tmp).ok();
            return Err(e);
        }
    }
    // Replace without a no-copy window: move the old snapshot aside, put
    // the new one in place, then drop the old. A directory cannot be
    // renamed over a non-empty directory on POSIX, so remove-then-rename
    // would briefly leave NO copy — fatal for a durability subsystem.
    let mut aside: Option<PathBuf> = None;
    if final_dir.exists() {
        let old = parent.join(format!(".{name}.old-{}", std::process::id()));
        if old.exists() {
            std::fs::remove_dir_all(&old)
                .with_context(|| format!("clearing stale {}", old.display()))?;
        }
        std::fs::rename(final_dir, &old)
            .with_context(|| format!("moving old {} aside", final_dir.display()))?;
        aside = Some(old);
    }
    std::fs::rename(&tmp, final_dir).with_context(|| {
        format!("renaming {} into place as {}", tmp.display(), final_dir.display())
    })?;
    if let Some(old) = aside {
        std::fs::remove_dir_all(&old).ok();
    }
    Ok(())
}

/// Hex helpers for 64-bit checksums / PRNG states in the JSON manifest
/// (u64 does not survive a JSON f64 round-trip above 2^53).
pub fn u64_to_hex(v: u64) -> String {
    format!("{v:016x}")
}

pub fn u64_from_hex(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("phantom-ckpt-io-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn records_roundtrip_bitwise() {
        let mut rng = Prng::new(7);
        let tensors: Vec<(String, Tensor)> = vec![
            ("L0".into(), Tensor::randn(&[4, 4], 1.0, &mut rng)),
            ("C0".into(), Tensor::randn(&[4, 2], 0.5, &mut rng)),
            ("D0".into(), Tensor::randn(&[2, 2, 4], 0.5, &mut rng)),
            ("b0".into(), Tensor::randn(&[4], 0.01, &mut rng)),
            ("empty".into(), Tensor::zeros(&[0])),
        ];
        let refs: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        let bytes = encode_records(&refs);
        let back = decode_records(&bytes).unwrap();
        assert_eq!(back.len(), tensors.len());
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(&back) {
            assert_eq!(n1, n2);
            assert_eq!(t1.shape(), t2.shape());
            for (a, b) in t1.data().iter().zip(t2.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n1}");
            }
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut rng = Prng::new(9);
        let t = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let refs = vec![("W".to_string(), &t)];
        let good = encode_records(&refs);
        assert!(decode_records(&good).is_ok());

        // flip one payload byte
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(decode_records(&bad).is_err(), "payload corruption must fail");
        // truncate
        assert!(decode_records(&good[..good.len() - 3]).is_err());
        // trailing garbage
        let mut long = good.clone();
        long.push(0);
        assert!(decode_records(&long).is_err());
        // wrong magic
        let mut wrong = good;
        wrong[0] ^= 1;
        assert!(decode_records(&wrong).is_err());
    }

    #[test]
    fn shard_file_checks_length_and_checksum() {
        let dir = tdir("shard");
        let t = Tensor::filled(&[3], 2.0);
        let refs = vec![("b".to_string(), &t)];
        let bytes = encode_records(&refs);
        let path = dir.join("rank-0000.bin");
        std::fs::write(&path, &bytes).unwrap();
        let fnv = fnv1a64(&bytes);
        assert!(read_shard_file(&path, bytes.len() as u64, fnv).is_ok());
        assert!(read_shard_file(&path, bytes.len() as u64 + 1, fnv).is_err());
        assert!(read_shard_file(&path, bytes.len() as u64, fnv ^ 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_replaces_and_cleans_up_on_error() {
        let root = tdir("atomic");
        let dst = root.join("snap");
        atomic_write_dir(&dst, |d| {
            std::fs::write(d.join("a.txt"), "one")?;
            Ok(())
        })
        .unwrap();
        assert_eq!(std::fs::read_to_string(dst.join("a.txt")).unwrap(), "one");

        // replace an existing snapshot
        atomic_write_dir(&dst, |d| {
            std::fs::write(d.join("a.txt"), "two")?;
            Ok(())
        })
        .unwrap();
        assert_eq!(std::fs::read_to_string(dst.join("a.txt")).unwrap(), "two");

        // a failing build leaves the old contents and no temp litter
        let err = atomic_write_dir(&dst, |_| bail!("boom"));
        assert!(err.is_err());
        assert_eq!(std::fs::read_to_string(dst.join("a.txt")).unwrap(), "two");
        let litter: Vec<String> = std::fs::read_dir(&root)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains("tmp") || n.contains("old"))
            .collect();
        assert!(litter.is_empty(), "temp/aside dirs must not survive: {litter:?}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn hex_roundtrip() {
        for v in [0u64, 1, 0xF00D, u64::MAX, 0x9E3779B97F4A7C15] {
            assert_eq!(u64_from_hex(&u64_to_hex(v)).unwrap(), v);
        }
        assert!(u64_from_hex("xyz").is_err());
    }
}
