//! PJRT backend (behind the `xla` cargo feature): loads AOT artifacts
//! (HLO text) and executes them for the coordinator's rank threads.
//!
//! The `xla` crate's wrappers hold raw pointers (!Send), so a dedicated
//! executor thread owns the `PjRtClient` and the compiled-executable cache;
//! rank threads reach it through an mpsc channel. This also serializes
//! executions, which keeps measured per-call wall times free of cross-rank
//! CPU contention — the virtual-time contract every `Backend` must honor
//! (DESIGN.md §3).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::{Backend, ExecReply, ExecServer, Manifest};
use crate::tensor::Tensor;

/// A request to execute `entry` of artifact-config `config`.
struct ExecRequest {
    config: String,
    entry: String,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<ExecReply>>,
}

/// The PJRT-backed `Backend`: a channel to the executor thread.
pub struct PjrtBackend {
    tx: Mutex<Option<mpsc::Sender<ExecRequest>>>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

/// Start the executor for the given artifact directory.
pub fn start(artifact_dir: &Path) -> Result<ExecServer> {
    let dir = artifact_dir.to_path_buf();
    let manifest = Manifest::load(&dir)?;
    let manifest_for_thread = manifest.clone();
    let (tx, rx) = mpsc::channel::<ExecRequest>();
    let handle = std::thread::Builder::new()
        .name("pjrt-exec".into())
        .spawn(move || executor_loop(dir, manifest_for_thread, rx))
        .context("spawning executor thread")?;
    let backend = PjrtBackend {
        tx: Mutex::new(Some(tx)),
        handle: Mutex::new(Some(handle)),
    };
    Ok(ExecServer::new(Arc::new(backend), manifest))
}

impl Backend for PjrtBackend {
    fn execute(&self, config: &str, entry: &str, inputs: &[&Tensor]) -> Result<ExecReply> {
        let tx = self
            .tx
            .lock()
            .map_err(|_| anyhow!("exec server mutex poisoned"))?
            .as_ref()
            .ok_or_else(|| anyhow!("exec server is shut down"))?
            .clone();
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(ExecRequest {
            config: config.to_string(),
            entry: entry.to_string(),
            // The executor thread owns its inputs (they cross a channel and
            // are copied into device literals anyway).
            inputs: inputs.iter().map(|t| (*t).clone()).collect(),
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("exec server is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("exec server dropped the request"))?
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

impl Drop for PjrtBackend {
    fn drop(&mut self) {
        if let Ok(mut g) = self.tx.lock() {
            g.take();
        }
        if let Some(h) = self.handle.lock().ok().and_then(|mut g| g.take()) {
            let _ = h.join();
        }
    }
}

fn executor_loop(dir: PathBuf, manifest: Manifest, rx: mpsc::Receiver<ExecRequest>) {
    // PJRT client lives (and dies) on this thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(Err(anyhow!("PJRT client failed to start: {e}")));
            }
            return;
        }
    };
    let mut cache: HashMap<(String, String), xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        let result = serve_one(&client, &dir, &manifest, &mut cache, &req);
        let _ = req.reply.send(result);
    }
}

fn serve_one(
    client: &xla::PjRtClient,
    dir: &Path,
    manifest: &Manifest,
    cache: &mut HashMap<(String, String), xla::PjRtLoadedExecutable>,
    req: &ExecRequest,
) -> Result<ExecReply> {
    let key = (req.config.clone(), req.entry.clone());
    if !cache.contains_key(&key) {
        let cfg = manifest
            .config(&req.config)
            .with_context(|| format!("unknown artifact config '{}'", req.config))?;
        let fname = cfg
            .entries
            .get(&req.entry)
            .with_context(|| format!("config '{}' has no entry '{}'", req.config, req.entry))?;
        let path = dir.join(fname);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}/{}: {e}", req.config, req.entry))?;
        cache.insert(key.clone(), exe);
    }
    let exe = cache.get(&key).unwrap();

    let literals: Vec<xla::Literal> =
        req.inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;

    let t0 = Instant::now();
    let bufs = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing {}/{}: {e}", req.config, req.entry))?;
    let out_literal = bufs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result of {}/{}: {e}", req.config, req.entry))?;
    let wall_s = t0.elapsed().as_secs_f64();

    // aot.py lowers with return_tuple=True: the root is always a tuple.
    let parts = out_literal
        .to_tuple()
        .map_err(|e| anyhow!("untupling result of {}/{}: {e}", req.config, req.entry))?;
    let outputs: Vec<Tensor> = parts.iter().map(literal_to_tensor).collect::<Result<_>>()?;
    Ok(ExecReply { outputs, wall_s })
}

/// Host tensor -> XLA literal (f32, row-major). Single copy: the literal is
/// created directly from the tensor's bytes with its final shape (§Perf:
/// the previous vec1+reshape path copied twice per input).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), bytes)
        .map_err(|e| anyhow!("literal from shape {:?}: {e}", t.shape()))
}

/// XLA literal -> host tensor. Scalars become shape [1].
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
    let dims = if dims.is_empty() { vec![1] } else { dims };
    if dims.iter().product::<usize>() != data.len() {
        bail!("literal shape {:?} disagrees with {} elements", dims, data.len());
    }
    Tensor::from_vec(&dims, data)
}
