//! Execution runtime: pluggable compute `Backend`s behind a uniform
//! handle, serving the artifact entry points the coordinator's rank threads
//! execute between collectives.
//!
//! Two backends implement the contract (DESIGN.md §3):
//!
//! * `NativeBackend` (native.rs, always available) — fused pure-Rust
//!   kernels over the blocked-GEMM tensor substrate. Self-contained: no
//!   artifact directory, no PJRT/XLA install.
//! * The PJRT executor (pjrt.rs, behind the `xla` cargo feature) — loads
//!   AOT HLO artifacts and executes them through a dedicated executor
//!   thread (the `xla` crate's wrappers hold raw pointers and are !Send).
//!
//! Both serialize kernel execution so the `wall_s` each reply reports is
//! free of cross-rank CPU contention — the virtual-time model (DESIGN.md
//! §2) wants each rank's compute time as if it had the device to itself.
//!
//! `ExecHandle::execute` borrows its inputs (`&[&Tensor]`): rank workers
//! pass weights, decompressors and activations by reference every
//! iteration instead of cloning them per call.

pub mod manifest;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{BackendKind, RunConfig};
use crate::tensor::Tensor;
pub use manifest::{Manifest, ManifestConfig};
pub use native::NativeBackend;

/// Execution result: output tensors (tuple-unpacked) + wall time of the
/// kernel on the backend, measured contention-free.
pub struct ExecReply {
    pub outputs: Vec<Tensor>,
    pub wall_s: f64,
}

/// A compute backend. Implementations must (DESIGN.md §3):
/// 1. be callable from many rank threads concurrently,
/// 2. report `wall_s` as the kernel's own execution time, serialized or
///    otherwise isolated from cross-rank CPU contention, and
/// 3. compute exactly the entry-point semantics of
///    python/compile/kernels/ref.py.
///
/// Entry points are batch-size polymorphic: shape checks are structural
/// (consistency among the inputs), with only the loss scale baked in from
/// the manifest config. The serving micro-batcher (serve/batcher.rs)
/// relies on this to dispatch partial batches of any size up to
/// `max_batch` through the same backend the fixed-batch trainer uses.
pub trait Backend: Send + Sync {
    /// Execute `entry` of artifact-config `config`; blocks until done.
    fn execute(&self, config: &str, entry: &str, inputs: &[&Tensor]) -> Result<ExecReply>;

    /// Short name for reports ("native", "pjrt").
    fn name(&self) -> &'static str;
}

/// Cloneable handle used by rank threads.
#[derive(Clone)]
pub struct ExecHandle {
    backend: Arc<dyn Backend>,
}

impl ExecHandle {
    /// Execute an entry point; blocks until the backend replies. Inputs are
    /// borrowed — the caller keeps ownership of weights and activations.
    pub fn execute(&self, config: &str, entry: &str, inputs: &[&Tensor]) -> Result<ExecReply> {
        self.backend.execute(config, entry, inputs)
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

/// The execution server handed to the coordinator: a backend plus the
/// manifest describing the artifact-config geometries it can serve.
pub struct ExecServer {
    backend: Arc<dyn Backend>,
    pub manifest: Manifest,
}

impl ExecServer {
    pub(crate) fn new(backend: Arc<dyn Backend>, manifest: Manifest) -> ExecServer {
        ExecServer { backend, manifest }
    }

    /// The native backend over the full preset-config set — the default
    /// way to run on a machine with no artifacts and no libxla.
    pub fn native() -> ExecServer {
        let manifest = native::preset_manifest();
        ExecServer::new(Arc::new(NativeBackend::new(manifest.clone())), manifest)
    }

    /// Native backend guaranteed to serve `cfg`'s geometry: the preset set
    /// plus a synthetic config under `cfg`'s artifact name (overriding a
    /// preset of the same name if the geometry was customized).
    pub fn native_for(cfg: &RunConfig) -> Result<ExecServer> {
        let mut manifest = native::preset_manifest();
        if let Some(name) = cfg.artifact.as_deref() {
            manifest.insert(ManifestConfig::native(
                name,
                cfg.p,
                cfg.model.n,
                cfg.model.k,
                cfg.train.batch,
            ));
        }
        Ok(ExecServer::new(Arc::new(NativeBackend::new(manifest.clone())), manifest))
    }

    /// Start the PJRT executor for the given artifact directory. Requires
    /// the `xla` cargo feature; without it this fails with a pointer to
    /// `ExecServer::native()`.
    #[cfg(feature = "xla")]
    pub fn start(artifact_dir: impl AsRef<Path>) -> Result<ExecServer> {
        pjrt::start(artifact_dir.as_ref())
    }

    #[cfg(not(feature = "xla"))]
    pub fn start(artifact_dir: impl AsRef<Path>) -> Result<ExecServer> {
        let _ = artifact_dir.as_ref();
        anyhow::bail!(
            "this build has no PJRT support (the `xla` cargo feature is off); \
             use the native backend instead (ExecServer::native() / --backend native)"
        )
    }

    /// Start a backend with no run geometry attached: the native preset
    /// manifest, or the PJRT executor over the default artifact directory.
    /// The single dispatch point for `BackendKind` (CLI, benches).
    pub fn for_backend(kind: BackendKind) -> Result<ExecServer> {
        match kind {
            BackendKind::Native => Ok(ExecServer::native()),
            BackendKind::Xla => Self::start(default_artifact_dir()),
        }
    }

    /// Start the backend selected by `cfg.backend`, guaranteeing `cfg`'s
    /// geometry is servable.
    pub fn for_run(cfg: &RunConfig) -> Result<ExecServer> {
        match cfg.backend {
            BackendKind::Native => Self::native_for(cfg),
            BackendKind::Xla => Self::for_backend(BackendKind::Xla),
        }
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle { backend: self.backend.clone() }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Explicit shutdown; equivalent to dropping the server (backends tear
    /// down their executor threads on drop).
    pub fn shutdown(self) {}
}

/// Locate the artifact directory: $PHANTOM_ARTIFACTS or the nearest
/// ancestor directory containing artifacts/manifest.json.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PHANTOM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
