//! Artifact manifest: the contract between python/compile/aot.py and the
//! Rust runtime. Parsed from artifacts/manifest.json with util::json.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One static-shape artifact bundle (mirrors python/compile/shapes.py).
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestConfig {
    pub name: String,
    pub p: usize,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    /// Shard width n/p.
    pub np: usize,
    /// Baked-in MSE gradient scale 1/(batch*n).
    pub scale: f64,
    /// "jnp" (XLA-fused fast path) or "pallas" (L1 interpret kernels).
    pub variant: String,
    /// entry name -> HLO text filename.
    pub entries: BTreeMap<String, String>,
}

impl ManifestConfig {
    /// A synthetic config for the native backend: same geometry contract as
    /// an AOT bundle (np = n/p, scale = 1/(batch*n) baked into the loss
    /// kernels), but with no HLO files behind it.
    pub fn native(name: &str, p: usize, n: usize, k: usize, batch: usize) -> ManifestConfig {
        assert!(p > 0 && n % p == 0, "native config '{name}': n={n} not divisible by p={p}");
        ManifestConfig {
            name: name.to_string(),
            p,
            n,
            k,
            batch,
            np: n / p,
            scale: 1.0 / ((batch * n) as f64),
            variant: "native".to_string(),
            entries: BTreeMap::new(),
        }
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub fingerprint: String,
    configs: BTreeMap<String, ManifestConfig>,
}

impl Manifest {
    /// Build a manifest from in-memory configs (no artifact files). Used by
    /// the native backend, which has no on-disk bundle.
    pub fn synthetic(configs: Vec<ManifestConfig>) -> Manifest {
        let mut m = Manifest { fingerprint: "synthetic".to_string(), ..Default::default() };
        for c in configs {
            m.insert(c);
        }
        m
    }

    /// Insert (or replace) a config.
    pub fn insert(&mut self, cfg: ManifestConfig) {
        self.configs.insert(cfg.name.clone(), cfg);
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} (run `make artifacts` to build the AOT bundle)",
                path.display()
            )
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j.get("version").as_i64().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version} (want 1)");
        }
        let mut configs = BTreeMap::new();
        for c in j.get("configs").as_arr().context("manifest: configs[]")?.iter() {
            let name = c.get("name").as_str().context("config name")?.to_string();
            let entries = c
                .get("entries")
                .as_obj()
                .context("config entries")?
                .iter()
                .map(|(k, v)| {
                    v.as_str()
                        .map(|s| (k.clone(), s.to_string()))
                        .ok_or_else(|| anyhow!("entry '{k}' is not a string"))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            let cfg = ManifestConfig {
                name: name.clone(),
                p: c.get("p").as_usize().context("p")?,
                n: c.get("n").as_usize().context("n")?,
                k: c.get("k").as_usize().context("k")?,
                batch: c.get("batch").as_usize().context("batch")?,
                np: c.get("np").as_usize().context("np")?,
                scale: c.get("scale").as_f64().context("scale")?,
                variant: c.get("variant").as_str().unwrap_or("jnp").to_string(),
                entries,
            };
            if cfg.np * cfg.p != cfg.n {
                bail!("config '{name}': np * p != n ({} * {} != {})", cfg.np, cfg.p, cfg.n);
            }
            configs.insert(name, cfg);
        }
        Ok(Manifest {
            fingerprint: j.get("fingerprint").as_str().unwrap_or("").to_string(),
            configs,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ManifestConfig> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!(
                "artifact config '{name}' not in manifest (have: {})",
                self.names().join(", ")
            )
        })
    }

    pub fn names(&self) -> Vec<String> {
        self.configs.keys().cloned().collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ManifestConfig> {
        self.configs.values()
    }

    /// Find a config matching the run geometry.
    pub fn find(
        &self,
        p: usize,
        n: usize,
        k: usize,
        batch: usize,
        variant: &str,
    ) -> Option<&ManifestConfig> {
        self.configs.values().find(|c| {
            c.p == p && c.n == n && c.k == k && c.batch == batch && c.variant == variant
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "fingerprint": "abc123",
      "configs": [
        {"name": "tiny", "p": 4, "n": 64, "k": 4, "batch": 8, "np": 16,
         "scale": 0.001953125, "variant": "jnp",
         "entries": {"pp_fwd_local": "pp_fwd_local__tiny.hlo.txt"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.fingerprint, "abc123");
        let c = m.config("tiny").unwrap();
        assert_eq!(c.p, 4);
        assert_eq!(c.np, 16);
        assert_eq!(c.entries["pp_fwd_local"], "pp_fwd_local__tiny.hlo.txt");
        assert!(m.config("nope").is_err());
        assert_eq!(m.find(4, 64, 4, 8, "jnp").unwrap().name, "tiny");
        assert!(m.find(4, 64, 4, 8, "pallas").is_none());
    }

    #[test]
    fn synthetic_native_configs() {
        let m = Manifest::synthetic(vec![ManifestConfig::native("tiny", 4, 64, 4, 8)]);
        let c = m.config("tiny").unwrap();
        assert_eq!(c.np, 16);
        assert!((c.scale - 1.0 / (8.0 * 64.0)).abs() < 1e-15);
        assert_eq!(c.variant, "native");
        assert!(c.entries.is_empty());
        let mut m = m;
        m.insert(ManifestConfig::native("tiny", 2, 64, 4, 8)); // replace
        assert_eq!(m.config("tiny").unwrap().p, 2);
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 2, "configs": []}"#).is_err());
    }

    #[test]
    fn rejects_inconsistent_np() {
        let bad = SAMPLE.replace("\"np\": 16", "\"np\": 8");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = crate::runtime::default_artifact_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.config("tiny").is_ok());
            let tiny = m.config("tiny").unwrap();
            // every entry file must exist on disk
            for f in tiny.entries.values() {
                assert!(dir.join(f).exists(), "{f} missing");
            }
        }
    }
}
