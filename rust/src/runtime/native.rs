//! NativeBackend: the self-contained pure-Rust compute backend.
//!
//! Implements every artifact entry point the rank workers execute
//! (python/compile/kernels/ref.py semantics) as fused kernels over the
//! blocked-GEMM tensor substrate:
//!
//! * forward: `pp_fwd_local`, `pp_fwd_combine`, `pp_fwd_step`, `tp_fwd`
//! * loss:    `mse_delta`, `pp_loss_step`
//! * backward: `pp_bwd_compress`, `pp_bwd_combine`, `pp_bwd_step`,
//!   `pp_grads`, `tp_bwd_partial`, `tp_bwd_finish`, `tp_bwd_step`,
//!   `tp_grads`
//!
//! "Fused" here means each inter-collective segment is ONE backend call
//! whose multi-term products accumulate into a single output buffer
//! (`gemm_acc` / `gemm_a_bt_acc` / `gemm_at_b_acc`) — no intermediate
//! tensors are materialized between the matmul, bias, and activation
//! stages, unlike the unfused composition the property tests compare
//! against.
//!
//! The backward kernels allocate their output tensors from the bounded
//! band pool (`Tensor::zeros_pooled`, the same pool the GEMM bands pack
//! panels from): the rank loops recycle them when the gradients die, so
//! a steady-state training iteration reuses the same buffers call after
//! call instead of churning the allocator once per micro-batch.
//!
//! Shape conventions (batch-major, matching ref.py):
//!   y [B, m] · L [m, m] · C [m, k] · D [p, k, m] · g_all [p, B, k] ·
//!   b [m] · h_sum [B, k];  m = n/p.
//!
//! A kernel call is serialized behind a mutex so the wall time it reports
//! is free of cross-rank CPU contention (the virtual-time contract,
//! DESIGN.md §3); the GEMMs inside a call still use every core via
//! row-band threading.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use super::{Backend, ExecReply, Manifest, ManifestConfig};
use crate::config::{preset, preset_names, Parallelism};
use crate::tensor::{gemm_a_bt_acc, gemm_acc, gemm_at_b_acc, Tensor};

/// The synthetic manifest the native backend serves by default: every
/// preset geometry from config::preset, no files behind any of them.
pub fn preset_manifest() -> Manifest {
    let mut m = Manifest::synthetic(Vec::new());
    for name in preset_names() {
        let cfg = preset(name, Parallelism::Phantom).expect("preset table entry");
        m.insert(ManifestConfig::native(
            name,
            cfg.p,
            cfg.model.n,
            cfg.model.k,
            cfg.train.batch,
        ));
    }
    m
}

pub struct NativeBackend {
    manifest: Manifest,
    /// Serializes kernel execution so each reply's wall time is measured
    /// as if the rank had the machine to itself.
    gate: Mutex<()>,
}

impl NativeBackend {
    pub fn new(manifest: Manifest) -> NativeBackend {
        // Pick up the persisted GEMM tuning manifest (phantom-tune.json /
        // $PHANTOM_TUNE) once per process, so every kernel this backend
        // dispatches runs with tuned block/thread parameters.
        crate::tensor::tune::ensure_loaded();
        NativeBackend { manifest, gate: Mutex::new(()) }
    }
}

impl Backend for NativeBackend {
    fn execute(&self, config: &str, entry: &str, inputs: &[&Tensor]) -> Result<ExecReply> {
        let geo = self.manifest.config(config)?;
        let _serialized = self.gate.lock().unwrap_or_else(|p| p.into_inner());
        let t0 = Instant::now();
        let outputs = run_entry(geo, entry, inputs)?;
        Ok(ExecReply { outputs, wall_s: t0.elapsed().as_secs_f64() })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Dispatch one entry point. Shape checks are structural (consistency
/// among the inputs); the config supplies only the baked-in loss scale,
/// exactly as the AOT artifacts bake 1/(batch*n) into their loss kernels.
pub fn run_entry(geo: &ManifestConfig, entry: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    match entry {
        "pp_fwd_local" => {
            let [y, l, c] = args(entry, inputs)?;
            pp_fwd_local(entry, y, l, c)
        }
        "pp_fwd_combine" => {
            let [z_loc, g_all, d, b] = args(entry, inputs)?;
            pp_fwd_combine(entry, z_loc, g_all, d, b)
        }
        "pp_fwd_step" => {
            // fused: combine(l) then local(l+1) on the fresh activation
            let [z_loc, g_all, d, b, l_next, c_next] = args(entry, inputs)?;
            let mut out = pp_fwd_combine(entry, z_loc, g_all, d, b)?;
            let next = pp_fwd_local(entry, &out[0], l_next, c_next)?;
            out.extend(next);
            Ok(out)
        }
        "mse_delta" => {
            let [y, z, t] = args(entry, inputs)?;
            mse_delta(entry, y, z, t, geo.scale as f32)
        }
        "pp_loss_step" => {
            // fused: mse_delta then compress of the fresh top-layer error
            let [y, z, t, d] = args(entry, inputs)?;
            let mut out = mse_delta(entry, y, z, t, geo.scale as f32)?;
            let h_out = compress(entry, &out[1], d)?;
            out.push(h_out);
            Ok(out)
        }
        "pp_bwd_compress" => {
            let [delta, d] = args(entry, inputs)?;
            Ok(vec![compress(entry, delta, d)?])
        }
        "pp_bwd_combine" => {
            let [delta, h_sum, l, c, z_prev] = args(entry, inputs)?;
            Ok(vec![pp_bwd_combine(entry, delta, h_sum, l, c, z_prev)?])
        }
        "pp_bwd_step" => {
            // fused: combine(l) then compress(l-1) of the fresh error
            let [delta, h_sum, l, c, z_prev, d_prev] = args(entry, inputs)?;
            let delta_prev = pp_bwd_combine(entry, delta, h_sum, l, c, z_prev)?;
            let h_out_prev = compress(entry, &delta_prev, d_prev)?;
            Ok(vec![delta_prev, h_out_prev])
        }
        "pp_grads" => {
            let [y_prev, delta, h_sum, g_all] = args(entry, inputs)?;
            pp_grads(entry, y_prev, delta, h_sum, g_all)
        }
        "tp_fwd" => {
            let [y_full, w, b] = args(entry, inputs)?;
            tp_fwd(entry, y_full, w, b)
        }
        "tp_grads" => {
            let [y_full, delta] = args(entry, inputs)?;
            tp_grads(entry, y_full, delta)
        }
        "tp_bwd_partial" => {
            let [delta, w] = args(entry, inputs)?;
            let (bsz, m) = d2(entry, "delta", delta)?;
            let (n, mw) = d2(entry, "W", w)?;
            if mw != m {
                bail!("{entry}: delta {:?} vs W {:?}", delta.shape(), w.shape());
            }
            let mut dy = Tensor::zeros_pooled(&[bsz, n]);
            delta.matmul_a_bt_into(w, &mut dy)?;
            Ok(vec![dy])
        }
        "tp_bwd_finish" => {
            let [dy, z_prev] = args(entry, inputs)?;
            Ok(vec![tp_bwd_finish(entry, dy, z_prev)?])
        }
        "tp_bwd_step" => {
            // fused: finish(l-1) then grads(l-1) from the fresh error
            let [dy, z_prev, y_full] = args(entry, inputs)?;
            let delta = tp_bwd_finish(entry, dy, z_prev)?;
            let grads = tp_grads(entry, y_full, &delta)?;
            let mut out = vec![delta];
            out.extend(grads);
            Ok(out)
        }
        other => bail!(
            "native backend has no entry '{other}' (config '{}'); \
             see runtime/native.rs for the entry-point inventory",
            geo.name
        ),
    }
}

// -- kernel bodies ----------------------------------------------------------

/// (z_loc, g) = (y @ L, y @ C): the per-rank forward hot-spot.
fn pp_fwd_local(entry: &str, y: &Tensor, l: &Tensor, c: &Tensor) -> Result<Vec<Tensor>> {
    let (bsz, m) = d2(entry, "y", y)?;
    let (ml, ml2) = d2(entry, "L", l)?;
    let (mc, k) = d2(entry, "C", c)?;
    if ml != m || ml2 != m || mc != m {
        bail!("{entry}: y {:?} vs L {:?} vs C {:?}", y.shape(), l.shape(), c.shape());
    }
    let mut z_loc = Tensor::zeros(&[bsz, m]);
    y.matmul_into(l, &mut z_loc)?;
    let mut g = Tensor::zeros(&[bsz, k]);
    y.matmul_into(c, &mut g)?;
    Ok(vec![z_loc, g])
}

/// z = z_loc + sum_i g_all[i] @ D[i] + b;  y_out = relu(z).
/// The p decompression products accumulate straight into z.
fn pp_fwd_combine(
    entry: &str,
    z_loc: &Tensor,
    g_all: &Tensor,
    d: &Tensor,
    b: &Tensor,
) -> Result<Vec<Tensor>> {
    let (bsz, m) = d2(entry, "z_loc", z_loc)?;
    let (p, bg, k) = d3(entry, "g_all", g_all)?;
    let (pd, kd, md) = d3(entry, "D", d)?;
    if bg != bsz || pd != p || kd != k || md != m || b.shape() != &[m] {
        bail!(
            "{entry}: z_loc {:?} vs g_all {:?} vs D {:?} vs b {:?}",
            z_loc.shape(),
            g_all.shape(),
            d.shape(),
            b.shape()
        );
    }
    let mut z = z_loc.clone();
    for i in 0..p {
        gemm_acc(
            &g_all.data()[i * bsz * k..(i + 1) * bsz * k],
            bsz,
            k,
            &d.data()[i * k * m..(i + 1) * k * m],
            m,
            z.data_mut(),
        );
    }
    for row in z.data_mut().chunks_mut(m) {
        for (x, &bv) in row.iter_mut().zip(b.data()) {
            *x += bv;
        }
    }
    let y_out = z.relu();
    Ok(vec![y_out, z])
}

/// loss = sum((y - t)^2) (rank-local partial), delta = 2*scale*(y - t)*relu'(z).
fn mse_delta(entry: &str, y: &Tensor, z: &Tensor, t: &Tensor, scale: f32) -> Result<Vec<Tensor>> {
    if y.shape() != z.shape() || y.shape() != t.shape() || y.shape().len() != 2 {
        bail!("{entry}: y {:?} vs z {:?} vs target {:?}", y.shape(), z.shape(), t.shape());
    }
    let mut delta = Tensor::zeros_pooled(y.shape());
    let mut loss = 0.0f64;
    let two_scale = 2.0 * scale;
    for ((dv, &yv), (&zv, &tv)) in delta
        .data_mut()
        .iter_mut()
        .zip(y.data())
        .zip(z.data().iter().zip(t.data()))
    {
        let diff = yv - tv;
        loss += (diff as f64) * (diff as f64);
        *dv = if zv > 0.0 { two_scale * diff } else { 0.0 };
    }
    Ok(vec![Tensor::from_vec(&[1], vec![loss as f32])?, delta])
}

/// h_out[i] = delta @ D[i]ᵀ for every destination rank i: [p, B, k].
fn compress(entry: &str, delta: &Tensor, d: &Tensor) -> Result<Tensor> {
    let (bsz, m) = d2(entry, "delta", delta)?;
    let (p, k, md) = d3(entry, "D", d)?;
    if md != m {
        bail!("{entry}: delta {:?} vs D {:?}", delta.shape(), d.shape());
    }
    let mut h = Tensor::zeros_pooled(&[p, bsz, k]);
    for i in 0..p {
        gemm_a_bt_acc(
            delta.data(),
            bsz,
            m,
            &d.data()[i * k * m..(i + 1) * k * m],
            k,
            &mut h.data_mut()[i * bsz * k..(i + 1) * bsz * k],
        );
    }
    Ok(h)
}

/// delta_prev = (delta @ Lᵀ + h_sum @ Cᵀ) * relu'(z_prev), the two products
/// accumulated into one buffer before masking.
fn pp_bwd_combine(
    entry: &str,
    delta: &Tensor,
    h_sum: &Tensor,
    l: &Tensor,
    c: &Tensor,
    z_prev: &Tensor,
) -> Result<Tensor> {
    let (bsz, m) = d2(entry, "delta", delta)?;
    let (bh, k) = d2(entry, "h_sum", h_sum)?;
    let (ml, ml2) = d2(entry, "L", l)?;
    let (mc, kc) = d2(entry, "C", c)?;
    if bh != bsz || ml != m || ml2 != m || mc != m || kc != k || z_prev.shape() != &[bsz, m] {
        bail!(
            "{entry}: delta {:?} / h_sum {:?} / L {:?} / C {:?} / z_prev {:?}",
            delta.shape(),
            h_sum.shape(),
            l.shape(),
            c.shape(),
            z_prev.shape()
        );
    }
    let mut out = Tensor::zeros_pooled(&[bsz, m]);
    delta.matmul_a_bt_into(l, &mut out)?;
    gemm_a_bt_acc(h_sum.data(), bsz, k, c.data(), m, out.data_mut());
    for (o, &zv) in out.data_mut().iter_mut().zip(z_prev.data()) {
        if zv <= 0.0 {
            *o = 0.0;
        }
    }
    Ok(out)
}

/// Parameter gradients (paper Eqns. 18-21), batch-summed:
/// dL = y_prevᵀ @ delta; dC = y_prevᵀ @ h_sum; dD[i] = g_all[i]ᵀ @ delta;
/// db = sum_B delta. The own slot of dD is structurally zero because the
/// coordinator zeroed the own slot of g_all.
fn pp_grads(
    entry: &str,
    y_prev: &Tensor,
    delta: &Tensor,
    h_sum: &Tensor,
    g_all: &Tensor,
) -> Result<Vec<Tensor>> {
    let (bsz, m) = d2(entry, "y_prev", y_prev)?;
    let (bd, md) = d2(entry, "delta", delta)?;
    let (bh, k) = d2(entry, "h_sum", h_sum)?;
    let (p, bg, kg) = d3(entry, "g_all", g_all)?;
    if bd != bsz || md != m || bh != bsz || bg != bsz || kg != k {
        bail!(
            "{entry}: y_prev {:?} / delta {:?} / h_sum {:?} / g_all {:?}",
            y_prev.shape(),
            delta.shape(),
            h_sum.shape(),
            g_all.shape()
        );
    }
    let mut dl = Tensor::zeros_pooled(&[m, m]);
    y_prev.matmul_at_b_into(delta, &mut dl)?;
    let mut dc = Tensor::zeros_pooled(&[m, k]);
    y_prev.matmul_at_b_into(h_sum, &mut dc)?;
    let mut dd = Tensor::zeros_pooled(&[p, k, m]);
    for i in 0..p {
        gemm_at_b_acc(
            &g_all.data()[i * bsz * k..(i + 1) * bsz * k],
            bsz,
            k,
            delta.data(),
            m,
            &mut dd.data_mut()[i * k * m..(i + 1) * k * m],
        );
    }
    let db = col_sum(delta, m);
    Ok(vec![dl, dc, dd, db])
}

/// z = y_full @ W + b;  y_out = relu(z).
fn tp_fwd(entry: &str, y_full: &Tensor, w: &Tensor, b: &Tensor) -> Result<Vec<Tensor>> {
    let (bsz, n) = d2(entry, "y_full", y_full)?;
    let (nw, m) = d2(entry, "W", w)?;
    if nw != n || b.shape() != &[m] {
        bail!("{entry}: y_full {:?} vs W {:?} vs b {:?}", y_full.shape(), w.shape(), b.shape());
    }
    let mut z = Tensor::zeros(&[bsz, m]);
    y_full.matmul_into(w, &mut z)?;
    for row in z.data_mut().chunks_mut(m) {
        for (x, &bv) in row.iter_mut().zip(b.data()) {
            *x += bv;
        }
    }
    let y_out = z.relu();
    Ok(vec![y_out, z])
}

/// dW = y_fullᵀ @ delta; db = sum_B delta.
fn tp_grads(entry: &str, y_full: &Tensor, delta: &Tensor) -> Result<Vec<Tensor>> {
    let (bsz, n) = d2(entry, "y_full", y_full)?;
    let (bd, m) = d2(entry, "delta", delta)?;
    if bd != bsz {
        bail!("{entry}: y_full {:?} vs delta {:?}", y_full.shape(), delta.shape());
    }
    let mut dw = Tensor::zeros_pooled(&[n, m]);
    y_full.matmul_at_b_into(delta, &mut dw)?;
    let db = col_sum(delta, m);
    Ok(vec![dw, db])
}

/// delta = dy * relu'(z_prev).
fn tp_bwd_finish(entry: &str, dy: &Tensor, z_prev: &Tensor) -> Result<Tensor> {
    if dy.shape() != z_prev.shape() || dy.shape().len() != 2 {
        bail!("{entry}: dy {:?} vs z_prev {:?}", dy.shape(), z_prev.shape());
    }
    let mut out = Tensor::zeros_pooled(dy.shape());
    for ((o, &dv), &zv) in out.data_mut().iter_mut().zip(dy.data()).zip(z_prev.data()) {
        *o = if zv > 0.0 { dv } else { 0.0 };
    }
    Ok(out)
}

// -- small helpers ----------------------------------------------------------

/// Fixed-arity input unpack with a good error message.
fn args<'a, const N: usize>(entry: &str, inputs: &[&'a Tensor]) -> Result<[&'a Tensor; N]> {
    if inputs.len() != N {
        bail!("{entry}: expected {N} inputs, got {}", inputs.len());
    }
    Ok(std::array::from_fn(|i| inputs[i]))
}

fn d2(entry: &str, what: &str, t: &Tensor) -> Result<(usize, usize)> {
    match t.shape() {
        [a, b] => Ok((*a, *b)),
        s => bail!("{entry}: {what} must be 2-D, got {s:?}"),
    }
}

fn d3(entry: &str, what: &str, t: &Tensor) -> Result<(usize, usize, usize)> {
    match t.shape() {
        [a, b, c] => Ok((*a, *b, *c)),
        s => bail!("{entry}: {what} must be 3-D, got {s:?}"),
    }
}

/// Column sums of a [B, m] tensor -> [m].
fn col_sum(t: &Tensor, m: usize) -> Tensor {
    let mut out = Tensor::zeros_pooled(&[m]);
    for row in t.data().chunks(m) {
        for (o, &v) in out.data_mut().iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ExecServer;
    use crate::util::prng::Prng;
    use crate::util::proptest::assert_close;

    fn geo() -> ManifestConfig {
        ManifestConfig::native("t", 4, 64, 4, 8)
    }

    #[test]
    fn preset_manifest_serves_every_preset() {
        let m = preset_manifest();
        for name in preset_names() {
            let c = m.config(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(c.variant, "native");
            assert_eq!(c.np * c.p, c.n);
        }
    }

    #[test]
    fn pp_fwd_local_matches_naive() {
        let mut rng = Prng::new(1);
        let y = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let l = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let c = Tensor::randn(&[16, 4], 1.0, &mut rng);
        let out = run_entry(&geo(), "pp_fwd_local", &[&y, &l, &c]).unwrap();
        assert_close(out[0].data(), y.matmul_naive(&l).unwrap().data(), 1e-5, 1e-6).unwrap();
        assert_close(out[1].data(), y.matmul_naive(&c).unwrap().data(), 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn pp_fwd_combine_matches_unfused_reference() {
        let (p, bsz, k, m) = (3usize, 5usize, 2usize, 6usize);
        let mut rng = Prng::new(2);
        let z_loc = Tensor::randn(&[bsz, m], 1.0, &mut rng);
        let g_all = Tensor::randn(&[p, bsz, k], 1.0, &mut rng);
        let d = Tensor::randn(&[p, k, m], 1.0, &mut rng);
        let b = Tensor::randn(&[m], 1.0, &mut rng);
        let out = run_entry(&geo(), "pp_fwd_combine", &[&z_loc, &g_all, &d, &b]).unwrap();

        // unfused: z = z_loc + sum_i g[i] @ D[i] + b, y = relu(z)
        let mut z = z_loc.clone();
        for i in 0..p {
            z.add_assign(&g_all.unstack_at(i).matmul_naive(&d.unstack_at(i)).unwrap());
        }
        for r in 0..bsz {
            for cidx in 0..m {
                let v = z.at(&[r, cidx]) + b.data()[cidx];
                z.set(&[r, cidx], v);
            }
        }
        assert_close(out[1].data(), z.data(), 1e-5, 1e-6).unwrap();
        assert_close(out[0].data(), z.relu().data(), 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn tp_fwd_matches_naive() {
        let mut rng = Prng::new(3);
        let y = Tensor::randn(&[4, 12], 1.0, &mut rng);
        let w = Tensor::randn(&[12, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[3], 1.0, &mut rng);
        let out = run_entry(&geo(), "tp_fwd", &[&y, &w, &b]).unwrap();
        let mut z = y.matmul_naive(&w).unwrap();
        for r in 0..4 {
            for c in 0..3 {
                let v = z.at(&[r, c]) + b.data()[c];
                z.set(&[r, c], v);
            }
        }
        assert_close(out[1].data(), z.data(), 1e-5, 1e-6).unwrap();
        assert_close(out[0].data(), z.relu().data(), 1e-5, 1e-6).unwrap();
    }

    #[test]
    fn mse_delta_uses_config_scale() {
        let g = geo(); // scale = 1/(8*64)
        let y = Tensor::from_vec(&[1, 2], vec![1.0, -1.0]).unwrap();
        let z = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]).unwrap();
        let t = Tensor::from_vec(&[1, 2], vec![0.0, 0.0]).unwrap();
        let out = run_entry(&g, "mse_delta", &[&y, &z, &t]).unwrap();
        assert!((out[0].data()[0] - 2.0).abs() < 1e-6); // 1 + 1
        let s = 2.0 * (g.scale as f32);
        // z > 0 passes the gradient; z <= 0 kills it
        assert!((out[1].data()[0] - s).abs() < 1e-7);
        assert_eq!(out[1].data()[1], 0.0);
    }

    #[test]
    fn grads_own_slot_stays_zero() {
        let (p, bsz, k, m) = (4usize, 8usize, 3usize, 5usize);
        let mut rng = Prng::new(4);
        let y_prev = Tensor::randn(&[bsz, m], 1.0, &mut rng);
        let delta = Tensor::randn(&[bsz, m], 1.0, &mut rng);
        let h_sum = Tensor::randn(&[bsz, k], 1.0, &mut rng);
        let mut g_all = Tensor::randn(&[p, bsz, k], 1.0, &mut rng);
        g_all.zero_slot(2);
        let out = run_entry(&geo(), "pp_grads", &[&y_prev, &delta, &h_sum, &g_all]).unwrap();
        let dd = &out[2];
        assert!(dd.unstack_at(2).data().iter().all(|&v| v == 0.0));
        assert!(dd.unstack_at(0).data().iter().any(|&v| v != 0.0));
        // db is the column sum of delta
        let db = &out[3];
        let mut want = vec![0.0f32; m];
        for row in delta.data().chunks(m) {
            for (o, &v) in want.iter_mut().zip(row) {
                *o += v;
            }
        }
        assert_close(db.data(), &want, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn bad_arity_and_unknown_entry_error() {
        let y = Tensor::zeros(&[2, 2]);
        assert!(run_entry(&geo(), "pp_fwd_local", &[&y]).is_err());
        assert!(run_entry(&geo(), "no_such_entry", &[&y]).is_err());
        let l = Tensor::zeros(&[3, 3]); // mismatched vs y
        let c = Tensor::zeros(&[3, 1]);
        assert!(run_entry(&geo(), "pp_fwd_local", &[&y, &l, &c]).is_err());
    }

    #[test]
    fn executes_through_server_handle() {
        let server = ExecServer::native();
        let h = server.handle();
        assert_eq!(h.backend_name(), "native");
        let g = server.manifest.config("tiny").unwrap().clone();
        let mut rng = Prng::new(5);
        let y = Tensor::randn(&[g.batch, g.np], 1.0, &mut rng);
        let l = Tensor::randn(&[g.np, g.np], 1.0, &mut rng);
        let c = Tensor::randn(&[g.np, g.k], 1.0, &mut rng);
        let r = h.execute("tiny", "pp_fwd_local", &[&y, &l, &c]).unwrap();
        assert_eq!(r.outputs.len(), 2);
        assert_eq!(r.outputs[0].shape(), &[g.batch, g.np]);
        assert_eq!(r.outputs[1].shape(), &[g.batch, g.k]);
        assert!(r.wall_s >= 0.0);
        assert!(h.execute("nope", "pp_fwd_local", &[&y, &l, &c]).is_err());
    }
}
