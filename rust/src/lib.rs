//! Phantom parallelism: an energy-efficient alternative to tensor
//! parallelism for neural-network training and inferencing.
//!
//! Rust reproduction of Seal et al., *A Parallel Alternative for
//! Energy-Efficient Neural Network Training and Inferencing* (ORNL, 2025),
//! built as a three-layer stack:
//!
//! * L1 — Pallas kernels (python/compile/kernels, build-time only)
//! * L2 — JAX per-rank step functions, AOT-lowered to HLO text artifacts
//! * L3 — this crate: the distributed coordinator, collective fabric,
//!   virtual-time network + energy simulation, training loop, and the
//!   experiment harness that regenerates every table/figure of the paper.
//!
//! The crate is self-contained by default: the native backend
//! (runtime/native.rs) executes every per-rank kernel as fused pure-Rust
//! GEMMs, so L1/L2 and the PJRT runtime are optional (`xla` cargo
//! feature) accelerators rather than prerequisites.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod ckpt;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod model;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod simnet;
pub mod tensor;
pub mod testkit;
pub mod train;
pub mod util;
