//! Typed configuration system: model / parallelism / training / hardware,
//! with JSON load/save and validation. Presets mirror the artifact config
//! set in python/compile/shapes.py.

use anyhow::{bail, Context, Result};

use crate::energy::PowerModel;
use crate::simnet::NetworkProfile;
use crate::util::json::Json;

/// Which parallelism strategy a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// Conventional tensor parallelism (the paper's baseline).
    Tensor,
    /// Phantom parallelism (the paper's contribution).
    Phantom,
}

impl Parallelism {
    pub fn name(&self) -> &'static str {
        match self {
            Parallelism::Tensor => "tp",
            Parallelism::Phantom => "pp",
        }
    }

    pub fn parse(s: &str) -> Result<Parallelism> {
        match s {
            "tp" | "tensor" => Ok(Parallelism::Tensor),
            "pp" | "phantom" => Ok(Parallelism::Phantom),
            _ => bail!("unknown parallelism '{s}' (want tp|pp)"),
        }
    }
}

/// Which compute backend executes the per-rank kernels (runtime::Backend).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust fused kernels (runtime/native.rs); self-contained, no
    /// artifacts or libxla needed. The default.
    #[default]
    Native,
    /// PJRT over AOT HLO artifacts; needs the `xla` cargo feature plus an
    /// artifact bundle (`make artifacts`).
    Xla,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }

    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            _ => bail!("unknown backend '{s}' (want native|xla)"),
        }
    }
}

/// The FFN being trained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    /// Global layer width n (input, hidden and output widths all n).
    pub n: usize,
    /// Depth L (number of weight layers).
    pub layers: usize,
    /// Ghost neurons per phantom layer (ignored for TP).
    pub k: usize,
}

impl ModelConfig {
    pub fn validate(&self, p: usize) -> Result<()> {
        if self.n == 0 || self.layers == 0 {
            bail!("n and layers must be positive");
        }
        if self.n % p != 0 {
            bail!("n={} must be divisible by p={}", self.n, p);
        }
        let m = self.n / p;
        // Paper Eqn. (8): PP only wins when k < (n/p)(1 - 1/p); we enforce
        // the (weaker) hard requirement k < n/p and surface the Eqn. 8
        // bound through `phantom_smaller_than_tp`.
        if self.k >= m {
            bail!("k={} must be < n/p = {}", self.k, m);
        }
        Ok(())
    }

    /// True iff Eqn. (8) holds, i.e. the PP model has fewer parameters than
    /// the TP model at this (p, k).
    pub fn phantom_smaller_than_tp(&self, p: usize) -> bool {
        let m = self.n as f64 / p as f64;
        (self.k as f64) < m * (1.0 - 1.0 / p as f64)
    }
}

/// Optimizer selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerConfig {
    Sgd { lr: f32 },
    Momentum { lr: f32, beta: f32 },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
}

impl OptimizerConfig {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerConfig::Sgd { .. } => "sgd",
            OptimizerConfig::Momentum { .. } => "momentum",
            OptimizerConfig::Adam { .. } => "adam",
        }
    }
}

/// Micro-batch schedule of the PP training loop (DESIGN.md §15).
///
/// Both schedules split the rank's batch shard into `micro` contiguous
/// row chunks, complete every chunk's backward in chunk order, and
/// accumulate gradients in chunk order — so the two are bit-identical at
/// equal `micro` and differ only in *when* collectives overlap compute in
/// virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Each micro-batch runs forward + backward to completion before the
    /// next starts; every collective's wire time is exposed.
    Sync,
    /// Interleaved one-forward-one-backward: warmup forwards fill the
    /// pipeline, steady-state alternates backward/forward, cooldown drains
    /// — boundary collectives of in-flight micro-batches defer their wire
    /// time onto the ledger's overlap register, where the next chunk's
    /// compute hides it.
    OneFOneB,
}

impl Schedule {
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Sync => "sync",
            Schedule::OneFOneB => "1f1b",
        }
    }

    pub fn parse(s: &str) -> Result<Schedule> {
        match s {
            "sync" => Ok(Schedule::Sync),
            "1f1b" => Ok(Schedule::OneFOneB),
            other => bail!("unknown schedule '{other}' (sync | 1f1b)"),
        }
    }
}

/// Training-loop configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub batch: usize,
    pub optimizer: OptimizerConfig,
    pub seed: u64,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Stop early when the loss reaches this value (fixed-loss experiments).
    pub target_loss: Option<f64>,
    /// Iterations excluded from timing/energy (the paper excludes the first
    /// epoch: PyTorch data-structure warmup; for us: PJRT compilation).
    pub warmup_iters: usize,
    /// Size of the fixed dataset in batches; iteration i trains on batch
    /// i % dataset_batches (the paper keeps the dataset fixed).
    pub dataset_batches: usize,
    /// Micro-batches per iteration (PP only; 1 = the pre-pipeline loop,
    /// byte-identical to it). NOTE: micro > 1 splits each GEMM into
    /// per-chunk GEMMs, which changes f32 summation order — trajectories
    /// at different `micro` are numerically close but not bitwise equal.
    pub micro: usize,
    /// Micro-batch schedule (PP only; irrelevant at micro = 1, where both
    /// schedules price identically).
    pub schedule: Schedule,
    /// ZeRO-1: shard optimizer state across the DP group — reduce-scatter
    /// the flat gradient, update only the owned parameter slice, all-gather
    /// the updated slices. Bit-identical to the flat path (the DP
    /// reduce-scatter folds in the same rank order as the all-reduce);
    /// per-rank optimizer-state floats drop to ~1/dp. No-op at dp = 1.
    pub sharded_state: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 32,
            optimizer: OptimizerConfig::Sgd { lr: 1.0 },
            seed: 0xF00D,
            max_iters: 200,
            target_loss: None,
            warmup_iters: 1,
            dataset_batches: 16,
            micro: 1,
            schedule: Schedule::Sync,
            sharded_state: false,
        }
    }
}

/// Serving-subsystem configuration (rust/src/serve, DESIGN.md §7): the
/// bounded admission queue and dynamic micro-batcher in front of the
/// persistent rank pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeConfig {
    /// Admission-queue capacity in queries. Arrivals beyond this see
    /// backpressure: shed (open-loop clients) or blocked (closed-loop).
    pub queue_depth: usize,
    /// Maximum queries coalesced into one dispatched forward batch.
    pub max_batch: usize,
    /// Batcher linger deadline in virtual seconds: a forming batch waits at
    /// most this long past pool-ready for stragglers before dispatching.
    pub linger_s: f64,
    /// Which forward pipeline serves the queries (PP or the TP baseline).
    pub mode: Parallelism,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_depth: 128,
            max_batch: 32,
            linger_s: 2e-3,
            mode: Parallelism::Phantom,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("max_batch must be positive");
        }
        if self.queue_depth < self.max_batch {
            bail!(
                "queue_depth={} must be >= max_batch={} (a full queue must \
                 always contain a dispatchable batch)",
                self.queue_depth,
                self.max_batch
            );
        }
        if !self.linger_s.is_finite() || self.linger_s < 0.0 {
            bail!("linger_s must be finite and non-negative, got {}", self.linger_s);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_depth", Json::int(self.queue_depth as i64)),
            ("max_batch", Json::int(self.max_batch as i64)),
            ("linger_s", Json::num(self.linger_s)),
            ("mode", Json::str(self.mode.name())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let cfg = ServeConfig {
            queue_depth: j.get("queue_depth").as_usize().unwrap_or(d.queue_depth),
            max_batch: j.get("max_batch").as_usize().unwrap_or(d.max_batch),
            linger_s: j.get("linger_s").as_f64().unwrap_or(d.linger_s),
            mode: match j.get("mode").as_str() {
                Some(s) => Parallelism::parse(s)?,
                None => d.mode,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Periodic-checkpoint policy for training runs (rust/src/ckpt,
/// DESIGN.md §8): snapshot the full training state every `every`
/// iterations into numbered subdirectories of `dir`.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptPolicy {
    /// Snapshot cadence in iterations (>= 1).
    pub every: usize,
    /// Directory receiving `ckpt-NNNNNN` snapshot subdirectories.
    pub dir: std::path::PathBuf,
}

impl CkptPolicy {
    pub fn validate(&self) -> Result<()> {
        if self.every == 0 {
            bail!("ckpt every must be >= 1");
        }
        if self.dir.as_os_str().is_empty() {
            bail!("ckpt dir must be non-empty");
        }
        Ok(())
    }
}

/// How per-rank compute time is charged to the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ComputeModel {
    /// Wall-time of the real PJRT execution (serialized on the exec server).
    Measured,
    /// Analytic FLOP model at `gflops` effective throughput per rank
    /// (Frontier-scale predictions; see perfmodel).
    Analytic { gflops: f64 },
}

/// Hardware profile: power + network + compute-time model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HardwareConfig {
    pub power: PowerModel,
    pub net: NetworkProfile,
    pub compute: ComputeModel,
}

impl HardwareConfig {
    pub fn frontier_measured() -> HardwareConfig {
        HardwareConfig {
            power: PowerModel::frontier(),
            net: NetworkProfile::frontier(),
            compute: ComputeModel::Measured,
        }
    }

    /// MI250X GCD effective GEMM throughput used for modeled runs. The
    /// headline is ~23.9 TF/s fp32 (vector); large-GEMM efficiency on GCDs
    /// is ~70%, so the perfmodel default is 17 TF/s before the small-GEMM
    /// efficiency curve is applied.
    pub fn frontier_modeled() -> HardwareConfig {
        HardwareConfig {
            power: PowerModel::frontier(),
            net: NetworkProfile::frontier(),
            compute: ComputeModel::Analytic { gflops: 17_000.0 },
        }
    }
}

/// A full run description.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub mode: Parallelism,
    /// Model-parallel group size (the paper's p). The cluster runs
    /// `p * dp` ranks in total.
    pub p: usize,
    /// Data-parallel replica count (hybrid DP × TP|PP). Each replica is a
    /// full model-parallel group training on its own row shard of the
    /// global batch; gradients are summed across replicas with one DP
    /// All-Reduce per iteration. `1` = pure model parallelism, exactly the
    /// pre-hybrid behavior.
    pub dp: usize,
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub hardware: HardwareConfig,
    /// Artifact config name (python/compile/shapes.py); Measured mode only.
    pub artifact: Option<String>,
    /// Which compute backend executes the kernels (native by default).
    pub backend: BackendKind,
}

impl RunConfig {
    /// Total ranks in the cluster: p model ranks × dp replicas.
    pub fn world(&self) -> usize {
        self.p * self.dp.max(1)
    }

    pub fn validate(&self) -> Result<()> {
        if self.p == 0 {
            bail!("p must be positive");
        }
        if self.dp == 0 {
            bail!("dp must be positive (1 = no data parallelism)");
        }
        self.model.validate(self.p)?;
        if self.train.batch == 0 {
            bail!("batch must be positive");
        }
        if self.train.batch < self.dp {
            bail!(
                "batch={} must be >= dp={} (every DP replica needs at least one sample)",
                self.train.batch,
                self.dp
            );
        }
        if self.train.micro == 0 {
            bail!("micro must be positive (1 = no micro-batching)");
        }
        if self.mode == Parallelism::Tensor
            && (self.train.micro != 1 || self.train.schedule != Schedule::Sync)
        {
            bail!(
                "micro-batch pipelining (micro={}, schedule={}) is a PP schedule; \
                 TP runs take micro=1, schedule=sync",
                self.train.micro,
                self.train.schedule.name()
            );
        }
        // The smallest DP replica shard carries floor(batch/dp) rows; every
        // micro-batch chunk needs at least one of them.
        if self.train.micro > self.train.batch / self.dp {
            bail!(
                "micro={} exceeds the {} rows of the smallest DP replica shard \
                 (batch={} over dp={})",
                self.train.micro,
                self.train.batch / self.dp,
                self.train.batch,
                self.dp
            );
        }
        if matches!(self.hardware.compute, ComputeModel::Measured) && self.artifact.is_none() {
            bail!("measured compute requires an artifact config name");
        }
        Ok(())
    }

    // -- JSON round-trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let opt = match self.train.optimizer {
            OptimizerConfig::Sgd { lr } => {
                Json::obj(vec![("kind", Json::str("sgd")), ("lr", Json::num(lr as f64))])
            }
            OptimizerConfig::Momentum { lr, beta } => Json::obj(vec![
                ("kind", Json::str("momentum")),
                ("lr", Json::num(lr as f64)),
                ("beta", Json::num(beta as f64)),
            ]),
            OptimizerConfig::Adam { lr, beta1, beta2, eps } => Json::obj(vec![
                ("kind", Json::str("adam")),
                ("lr", Json::num(lr as f64)),
                ("beta1", Json::num(beta1 as f64)),
                ("beta2", Json::num(beta2 as f64)),
                ("eps", Json::num(eps as f64)),
            ]),
        };
        let compute = match self.hardware.compute {
            ComputeModel::Measured => Json::str("measured"),
            ComputeModel::Analytic { gflops } => {
                Json::obj(vec![("gflops", Json::num(gflops))])
            }
        };
        Json::obj(vec![
            ("mode", Json::str(self.mode.name())),
            ("p", Json::int(self.p as i64)),
            ("dp", Json::int(self.dp as i64)),
            ("n", Json::int(self.model.n as i64)),
            ("layers", Json::int(self.model.layers as i64)),
            ("k", Json::int(self.model.k as i64)),
            ("batch", Json::int(self.train.batch as i64)),
            ("optimizer", opt),
            ("seed", Json::int(self.train.seed as i64)),
            ("max_iters", Json::int(self.train.max_iters as i64)),
            (
                "target_loss",
                self.train.target_loss.map(Json::num).unwrap_or(Json::Null),
            ),
            ("warmup_iters", Json::int(self.train.warmup_iters as i64)),
            ("dataset_batches", Json::int(self.train.dataset_batches as i64)),
            ("micro", Json::int(self.train.micro as i64)),
            ("schedule", Json::str(self.train.schedule.name())),
            ("sharded_state", Json::Bool(self.train.sharded_state)),
            ("compute", compute),
            (
                "artifact",
                self.artifact.clone().map(Json::str).unwrap_or(Json::Null),
            ),
            ("backend", Json::str(self.backend.name())),
            ("busy_w", Json::num(self.hardware.power.busy_w)),
            ("idle_w", Json::num(self.hardware.power.idle_w)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let cfg = Self::from_json_unchecked(j)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse without the final `validate` pass. Checkpoint re-sharding can
    /// produce geometries the training-side validator rejects by design —
    /// a dense-phantom conversion carries k = n/p (identity compressor) and
    /// no artifact name — so snapshot loading parses with this and applies
    /// the checkpoint layer's structural validation instead (ckpt::Snapshot).
    pub fn from_json_unchecked(j: &Json) -> Result<RunConfig> {
        let mode = Parallelism::parse(j.get("mode").as_str().context("mode")?)?;
        let p = j.get("p").as_usize().context("p")?;
        // Pre-hybrid configs/snapshots have no dp field: default 1.
        let dp = j.get("dp").as_usize().unwrap_or(1);
        let model = ModelConfig {
            n: j.get("n").as_usize().context("n")?,
            layers: j.get("layers").as_usize().context("layers")?,
            k: j.get("k").as_usize().unwrap_or(0),
        };
        let opt_j = j.get("optimizer");
        let optimizer = match opt_j.get("kind").as_str().unwrap_or("sgd") {
            "sgd" => OptimizerConfig::Sgd { lr: opt_j.get("lr").as_f64().unwrap_or(0.05) as f32 },
            "momentum" => OptimizerConfig::Momentum {
                lr: opt_j.get("lr").as_f64().unwrap_or(0.05) as f32,
                beta: opt_j.get("beta").as_f64().unwrap_or(0.9) as f32,
            },
            "adam" => OptimizerConfig::Adam {
                lr: opt_j.get("lr").as_f64().unwrap_or(1e-3) as f32,
                beta1: opt_j.get("beta1").as_f64().unwrap_or(0.9) as f32,
                beta2: opt_j.get("beta2").as_f64().unwrap_or(0.999) as f32,
                eps: opt_j.get("eps").as_f64().unwrap_or(1e-8) as f32,
            },
            other => bail!("unknown optimizer kind '{other}'"),
        };
        let compute = match j.get("compute") {
            Json::Str(s) if s == "measured" => ComputeModel::Measured,
            other => ComputeModel::Analytic {
                gflops: other.get("gflops").as_f64().unwrap_or(17_000.0),
            },
        };
        let hardware = HardwareConfig {
            power: PowerModel {
                busy_w: j.get("busy_w").as_f64().unwrap_or(560.0),
                idle_w: j.get("idle_w").as_f64().unwrap_or(90.0),
            },
            net: NetworkProfile::frontier(),
            compute,
        };
        let cfg = RunConfig {
            mode,
            p,
            dp,
            model,
            train: TrainConfig {
                batch: j.get("batch").as_usize().context("batch")?,
                optimizer,
                seed: j.get("seed").as_i64().unwrap_or(0xF00D) as u64,
                max_iters: j.get("max_iters").as_usize().unwrap_or(200),
                target_loss: j.get("target_loss").as_f64(),
                warmup_iters: j.get("warmup_iters").as_usize().unwrap_or(1),
                dataset_batches: j.get("dataset_batches").as_usize().unwrap_or(16),
                // Pre-pipeline configs/snapshots lack the schedule fields:
                // default to the exact pre-pipeline behavior.
                micro: j.get("micro").as_usize().unwrap_or(1),
                schedule: match j.get("schedule").as_str() {
                    Some(s) => Schedule::parse(s)?,
                    None => Schedule::Sync,
                },
                sharded_state: j.get("sharded_state").as_bool().unwrap_or(false),
            },
            hardware,
            artifact: j.get("artifact").as_str().map(|s| s.to_string()),
            backend: match j.get("backend").as_str() {
                Some(s) => BackendKind::parse(s)?,
                None => BackendKind::Native,
            },
        };
        Ok(cfg)
    }
}

/// Preset geometry table, shared by `preset` and the native backend's
/// synthetic manifest (runtime::native::preset_manifest).
const PRESETS: &[(&str, (usize, usize, usize, usize))] = &[
    ("tiny", (4, 64, 4, 8)),
    ("tiny_pallas", (4, 64, 4, 8)),
    ("tiny_p2", (2, 32, 4, 4)),
    ("tiny_p2_pallas", (2, 32, 4, 4)),
    ("quickstart", (4, 256, 8, 16)),
    ("small", (8, 1024, 16, 32)),
    ("small_k4", (8, 1024, 4, 32)),
    ("small_k8", (8, 1024, 8, 32)),
    ("small_k32", (8, 1024, 32, 32)),
    ("small_p2", (2, 1024, 16, 32)),
    ("small_p4", (4, 1024, 16, 32)),
    ("medium", (8, 2048, 16, 32)),
    ("e2e", (8, 8192, 32, 16)),
];

/// All preset names, in table order.
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, _)| *n).collect()
}

/// Presets matching python/compile/shapes.py (Measured mode). `mode` picks
/// TP or PP over the same artifact bundle.
pub fn preset(artifact: &str, mode: Parallelism) -> Result<RunConfig> {
    let (p, n, k, batch) = PRESETS
        .iter()
        .find(|(name, _)| *name == artifact)
        .map(|(_, g)| *g)
        .ok_or_else(|| anyhow::anyhow!("unknown preset '{artifact}'"))?;
    Ok(RunConfig {
        mode,
        p,
        dp: 1,
        model: ModelConfig { n, layers: 2, k },
        train: TrainConfig { batch, ..TrainConfig::default() },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some(artifact.to_string()),
        backend: BackendKind::Native,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_catches_bad_shapes() {
        let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
        assert!(cfg.validate().is_ok());
        cfg.model.k = cfg.model.n / cfg.p; // k == n/p violates Eqn. 8
        assert!(cfg.validate().is_err());
        cfg.model.k = 1;
        cfg.model.n = 63; // not divisible by p
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn eqn8_bound() {
        let m = ModelConfig { n: 64, layers: 2, k: 4 };
        assert!(m.phantom_smaller_than_tp(4)); // 4 < 16*(3/4) = 12
        let m = ModelConfig { n: 64, layers: 2, k: 13 };
        assert!(!m.phantom_smaller_than_tp(4)); // 13 >= 12
    }

    #[test]
    fn json_roundtrip() {
        for mode in [Parallelism::Tensor, Parallelism::Phantom] {
            let mut cfg = preset("small", mode).unwrap();
            cfg.train.optimizer =
                OptimizerConfig::Adam { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
            cfg.train.target_loss = Some(0.01);
            let j = cfg.to_json();
            let back = RunConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back);
        }
    }

    #[test]
    fn all_presets_valid() {
        for name in [
            "tiny", "tiny_p2", "quickstart", "small", "small_k4", "small_k8", "small_k32",
            "small_p2", "small_p4", "medium", "e2e",
        ] {
            let cfg = preset(name, Parallelism::Phantom).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(preset("nope", Parallelism::Tensor).is_err());
    }

    #[test]
    fn parallelism_parse() {
        assert_eq!(Parallelism::parse("tp").unwrap(), Parallelism::Tensor);
        assert_eq!(Parallelism::parse("phantom").unwrap(), Parallelism::Phantom);
        assert!(Parallelism::parse("x").is_err());
    }

    #[test]
    fn backend_parse_and_default() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("cuda").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Native);
        // JSON without a backend field defaults to native
        let mut j = preset("tiny", Parallelism::Phantom).unwrap().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("backend");
        }
        assert_eq!(RunConfig::from_json(&j).unwrap().backend, BackendKind::Native);
    }

    #[test]
    fn serve_config_validates_and_roundtrips() {
        let d = ServeConfig::default();
        assert!(d.validate().is_ok());
        assert_eq!(ServeConfig::from_json(&d.to_json()).unwrap(), d);

        let custom = ServeConfig {
            queue_depth: 16,
            max_batch: 4,
            linger_s: 5e-4,
            mode: Parallelism::Tensor,
        };
        assert_eq!(ServeConfig::from_json(&custom.to_json()).unwrap(), custom);

        let bad = ServeConfig { max_batch: 0, ..d };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { queue_depth: 3, max_batch: 4, ..d };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { linger_s: -1.0, ..d };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { linger_s: f64::NAN, ..d };
        assert!(bad.validate().is_err());

        // missing fields fall back to defaults
        let partial = Json::parse("{\"max_batch\": 8, \"queue_depth\": 8}").unwrap();
        let cfg = ServeConfig::from_json(&partial).unwrap();
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.mode, Parallelism::Phantom);
    }

    #[test]
    fn ckpt_policy_validates() {
        let ok = CkptPolicy { every: 4, dir: std::path::PathBuf::from("ckpts") };
        assert!(ok.validate().is_ok());
        let bad = CkptPolicy { every: 0, ..ok.clone() };
        assert!(bad.validate().is_err());
        let bad = CkptPolicy { dir: std::path::PathBuf::new(), ..ok };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_json_unchecked_admits_dense_phantom_geometry() {
        // A re-sharded dense-phantom snapshot carries k = n/p and no
        // artifact; strict from_json rejects it, unchecked parses it.
        let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
        cfg.model.k = cfg.model.n / cfg.p;
        cfg.artifact = None;
        let j = cfg.to_json();
        assert!(RunConfig::from_json(&j).is_err());
        let back = RunConfig::from_json_unchecked(&j).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn hybrid_dp_validates_and_roundtrips() {
        let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
        assert_eq!(cfg.dp, 1, "presets are pure model-parallel");
        cfg.dp = 2;
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.world(), cfg.p * 2);
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // A pre-hybrid JSON (no dp field) defaults to dp = 1.
        let mut j = preset("tiny", Parallelism::Phantom).unwrap().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("dp");
        }
        assert_eq!(RunConfig::from_json(&j).unwrap().dp, 1);
        // dp = 0 and batch < dp are rejected.
        cfg.dp = 0;
        assert!(cfg.validate().is_err());
        cfg.dp = cfg.train.batch + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn preset_names_cover_the_table() {
        for name in preset_names() {
            assert!(preset(name, Parallelism::Phantom).is_ok(), "{name}");
        }
        assert!(preset_names().contains(&"quickstart"));
    }
}
