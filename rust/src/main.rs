//! `phantom` — launcher for the phantom-parallelism training system.
//!
//! See `phantom help` (cli::USAGE) for the command reference. Python/JAX
//! never runs here. The default `--backend native` executes the fused
//! pure-Rust kernels, fully self-contained; `--backend xla` loads AOT
//! artifacts through PJRT (requires the `xla` cargo feature and
//! `make artifacts`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use phantom::ckpt::{self, Snapshot};
use phantom::cli::{Args, USAGE};
use phantom::config::{preset, BackendKind, CkptPolicy, OptimizerConfig, Parallelism, ServeConfig};
use phantom::coordinator::{self, TrainOptions};
use phantom::experiments;
use phantom::perfmodel::{self, GemmModel, Workload};
use phantom::runtime::{default_artifact_dir, ExecServer};
use phantom::simnet::NetworkProfile;
use phantom::util::json::Json;
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "ckpt" => cmd_ckpt(&args),
        "chaos" => cmd_chaos(&args),
        "experiment" => cmd_experiment(&args),
        "predict" => cmd_predict(&args),
        "plan" => cmd_plan(&args),
        "inspect" => cmd_inspect(&args),
        "fit-comm" => cmd_fit_comm(),
        "tune" => cmd_tune(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "preset",
        "mode",
        "dp",
        "iters",
        "target-loss",
        "lr",
        "optimizer",
        "seed",
        "out",
        "backend",
        "ckpt-every",
        "ckpt-dir",
        "resume",
    ])?;

    let resume_dir = args.opt("resume");
    let (mut cfg, preset_name, resume) = match resume_dir {
        Some(dir) => {
            // The snapshot fixes everything that shapes the math; allowing
            // these flags alongside --resume would silently diverge from
            // the saved trajectory.
            for fixed in ["preset", "mode", "dp", "optimizer", "lr", "seed", "backend"] {
                if args.opt(fixed).is_some() || args.flag(fixed) {
                    bail!("--{fixed} cannot be combined with --resume (the snapshot fixes it)");
                }
            }
            let snap = Snapshot::load(Path::new(dir))
                .with_context(|| format!("loading --resume snapshot {dir}"))?;
            let cfg = snap.config.clone();
            cfg.validate().context("resumed snapshot config")?;
            eprintln!(
                "resuming from {dir} at iteration {} (loss {:.6})",
                snap.progress.iter,
                snap.progress.losses.last().copied().unwrap_or(f64::NAN)
            );
            (cfg, "resumed".to_string(), Some(snap))
        }
        None => {
            let preset_name = args.opt("preset").unwrap_or("quickstart");
            let mode = Parallelism::parse(args.opt("mode").unwrap_or("pp"))?;
            let mut cfg = preset(preset_name, mode)?;
            cfg.backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
            if let Some(dp) = args.opt_parse::<usize>("dp")? {
                cfg.dp = dp;
            }
            if let Some(seed) = args.opt_parse::<u64>("seed")? {
                cfg.train.seed = seed;
            }
            let lr = args.opt_parse::<f32>("lr")?.unwrap_or(1.0);
            cfg.train.optimizer = match args.opt("optimizer").unwrap_or("sgd") {
                "sgd" => OptimizerConfig::Sgd { lr },
                "momentum" => OptimizerConfig::Momentum { lr, beta: 0.9 },
                "adam" => OptimizerConfig::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
                o => bail!("unknown optimizer '{o}'"),
            };
            (cfg, preset_name.to_string(), None)
        }
    };
    if let Some(iters) = args.opt_parse::<usize>("iters")? {
        cfg.train.max_iters = iters;
    }
    if let Some(target) = args.opt_parse::<f64>("target-loss")? {
        cfg.train.target_loss = Some(target);
    }
    let ckpt = match (args.opt_parse::<usize>("ckpt-every")?, args.opt("ckpt-dir")) {
        (Some(every), Some(dir)) => {
            let policy = CkptPolicy { every, dir: dir.into() };
            policy.validate()?;
            Some(policy)
        }
        (None, None) => None,
        _ => bail!("--ckpt-every and --ckpt-dir must be given together"),
    };

    let server = ExecServer::for_run(&cfg)?;
    eprintln!(
        "training {} / {} on {} simulated ranks ({} model x {} dp; n={}, k={}, L={}, \
         backend={})...",
        preset_name,
        cfg.mode.name(),
        cfg.world(),
        cfg.p,
        cfg.dp,
        cfg.model.n,
        cfg.model.k,
        cfg.model.layers,
        server.backend_name()
    );
    let opts = TrainOptions { ckpt, resume, ..Default::default() };
    let report = coordinator::train_with(&cfg, &server, opts)?;

    let mut t = Table::new(
        &format!("Training report — {} ({})", preset_name, cfg.mode.name()),
        &["metric", "value"],
    );
    t.row(vec!["iterations".into(), report.iterations.to_string()]);
    t.row(vec![
        "final loss".into(),
        format!("{:.6}", report.losses.last().copied().unwrap_or(f64::NAN)),
    ]);
    t.row(vec!["model params".into(), report.model_params.to_string()]);
    t.row(vec!["energy (train)".into(), fmt_joules(report.energy_train_j)]);
    t.row(vec!["energy/iter".into(), fmt_joules(report.energy_per_iter_j())]);
    t.row(vec!["virtual wall".into(), fmt_secs(report.wall_train_s)]);
    if report.dp > 1 {
        // Hybrid runs: surface the DP gradient-sync bucket on its own
        // row. Full-run total (warmup included) — labeled as such, since
        // the energy rows above are post-warmup.
        let dp_s: f64 = report.per_rank.iter().map(|r| r.ledger.dp_comm_s).sum();
        t.row(vec!["ranks (model x dp)".into(), format!("{} x {}", report.p, report.dp)]);
        t.row(vec![
            "dp grad sync (full run)".into(),
            format!(
                "{} ({})",
                fmt_secs(dp_s),
                fmt_joules(cfg.hardware.power.idle_w * dp_s)
            ),
        ]);
    }
    print!("{}", t.markdown());

    // loss curve (sparse print)
    let stride = (report.losses.len() / 10).max(1);
    println!("\nloss curve:");
    for (i, l) in report.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == report.losses.len() {
            println!("  iter {i:>5}  loss {l:.6}");
        }
    }

    if let Some(path) = args.opt("out") {
        std::fs::write(path, report_json(&report).pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "preset",
        "mode",
        "backend",
        "queries",
        "rate",
        "max-batch",
        "linger-ms",
        "queue-depth",
        "open-loop",
        "seed",
        "out",
    ])?;
    let preset_name = args.opt("preset").unwrap_or("small");
    let modes: Vec<Parallelism> = match args.opt("mode").unwrap_or("both") {
        "both" => vec![Parallelism::Phantom, Parallelism::Tensor],
        m => vec![Parallelism::parse(m)?],
    };
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    let open_loop = args.flag("open-loop");

    let mut table = Table::new(
        &format!("Serving — preset {preset_name}, dynamic batching"),
        &[
            "mode",
            "batches",
            "mean batch",
            "p50 latency",
            "p95 latency",
            "throughput (q/s)",
            "energy / 1k queries",
            "shed",
            "blocked",
        ],
    );
    let mut reports = Vec::new();
    for mode in modes {
        let mut cfg = preset(preset_name, mode)?;
        cfg.backend = backend;
        let server = ExecServer::for_run(&cfg)?;
        let max_batch = args.opt_parse::<usize>("max-batch")?.unwrap_or(cfg.train.batch);
        let scfg = ServeConfig {
            queue_depth: args.opt_parse::<usize>("queue-depth")?.unwrap_or(4 * max_batch),
            max_batch,
            linger_s: args.opt_parse::<f64>("linger-ms")?.unwrap_or(2.0) * 1e-3,
            mode,
        };
        let defaults = phantom::serve::LoadGenConfig::default();
        let lcfg = phantom::serve::LoadGenConfig {
            queries: args.opt_parse::<usize>("queries")?.unwrap_or(defaults.queries),
            rate_qps: args.opt_parse::<f64>("rate")?.unwrap_or(defaults.rate_qps),
            seed: args.opt_parse::<u64>("seed")?.unwrap_or(defaults.seed),
            open_loop,
        };
        eprintln!(
            "serving {} / {} ({} queries @ {} q/s, batch<={}, linger {:.1} ms)...",
            preset_name,
            mode.name(),
            lcfg.queries,
            lcfg.rate_qps,
            scfg.max_batch,
            scfg.linger_s * 1e3
        );
        let r = phantom::serve::run_load(&cfg, &scfg, &lcfg, &server)?;
        if r.misordered > 0 {
            bail!("{} responses arrived out of order (serve bug)", r.misordered);
        }
        if !open_loop && r.completed != lcfg.queries {
            bail!(
                "dropped {} of {} queries despite blocking backpressure",
                lcfg.queries - r.completed,
                lcfg.queries
            );
        }
        table.row(vec![
            mode.name().to_uppercase(),
            r.batches.to_string(),
            format!("{:.1}", r.mean_batch),
            fmt_secs(r.latency.p50),
            fmt_secs(r.latency.p95),
            format!("{:.0}", r.throughput_qps),
            fmt_joules(r.energy_per_kq_j),
            r.rejected.to_string(),
            r.blocked.to_string(),
        ]);
        reports.push(r);
    }
    print!("{}", table.markdown());

    let records = phantom::serve::combined_records(&reports);
    if let Some((_, ratio)) = records.iter().find(|(k, _)| k == "pp_over_tp_energy") {
        println!(
            "\nPP serves at {:.1}% of TP's energy per 1k queries (Table II traffic savings).",
            ratio * 100.0
        );
    }
    let out = args.opt("out").unwrap_or("BENCH_serve.json");
    phantom::serve::write_records_json(std::path::Path::new(out), &records)?;
    eprintln!("wrote {out}");
    Ok(())
}

fn cmd_ckpt(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: phantom ckpt <inspect|reshard|verify> ..."))?;
    match sub {
        "inspect" => {
            args.check_known(&["dir"])?;
            let dir = args.require("dir")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let mut t = Table::new(&format!("Snapshot — {dir}"), &["field", "value"]);
            t.row(vec!["mode".into(), snap.mode().name().to_uppercase()]);
            t.row(vec!["p".into(), snap.p().to_string()]);
            t.row(vec!["n".into(), snap.n().to_string()]);
            t.row(vec!["k".into(), snap.k().to_string()]);
            t.row(vec!["layers".into(), snap.layers().to_string()]);
            t.row(vec!["batch".into(), snap.config.train.batch.to_string()]);
            t.row(vec!["optimizer".into(), snap.config.train.optimizer.name().into()]);
            t.row(vec!["iterations".into(), snap.progress.iter.to_string()]);
            t.row(vec![
                "last loss".into(),
                snap.progress
                    .losses
                    .last()
                    .map(|l| format!("{l:.6}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
            let params: u64 = snap
                .shards
                .iter()
                .map(|s| match &s.params {
                    phantom::ckpt::RankParams::Phantom(p) => p.param_count(),
                    phantom::ckpt::RankParams::Tensor(p) => p.param_count(),
                })
                .sum();
            t.row(vec!["model params".into(), params.to_string()]);
            t.row(vec![
                "optimizer state".into(),
                snap.shards[0]
                    .opt
                    .as_ref()
                    .map(|o| o.kind().to_string())
                    .unwrap_or_else(|| "fresh".into()),
            ]);
            print!("{}", t.markdown());
            Ok(())
        }
        "reshard" => {
            args.check_known(&["dir", "out", "p", "mode"])?;
            let dir = args.require("dir")?;
            let out = args.require("out")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let target_p = args.opt_parse::<usize>("p")?.unwrap_or(snap.p());
            let target_mode = match args.opt("mode") {
                Some(m) => Parallelism::parse(m)?,
                None => snap.mode(),
            };
            let re = ckpt::reshard(&snap, target_p, target_mode)?;
            re.save(Path::new(out))?;
            eprintln!(
                "resharded {} (p={}, {}) -> {} (p={}, {}, k={})",
                dir,
                snap.p(),
                snap.mode().name(),
                out,
                re.p(),
                re.mode().name(),
                re.k()
            );
            Ok(())
        }
        "verify" => {
            args.check_known(&["dir", "against", "batch", "seed", "tol"])?;
            let dir = args.require("dir")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let batch = args.opt_parse::<usize>("batch")?.unwrap_or(8);
            let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0xC4EC);
            let tol = args.opt_parse::<f32>("tol")?.unwrap_or(1e-4);
            let mut rng = phantom::util::prng::Prng::new(seed);
            let x = phantom::tensor::Tensor::randn(&[batch, snap.n()], 1.0, &mut rng);
            let y = snap.forward_host(&x)?;
            if !y.data().iter().all(|v| v.is_finite()) {
                bail!("{dir}: forward produced non-finite outputs");
            }
            eprintln!("{dir}: checksums ok, forward on [{batch}, {}] finite", snap.n());
            if let Some(other) = args.opt("against") {
                let snap2 = Snapshot::load(Path::new(other))?;
                if snap2.n() != snap.n() {
                    bail!("{other}: n={} does not match {dir} n={}", snap2.n(), snap.n());
                }
                let y2 = snap2.forward_host(&x)?;
                if !y2.data().iter().all(|v| v.is_finite()) {
                    bail!("{other}: forward produced non-finite outputs");
                }
                let mut worst = 0.0f32;
                for (a, b) in y.data().iter().zip(y2.data()) {
                    worst = worst.max((a - b).abs() / (1.0 + a.abs()));
                }
                if worst > tol {
                    bail!(
                        "forward outputs diverge: worst relative error {worst:.3e} > tol \
                         {tol:.3e}"
                    );
                }
                println!(
                    "equivalent: worst relative error {worst:.3e} <= tol {tol:.3e} \
                     ({} p={} vs {} p={})",
                    snap.mode().name(),
                    snap.p(),
                    snap2.mode().name(),
                    snap2.p()
                );
            }
            Ok(())
        }
        other => bail!("unknown ckpt subcommand '{other}' (want inspect|reshard|verify)"),
    }
}

fn cmd_chaos(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario",
        "configs",
        "iters",
        "seed",
        "preset",
        "crash-rank",
        "crash-iter",
        "out",
    ])?;
    let scenario = args.opt("scenario").unwrap_or("all");
    if !matches!(scenario, "sweep" | "train" | "serve" | "all") {
        bail!("unknown chaos scenario '{scenario}' (want sweep|train|serve|all)");
    }
    let preset_name = args.opt("preset").unwrap_or("tiny_p2");
    let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0xC4A05);
    let crash_rank = args.opt_parse::<usize>("crash-rank")?.unwrap_or(1);
    let crash_iter = args.opt_parse::<u64>("crash-iter")?.unwrap_or(3);
    // Validate the chaos parameters up front, before the (comparatively
    // expensive) differential sweep runs under --scenario all — and reject
    // options the chosen scenario would silently ignore.
    if scenario == "serve" && args.opt("crash-iter").is_some() {
        bail!("--crash-iter applies to the train scenario only (serve crashes at a fixed batch)");
    }
    if scenario == "sweep"
        && (args.opt("crash-rank").is_some() || args.opt("crash-iter").is_some())
    {
        bail!("--crash-rank/--crash-iter apply to the train/serve scenarios only");
    }
    if matches!(scenario, "train" | "serve")
        && (args.opt("configs").is_some() || args.opt("iters").is_some())
    {
        bail!("--configs/--iters apply to the sweep scenario only");
    }
    if matches!(scenario, "train" | "serve" | "all") {
        let probe = preset(preset_name, Parallelism::Phantom)?;
        if crash_rank >= probe.p {
            bail!(
                "--crash-rank {crash_rank} out of range for preset '{preset_name}' (p={})",
                probe.p
            );
        }
        // The train scenario runs 8 iterations with snapshots every 2.
        if matches!(scenario, "train" | "all") && !(2..8).contains(&crash_iter) {
            bail!(
                "--crash-iter {crash_iter} must be in [2, 8) (the train scenario runs 8 \
                 iterations with snapshots every 2)"
            );
        }
    }
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut table = Table::new("Chaos & conformance harness", &["check", "result"]);

    if matches!(scenario, "sweep" | "all") {
        let sw = phantom::testkit::SweepConfig {
            cases: args.opt_parse::<usize>("configs")?.unwrap_or(25),
            iters: args.opt_parse::<usize>("iters")?.unwrap_or(3),
            seed,
            ..Default::default()
        };
        eprintln!(
            "differential sweep: {} randomized configs x 2 modes, {} iters each...",
            sw.cases, sw.iters
        );
        let report = phantom::testkit::run_sweep(&sw)?;
        table.row(vec![
            "differential sweep".into(),
            format!(
                "{} configs ok (loss dev {:.1e}, grad dev {:.1e}, reshard dev {:.1e})",
                report.cases.len(),
                report.max_loss_dev,
                report.max_grad_dev,
                report.max_forward_dev
            ),
        ]);
        records.extend(report.records());
    }

    if matches!(scenario, "train" | "all") {
        let mut cfg = preset(preset_name, Parallelism::Phantom)?;
        cfg.train.seed = seed;
        let dir = std::env::temp_dir()
            .join(format!("phantom-chaos-{}-{}", std::process::id(), seed));
        eprintln!(
            "train chaos: crash rank {crash_rank} at iteration {crash_iter}, then resume..."
        );
        let result =
            phantom::testkit::train_crash_resume(&cfg, 8, 2, crash_rank, crash_iter, &dir);
        std::fs::remove_dir_all(&dir).ok(); // clean up snapshots on error paths too
        let report = result?;
        if !report.bit_identical {
            bail!("crash-resume trajectory diverged from the uninterrupted run");
        }
        table.row(vec![
            "train crash-resume".into(),
            format!(
                "bit-identical over {} iters (resumed from iter {}; \"{}\")",
                report.baseline.len(),
                report.resumed_from,
                report.crash_error
            ),
        ]);
        records.push(("chaos_train_bit_identical".to_string(), 1.0));
        records.push(("chaos_train_resumed_from".to_string(), report.resumed_from as f64));
    }

    if matches!(scenario, "serve" | "all") {
        let mut cfg = preset(preset_name, Parallelism::Phantom)?;
        cfg.train.seed = seed;
        let scfg = ServeConfig {
            max_batch: cfg.train.batch,
            queue_depth: 4 * cfg.train.batch,
            linger_s: 1e-3,
            mode: cfg.mode,
        };
        let crash_seq = phantom::testkit::collectives_per_forward(cfg.model.layers) * 2;
        eprintln!("serve chaos: crash rank {crash_rank} mid-stream, hot-swap recovery...");
        let report =
            phantom::testkit::serve_crash_swap(&cfg, &scfg, 6, crash_rank, crash_seq)?;
        if !report.outputs_match {
            bail!("recovered serve answers diverged from the reference runs");
        }
        if !report.swap_observable {
            bail!("hot-swap weights were indistinguishable — the swap was not exercised");
        }
        table.row(vec![
            "serve crash + hot-swap".into(),
            format!(
                "{} batches, zero dropped (replayed batch {} on swapped weights)",
                report.batches, report.recovered_batch
            ),
        ]);
        records.push(("chaos_serve_outputs_match".to_string(), 1.0));
        records.push(("chaos_serve_recovered_batch".to_string(), report.recovered_batch as f64));
    }

    print!("{}", table.markdown());
    let out = args.opt("out").unwrap_or("BENCH_conformance.json");
    let out_path = Path::new(out);
    // Scoped runs (--scenario train/serve/sweep) keep the other scenarios'
    // records: merge by key into an existing record file, don't clobber it.
    let mut merged =
        phantom::util::json::read_records_json(out_path).unwrap_or_default();
    for (k, v) in records {
        match merged.iter_mut().find(|(mk, _)| *mk == k) {
            Some(slot) => slot.1 = v,
            None => merged.push((k, v)),
        }
    }
    phantom::serve::write_records_json(out_path, &merged)?;
    eprintln!("wrote {out}");
    Ok(())
}

fn report_json(r: &coordinator::TrainReport) -> Json {
    Json::obj(vec![
        ("mode", Json::str(r.mode.name())),
        ("p", Json::int(r.p as i64)),
        ("dp", Json::int(r.dp as i64)),
        ("n", Json::int(r.n as i64)),
        ("k", Json::int(r.k as i64)),
        ("layers", Json::int(r.layers as i64)),
        ("batch", Json::int(r.batch as i64)),
        ("iterations", Json::int(r.iterations as i64)),
        ("reached_target", Json::Bool(r.reached_target)),
        ("model_params", Json::int(r.model_params as i64)),
        ("energy_total_j", Json::num(r.energy_total_j)),
        ("energy_train_j", Json::num(r.energy_train_j)),
        ("wall_s", Json::num(r.wall_s)),
        ("wall_train_s", Json::num(r.wall_train_s)),
        ("losses", Json::arr(r.losses.iter().map(|&l| Json::num(l)).collect())),
        (
            "per_rank",
            Json::arr(
                r.per_rank
                    .iter()
                    .map(|rr| {
                        Json::obj(vec![
                            ("rank", Json::int(rr.rank as i64)),
                            ("busy_s", Json::num(rr.ledger.busy_s)),
                            ("comm_s", Json::num(rr.ledger.comm_s)),
                            ("idle_s", Json::num(rr.ledger.idle_s)),
                            ("dp_comm_s", Json::num(rr.ledger.dp_comm_s)),
                            ("floats_moved", Json::int(rr.stats.floats_moved as i64)),
                            ("collectives", Json::int(rr.stats.collectives() as i64)),
                            ("dp_floats_moved", Json::int(rr.dp_stats.floats_moved as i64)),
                            ("dp_collectives", Json::int(rr.dp_stats.collectives() as i64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.check_known(&["out-dir", "backend"])?;
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: phantom experiment <id|all>"))?;
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    // Start the server lazily: the modeled experiments don't need it.
    let needs_server = ids.iter().any(|i| i.starts_with("fig7") || *i == "table1");
    let server = if needs_server {
        Some(ExecServer::for_backend(backend)?)
    } else {
        None
    };
    for id in ids {
        eprintln!("running {id}...");
        let result = experiments::run(id, server.as_ref())?;
        print!("{}", result.render_markdown());
        if let Some(dir) = args.opt("out-dir") {
            std::fs::create_dir_all(dir)?;
            std::fs::write(
                format!("{dir}/{id}.md"),
                result.render_markdown(),
            )?;
            std::fs::write(format!("{dir}/{id}.json"), result.raw.pretty())?;
        }
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    args.check_known(&["n", "p", "k", "layers", "batch"])?;
    let w = Workload::new(
        args.opt_parse::<usize>("n")?.unwrap_or(131_072),
        args.opt_parse::<usize>("layers")?.unwrap_or(2),
        args.opt_parse::<usize>("p")?.unwrap_or(64),
        args.opt_parse::<usize>("k")?.unwrap_or(64),
        args.opt_parse::<usize>("batch")?.unwrap_or(32),
    )
    .context("infeasible workload")?;
    let g = GemmModel::frontier();
    let net = NetworkProfile::frontier();
    let power = phantom::energy::PowerModel::frontier();
    let mut t = Table::new(
        &format!(
            "Analytic prediction — n={}, p={}, k={}, L={}, batch={}",
            w.n, w.p, w.k, w.layers, w.batch
        ),
        &["mode", "compute", "comm", "dispatch", "total/iter", "energy/iter", "fits HBM"],
    );
    for mode in [Parallelism::Tensor, Parallelism::Phantom] {
        let c = perfmodel::predict(mode, &w, &g, &net)?;
        t.row(vec![
            mode.name().to_uppercase(),
            fmt_secs(c.compute_s),
            fmt_secs(c.comm_s),
            fmt_secs(c.dispatch_s),
            fmt_secs(c.total_s()),
            fmt_joules(c.energy_j(&power)),
            perfmodel::fits_memory(mode, &w).to_string(),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

/// Parse a comma-separated list ("2,4,8") into values.
fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>> {
    let vals: Vec<T> = s
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<T>().map_err(|_| anyhow::anyhow!("bad {what} value '{t}' in '{s}'")))
        .collect::<Result<_>>()?;
    if vals.is_empty() {
        bail!("empty {what} list '{s}'");
    }
    Ok(vals)
}

fn cmd_plan(args: &Args) -> Result<()> {
    use phantom::perfmodel::{calib, plan};

    args.check_known(&[
        "objective",
        "n",
        "layers",
        "p",
        "dp",
        "k",
        "batch",
        "linger-ms",
        "slo-ms",
        "calib",
        "iters",
        "queries",
        "out",
        "no-validate",
        "write-calib",
    ])?;

    if args.flag("write-calib") {
        // Regenerate the calibration fixture: real wall-clock GEMM rates
        // from this machine's kernels (what the measured simulator runs),
        // plus collective/power rows stamped from the virtual fabric's own
        // constants (for those two groups the model IS the measurement).
        let iters = args.opt_parse::<usize>("iters")?.unwrap_or(5);
        let out = args.opt("out").unwrap_or(calib::DEFAULT_CALIB_PATH);
        let mut records = calib::measure_gemm_records(calib::CALIB_GEMM_SHAPES, iters);
        let synth = calib::synthesize_records(
            &GemmModel::frontier(),
            &NetworkProfile::frontier(),
            &phantom::energy::PowerModel::frontier(),
        );
        records.extend(synth.into_iter().filter(|(k, _)| !k.ends_with("_gflops")));
        if let Some(parent) = Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        phantom::util::json::write_records_json(Path::new(out), &records)?;
        eprintln!("wrote {out} ({} calibration records)", records.len());
        return Ok(());
    }

    let objective = plan::Objective::parse(args.opt("objective").unwrap_or("train"))?;
    let calib_path = args.opt("calib").unwrap_or(calib::DEFAULT_CALIB_PATH);
    let calibration = calib::Calibration::load_or_default(Path::new(calib_path));
    calibration.log_warnings();
    eprintln!("plan: calibration from {}", calibration.source.describe());

    let space = plan::PlanSpace {
        n: args.opt_parse::<usize>("n")?.unwrap_or(256),
        layers: args.opt_parse::<usize>("layers")?.unwrap_or(2),
        modes: vec![Parallelism::Phantom, Parallelism::Tensor],
        p_choices: parse_list(args.opt("p").unwrap_or("2,4,8"), "--p")?,
        dp_choices: parse_list(args.opt("dp").unwrap_or("1,2"), "--dp")?,
        k_choices: parse_list(args.opt("k").unwrap_or("4,16"), "--k")?,
        batch_choices: parse_list(args.opt("batch").unwrap_or("16"), "--batch")?,
        linger_choices_s: parse_list::<f64>(args.opt("linger-ms").unwrap_or("0,2"), "--linger-ms")?
            .into_iter()
            .map(|ms| ms * 1e-3)
            .collect(),
    };
    let slo_s = args.opt_parse::<f64>("slo-ms")?.map(|ms| ms * 1e-3);
    let report = plan::plan(&space, objective, slo_s, &calibration)?;

    // Feasible cells, cheapest first.
    let mut priced: Vec<(&plan::PlanCell, &plan::CellPrediction)> = report
        .cells
        .iter()
        .filter_map(|(c, o)| o.prediction().map(|p| (c, p)))
        .collect();
    priced.sort_by(|a, b| a.1.j_per_unit.total_cmp(&b.1.j_per_unit));
    let mut t = Table::new(
        &format!(
            "Plan sweep — n={}, L={}, objective {} ({} feasible / {} cells)",
            space.n,
            space.layers,
            objective.name(),
            priced.len(),
            report.cells.len()
        ),
        &["config", &format!("predicted {}", objective.unit()), "latency", "rank"],
    );
    for (i, (cell, pred)) in priced.iter().enumerate() {
        let rank = match i {
            0 => "BEST".to_string(),
            i if i + 1 == priced.len() => "WORST".to_string(),
            i => (i + 1).to_string(),
        };
        t.row(vec![
            cell.label(),
            fmt_joules(pred.j_per_unit),
            fmt_secs(pred.latency_s),
            rank,
        ]);
    }
    print!("{}", t.markdown());
    let infeasible = report.cells.len() - priced.len();
    if infeasible > 0 {
        eprintln!("plan: {infeasible} cell(s) infeasible (reasons recorded in the sweep output)");
    }

    let validation = if args.flag("no-validate") {
        None
    } else {
        let opts = plan::ValidateOptions {
            iters: args.opt_parse::<usize>("iters")?.unwrap_or(6),
            queries: args.opt_parse::<usize>("queries")?.unwrap_or(96),
            ..Default::default()
        };
        eprintln!("plan: measuring predicted-best and predicted-worst cells...");
        Some(plan::validate(&report, &space, &opts)?)
    };

    let out = args.opt("out").unwrap_or("BENCH_plan.json");
    phantom::util::json::write_json(
        Path::new(out),
        &plan::report_json(&report, &calibration, validation.as_ref()),
    )?;
    eprintln!("wrote {out}");

    if let Some(v) = &validation {
        let mut vt = Table::new(
            &format!("Plan validation — measured {}", objective.unit()),
            &["cell", "config", "predicted", "measured"],
        );
        vt.row(vec![
            "best".into(),
            v.best.cell.label(),
            fmt_joules(v.best.predicted_j),
            fmt_joules(v.best.measured_j),
        ]);
        vt.row(vec![
            "worst".into(),
            v.worst.cell.label(),
            fmt_joules(v.worst.predicted_j),
            fmt_joules(v.worst.measured_j),
        ]);
        print!("{}", vt.markdown());
        if v.ranking_holds {
            println!(
                "\nranking holds: measured best {} < measured worst {}",
                fmt_joules(v.best.measured_j),
                fmt_joules(v.worst.measured_j)
            );
        } else {
            bail!(
                "ranking verdict FAILED: predicted-best measured {} >= predicted-worst \
                 measured {} (see {out})",
                fmt_joules(v.best.measured_j),
                fmt_joules(v.worst.measured_j)
            );
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.check_known(&["backend"])?;
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    let server = ExecServer::for_backend(backend)?;
    let source = match backend {
        BackendKind::Native => "native synthetic manifest".to_string(),
        BackendKind::Xla => format!("{}", default_artifact_dir().display()),
    };
    let mut t = Table::new(
        &format!("Artifact manifest — {source}"),
        &["config", "p", "n", "k", "batch", "variant", "entries"],
    );
    for c in server.manifest.iter() {
        t.row(vec![
            c.name.clone(),
            c.p.to_string(),
            c.n.to_string(),
            c.k.to_string(),
            c.batch.to_string(),
            c.variant.clone(),
            c.entries.len().to_string(),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn cmd_fit_comm() -> Result<()> {
    let result = experiments::run("table3", None)?;
    print!("{}", result.render_markdown());
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use phantom::tensor::tune;

    args.check_known(&["shapes", "iters", "out", "quick", "fresh", "show"])?;
    let out_path = args
        .opt("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(tune::default_manifest_path);

    if args.flag("show") {
        let isa = phantom::tensor::simd::active();
        println!("active ISA: {}", isa.name());
        match tune::Tuning::load(&out_path)? {
            None => println!("no tuning manifest at {} (defaults in use)", out_path.display()),
            Some(t) => {
                println!("manifest: {} (tuned on {})", out_path.display(), t.isa);
                let mut tab = Table::new(
                    "GEMM tuning manifest",
                    &["class", "mr", "kc", "jc", "max_bands", "par_min_flops"],
                );
                for (key, p) in &t.classes {
                    tab.row(vec![
                        tune::class_name(*key),
                        p.mr.to_string(),
                        p.kc.to_string(),
                        p.jc.to_string(),
                        p.max_bands.to_string(),
                        p.par_min_flops.to_string(),
                    ]);
                }
                print!("{}", tab.markdown());
            }
        }
        return Ok(());
    }

    let shapes = tune::parse_shapes_arg(args.opt("shapes").unwrap_or("tracked"))?;
    let iters = args.opt_parse::<usize>("iters")?.unwrap_or(5);
    let quick = args.flag("quick");
    let isa = phantom::tensor::simd::active();
    eprintln!(
        "tune: ISA {}, {} shape(s), {} iters/candidate{}",
        isa.name(),
        shapes.len(),
        iters,
        if quick { ", quick grid" } else { "" }
    );

    let (mut tuning, outcomes) = tune::autotune(&shapes, iters, quick);

    // Merge into an existing manifest unless --fresh: re-tuning one shape
    // set must not throw away winners for the others.
    if !args.flag("fresh") {
        match tune::Tuning::load(&out_path) {
            Ok(Some(prev)) => {
                for (key, params) in prev.classes {
                    tuning.classes.entry(key).or_insert(params);
                }
            }
            Ok(None) => {}
            Err(e) => eprintln!("tune: warning: not merging unreadable manifest: {e}"),
        }
    }
    tuning.save(&out_path)?;

    let mut tab = Table::new(
        &format!("Autotune — ISA {}", isa.name()),
        &["shape", "class", "mr", "kc", "jc", "GFLOP/s", "vs default"],
    );
    for o in &outcomes {
        let (m, k, n) = o.shape;
        tab.row(vec![
            format!("{m}x{k}x{n}"),
            tune::class_name(o.class),
            o.best.mr.to_string(),
            o.best.kc.to_string(),
            o.best.jc.to_string(),
            format!("{:.2}", o.gflops()),
            format!("{:.2}x", o.speedup_vs_default()),
        ]);
    }
    print!("{}", tab.markdown());
    println!("wrote {} ({} shape classes)", out_path.display(), tuning.classes.len());
    Ok(())
}
