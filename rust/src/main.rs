//! `phantom` — launcher for the phantom-parallelism training system.
//!
//! See `phantom help` (cli::USAGE) for the command reference. Python/JAX
//! never runs here. The default `--backend native` executes the fused
//! pure-Rust kernels, fully self-contained; `--backend xla` loads AOT
//! artifacts through PJRT (requires the `xla` cargo feature and
//! `make artifacts`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use phantom::ckpt::{self, Snapshot};
use phantom::cli::{Args, USAGE};
use phantom::config::{
    preset, BackendKind, CkptPolicy, OptimizerConfig, Parallelism, Schedule, ServeConfig,
};
use phantom::coordinator::{self, TrainOptions};
use phantom::experiments;
use phantom::perfmodel::{self, GemmModel, Workload};
use phantom::runtime::{default_artifact_dir, ExecServer};
use phantom::simnet::NetworkProfile;
use phantom::util::json::Json;
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() {
    // The binary is chatty by default; libraries and tests inherit the
    // quiet Warn default. PHANTOM_LOG overrides either way.
    phantom::obs::log::init(phantom::obs::log::Level::Info);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        phantom::log_error!("{e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "pipeline" => cmd_pipeline(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "ckpt" => cmd_ckpt(&args),
        "chaos" => cmd_chaos(&args),
        "experiment" => cmd_experiment(&args),
        "predict" => cmd_predict(&args),
        "plan" => cmd_plan(&args),
        "inspect" => cmd_inspect(&args),
        "fit-comm" => cmd_fit_comm(),
        "tune" => cmd_tune(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "preset",
        "mode",
        "dp",
        "micro",
        "schedule",
        "sharded",
        "iters",
        "target-loss",
        "lr",
        "optimizer",
        "seed",
        "out",
        "backend",
        "ckpt-every",
        "ckpt-dir",
        "resume",
    ])?;

    let resume_dir = args.opt("resume");
    let (mut cfg, preset_name, resume) = match resume_dir {
        Some(dir) => {
            // The snapshot fixes everything that shapes the math; allowing
            // these flags alongside --resume would silently diverge from
            // the saved trajectory.
            for fixed in [
                "preset",
                "mode",
                "dp",
                "micro",
                "schedule",
                "sharded",
                "optimizer",
                "lr",
                "seed",
                "backend",
            ] {
                if args.opt(fixed).is_some() || args.flag(fixed) {
                    bail!("--{fixed} cannot be combined with --resume (the snapshot fixes it)");
                }
            }
            let snap = Snapshot::load(Path::new(dir))
                .with_context(|| format!("loading --resume snapshot {dir}"))?;
            let cfg = snap.config.clone();
            cfg.validate().context("resumed snapshot config")?;
            phantom::log_info!(
                "resuming from {dir} at iteration {} (loss {:.6})",
                snap.progress.iter,
                snap.progress.losses.last().copied().unwrap_or(f64::NAN)
            );
            (cfg, "resumed".to_string(), Some(snap))
        }
        None => {
            let preset_name = args.opt("preset").unwrap_or("quickstart");
            let mode = Parallelism::parse(args.opt("mode").unwrap_or("pp"))?;
            let mut cfg = preset(preset_name, mode)?;
            cfg.backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
            if let Some(dp) = args.opt_parse::<usize>("dp")? {
                cfg.dp = dp;
            }
            if let Some(seed) = args.opt_parse::<u64>("seed")? {
                cfg.train.seed = seed;
            }
            let lr = args.opt_parse::<f32>("lr")?.unwrap_or(1.0);
            cfg.train.optimizer = match args.opt("optimizer").unwrap_or("sgd") {
                "sgd" => OptimizerConfig::Sgd { lr },
                "momentum" => OptimizerConfig::Momentum { lr, beta: 0.9 },
                "adam" => OptimizerConfig::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
                o => bail!("unknown optimizer '{o}'"),
            };
            if let Some(micro) = args.opt_parse::<usize>("micro")? {
                cfg.train.micro = micro;
            }
            if let Some(s) = args.opt("schedule") {
                cfg.train.schedule = Schedule::parse(s)?;
            }
            if args.flag("sharded") {
                cfg.train.sharded_state = true;
            }
            (cfg, preset_name.to_string(), None)
        }
    };
    if let Some(iters) = args.opt_parse::<usize>("iters")? {
        cfg.train.max_iters = iters;
    }
    if let Some(target) = args.opt_parse::<f64>("target-loss")? {
        cfg.train.target_loss = Some(target);
    }
    let ckpt = match (args.opt_parse::<usize>("ckpt-every")?, args.opt("ckpt-dir")) {
        (Some(every), Some(dir)) => {
            let policy = CkptPolicy { every, dir: dir.into() };
            policy.validate()?;
            Some(policy)
        }
        (None, None) => None,
        _ => bail!("--ckpt-every and --ckpt-dir must be given together"),
    };

    let server = ExecServer::for_run(&cfg)?;
    phantom::log_info!(
        "training {} / {} on {} simulated ranks ({} model x {} dp; n={}, k={}, L={}, \
         backend={})...",
        preset_name,
        cfg.mode.name(),
        cfg.world(),
        cfg.p,
        cfg.dp,
        cfg.model.n,
        cfg.model.k,
        cfg.model.layers,
        server.backend_name()
    );
    let opts = TrainOptions { ckpt, resume, ..Default::default() };
    let report = coordinator::train_with(&cfg, &server, opts)?;

    let mut t = Table::new(
        &format!("Training report — {} ({})", preset_name, cfg.mode.name()),
        &["metric", "value"],
    );
    t.row(vec!["iterations".into(), report.iterations.to_string()]);
    t.row(vec![
        "final loss".into(),
        format!("{:.6}", report.losses.last().copied().unwrap_or(f64::NAN)),
    ]);
    t.row(vec!["model params".into(), report.model_params.to_string()]);
    t.row(vec!["energy (train)".into(), fmt_joules(report.energy_train_j)]);
    t.row(vec!["energy/iter".into(), fmt_joules(report.energy_per_iter_j())]);
    t.row(vec!["virtual wall".into(), fmt_secs(report.wall_train_s)]);
    if report.dp > 1 {
        // Hybrid runs: surface the DP gradient-sync bucket on its own
        // row. Full-run total (warmup included) — labeled as such, since
        // the energy rows above are post-warmup.
        let dp_s: f64 = report.per_rank.iter().map(|r| r.ledger.dp_comm_s).sum();
        t.row(vec!["ranks (model x dp)".into(), format!("{} x {}", report.p, report.dp)]);
        t.row(vec![
            "dp grad sync (full run)".into(),
            format!(
                "{} ({})",
                fmt_secs(dp_s),
                fmt_joules(cfg.hardware.power.idle_w * dp_s)
            ),
        ]);
    }
    print!("{}", t.markdown());

    // loss curve (sparse print)
    let stride = (report.losses.len() / 10).max(1);
    println!("\nloss curve:");
    for (i, l) in report.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == report.losses.len() {
            println!("  iter {i:>5}  loss {l:.6}");
        }
    }

    if let Some(path) = args.opt("out") {
        std::fs::write(path, report_json(&report).pretty())?;
        phantom::log_info!("wrote {path}");
    }
    Ok(())
}

/// Rank-seconds not spent computing (exposed comm + DP sync + idle) as a
/// fraction of total rank-seconds: the pipeline-bubble metric the 1F1B
/// schedule exists to shrink.
fn bubble_fraction(report: &coordinator::TrainReport) -> f64 {
    let mut stall = 0.0;
    let mut total = 0.0;
    for r in &report.per_rank {
        stall += r.ledger.comm_s + r.ledger.dp_comm_s + r.ledger.idle_s;
        total += r.ledger.end_s;
    }
    if total > 0.0 {
        stall / total
    } else {
        0.0
    }
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    args.check_known(&["preset", "iters", "micro", "dp", "seed", "out"])?;
    let preset_name = args.opt("preset").unwrap_or("tiny");
    let iters = args.opt_parse::<usize>("iters")?.unwrap_or(8);
    let dp = args.opt_parse::<usize>("dp")?.unwrap_or(2);
    if dp < 2 {
        bail!("--dp must be >= 2 (the flat-vs-sharded arm shards optimizer state across DP)");
    }

    // All four arms share geometry, seed and a stateful (momentum)
    // optimizer so the sharded arm has per-rank moment floats to shrink.
    let mut base = preset(preset_name, Parallelism::Phantom)?;
    base.train.max_iters = iters;
    base.train.optimizer = OptimizerConfig::Momentum { lr: 0.05, beta: 0.9 };
    if let Some(seed) = args.opt_parse::<u64>("seed")? {
        base.train.seed = seed;
    }
    let micro = args.opt_parse::<usize>("micro")?.unwrap_or_else(|| base.train.batch.min(4));
    let run = |cfg: &phantom::config::RunConfig| -> Result<coordinator::TrainReport> {
        cfg.validate()?;
        let server = ExecServer::for_run(cfg)?;
        coordinator::train_with(cfg, &server, TrainOptions::default())
    };

    // Arm 1/2 — schedule: sync vs 1F1B at the same micro-batching. The
    // interleaved schedule must reproduce the sync trajectory bitwise while
    // hiding boundary-collective wire time behind the next chunk's compute.
    phantom::log_info!(
        "pipeline bench: {preset_name} p={} micro={micro}, sync vs 1f1b...",
        base.p
    );
    let mut sync_cfg = base.clone();
    sync_cfg.train.micro = micro;
    sync_cfg.train.schedule = Schedule::Sync;
    let sync = run(&sync_cfg)?;
    let mut ofob_cfg = sync_cfg.clone();
    ofob_cfg.train.schedule = Schedule::OneFOneB;
    let ofob = run(&ofob_cfg)?;

    // Arm 3/4 — optimizer state: flat vs ZeRO-1 sharded at dp replicas
    // (micro=1/sync isolates the sharding change). Bitwise-equal losses and
    // ~1/dp per-rank optimizer-state floats are the contract.
    phantom::log_info!("pipeline bench: {preset_name} dp={dp}, flat vs sharded state...");
    let mut flat_cfg = base.clone();
    flat_cfg.dp = dp;
    let flat = run(&flat_cfg)?;
    let mut shard_cfg = flat_cfg.clone();
    shard_cfg.train.sharded_state = true;
    let sharded = run(&shard_cfg)?;

    let opt_floats = |r: &coordinator::TrainReport| {
        r.per_rank.iter().map(|pr| pr.opt_state_floats).max().unwrap_or(0) as f64
    };
    let sync_bubble = bubble_fraction(&sync);
    let ofob_bubble = bubble_fraction(&ofob);
    let bubble_reduced = ofob_bubble < sync_bubble;
    let schedule_bitwise = sync.losses == ofob.losses && sync.iterations == ofob.iterations;
    let sharded_bitwise = flat.losses == sharded.losses && flat.iterations == sharded.iterations;

    let mut t = Table::new(
        &format!("Pipeline bench — {preset_name} (p={}, micro={micro}, dp={dp})", base.p),
        &["arm", "J/step", "bubble", "opt floats/rank", "virtual wall"],
    );
    let flat_label = format!("flat dp={dp}");
    let shard_label = format!("sharded dp={dp}");
    for (name, r) in [
        ("sync", &sync),
        ("1f1b", &ofob),
        (flat_label.as_str(), &flat),
        (shard_label.as_str(), &sharded),
    ] {
        t.row(vec![
            name.to_string(),
            fmt_joules(r.energy_per_iter_j()),
            format!("{:.1}%", bubble_fraction(r) * 100.0),
            format!("{:.0}", opt_floats(r)),
            fmt_secs(r.wall_train_s),
        ]);
    }
    print!("{}", t.markdown());

    let verdict = |ok: bool| if ok { 1.0 } else { 0.0 };
    let records: Vec<(String, f64)> = vec![
        ("pipeline_p".into(), base.p as f64),
        ("pipeline_micro".into(), micro as f64),
        ("pipeline_dp".into(), dp as f64),
        ("pipeline_iters".into(), iters as f64),
        ("sync_j_per_step".into(), sync.energy_per_iter_j()),
        ("1f1b_j_per_step".into(), ofob.energy_per_iter_j()),
        ("sync_bubble_frac".into(), sync_bubble),
        ("1f1b_bubble_frac".into(), ofob_bubble),
        ("flat_j_per_step".into(), flat.energy_per_iter_j()),
        ("sharded_j_per_step".into(), sharded.energy_per_iter_j()),
        ("flat_opt_state_floats".into(), opt_floats(&flat)),
        ("sharded_opt_state_floats".into(), opt_floats(&sharded)),
        ("bubble_reduced".into(), verdict(bubble_reduced)),
        ("schedule_bitwise".into(), verdict(schedule_bitwise)),
        ("sharded_bitwise".into(), verdict(sharded_bitwise)),
    ];
    let out = args.opt("out").unwrap_or("BENCH_pipeline.json");
    let virtual_s = [&sync, &ofob, &flat, &sharded]
        .iter()
        .flat_map(|r| r.per_rank.iter())
        .map(|pr| pr.ledger.end_s)
        .fold(0.0, f64::max);
    let meta = phantom::util::json::BenchMeta::new("pipeline", virtual_s);
    phantom::util::json::write_records_json_with_meta(Path::new(out), &records, &meta)?;
    phantom::log_info!("wrote {out}");
    phantom::log_info!(
        "verdicts: bubble_reduced={} ({:.2}% -> {:.2}%), schedule_bitwise={}, \
         sharded_bitwise={} (opt floats {} -> {})",
        bubble_reduced,
        sync_bubble * 100.0,
        ofob_bubble * 100.0,
        schedule_bitwise,
        sharded_bitwise,
        opt_floats(&flat),
        opt_floats(&sharded),
    );
    if !bubble_reduced {
        bail!("1f1b bubble {ofob_bubble:.4} is not below the sync bubble {sync_bubble:.4}");
    }
    if !schedule_bitwise {
        bail!("1f1b loss trajectory diverged bitwise from the sync schedule at equal micro");
    }
    if !sharded_bitwise {
        bail!("sharded-state loss trajectory diverged bitwise from the flat dp={dp} run");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "preset",
        "mode",
        "backend",
        "queries",
        "rate",
        "max-batch",
        "linger-ms",
        "queue-depth",
        "open-loop",
        "seed",
        "out",
    ])?;
    let preset_name = args.opt("preset").unwrap_or("small");
    let modes: Vec<Parallelism> = match args.opt("mode").unwrap_or("both") {
        "both" => vec![Parallelism::Phantom, Parallelism::Tensor],
        m => vec![Parallelism::parse(m)?],
    };
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    let open_loop = args.flag("open-loop");

    let mut table = Table::new(
        &format!("Serving — preset {preset_name}, dynamic batching"),
        &[
            "mode",
            "batches",
            "mean batch",
            "p50 latency",
            "p95 latency",
            "throughput (q/s)",
            "energy / 1k queries",
            "shed",
            "blocked",
        ],
    );
    let mut reports = Vec::new();
    for mode in modes {
        let mut cfg = preset(preset_name, mode)?;
        cfg.backend = backend;
        let server = ExecServer::for_run(&cfg)?;
        let max_batch = args.opt_parse::<usize>("max-batch")?.unwrap_or(cfg.train.batch);
        let scfg = ServeConfig {
            queue_depth: args.opt_parse::<usize>("queue-depth")?.unwrap_or(4 * max_batch),
            max_batch,
            linger_s: args.opt_parse::<f64>("linger-ms")?.unwrap_or(2.0) * 1e-3,
            mode,
        };
        let defaults = phantom::serve::LoadGenConfig::default();
        let lcfg = phantom::serve::LoadGenConfig {
            queries: args.opt_parse::<usize>("queries")?.unwrap_or(defaults.queries),
            rate_qps: args.opt_parse::<f64>("rate")?.unwrap_or(defaults.rate_qps),
            seed: args.opt_parse::<u64>("seed")?.unwrap_or(defaults.seed),
            open_loop,
        };
        phantom::log_info!(
            "serving {} / {} ({} queries @ {} q/s, batch<={}, linger {:.1} ms)...",
            preset_name,
            mode.name(),
            lcfg.queries,
            lcfg.rate_qps,
            scfg.max_batch,
            scfg.linger_s * 1e3
        );
        let r = phantom::serve::run_load(&cfg, &scfg, &lcfg, &server)?;
        if r.misordered > 0 {
            bail!("{} responses arrived out of order (serve bug)", r.misordered);
        }
        if !open_loop && r.completed != lcfg.queries {
            bail!(
                "dropped {} of {} queries despite blocking backpressure",
                lcfg.queries - r.completed,
                lcfg.queries
            );
        }
        table.row(vec![
            mode.name().to_uppercase(),
            r.batches.to_string(),
            format!("{:.1}", r.mean_batch),
            fmt_secs(r.latency.p50),
            fmt_secs(r.latency.p95),
            format!("{:.0}", r.throughput_qps),
            fmt_joules(r.energy_per_kq_j),
            r.rejected.to_string(),
            r.blocked.to_string(),
        ]);
        reports.push(r);
    }
    print!("{}", table.markdown());

    let records = phantom::serve::combined_records(&reports);
    if let Some((_, ratio)) = records.iter().find(|(k, _)| k == "pp_over_tp_energy") {
        println!(
            "\nPP serves at {:.1}% of TP's energy per 1k queries (Table II traffic savings).",
            ratio * 100.0
        );
    }
    let out = args.opt("out").unwrap_or("BENCH_serve.json");
    let virtual_s = reports
        .iter()
        .flat_map(|r| r.per_rank.iter())
        .map(|pr| pr.ledger.end_s)
        .fold(0.0, f64::max);
    let meta = phantom::util::json::BenchMeta::new("serve", virtual_s);
    phantom::serve::write_records_json_with_meta(std::path::Path::new(out), &records, &meta)?;
    phantom::log_info!("wrote {out}");
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use phantom::serve::{AutoscaleConfig, BurstModel, FleetConfig, RoutePolicy};

    args.check_known(&[
        "preset",
        "mode",
        "backend",
        "replicas",
        "policy",
        "queries",
        "base-qps",
        "max-batch",
        "linger-ms",
        "queue-depth",
        "seed",
        "out",
    ])?;
    let preset_name = args.opt("preset").unwrap_or("quickstart");
    let mode = Parallelism::parse(args.opt("mode").unwrap_or("pp"))?;
    let mut cfg = preset(preset_name, mode)?;
    cfg.backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    let exec = ExecServer::for_run(&cfg)?;

    let max_batch = args.opt_parse::<usize>("max-batch")?.unwrap_or(cfg.train.batch);
    let scfg = ServeConfig {
        // Per-replica bound defaults to one batch: shedding and occupancy
        // pressure show up at realistic replica counts.
        queue_depth: args.opt_parse::<usize>("queue-depth")?.unwrap_or(max_batch),
        max_batch,
        linger_s: args.opt_parse::<f64>("linger-ms")?.unwrap_or(2.0) * 1e-3,
        mode,
    };
    let replica_counts: Vec<usize> = args
        .opt("replicas")
        .unwrap_or("2,3")
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--replicas {s}: {e}")))
        .collect::<Result<_>>()?;
    let policies: Vec<RoutePolicy> = match args.opt("policy").unwrap_or("all") {
        "all" => RoutePolicy::all().to_vec(),
        list => list.split(',').map(|s| RoutePolicy::parse(s.trim())).collect::<Result<_>>()?,
    };
    let queries = args.opt_parse::<usize>("queries")?.unwrap_or(480);
    let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0xF1EE7);
    let burst = BurstModel {
        base_qps: args.opt_parse::<f64>("base-qps")?.unwrap_or(BurstModel::default().base_qps),
        ..BurstModel::default()
    };
    burst.validate()?;
    // One trace per run: every replica count and policy serves the same
    // arrivals and payloads, so rows are directly comparable.
    let arrivals = burst.trace(seed, queries);

    let mut table = Table::new(
        &format!("Replica fleet — preset {preset_name} ({}), bursty load", mode.name()),
        &[
            "replicas",
            "policy",
            "completed",
            "shed rate",
            "p50 latency",
            "p99 latency",
            "mean active",
            "energy / 1k queries",
            "scale up/down",
        ],
    );
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut total_misordered = 0usize;
    let mut energy_ok = true;
    let mut compared = false;
    let mut virtual_s = 0.0f64;
    for &rmax in &replica_counts {
        let autoscale = AutoscaleConfig { max_replicas: rmax, ..AutoscaleConfig::default() };
        let mut rr_jkq: Option<f64> = None;
        for &policy in &policies {
            let fcfg = FleetConfig { policy, autoscale };
            phantom::log_info!(
                "fleet {preset_name}/{}: {} queries, {} replicas max, policy {}...",
                mode.name(),
                queries,
                rmax,
                policy.name()
            );
            let r = phantom::serve::run_fleet(&cfg, &scfg, &fcfg, &arrivals, seed, &exec)?;
            total_misordered += r.misordered;
            virtual_s = virtual_s.max(r.virtual_s);
            match policy {
                RoutePolicy::RoundRobin => rr_jkq = Some(r.energy_per_kq_j),
                RoutePolicy::EnergyAware => {
                    if let Some(rr) = rr_jkq {
                        compared = true;
                        let beats = r.energy_per_kq_j <= rr;
                        energy_ok &= beats;
                        records.push((
                            format!("r{rmax}_energy_beats_rr"),
                            if beats { 1.0 } else { 0.0 },
                        ));
                    }
                }
                RoutePolicy::LeastQueue => {}
            }
            table.row(vec![
                rmax.to_string(),
                policy.name().to_string(),
                format!("{}/{}", r.completed, r.queries),
                format!("{:.1}%", 100.0 * r.shed as f64 / r.queries as f64),
                fmt_secs(r.latency.p50),
                fmt_secs(r.latency.p99),
                format!("{:.2}", r.mean_active),
                fmt_joules(r.energy_per_kq_j),
                format!("{}/{}", r.scale_ups, r.scale_downs),
            ]);
            records.extend(phantom::serve::fleet_records(&r));
        }
    }
    print!("{}", table.markdown());

    if total_misordered > 0 {
        bail!("{total_misordered} fleet responses arrived out of order (serve bug)");
    }
    records.push(("fleet_misordered".to_string(), total_misordered as f64));
    if compared {
        // The CI smoke greps this verdict: the energy-aware router must
        // serve at or below round-robin's J/query on the same trace.
        records.push(("energy_beats_rr".to_string(), if energy_ok { 1.0 } else { 0.0 }));
        println!(
            "\nenergy-aware router {} round-robin on J/query across replica counts.",
            if energy_ok { "beats or matches" } else { "LOSES to" }
        );
    }
    let out = args.opt("out").unwrap_or("BENCH_fleet.json");
    let meta = phantom::util::json::BenchMeta::new("fleet", virtual_s);
    phantom::serve::write_records_json_with_meta(std::path::Path::new(out), &records, &meta)?;
    phantom::log_info!("wrote {out}");
    Ok(())
}

fn cmd_ckpt(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: phantom ckpt <inspect|reshard|verify> ..."))?;
    match sub {
        "inspect" => {
            args.check_known(&["dir"])?;
            let dir = args.require("dir")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let mut t = Table::new(&format!("Snapshot — {dir}"), &["field", "value"]);
            t.row(vec!["mode".into(), snap.mode().name().to_uppercase()]);
            t.row(vec!["p".into(), snap.p().to_string()]);
            t.row(vec!["n".into(), snap.n().to_string()]);
            t.row(vec!["k".into(), snap.k().to_string()]);
            t.row(vec!["layers".into(), snap.layers().to_string()]);
            t.row(vec!["batch".into(), snap.config.train.batch.to_string()]);
            t.row(vec!["optimizer".into(), snap.config.train.optimizer.name().into()]);
            t.row(vec!["iterations".into(), snap.progress.iter.to_string()]);
            t.row(vec![
                "last loss".into(),
                snap.progress
                    .losses
                    .last()
                    .map(|l| format!("{l:.6}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
            let params: u64 = snap
                .shards
                .iter()
                .map(|s| match &s.params {
                    phantom::ckpt::RankParams::Phantom(p) => p.param_count(),
                    phantom::ckpt::RankParams::Tensor(p) => p.param_count(),
                })
                .sum();
            t.row(vec!["model params".into(), params.to_string()]);
            t.row(vec![
                "optimizer state".into(),
                snap.shards[0]
                    .opt
                    .as_ref()
                    .map(|o| o.kind().to_string())
                    .unwrap_or_else(|| "fresh".into()),
            ]);
            print!("{}", t.markdown());
            Ok(())
        }
        "reshard" => {
            args.check_known(&["dir", "out", "p", "mode"])?;
            let dir = args.require("dir")?;
            let out = args.require("out")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let target_p = args.opt_parse::<usize>("p")?.unwrap_or(snap.p());
            let target_mode = match args.opt("mode") {
                Some(m) => Parallelism::parse(m)?,
                None => snap.mode(),
            };
            let re = ckpt::reshard(&snap, target_p, target_mode)?;
            re.save(Path::new(out))?;
            phantom::log_info!(
                "resharded {} (p={}, {}) -> {} (p={}, {}, k={})",
                dir,
                snap.p(),
                snap.mode().name(),
                out,
                re.p(),
                re.mode().name(),
                re.k()
            );
            Ok(())
        }
        "verify" => {
            args.check_known(&["dir", "against", "batch", "seed", "tol"])?;
            let dir = args.require("dir")?;
            let snap = Snapshot::load(Path::new(dir))?;
            let batch = args.opt_parse::<usize>("batch")?.unwrap_or(8);
            let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0xC4EC);
            let tol = args.opt_parse::<f32>("tol")?.unwrap_or(1e-4);
            let mut rng = phantom::util::prng::Prng::new(seed);
            let x = phantom::tensor::Tensor::randn(&[batch, snap.n()], 1.0, &mut rng);
            let y = snap.forward_host(&x)?;
            if !y.data().iter().all(|v| v.is_finite()) {
                bail!("{dir}: forward produced non-finite outputs");
            }
            phantom::log_info!("{dir}: checksums ok, forward on [{batch}, {}] finite", snap.n());
            if let Some(other) = args.opt("against") {
                let snap2 = Snapshot::load(Path::new(other))?;
                if snap2.n() != snap.n() {
                    bail!("{other}: n={} does not match {dir} n={}", snap2.n(), snap.n());
                }
                let y2 = snap2.forward_host(&x)?;
                if !y2.data().iter().all(|v| v.is_finite()) {
                    bail!("{other}: forward produced non-finite outputs");
                }
                let mut worst = 0.0f32;
                for (a, b) in y.data().iter().zip(y2.data()) {
                    worst = worst.max((a - b).abs() / (1.0 + a.abs()));
                }
                if worst > tol {
                    bail!(
                        "forward outputs diverge: worst relative error {worst:.3e} > tol \
                         {tol:.3e}"
                    );
                }
                println!(
                    "equivalent: worst relative error {worst:.3e} <= tol {tol:.3e} \
                     ({} p={} vs {} p={})",
                    snap.mode().name(),
                    snap.p(),
                    snap2.mode().name(),
                    snap2.p()
                );
            }
            Ok(())
        }
        other => bail!("unknown ckpt subcommand '{other}' (want inspect|reshard|verify)"),
    }
}

fn cmd_chaos(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario",
        "configs",
        "iters",
        "seed",
        "preset",
        "crash-rank",
        "crash-iter",
        "out",
    ])?;
    let scenario = args.opt("scenario").unwrap_or("all");
    if !matches!(scenario, "sweep" | "train" | "serve" | "all") {
        bail!("unknown chaos scenario '{scenario}' (want sweep|train|serve|all)");
    }
    let preset_name = args.opt("preset").unwrap_or("tiny_p2");
    let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0xC4A05);
    let crash_rank = args.opt_parse::<usize>("crash-rank")?.unwrap_or(1);
    let crash_iter = args.opt_parse::<u64>("crash-iter")?.unwrap_or(3);
    // Validate the chaos parameters up front, before the (comparatively
    // expensive) differential sweep runs under --scenario all — and reject
    // options the chosen scenario would silently ignore.
    if scenario == "serve" && args.opt("crash-iter").is_some() {
        bail!("--crash-iter applies to the train scenario only (serve crashes at a fixed batch)");
    }
    if scenario == "sweep"
        && (args.opt("crash-rank").is_some() || args.opt("crash-iter").is_some())
    {
        bail!("--crash-rank/--crash-iter apply to the train/serve scenarios only");
    }
    if matches!(scenario, "train" | "serve")
        && (args.opt("configs").is_some() || args.opt("iters").is_some())
    {
        bail!("--configs/--iters apply to the sweep scenario only");
    }
    if matches!(scenario, "train" | "serve" | "all") {
        let probe = preset(preset_name, Parallelism::Phantom)?;
        if crash_rank >= probe.p {
            bail!(
                "--crash-rank {crash_rank} out of range for preset '{preset_name}' (p={})",
                probe.p
            );
        }
        // The train scenario runs 8 iterations with snapshots every 2.
        if matches!(scenario, "train" | "all") && !(2..8).contains(&crash_iter) {
            bail!(
                "--crash-iter {crash_iter} must be in [2, 8) (the train scenario runs 8 \
                 iterations with snapshots every 2)"
            );
        }
    }
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut table = Table::new("Chaos & conformance harness", &["check", "result"]);

    if matches!(scenario, "sweep" | "all") {
        let sw = phantom::testkit::SweepConfig {
            cases: args.opt_parse::<usize>("configs")?.unwrap_or(25),
            iters: args.opt_parse::<usize>("iters")?.unwrap_or(3),
            seed,
            ..Default::default()
        };
        phantom::log_info!(
            "differential sweep: {} randomized configs x 2 modes, {} iters each...",
            sw.cases, sw.iters
        );
        let report = phantom::testkit::run_sweep(&sw)?;
        table.row(vec![
            "differential sweep".into(),
            format!(
                "{} configs ok (loss dev {:.1e}, grad dev {:.1e}, reshard dev {:.1e})",
                report.cases.len(),
                report.max_loss_dev,
                report.max_grad_dev,
                report.max_forward_dev
            ),
        ]);
        records.extend(report.records());
    }

    if matches!(scenario, "train" | "all") {
        let mut cfg = preset(preset_name, Parallelism::Phantom)?;
        cfg.train.seed = seed;
        let dir = std::env::temp_dir()
            .join(format!("phantom-chaos-{}-{}", std::process::id(), seed));
        phantom::log_info!(
            "train chaos: crash rank {crash_rank} at iteration {crash_iter}, then resume..."
        );
        let result =
            phantom::testkit::train_crash_resume(&cfg, 8, 2, crash_rank, crash_iter, &dir);
        std::fs::remove_dir_all(&dir).ok(); // clean up snapshots on error paths too
        let report = result?;
        if !report.bit_identical {
            bail!("crash-resume trajectory diverged from the uninterrupted run");
        }
        table.row(vec![
            "train crash-resume".into(),
            format!(
                "bit-identical over {} iters (resumed from iter {}; \"{}\")",
                report.baseline.len(),
                report.resumed_from,
                report.crash_error
            ),
        ]);
        records.push(("chaos_train_bit_identical".to_string(), 1.0));
        records.push(("chaos_train_resumed_from".to_string(), report.resumed_from as f64));
    }

    if matches!(scenario, "serve" | "all") {
        let mut cfg = preset(preset_name, Parallelism::Phantom)?;
        cfg.train.seed = seed;
        let scfg = ServeConfig {
            max_batch: cfg.train.batch,
            queue_depth: 4 * cfg.train.batch,
            linger_s: 1e-3,
            mode: cfg.mode,
        };
        let crash_seq = phantom::testkit::collectives_per_forward(cfg.model.layers) * 2;
        phantom::log_info!("serve chaos: crash rank {crash_rank} mid-stream, hot-swap recovery...");
        let report =
            phantom::testkit::serve_crash_swap(&cfg, &scfg, 6, crash_rank, crash_seq)?;
        if !report.outputs_match {
            bail!("recovered serve answers diverged from the reference runs");
        }
        if !report.swap_observable {
            bail!("hot-swap weights were indistinguishable — the swap was not exercised");
        }
        table.row(vec![
            "serve crash + hot-swap".into(),
            format!(
                "{} batches, zero dropped (replayed batch {} on swapped weights)",
                report.batches, report.recovered_batch
            ),
        ]);
        records.push(("chaos_serve_outputs_match".to_string(), 1.0));
        records.push(("chaos_serve_recovered_batch".to_string(), report.recovered_batch as f64));
    }

    print!("{}", table.markdown());
    let out = args.opt("out").unwrap_or("BENCH_conformance.json");
    let out_path = Path::new(out);
    // Scoped runs (--scenario train/serve/sweep) keep the other scenarios'
    // records: merge by key into an existing record file, don't clobber it.
    let mut merged =
        phantom::util::json::read_records_json(out_path).unwrap_or_default();
    for (k, v) in records {
        match merged.iter_mut().find(|(mk, _)| *mk == k) {
            Some(slot) => slot.1 = v,
            None => merged.push((k, v)),
        }
    }
    let meta = phantom::util::json::BenchMeta::new("chaos", 0.0);
    phantom::serve::write_records_json_with_meta(out_path, &merged, &meta)?;
    phantom::log_info!("wrote {out}");
    Ok(())
}

fn report_json(r: &coordinator::TrainReport) -> Json {
    Json::obj(vec![
        ("mode", Json::str(r.mode.name())),
        ("p", Json::int(r.p as i64)),
        ("dp", Json::int(r.dp as i64)),
        ("n", Json::int(r.n as i64)),
        ("k", Json::int(r.k as i64)),
        ("layers", Json::int(r.layers as i64)),
        ("batch", Json::int(r.batch as i64)),
        ("iterations", Json::int(r.iterations as i64)),
        ("reached_target", Json::Bool(r.reached_target)),
        ("model_params", Json::int(r.model_params as i64)),
        ("energy_total_j", Json::num(r.energy_total_j)),
        ("energy_train_j", Json::num(r.energy_train_j)),
        ("wall_s", Json::num(r.wall_s)),
        ("wall_train_s", Json::num(r.wall_train_s)),
        ("losses", Json::arr(r.losses.iter().map(|&l| Json::num(l)).collect())),
        (
            "per_rank",
            Json::arr(
                r.per_rank
                    .iter()
                    .map(|rr| {
                        Json::obj(vec![
                            ("rank", Json::int(rr.rank as i64)),
                            ("busy_s", Json::num(rr.ledger.busy_s)),
                            ("comm_s", Json::num(rr.ledger.comm_s)),
                            ("idle_s", Json::num(rr.ledger.idle_s)),
                            ("dp_comm_s", Json::num(rr.ledger.dp_comm_s)),
                            ("floats_moved", Json::int(rr.stats.floats_moved as i64)),
                            ("collectives", Json::int(rr.stats.collectives() as i64)),
                            ("dp_floats_moved", Json::int(rr.dp_stats.floats_moved as i64)),
                            ("dp_collectives", Json::int(rr.dp_stats.collectives() as i64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.check_known(&["out-dir", "backend"])?;
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: phantom experiment <id|all>"))?;
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    // Start the server lazily: the modeled experiments don't need it.
    let needs_server = ids.iter().any(|i| i.starts_with("fig7") || *i == "table1");
    let server = if needs_server {
        Some(ExecServer::for_backend(backend)?)
    } else {
        None
    };
    for id in ids {
        phantom::log_info!("running {id}...");
        let result = experiments::run(id, server.as_ref())?;
        print!("{}", result.render_markdown());
        if let Some(dir) = args.opt("out-dir") {
            std::fs::create_dir_all(dir)?;
            std::fs::write(
                format!("{dir}/{id}.md"),
                result.render_markdown(),
            )?;
            std::fs::write(format!("{dir}/{id}.json"), result.raw.pretty())?;
        }
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    args.check_known(&["n", "p", "k", "layers", "batch"])?;
    let w = Workload::new(
        args.opt_parse::<usize>("n")?.unwrap_or(131_072),
        args.opt_parse::<usize>("layers")?.unwrap_or(2),
        args.opt_parse::<usize>("p")?.unwrap_or(64),
        args.opt_parse::<usize>("k")?.unwrap_or(64),
        args.opt_parse::<usize>("batch")?.unwrap_or(32),
    )
    .context("infeasible workload")?;
    let g = GemmModel::frontier();
    let net = NetworkProfile::frontier();
    let power = phantom::energy::PowerModel::frontier();
    let mut t = Table::new(
        &format!(
            "Analytic prediction — n={}, p={}, k={}, L={}, batch={}",
            w.n, w.p, w.k, w.layers, w.batch
        ),
        &["mode", "compute", "comm", "dispatch", "total/iter", "energy/iter", "fits HBM"],
    );
    for mode in [Parallelism::Tensor, Parallelism::Phantom] {
        let c = perfmodel::predict(mode, &w, &g, &net)?;
        t.row(vec![
            mode.name().to_uppercase(),
            fmt_secs(c.compute_s),
            fmt_secs(c.comm_s),
            fmt_secs(c.dispatch_s),
            fmt_secs(c.total_s()),
            fmt_joules(c.energy_j(&power)),
            perfmodel::fits_memory(mode, &w).to_string(),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

/// Parse a comma-separated list ("2,4,8") into values.
fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>> {
    let vals: Vec<T> = s
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<T>().map_err(|_| anyhow::anyhow!("bad {what} value '{t}' in '{s}'")))
        .collect::<Result<_>>()?;
    if vals.is_empty() {
        bail!("empty {what} list '{s}'");
    }
    Ok(vals)
}

fn cmd_plan(args: &Args) -> Result<()> {
    use phantom::perfmodel::{calib, plan};

    args.check_known(&[
        "objective",
        "n",
        "layers",
        "p",
        "dp",
        "k",
        "batch",
        "linger-ms",
        "slo-ms",
        "calib",
        "iters",
        "queries",
        "out",
        "no-validate",
        "write-calib",
    ])?;

    if args.flag("write-calib") {
        // Regenerate the calibration fixture: real wall-clock GEMM rates
        // from this machine's kernels (what the measured simulator runs),
        // plus collective/power rows stamped from the virtual fabric's own
        // constants (for those two groups the model IS the measurement).
        let iters = args.opt_parse::<usize>("iters")?.unwrap_or(5);
        let out = args.opt("out").unwrap_or(calib::DEFAULT_CALIB_PATH);
        let mut records = calib::measure_gemm_records(calib::CALIB_GEMM_SHAPES, iters);
        let synth = calib::synthesize_records(
            &GemmModel::frontier(),
            &NetworkProfile::frontier(),
            &phantom::energy::PowerModel::frontier(),
        );
        records.extend(synth.into_iter().filter(|(k, _)| !k.ends_with("_gflops")));
        if let Some(parent) = Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let meta = phantom::util::json::BenchMeta::new("calib", 0.0);
        phantom::util::json::write_records_json_with_meta(Path::new(out), &records, &meta)?;
        phantom::log_info!("wrote {out} ({} calibration records)", records.len());
        return Ok(());
    }

    let objective = plan::Objective::parse(args.opt("objective").unwrap_or("train"))?;
    // --calib pins an explicit record file; otherwise auto-calibrate from
    // the real measured trajectories the benches leave at the repo root
    // (BENCH_kernels/hybrid/serve), seed fixture for whatever they miss.
    let calibration = match args.opt("calib") {
        Some(path) => calib::Calibration::load_or_default(Path::new(path)),
        None => calib::Calibration::auto_load(Path::new(".")),
    };
    calibration.log_warnings();
    phantom::log_info!("plan: calibration from {}", calibration.source.describe());

    let space = plan::PlanSpace {
        n: args.opt_parse::<usize>("n")?.unwrap_or(256),
        layers: args.opt_parse::<usize>("layers")?.unwrap_or(2),
        modes: vec![Parallelism::Phantom, Parallelism::Tensor],
        p_choices: parse_list(args.opt("p").unwrap_or("2,4,8"), "--p")?,
        dp_choices: parse_list(args.opt("dp").unwrap_or("1,2"), "--dp")?,
        k_choices: parse_list(args.opt("k").unwrap_or("4,16"), "--k")?,
        batch_choices: parse_list(args.opt("batch").unwrap_or("16"), "--batch")?,
        linger_choices_s: parse_list::<f64>(args.opt("linger-ms").unwrap_or("0,2"), "--linger-ms")?
            .into_iter()
            .map(|ms| ms * 1e-3)
            .collect(),
    };
    let slo_s = args.opt_parse::<f64>("slo-ms")?.map(|ms| ms * 1e-3);
    let report = plan::plan(&space, objective, slo_s, &calibration)?;

    // Feasible cells, cheapest first.
    let mut priced: Vec<(&plan::PlanCell, &plan::CellPrediction)> = report
        .cells
        .iter()
        .filter_map(|(c, o)| o.prediction().map(|p| (c, p)))
        .collect();
    priced.sort_by(|a, b| a.1.j_per_unit.total_cmp(&b.1.j_per_unit));
    let mut t = Table::new(
        &format!(
            "Plan sweep — n={}, L={}, objective {} ({} feasible / {} cells)",
            space.n,
            space.layers,
            objective.name(),
            priced.len(),
            report.cells.len()
        ),
        &["config", &format!("predicted {}", objective.unit()), "latency", "rank"],
    );
    for (i, (cell, pred)) in priced.iter().enumerate() {
        let rank = match i {
            0 => "BEST".to_string(),
            i if i + 1 == priced.len() => "WORST".to_string(),
            i => (i + 1).to_string(),
        };
        t.row(vec![
            cell.label(),
            fmt_joules(pred.j_per_unit),
            fmt_secs(pred.latency_s),
            rank,
        ]);
    }
    print!("{}", t.markdown());
    let infeasible = report.cells.len() - priced.len();
    if infeasible > 0 {
        phantom::log_info!(
            "plan: {infeasible} cell(s) infeasible (reasons recorded in the sweep output)"
        );
    }

    let validation = if args.flag("no-validate") {
        None
    } else {
        let opts = plan::ValidateOptions {
            iters: args.opt_parse::<usize>("iters")?.unwrap_or(6),
            queries: args.opt_parse::<usize>("queries")?.unwrap_or(96),
            ..Default::default()
        };
        phantom::log_info!("plan: measuring predicted-best and predicted-worst cells...");
        Some(plan::validate(&report, &space, &opts)?)
    };

    let out = args.opt("out").unwrap_or("BENCH_plan.json");
    let mut report_doc = plan::report_json(&report, &calibration, validation.as_ref());
    if let Json::Obj(m) = &mut report_doc {
        let meta = phantom::util::json::BenchMeta::new("plan", 0.0);
        m.insert("meta".to_string(), meta.to_json());
    }
    phantom::util::json::write_json(Path::new(out), &report_doc)?;
    phantom::log_info!("wrote {out}");

    if let Some(v) = &validation {
        let mut vt = Table::new(
            &format!("Plan validation — measured {}", objective.unit()),
            &["cell", "config", "predicted", "measured"],
        );
        vt.row(vec![
            "best".into(),
            v.best.cell.label(),
            fmt_joules(v.best.predicted_j),
            fmt_joules(v.best.measured_j),
        ]);
        vt.row(vec![
            "worst".into(),
            v.worst.cell.label(),
            fmt_joules(v.worst.predicted_j),
            fmt_joules(v.worst.measured_j),
        ]);
        print!("{}", vt.markdown());
        if v.ranking_holds {
            println!(
                "\nranking holds: measured best {} < measured worst {}",
                fmt_joules(v.best.measured_j),
                fmt_joules(v.worst.measured_j)
            );
        } else {
            bail!(
                "ranking verdict FAILED: predicted-best measured {} >= predicted-worst \
                 measured {} (see {out})",
                fmt_joules(v.best.measured_j),
                fmt_joules(v.worst.measured_j)
            );
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.check_known(&["backend"])?;
    let backend = BackendKind::parse(args.opt("backend").unwrap_or("native"))?;
    let server = ExecServer::for_backend(backend)?;
    let source = match backend {
        BackendKind::Native => "native synthetic manifest".to_string(),
        BackendKind::Xla => format!("{}", default_artifact_dir().display()),
    };
    let mut t = Table::new(
        &format!("Artifact manifest — {source}"),
        &["config", "p", "n", "k", "batch", "variant", "entries"],
    );
    for c in server.manifest.iter() {
        t.row(vec![
            c.name.clone(),
            c.p.to_string(),
            c.n.to_string(),
            c.k.to_string(),
            c.batch.to_string(),
            c.variant.clone(),
            c.entries.len().to_string(),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn cmd_fit_comm() -> Result<()> {
    let result = experiments::run("table3", None)?;
    print!("{}", result.render_markdown());
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use phantom::tensor::tune;

    args.check_known(&["shapes", "iters", "out", "quick", "fresh", "show"])?;
    let out_path = args
        .opt("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(tune::default_manifest_path);

    if args.flag("show") {
        let isa = phantom::tensor::simd::active();
        println!("active ISA: {}", isa.name());
        match tune::Tuning::load(&out_path)? {
            None => println!("no tuning manifest at {} (defaults in use)", out_path.display()),
            Some(t) => {
                println!("manifest: {} (tuned on {})", out_path.display(), t.isa);
                let mut tab = Table::new(
                    "GEMM tuning manifest",
                    &["class", "mr", "kc", "jc", "max_bands", "par_min_flops"],
                );
                for (key, p) in &t.classes {
                    tab.row(vec![
                        tune::class_name(*key),
                        p.mr.to_string(),
                        p.kc.to_string(),
                        p.jc.to_string(),
                        p.max_bands.to_string(),
                        p.par_min_flops.to_string(),
                    ]);
                }
                print!("{}", tab.markdown());
            }
        }
        return Ok(());
    }

    let shapes = tune::parse_shapes_arg(args.opt("shapes").unwrap_or("tracked"))?;
    let iters = args.opt_parse::<usize>("iters")?.unwrap_or(5);
    let quick = args.flag("quick");
    let isa = phantom::tensor::simd::active();
    phantom::log_info!(
        "tune: ISA {}, {} shape(s), {} iters/candidate{}",
        isa.name(),
        shapes.len(),
        iters,
        if quick { ", quick grid" } else { "" }
    );

    let (mut tuning, outcomes) = tune::autotune(&shapes, iters, quick);

    // Merge into an existing manifest unless --fresh: re-tuning one shape
    // set must not throw away winners for the others.
    if !args.flag("fresh") {
        match tune::Tuning::load(&out_path) {
            Ok(Some(prev)) => {
                for (key, params) in prev.classes {
                    tuning.classes.entry(key).or_insert(params);
                }
            }
            Ok(None) => {}
            Err(e) => {
                phantom::log_warn!("tune: warning: not merging unreadable manifest: {e}")
            }
        }
    }
    tuning.save(&out_path)?;

    let mut tab = Table::new(
        &format!("Autotune — ISA {}", isa.name()),
        &["shape", "class", "mr", "kc", "jc", "GFLOP/s", "vs default"],
    );
    for o in &outcomes {
        let (m, k, n) = o.shape;
        tab.row(vec![
            format!("{m}x{k}x{n}"),
            tune::class_name(o.class),
            o.best.mr.to_string(),
            o.best.kc.to_string(),
            o.best.jc.to_string(),
            format!("{:.2}", o.gflops()),
            format!("{:.2}x", o.speedup_vs_default()),
        ]);
    }
    print!("{}", tab.markdown());
    println!("wrote {} ({} shape classes)", out_path.display(), tuning.classes.len());
    Ok(())
}

/// `phantom trace` — run the train and/or serve drivers traced and
/// untraced, reconcile the per-category energy attribution against the
/// exact ledgers (1e-9 relative), export Perfetto-loadable timelines,
/// and record the tracing overhead (DESIGN.md §13).
fn cmd_trace(args: &Args) -> Result<()> {
    args.check_known(&[
        "scenario",
        "preset",
        "mode",
        "iters",
        "queries",
        "rate",
        "seed",
        "runs",
        "out-dir",
        "bench-out",
    ])?;
    let scenario = args.opt("scenario").unwrap_or("all");
    if !matches!(scenario, "train" | "serve" | "all") {
        bail!("unknown --scenario '{scenario}' (expected train, serve, or all)");
    }
    let out_dir = std::path::PathBuf::from(args.opt("out-dir").unwrap_or("."));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating --out-dir {}", out_dir.display()))?;
    // Wall-clock overhead on these small runs is noisy: every arm takes
    // the minimum over `runs` repeats, after one discarded warmup run.
    let runs = args.opt_parse::<usize>("runs")?.unwrap_or(3).max(1);

    let mut records: Vec<(String, f64)> = Vec::new();
    let mut virtual_s = 0.0f64;
    if scenario != "serve" {
        let (r, v) = trace_train(args, &out_dir, runs)?;
        records.extend(r);
        virtual_s = virtual_s.max(v);
    }
    if scenario != "train" {
        let (r, v) = trace_serve(args, &out_dir, runs)?;
        records.extend(r);
        virtual_s = virtual_s.max(v);
    }

    let out = args.opt("bench-out").unwrap_or("BENCH_trace.json");
    let meta = phantom::util::json::BenchMeta::new("trace", virtual_s);
    phantom::serve::write_records_json_with_meta(Path::new(out), &records, &meta)?;
    phantom::log_info!("wrote {out}");
    Ok(())
}

fn trace_train(args: &Args, out_dir: &Path, runs: usize) -> Result<(Vec<(String, f64)>, f64)> {
    let preset_name = args.opt("preset").unwrap_or("quickstart");
    let mode = Parallelism::parse(args.opt("mode").unwrap_or("pp"))?;
    let mut cfg = preset(preset_name, mode)?;
    cfg.train.max_iters = args.opt_parse::<usize>("iters")?.unwrap_or(12);
    cfg.train.target_loss = None;
    let server = ExecServer::for_run(&cfg)?;
    let power = cfg.hardware.power;
    phantom::log_info!(
        "tracing train {} / {} ({} iters, min of {} runs per arm)...",
        preset_name,
        mode.name(),
        cfg.train.max_iters,
        runs
    );

    coordinator::train_with(&cfg, &server, TrainOptions::default())?;
    let mut untraced_wall = f64::INFINITY;
    for _ in 0..runs {
        let t0 = std::time::Instant::now();
        coordinator::train_with(&cfg, &server, TrainOptions::default())?;
        untraced_wall = untraced_wall.min(t0.elapsed().as_secs_f64());
    }
    let mut traced_wall = f64::INFINITY;
    let mut report = None;
    for _ in 0..runs {
        let opts = TrainOptions { trace: true, ..Default::default() };
        let t0 = std::time::Instant::now();
        let r = coordinator::train_with(&cfg, &server, opts)?;
        traced_wall = traced_wall.min(t0.elapsed().as_secs_f64());
        report = Some(r);
    }
    let report = report.expect("runs >= 1");

    let caps: Vec<(usize, &phantom::obs::TraceCapture, f64)> = report
        .per_rank
        .iter()
        .map(|rr| {
            let cap = rr.trace.as_ref().expect("traced run must capture every rank");
            (rr.rank, cap, rr.ledger.energy_j(&power))
        })
        .collect();

    let mut tracks: Vec<phantom::obs::trace::Track> = caps
        .iter()
        .map(|(rank, cap, _)| phantom::obs::trace::Track {
            name: format!("rank {rank} ({})", mode.name()),
            tid: *rank as i64,
            recorder: &cap.recorder,
        })
        .collect();
    if let Some(host) = &report.host_trace {
        tracks.push(phantom::obs::trace::Track {
            name: "host (real time)".to_string(),
            tid: report.per_rank.len() as i64,
            recorder: host,
        });
    }
    let doc = phantom::obs::trace::chrome_trace(&tracks);
    phantom::obs::trace::validate_trace(&doc)
        .map_err(|e| anyhow::anyhow!("train trace failed validation: {e}"))?;
    let path = out_dir.join("trace_train.json");
    std::fs::write(&path, doc.pretty())?;
    phantom::log_info!("wrote {} ({} tracks)", path.display(), tracks.len());

    let title = format!("Energy attribution — train {preset_name} ({})", mode.name());
    let records = attribution_records("train", &title, &caps, &power, untraced_wall, traced_wall)?;
    Ok((records, report.wall_s))
}

/// Everything `trace serve` needs from one driven run of the pool.
struct ServeTraceRun {
    /// Real seconds for the whole driven run (submission to shutdown).
    wall_s: f64,
    /// Latest virtual rank clock, for the BENCH meta header.
    virtual_s: f64,
    per_rank: Vec<phantom::serve::PoolRankReport>,
    metrics: phantom::obs::MetricsSnapshot,
    events: Option<phantom::obs::SpanRecorder>,
    completed: usize,
}

/// One closed-loop serve run against a fresh pool: `queries` spaced
/// arrivals, then a same-instant burst past the queue depth so the shed
/// path shows up in the metrics and (traced) in the event timeline.
fn drive_serve(
    cfg: &phantom::config::RunConfig,
    exec: &ExecServer,
    scfg: ServeConfig,
    queries: usize,
    rate_qps: f64,
    seed: u64,
    trace: bool,
) -> Result<ServeTraceRun> {
    let opts = phantom::serve::PoolOptions { trace, ..Default::default() };
    let mut server = phantom::serve::Server::start_with(cfg, scfg, exec, opts)?;
    let n = cfg.model.n;
    let mut rng = phantom::util::prng::Prng::new(seed);
    let dt = 1.0 / rate_qps.max(1e-9);
    let t0 = std::time::Instant::now();
    let mut t = 0.0f64;
    for _ in 0..queries {
        t += dt;
        let x = phantom::tensor::Tensor::randn(&[n], 1.0, &mut rng);
        let (_, effective_s) = server.submit_blocking(t, x)?;
        t = t.max(effective_s);
    }
    for _ in 0..scfg.queue_depth + 2 {
        let x = phantom::tensor::Tensor::randn(&[n], 1.0, &mut rng);
        server.try_submit(t, x)?;
    }
    server.drain()?;
    let metrics = server.metrics();
    let events = server.take_host_events();
    let (responses, _stats, per_rank) = server.finish()?;
    let wall_s = t0.elapsed().as_secs_f64();
    let virtual_s = per_rank.iter().map(|pr| pr.ledger.end_s).fold(0.0, f64::max);
    Ok(ServeTraceRun {
        wall_s,
        virtual_s,
        per_rank,
        metrics,
        events,
        completed: responses.len(),
    })
}

fn trace_serve(args: &Args, out_dir: &Path, runs: usize) -> Result<(Vec<(String, f64)>, f64)> {
    let preset_name = args.opt("preset").unwrap_or("quickstart");
    let mode = Parallelism::parse(args.opt("mode").unwrap_or("pp"))?;
    let cfg = preset(preset_name, mode)?;
    let exec = ExecServer::for_run(&cfg)?;
    let power = cfg.hardware.power;
    let queries = args.opt_parse::<usize>("queries")?.unwrap_or(64);
    let rate = args.opt_parse::<f64>("rate")?.unwrap_or(2_000.0);
    let seed = args.opt_parse::<u64>("seed")?.unwrap_or(0x7ACE);
    let scfg = ServeConfig {
        queue_depth: 2 * cfg.train.batch,
        max_batch: cfg.train.batch,
        linger_s: 2e-3,
        mode,
    };
    phantom::log_info!(
        "tracing serve {} / {} ({} queries @ {} q/s, min of {} runs per arm)...",
        preset_name,
        mode.name(),
        queries,
        rate,
        runs
    );

    drive_serve(&cfg, &exec, scfg, queries, rate, seed, false)?;
    let mut untraced_wall = f64::INFINITY;
    for _ in 0..runs {
        let r = drive_serve(&cfg, &exec, scfg, queries, rate, seed, false)?;
        untraced_wall = untraced_wall.min(r.wall_s);
    }
    let mut traced_wall = f64::INFINITY;
    let mut run = None;
    for _ in 0..runs {
        let r = drive_serve(&cfg, &exec, scfg, queries, rate, seed, true)?;
        traced_wall = traced_wall.min(r.wall_s);
        run = Some(r);
    }
    let run = run.expect("runs >= 1");

    let caps: Vec<(usize, &phantom::obs::TraceCapture, f64)> = run
        .per_rank
        .iter()
        .map(|pr| {
            let cap = pr.trace.as_ref().expect("traced pool must capture every rank");
            (pr.rank, cap, pr.ledger.energy_j(&power))
        })
        .collect();

    let mut tracks: Vec<phantom::obs::trace::Track> = caps
        .iter()
        .map(|(rank, cap, _)| phantom::obs::trace::Track {
            name: format!("rank {rank} ({})", mode.name()),
            tid: *rank as i64,
            recorder: &cap.recorder,
        })
        .collect();
    if let Some(ev) = &run.events {
        tracks.push(phantom::obs::trace::Track {
            name: "batcher".to_string(),
            tid: cfg.p as i64,
            recorder: ev,
        });
    }
    let doc = phantom::obs::trace::chrome_trace(&tracks);
    phantom::obs::trace::validate_trace(&doc)
        .map_err(|e| anyhow::anyhow!("serve trace failed validation: {e}"))?;
    let path = out_dir.join("trace_serve.json");
    std::fs::write(&path, doc.pretty())?;
    phantom::log_info!("wrote {} ({} tracks)", path.display(), tracks.len());

    let title = format!("Energy attribution — serve {preset_name} ({})", mode.name());
    let mut records =
        attribution_records("serve", &title, &caps, &power, untraced_wall, traced_wall)?;
    records.push(("serve_completed".to_string(), run.completed as f64));
    for (k, v) in &run.metrics.records {
        records.push((format!("serve_metric_{k}"), *v));
    }
    Ok((records, run.virtual_s))
}

/// Shared tail of both trace scenarios: reconcile every rank's span
/// attribution against its exact ledger energy (1e-9 relative — the
/// invariant is exactness, not approximation), print the per-category
/// rollup, and emit the `{label}_*` BENCH records.
fn attribution_records(
    label: &str,
    title: &str,
    caps: &[(usize, &phantom::obs::TraceCapture, f64)],
    power: &phantom::energy::PowerModel,
    untraced_wall: f64,
    traced_wall: f64,
) -> Result<Vec<(String, f64)>> {
    let mut rollup = phantom::obs::Attribution::default();
    let mut exact_total = 0.0f64;
    let mut rel_err_max = 0.0f64;
    let mut spans = 0u64;
    let mut dropped = 0u64;
    for (rank, cap, exact_j) in caps {
        let attr = cap.attribution(power);
        if !attr.reconciles(*exact_j, 1e-9) {
            bail!(
                "rank {rank}: attribution {} J does not reconcile with ledger {} J",
                attr.total_j(),
                exact_j
            );
        }
        let rel = (attr.total_j() - exact_j).abs() / exact_j.abs().max(1e-12);
        rel_err_max = rel_err_max.max(rel);
        spans += cap.recorder.spans().len() as u64;
        dropped += cap.recorder.dropped();
        exact_total += exact_j;
        rollup.accumulate(&attr);
    }

    let total = rollup.total_j();
    let mut t = Table::new(title, &["category", "busy", "stall", "energy", "share"]);
    for (cat, ce) in &rollup.by_category {
        t.row(vec![
            cat.clone(),
            fmt_secs(ce.busy_s),
            fmt_secs(ce.stall_s),
            fmt_joules(ce.energy_j),
            format!("{:.1}%", 100.0 * ce.energy_j / total.max(1e-12)),
        ]);
    }
    let u = &rollup.untraced;
    t.row(vec![
        "(untraced)".into(),
        fmt_secs(u.busy_s),
        fmt_secs(u.stall_s),
        fmt_joules(u.energy_j),
        format!("{:.1}%", 100.0 * u.energy_j / total.max(1e-12)),
    ]);
    print!("{}", t.markdown());

    let overhead = ((traced_wall - untraced_wall) / untraced_wall.max(1e-9)).max(0.0);
    let mut records = vec![
        (format!("{label}_untraced_wall_s"), untraced_wall),
        (format!("{label}_traced_wall_s"), traced_wall),
        (format!("{label}_overhead_frac"), overhead),
        (format!("{label}_overhead_ok"), if overhead < 0.05 { 1.0 } else { 0.0 }),
        (format!("{label}_reconciled"), 1.0),
        (format!("{label}_rel_err_max"), rel_err_max),
        (format!("{label}_spans"), spans as f64),
        (format!("{label}_spans_dropped"), dropped as f64),
        (format!("{label}_ledger_j"), exact_total),
    ];
    for (cat, ce) in &rollup.by_category {
        records.push((format!("{label}_cat_{}_j", cat.replace('.', "_")), ce.energy_j));
    }
    records.push((format!("{label}_cat_untraced_j"), u.energy_j));
    Ok(records)
}
