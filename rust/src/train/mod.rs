//! Training utilities: optimizers over host tensors, loss tracking, and the
//! fixed-loss stopping rule used by the paper's energy experiments.
//!
//! Optimizers run rank-locally in Rust (no collective is needed: every
//! parameter lives on exactly one rank in both TP and PP). The frozen zero
//! slot of phantom decompressors never moves because its gradient is
//! structurally zero (pp_grads sees a zeroed g_all slot).

use anyhow::{bail, Result};

use crate::config::OptimizerConfig;
use crate::tensor::Tensor;

/// Optimizer state for one parameter list.
pub enum Optimizer {
    Sgd { lr: f32 },
    Momentum { lr: f32, beta: f32, velocity: Vec<Tensor> },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32, t: u64, m: Vec<Tensor>, v: Vec<Tensor> },
}

/// The serializable part of an `Optimizer`: the accumulated moments and the
/// step count, without the hyperparameters (those live in
/// `OptimizerConfig`). Checkpoints persist this so a resumed run continues
/// the exact update sequence of the uninterrupted one.
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizerState {
    Sgd,
    Momentum { velocity: Vec<Tensor> },
    Adam { t: u64, m: Vec<Tensor>, v: Vec<Tensor> },
}

impl OptimizerState {
    pub fn kind(&self) -> &'static str {
        match self {
            OptimizerState::Sgd => "sgd",
            OptimizerState::Momentum { .. } => "momentum",
            OptimizerState::Adam { .. } => "adam",
        }
    }

    /// Number of f32 moment values this state holds. Under ZeRO-1 sharding
    /// each DP replica keeps state only for its owned flat slice, so this
    /// drops to ~1/dp of the replicated baseline — the memory/energy term
    /// BENCH_pipeline.json reports.
    pub fn floats(&self) -> usize {
        match self {
            OptimizerState::Sgd => 0,
            OptimizerState::Momentum { velocity } => velocity.iter().map(Tensor::numel).sum(),
            OptimizerState::Adam { m, v, .. } => {
                m.iter().map(Tensor::numel).sum::<usize>()
                    + v.iter().map(Tensor::numel).sum::<usize>()
            }
        }
    }

    /// Re-materialize a full per-parameter state from dp-rank-ordered
    /// sharded slice states (each holding one flat `[slot]` tensor per
    /// moment). Concatenating the owned slices reproduces the padded flat
    /// moment vector; the zero pad is truncated and the rest unflattened
    /// into `shapes`. Used by `ckpt::collapse_dp` so elastic resume from a
    /// sharded-state checkpoint is bit-identical.
    pub fn concat_sharded(
        parts: &[&OptimizerState],
        shapes: &[Vec<usize>],
    ) -> Result<OptimizerState> {
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        let gather = |slices: Vec<&[Tensor]>| -> Result<Vec<Tensor>> {
            let mut flat = Vec::with_capacity(total);
            for (r, ts) in slices.iter().enumerate() {
                if ts.len() != 1 {
                    bail!("sharded slice {r}: expected 1 flat moment tensor, got {}", ts.len());
                }
                flat.extend_from_slice(ts[0].data());
            }
            if flat.len() < total {
                bail!("sharded slices hold {} floats for {} parameters", flat.len(), total);
            }
            flat.truncate(total); // drop the zero pad
            let mut out = Vec::with_capacity(shapes.len());
            let mut at = 0usize;
            for s in shapes {
                let n: usize = s.iter().product();
                out.push(Tensor::from_vec(s, flat[at..at + n].to_vec())?);
                at += n;
            }
            Ok(out)
        };
        let Some(first) = parts.first() else { bail!("no sharded optimizer slices") };
        for p in parts {
            if p.kind() != first.kind() {
                bail!("mixed sharded state kinds: {} vs {}", p.kind(), first.kind());
            }
        }
        Ok(match first {
            OptimizerState::Sgd => OptimizerState::Sgd,
            OptimizerState::Momentum { .. } => {
                let vs: Vec<&[Tensor]> = parts
                    .iter()
                    .map(|p| match p {
                        OptimizerState::Momentum { velocity } => velocity.as_slice(),
                        _ => unreachable!("kind checked above"),
                    })
                    .collect();
                OptimizerState::Momentum { velocity: gather(vs)? }
            }
            OptimizerState::Adam { t, .. } => {
                let t0 = *t;
                let mut ms = Vec::with_capacity(parts.len());
                let mut vs = Vec::with_capacity(parts.len());
                for p in parts {
                    match p {
                        OptimizerState::Adam { t, m, v } => {
                            if *t != t0 {
                                bail!("sharded Adam step counts diverge: {t} vs {t0}");
                            }
                            ms.push(m.as_slice());
                            vs.push(v.as_slice());
                        }
                        _ => unreachable!("kind checked above"),
                    }
                }
                OptimizerState::Adam { t: t0, m: gather(ms)?, v: gather(vs)? }
            }
        })
    }
}

impl Optimizer {
    /// Build from config for a parameter list with the given shapes.
    pub fn new(cfg: OptimizerConfig, shapes: &[Vec<usize>]) -> Optimizer {
        match cfg {
            OptimizerConfig::Sgd { lr } => Optimizer::Sgd { lr },
            OptimizerConfig::Momentum { lr, beta } => Optimizer::Momentum {
                lr,
                beta,
                velocity: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
            OptimizerConfig::Adam { lr, beta1, beta2, eps } => Optimizer::Adam {
                lr,
                beta1,
                beta2,
                eps,
                t: 0,
                m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
                v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
        }
    }

    /// Build from config, adopting a previously exported state. `None`
    /// starts fresh (identical to `new`). The state's kind and tensor
    /// shapes must match the config and parameter list.
    pub fn with_state(
        cfg: OptimizerConfig,
        shapes: &[Vec<usize>],
        state: Option<OptimizerState>,
    ) -> Result<Optimizer> {
        let Some(state) = state else {
            return Ok(Optimizer::new(cfg, shapes));
        };
        if state.kind() != cfg.name() {
            bail!(
                "optimizer state kind '{}' does not match config '{}'",
                state.kind(),
                cfg.name()
            );
        }
        let check = |name: &str, ts: &[Tensor]| -> Result<()> {
            if ts.len() != shapes.len() {
                bail!("{name}: {} state tensors for {} parameters", ts.len(), shapes.len());
            }
            for (i, (t, s)) in ts.iter().zip(shapes).enumerate() {
                if t.shape() != s.as_slice() {
                    bail!("{name}[{i}]: state shape {:?} vs parameter {:?}", t.shape(), s);
                }
            }
            Ok(())
        };
        Ok(match (cfg, state) {
            (OptimizerConfig::Sgd { lr }, OptimizerState::Sgd) => Optimizer::Sgd { lr },
            (OptimizerConfig::Momentum { lr, beta }, OptimizerState::Momentum { velocity }) => {
                check("velocity", &velocity)?;
                Optimizer::Momentum { lr, beta, velocity }
            }
            (OptimizerConfig::Adam { lr, beta1, beta2, eps }, OptimizerState::Adam { t, m, v }) => {
                check("m", &m)?;
                check("v", &v)?;
                Optimizer::Adam { lr, beta1, beta2, eps, t, m, v }
            }
            _ => unreachable!("kind checked above"),
        })
    }

    /// Export the accumulated state (moments + step count) for
    /// checkpointing. Hyperparameters are not included; pair with the
    /// `OptimizerConfig` to rebuild via `with_state`.
    pub fn state(&self) -> OptimizerState {
        match self {
            Optimizer::Sgd { .. } => OptimizerState::Sgd,
            Optimizer::Momentum { velocity, .. } => {
                OptimizerState::Momentum { velocity: velocity.clone() }
            }
            Optimizer::Adam { t, m, v, .. } => {
                OptimizerState::Adam { t: *t, m: m.clone(), v: v.clone() }
            }
        }
    }

    /// Number of f32 moment values currently held (see
    /// [`OptimizerState::floats`]) without cloning the state.
    pub fn state_floats(&self) -> usize {
        match self {
            Optimizer::Sgd { .. } => 0,
            Optimizer::Momentum { velocity, .. } => velocity.iter().map(Tensor::numel).sum(),
            Optimizer::Adam { m, v, .. } => {
                m.iter().map(Tensor::numel).sum::<usize>()
                    + v.iter().map(Tensor::numel).sum::<usize>()
            }
        }
    }

    /// Apply one step: params[i] updated in place from grads[i].
    pub fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len(), "param/grad arity mismatch");
        match self {
            Optimizer::Sgd { lr } => {
                for (p, g) in params.iter_mut().zip(grads) {
                    p.axpy(-*lr, g);
                }
            }
            Optimizer::Momentum { lr, beta, velocity } => {
                for ((p, g), v) in params.iter_mut().zip(grads).zip(velocity) {
                    // v = beta*v + g;  p -= lr*v
                    v.scale(*beta);
                    v.add_assign(g);
                    p.axpy(-*lr, v);
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps, t, m, v } => {
                *t += 1;
                let bc1 = 1.0 - beta1.powi(*t as i32);
                let bc2 = 1.0 - beta2.powi(*t as i32);
                for ((p, g), (mi, vi)) in params.iter_mut().zip(grads).zip(m.iter_mut().zip(v)) {
                    let (b1, b2) = (*beta1, *beta2);
                    for i in 0..g.numel() {
                        let gd = g.data()[i];
                        let md = b1 * mi.data()[i] + (1.0 - b1) * gd;
                        let vd = b2 * vi.data()[i] + (1.0 - b2) * gd * gd;
                        mi.data_mut()[i] = md;
                        vi.data_mut()[i] = vd;
                        let mhat = md / bc1;
                        let vhat = vd / bc2;
                        p.data_mut()[i] -= *lr * mhat / (vhat.sqrt() + *eps);
                    }
                }
            }
        }
    }
}

/// Fixed-loss stopping rule (the nu_lambda of paper Eqn. 2): stop when the
/// smoothed loss reaches the target, or at the iteration cap.
#[derive(Debug, Clone)]
pub struct LossTracker {
    pub history: Vec<f64>,
    pub target: Option<f64>,
    pub max_iters: usize,
    /// EMA smoothing factor for the stopping test (1.0 = raw loss).
    pub ema_alpha: f64,
    ema: Option<f64>,
}

impl LossTracker {
    pub fn new(target: Option<f64>, max_iters: usize) -> LossTracker {
        LossTracker { history: Vec::new(), target, max_iters, ema_alpha: 1.0, ema: None }
    }

    /// Record a loss; returns true if training should stop.
    pub fn record(&mut self, loss: f64) -> bool {
        self.history.push(loss);
        let s = match self.ema {
            None => loss,
            Some(prev) => self.ema_alpha * loss + (1.0 - self.ema_alpha) * prev,
        };
        self.ema = Some(s);
        if let Some(t) = self.target {
            if s <= t {
                return true;
            }
        }
        self.history.len() >= self.max_iters
    }

    pub fn iterations(&self) -> usize {
        self.history.len()
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.history.last().copied()
    }

    pub fn reached_target(&self) -> bool {
        match (self.target, self.ema) {
            (Some(t), Some(s)) => s <= t,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn quad_grad(p: &Tensor) -> Tensor {
        // grad of f(p) = 0.5*||p||^2 is p
        p.clone()
    }

    #[test]
    fn sgd_descends_quadratic() {
        let mut p = Tensor::filled(&[4], 1.0);
        let mut opt = Optimizer::new(OptimizerConfig::Sgd { lr: 0.1 }, &[vec![4]]);
        for _ in 0..100 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g]);
        }
        assert!(p.sq_sum() < 1e-6, "{:?}", p.data());
    }

    #[test]
    fn momentum_descends_quadratic() {
        let mut p = Tensor::filled(&[4], 1.0);
        let mut opt =
            Optimizer::new(OptimizerConfig::Momentum { lr: 0.05, beta: 0.9 }, &[vec![4]]);
        for _ in 0..200 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g]);
        }
        assert!(p.sq_sum() < 1e-6);
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut p = Tensor::filled(&[4], 1.0);
        let mut opt = Optimizer::new(
            OptimizerConfig::Adam { lr: 0.05, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            &[vec![4]],
        );
        for _ in 0..400 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g]);
        }
        assert!(p.sq_sum() < 1e-4, "{}", p.sq_sum());
    }

    #[test]
    fn momentum_beats_sgd_on_illconditioned() {
        // f(p) = 0.5*(100*x^2 + y^2): heavy-ball should converge faster at
        // the same stable lr.
        let run = |cfg: OptimizerConfig| {
            let mut p = Tensor::from_vec(&[2], vec![1.0, 1.0]).unwrap();
            let mut opt = Optimizer::new(cfg, &[vec![2]]);
            for _ in 0..150 {
                let g =
                    Tensor::from_vec(&[2], vec![100.0 * p.data()[0], p.data()[1]]).unwrap();
                opt.step(&mut [&mut p], &[g]);
            }
            p.sq_sum()
        };
        let sgd = run(OptimizerConfig::Sgd { lr: 0.009 });
        let mom = run(OptimizerConfig::Momentum { lr: 0.009, beta: 0.9 });
        assert!(mom < sgd, "momentum {mom} should beat sgd {sgd}");
    }

    #[test]
    fn adam_matches_scalar_reference() {
        // Scalar textbook Adam (Kingma & Ba, Alg. 1) with bias correction,
        // written in the same f32 evaluation order as the vectorized
        // optimizer — the trajectories must agree bitwise, including the
        // large corrections at small t where 1 - beta^t is far from 1.
        crate::util::proptest::quickcheck("adam scalar reference", |rng| {
            let dim = 1 + (rng.next_u64() % 6) as usize;
            let steps = 1 + (rng.next_u64() % 6) as usize;
            let (lr, beta1, beta2, eps) = (0.07f32, 0.9f32, 0.999f32, 1e-8f32);
            let grads: Vec<Tensor> =
                (0..steps).map(|_| Tensor::randn(&[dim], 1.0, rng)).collect();

            let mut p = Tensor::randn(&[dim], 1.0, rng);
            let mut p_ref = p.data().to_vec();
            let mut opt = Optimizer::new(
                OptimizerConfig::Adam { lr, beta1, beta2, eps },
                &[vec![dim]],
            );
            let (mut m_ref, mut v_ref) = (vec![0.0f32; dim], vec![0.0f32; dim]);
            for (step, g) in grads.iter().enumerate() {
                opt.step(&mut [&mut p], std::slice::from_ref(g));
                let t = (step + 1) as i32;
                let bc1 = 1.0 - beta1.powi(t);
                let bc2 = 1.0 - beta2.powi(t);
                for i in 0..dim {
                    let gd = g.data()[i];
                    m_ref[i] = beta1 * m_ref[i] + (1.0 - beta1) * gd;
                    v_ref[i] = beta2 * v_ref[i] + (1.0 - beta2) * gd * gd;
                    let mhat = m_ref[i] / bc1;
                    let vhat = v_ref[i] / bc2;
                    p_ref[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
                for i in 0..dim {
                    if p.data()[i].to_bits() != p_ref[i].to_bits() {
                        return Err(format!(
                            "step {t} dim {i}: optimizer {} vs reference {}",
                            p.data()[i],
                            p_ref[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn adam_first_step_bias_correction_closed_form() {
        // At t = 1 the bias corrections cancel exactly: mhat = g, vhat = g^2,
        // so the update is lr * g / (|g| + eps) regardless of beta1/beta2.
        let (lr, eps) = (0.5f32, 1e-8f32);
        let g = Tensor::from_vec(&[3], vec![2.0, -0.25, 1e-3]).unwrap();
        let mut p = Tensor::zeros(&[3]);
        let mut opt = Optimizer::new(
            OptimizerConfig::Adam { lr, beta1: 0.9, beta2: 0.999, eps },
            &[vec![3]],
        );
        opt.step(&mut [&mut p], std::slice::from_ref(&g));
        for i in 0..3 {
            let gd = g.data()[i];
            let want = -lr * gd / (gd.abs() + eps);
            assert!(
                (p.data()[i] - want).abs() <= 1e-6 * want.abs().max(1.0),
                "dim {i}: {} vs {want}",
                p.data()[i]
            );
        }
    }

    #[test]
    fn momentum_beta0_is_exactly_sgd() {
        crate::util::proptest::quickcheck("momentum beta=0 == sgd", |rng| {
            let dim = 1 + (rng.next_u64() % 8) as usize;
            let steps = 1 + (rng.next_u64() % 8) as usize;
            let lr = 0.05f32;
            let grads: Vec<Tensor> =
                (0..steps).map(|_| Tensor::randn(&[dim], 1.0, rng)).collect();
            let init = Tensor::randn(&[dim], 1.0, rng);

            let mut p_sgd = init.clone();
            let mut sgd = Optimizer::new(OptimizerConfig::Sgd { lr }, &[vec![dim]]);
            let mut p_mom = init;
            let mut mom =
                Optimizer::new(OptimizerConfig::Momentum { lr, beta: 0.0 }, &[vec![dim]]);
            for g in &grads {
                sgd.step(&mut [&mut p_sgd], std::slice::from_ref(g));
                mom.step(&mut [&mut p_mom], std::slice::from_ref(g));
            }
            if p_sgd != p_mom {
                return Err(format!("{:?} vs {:?}", p_sgd.data(), p_mom.data()));
            }
            Ok(())
        });
    }

    #[test]
    fn state_restore_continues_bit_identically() {
        // For every optimizer: 3 steps, export, rebuild, 3 more steps ==
        // 6 uninterrupted steps. This is the rank-local half of the
        // checkpoint-resume guarantee.
        let mut rng = Prng::new(0xC4E7);
        let grads: Vec<Tensor> = (0..6).map(|_| Tensor::randn(&[5], 1.0, &mut rng)).collect();
        for cfg in [
            OptimizerConfig::Sgd { lr: 0.1 },
            OptimizerConfig::Momentum { lr: 0.1, beta: 0.9 },
            OptimizerConfig::Adam { lr: 0.05, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let mut p_full = Tensor::filled(&[5], 1.0);
            let mut full = Optimizer::new(cfg, &[vec![5]]);
            for g in &grads {
                full.step(&mut [&mut p_full], std::slice::from_ref(g));
            }

            let mut p_split = Tensor::filled(&[5], 1.0);
            let mut first = Optimizer::new(cfg, &[vec![5]]);
            for g in &grads[..3] {
                first.step(&mut [&mut p_split], std::slice::from_ref(g));
            }
            let state = first.state();
            assert_eq!(state.kind(), cfg.name());
            let mut second = Optimizer::with_state(cfg, &[vec![5]], Some(state)).unwrap();
            for g in &grads[3..] {
                second.step(&mut [&mut p_split], std::slice::from_ref(g));
            }
            assert_eq!(p_full, p_split, "{} resume diverged", cfg.name());
        }
    }

    #[test]
    fn with_state_rejects_mismatches() {
        let sgd = OptimizerConfig::Sgd { lr: 0.1 };
        let mom = OptimizerConfig::Momentum { lr: 0.1, beta: 0.9 };
        // kind mismatch
        let state = Optimizer::new(mom, &[vec![3]]).state();
        assert!(Optimizer::with_state(sgd, &[vec![3]], Some(state)).is_err());
        // shape mismatch
        let state = Optimizer::new(mom, &[vec![3]]).state();
        assert!(Optimizer::with_state(mom, &[vec![4]], Some(state)).is_err());
        // arity mismatch
        let state = Optimizer::new(mom, &[vec![3]]).state();
        assert!(Optimizer::with_state(mom, &[vec![3], vec![3]], Some(state)).is_err());
        // None starts fresh
        assert!(Optimizer::with_state(mom, &[vec![3]], None).is_ok());
    }

    #[test]
    fn zero_grad_slot_never_moves() {
        // The frozen decompressor slot: zero gradient -> parameter unchanged
        // under every optimizer.
        for cfg in [
            OptimizerConfig::Sgd { lr: 0.1 },
            OptimizerConfig::Momentum { lr: 0.1, beta: 0.9 },
            OptimizerConfig::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        ] {
            let mut p = Tensor::zeros(&[3]);
            let mut opt = Optimizer::new(cfg, &[vec![3]]);
            for _ in 0..10 {
                opt.step(&mut [&mut p], &[Tensor::zeros(&[3])]);
            }
            assert_eq!(p, Tensor::zeros(&[3]), "{:?}", cfg.name());
        }
    }

    #[test]
    fn optimizers_deterministic() {
        let mut rng = Prng::new(3);
        let g: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&[8], 1.0, &mut rng)).collect();
        let run = || {
            let mut p = Tensor::filled(&[8], 0.5);
            let mut opt = Optimizer::new(
                OptimizerConfig::Adam { lr: 0.01, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
                &[vec![8]],
            );
            for gi in &g {
                opt.step(&mut [&mut p], std::slice::from_ref(gi));
            }
            p
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn loss_tracker_stops_at_target() {
        let mut t = LossTracker::new(Some(0.1), 100);
        assert!(!t.record(1.0));
        assert!(!t.record(0.5));
        assert!(t.record(0.09));
        assert!(t.reached_target());
        assert_eq!(t.iterations(), 3);
    }

    #[test]
    fn loss_tracker_stops_at_cap() {
        let mut t = LossTracker::new(Some(0.0), 3);
        assert!(!t.record(1.0));
        assert!(!t.record(1.0));
        assert!(t.record(1.0));
        assert!(!t.reached_target());
    }
}
