//! The blocked, packed, SIMD-dispatched GEMM engine behind every matmul in
//! the native backend.
//!
//! One engine serves all three product families — `A·B`, `Aᵀ·B`, `A·Bᵀ` —
//! by describing each operand as a strided [`View`] and packing panels from
//! it. Packing makes the inner loop fully contiguous regardless of the
//! source layout, so the transpose families run the same register-tiled
//! microkernels (tensor::simd) and row-band threading as the plain product
//! instead of the naive loops they used in the seed kernel.
//!
//! Structure per GEMM (BLIS-style GEBP):
//!
//! ```text
//! for jc in steps of JC:            # B panel columns
//!   for kc in steps of KC:          # depth block
//!     pack Bp[kw x jw]              # row-major panel, contiguous lanes
//!     for i in steps of MR:         # A block rows
//!       pack Ap[kw x rb]            # row-interleaved: ap[kk*rb + r]
//!       block_kernel(...)           # rb x jw tile in registers
//! ```
//!
//! Blocking parameters (MR/KC/JC/threading) come from `tensor::tune` per
//! shape class; `*_with` variants take them explicitly (autotuner, property
//! tests). Threaded bands draw their packing workspace from a process-global
//! pool (`WS_POOL`), so spawned bands reuse allocations across calls instead
//! of burning a fresh thread-local arena that dies with the scope — the
//! scratch-waste fix the seed's `gemm_acc` comment conceded.

use std::cell::Cell;
use std::sync::Mutex;

use super::simd::{self, block_kernel, Isa};
use super::tune::{self, GemmParams};

// ---------------------------------------------------------------------------
// Per-thread GEMM tally (observability)
// ---------------------------------------------------------------------------

/// Distinct shapes a [`GemmTally`] records before it only counts them.
pub const TALLY_SHAPE_SLOTS: usize = 4;

/// Numeric per-thread tally of GEMM work since the last [`tally_take`]:
/// call/flop counts, the widest band fan-out, and up to
/// [`TALLY_SHAPE_SLOTS`] distinct `m x k x n` shapes. The coordinator
/// drains it around each kernel execution to annotate compute spans.
/// Counting is purely numeric (no allocation, no formatting) so it stays
/// on unconditionally. Bands spawned by a GEMM tally on the calling
/// thread; out-of-process backends (PJRT) execute elsewhere and read as
/// zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GemmTally {
    /// GEMM engine invocations.
    pub calls: u64,
    /// Multiply-add count summed over calls (saturating).
    pub flops: u64,
    /// Widest row-band fan-out any single call used.
    pub max_bands: u64,
    /// Distinct shapes observed (may exceed the slots stored).
    pub shapes_seen: u64,
    shapes: [u64; TALLY_SHAPE_SLOTS],
}

/// Pack a shape into one nonzero u64 slot key (21 bits per dim,
/// saturating; dims here are layer widths, far below 2^21).
fn pack_shape(m: usize, kd: usize, n: usize) -> u64 {
    const CAP: u64 = (1 << 21) - 1;
    let d = |v: usize| (v as u64).min(CAP);
    (d(m) << 42) | (d(kd) << 21) | d(n)
}

impl GemmTally {
    const fn empty() -> GemmTally {
        GemmTally {
            calls: 0,
            flops: 0,
            max_bands: 0,
            shapes_seen: 0,
            shapes: [0; TALLY_SHAPE_SLOTS],
        }
    }

    fn note(&mut self, m: usize, kd: usize, n: usize, flops: usize, bands: usize) {
        self.calls += 1;
        self.flops = self.flops.saturating_add(flops as u64);
        self.max_bands = self.max_bands.max(bands as u64);
        let key = pack_shape(m, kd, n);
        for slot in &mut self.shapes {
            if *slot == key {
                return;
            }
            if *slot == 0 {
                *slot = key;
                self.shapes_seen += 1;
                return;
            }
        }
        // All slots taken by other shapes: counted but not stored.
        self.shapes_seen += 1;
    }

    /// The stored distinct shapes, formatted `MxKxN` (oldest first).
    pub fn shape_names(&self) -> Vec<String> {
        const CAP: u64 = (1 << 21) - 1;
        self.shapes
            .iter()
            .take_while(|&&k| k != 0)
            .map(|&k| format!("{}x{}x{}", (k >> 42) & CAP, (k >> 21) & CAP, k & CAP))
            .collect()
    }
}

thread_local! {
    static TALLY: Cell<GemmTally> = const { Cell::new(GemmTally::empty()) };
}

/// Take (and reset) this thread's GEMM tally.
pub fn tally_take() -> GemmTally {
    TALLY.with(|t| t.replace(GemmTally::empty()))
}

// ---------------------------------------------------------------------------
// Strided operand views
// ---------------------------------------------------------------------------

/// A read-only strided 2-D view: element `(r, c)` is `data[r*rs + c*cs]`.
/// Copyable so row-band workers can capture it by value.
#[derive(Debug, Clone, Copy)]
pub(crate) struct View<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
    rows: usize,
    cols: usize,
}

impl<'a> View<'a> {
    /// A contiguous row-major `[rows, cols]` matrix.
    pub(crate) fn rowmajor(data: &'a [f32], rows: usize, cols: usize) -> View<'a> {
        debug_assert!(data.len() >= rows * cols);
        View { data, rs: cols, cs: 1, rows, cols }
    }

    /// The transpose of a stored row-major `[sr, sc]` matrix: a `[sc, sr]`
    /// view with unit row stride (columns of the stored matrix).
    pub(crate) fn transposed(data: &'a [f32], sr: usize, sc: usize) -> View<'a> {
        debug_assert!(data.len() >= sr * sc);
        View { data, rs: 1, cs: sc, rows: sc, cols: sr }
    }

    /// Rows `[row0, row0 + count)` as their own view.
    fn slice_rows(self, row0: usize, count: usize) -> View<'a> {
        debug_assert!(row0 + count <= self.rows);
        View { data: &self.data[row0 * self.rs..], rows: count, ..self }
    }
}

// ---------------------------------------------------------------------------
// Per-band workspace pool
// ---------------------------------------------------------------------------

/// Process-global pool of packing buffers. Every band of every GEMM takes
/// one buffer (B panel + A block, split once per call) and returns it when
/// the scope ends, so allocations amortize across calls no matter which
/// thread runs the band.
static WS_POOL: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());

/// Upper bound on idle pooled buffers (bounds memory after a burst of very
/// wide GEMMs; beyond this, returned buffers are simply dropped).
pub const PACK_POOL_CAP: usize = 64;

fn ws_take(count: usize, len: usize) -> Vec<Vec<f32>> {
    let mut out = {
        let mut pool = WS_POOL.lock().unwrap_or_else(|p| p.into_inner());
        let keep = pool.len() - count.min(pool.len());
        pool.split_off(keep)
    };
    while out.len() < count {
        out.push(Vec::new());
    }
    for b in &mut out {
        b.clear();
        b.resize(len, 0.0);
    }
    out
}

fn ws_put(bufs: Vec<Vec<f32>>) {
    let mut pool = WS_POOL.lock().unwrap_or_else(|p| p.into_inner());
    for b in bufs {
        if pool.len() >= PACK_POOL_CAP {
            break;
        }
        pool.push(b);
    }
}

/// A zero-filled `len`-float buffer drawn from the bounded band pool — the
/// allocation-reuse path for kernel output tensors on the per-iteration
/// critical path (the backward fused kernels' per-call scratch folds in
/// here). Return it with [`pooled_buf_put`] when the value dies.
pub fn pooled_buf(len: usize) -> Vec<f32> {
    ws_take(1, len).pop().expect("ws_take returns `count` buffers")
}

/// Return a buffer to the bounded band pool (silently dropped when the
/// pool already holds [`PACK_POOL_CAP`] idle buffers).
pub fn pooled_buf_put(buf: Vec<f32>) {
    ws_put(vec![buf]);
}

/// Idle buffers in the band workspace pool — observability hook for the
/// scratch-reuse tests.
#[doc(hidden)]
pub fn pack_pool_idle() -> usize {
    WS_POOL.lock().unwrap_or_else(|p| p.into_inner()).len()
}

pub(crate) fn hw_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// Public accumulate API (C += op(A) @ op(B))
// ---------------------------------------------------------------------------

/// C[m,n] += A[m,kd] @ B[kd,n]; all row-major and contiguous. Blocking and
/// threading come from the installed per-shape tuning.
pub fn gemm_acc(a: &[f32], m: usize, kd: usize, b: &[f32], n: usize, out: &mut [f32]) {
    gemm_acc_with(tune::params_for(m, kd, n), simd::active(), a, m, kd, b, n, out);
}

/// `gemm_acc` with explicit blocking parameters and ISA (autotuner and
/// property tests; everything else should use [`gemm_acc`]).
pub fn gemm_acc_with(
    params: GemmParams,
    isa: Isa,
    a: &[f32],
    m: usize,
    kd: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * kd, "gemm_acc: A length vs [{m}, {kd}]");
    assert_eq!(b.len(), kd * n, "gemm_acc: B length vs [{kd}, {n}]");
    assert_eq!(out.len(), m * n, "gemm_acc: C length vs [{m}, {n}]");
    gemm_view(View::rowmajor(a, m, kd), View::rowmajor(b, kd, n), out, params, isa);
}

/// C[m,n] += Aᵀ @ B with A stored as [kd, m], B as [kd, n] (the gradient
/// kernels' `Yᵀ·delta` shape). The transposed operand is a strided view —
/// packing materializes only one panel at a time, never the full transpose.
pub fn gemm_at_b_acc(a: &[f32], kd: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    gemm_at_b_acc_with(tune::params_for(m, kd, n), simd::active(), a, kd, m, b, n, out);
}

/// `gemm_at_b_acc` with explicit blocking parameters and ISA.
pub fn gemm_at_b_acc_with(
    params: GemmParams,
    isa: Isa,
    a: &[f32],
    kd: usize,
    m: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), kd * m, "gemm_at_b_acc: A length vs [{kd}, {m}]");
    assert_eq!(b.len(), kd * n, "gemm_at_b_acc: B length vs [{kd}, {n}]");
    assert_eq!(out.len(), m * n, "gemm_at_b_acc: C length vs [{m}, {n}]");
    gemm_view(View::transposed(a, kd, m), View::rowmajor(b, kd, n), out, params, isa);
}

/// C[m,n] += A @ Bᵀ with A stored as [m, kd], B as [n, kd] (the backward
/// `delta·Wᵀ` shape). Bᵀ is a strided view packed panel-by-panel.
pub fn gemm_a_bt_acc(a: &[f32], m: usize, kd: usize, b: &[f32], n: usize, out: &mut [f32]) {
    gemm_a_bt_acc_with(tune::params_for(m, kd, n), simd::active(), a, m, kd, b, n, out);
}

/// `gemm_a_bt_acc` with explicit blocking parameters and ISA.
pub fn gemm_a_bt_acc_with(
    params: GemmParams,
    isa: Isa,
    a: &[f32],
    m: usize,
    kd: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * kd, "gemm_a_bt_acc: A length vs [{m}, {kd}]");
    assert_eq!(b.len(), n * kd, "gemm_a_bt_acc: B length vs [{n}, {kd}]");
    assert_eq!(out.len(), m * n, "gemm_a_bt_acc: C length vs [{m}, {n}]");
    gemm_view(View::rowmajor(a, m, kd), View::transposed(b, n, kd), out, params, isa);
}

// ---------------------------------------------------------------------------
// The blocked engine
// ---------------------------------------------------------------------------

/// Accumulate `out[a.rows, b.cols] += A @ B` for two strided views, split
/// into row bands across threads when the work is large enough.
fn gemm_view(a: View<'_>, b: View<'_>, out: &mut [f32], params: GemmParams, isa: Isa) {
    let (m, kd, n) = (a.rows, a.cols, b.cols);
    debug_assert_eq!(a.cols, b.rows, "gemm_view inner dim");
    debug_assert_eq!(out.len(), m * n, "gemm_view out len");
    if m == 0 || kd == 0 || n == 0 {
        return;
    }
    let p = params.sanitized();
    let ws_len = p.kc.min(kd) * p.jc.min(n) + p.kc.min(kd) * p.mr;

    let flops = m.saturating_mul(kd).saturating_mul(n);
    let cap = if p.max_bands == 0 { hw_threads() } else { hw_threads().min(p.max_bands) };
    let bands = if flops >= p.par_min_flops { cap.min(m / p.mr).max(1) } else { 1 };
    TALLY.with(|t| {
        let mut tally = t.get();
        tally.note(m, kd, n, flops, bands);
        t.set(tally);
    });
    if bands <= 1 {
        let mut ws = ws_take(1, ws_len);
        gemm_band(a, b, out, p, isa, &mut ws[0]);
        ws_put(ws);
        return;
    }

    let rows_per = m.div_ceil(bands);
    let mut ws = ws_take(m.div_ceil(rows_per), ws_len);
    std::thread::scope(|s| {
        let mut first: Option<(&mut [f32], View<'_>, &mut Vec<f32>)> = None;
        for (bi, (band, buf)) in out.chunks_mut(rows_per * n).zip(ws.iter_mut()).enumerate() {
            let rows = band.len() / n;
            let a_band = a.slice_rows(bi * rows_per, rows);
            if first.is_none() {
                first = Some((band, a_band, buf));
                continue;
            }
            s.spawn(move || gemm_band(a_band, b, band, p, isa, buf));
        }
        // Band 0 runs on the calling thread; the others' workspaces return
        // to the global pool below, so nothing is lost when the scope ends.
        if let Some((band, a_band, buf)) = first {
            gemm_band(a_band, b, band, p, isa, buf);
        }
    });
    ws_put(ws);
}

/// One row band: the jc/kc/i loop nest over packed panels.
fn gemm_band(a: View<'_>, b: View<'_>, out: &mut [f32], p: GemmParams, isa: Isa, ws: &mut [f32]) {
    let (m, kd, n) = (a.rows, a.cols, b.cols);
    if m == 0 || kd == 0 || n == 0 {
        return;
    }
    let kcm = p.kc.min(kd);
    let jcm = p.jc.min(n);
    let (bp, ap) = ws.split_at_mut(kcm * jcm);
    let ldc = n;

    let mut jc0 = 0;
    while jc0 < n {
        let jw = jcm.min(n - jc0);
        let mut kc0 = 0;
        while kc0 < kd {
            let kw = kcm.min(kd - kc0);
            pack_b(b, kc0, jc0, kw, jw, bp);
            let mut i = 0;
            while i < m {
                let rb = p.mr.min(m - i);
                pack_a(a, i, kc0, rb, kw, ap);
                let c0 = i * ldc + jc0;
                if isa == Isa::Avx2Fma && rb > 4 && rb < 8 {
                    // Split 5..=7 remainder rows into a 4-row SIMD span plus
                    // a small portable block (same packed A, offset rows).
                    block_kernel(isa, 4, ap, rb, bp, jw, kw, jw, out, c0, ldc);
                    let c4 = c0 + 4 * ldc;
                    block_kernel(isa, rb - 4, &ap[4..], rb, bp, jw, kw, jw, out, c4, ldc);
                } else {
                    block_kernel(isa, rb, ap, rb, bp, jw, kw, jw, out, c0, ldc);
                }
                i += rb;
            }
            kc0 += kw;
        }
        jc0 += jw;
    }
}

/// Pack B panel rows `[k0, k0+kw) x [j0, j0+jw)` into `bp[kk*jw + j]`.
fn pack_b(b: View<'_>, k0: usize, j0: usize, kw: usize, jw: usize, bp: &mut [f32]) {
    if b.cs == 1 {
        for kk in 0..kw {
            let src = (k0 + kk) * b.rs + j0;
            bp[kk * jw..kk * jw + jw].copy_from_slice(&b.data[src..src + jw]);
        }
    } else {
        for kk in 0..kw {
            let base = (k0 + kk) * b.rs + j0 * b.cs;
            let dst = &mut bp[kk * jw..kk * jw + jw];
            for (j, d) in dst.iter_mut().enumerate() {
                *d = b.data[base + j * b.cs];
            }
        }
    }
}

/// Pack A block rows `[i0, i0+rows) x [k0, k0+kw)` row-interleaved into
/// `ap[kk*rows + r]`, the layout the microkernels broadcast from.
fn pack_a(a: View<'_>, i0: usize, k0: usize, rows: usize, kw: usize, ap: &mut [f32]) {
    if a.cs == 1 {
        for r in 0..rows {
            let base = (i0 + r) * a.rs + k0;
            for kk in 0..kw {
                ap[kk * rows + r] = a.data[base + kk];
            }
        }
    } else if a.rs == 1 {
        // Transposed view: a packed A column is contiguous in storage.
        for kk in 0..kw {
            let base = i0 + (k0 + kk) * a.cs;
            ap[kk * rows..kk * rows + rows].copy_from_slice(&a.data[base..base + rows]);
        }
    } else {
        for kk in 0..kw {
            let base = i0 * a.rs + (k0 + kk) * a.cs;
            let dst = &mut ap[kk * rows..kk * rows + rows];
            for (r, d) in dst.iter_mut().enumerate() {
                *d = a.data[base + r * a.rs];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ws_pool_reuses_and_caps() {
        // Other tests in this binary hit the pool concurrently, so all
        // assertions are one-sided (never exact counts).
        let bufs = ws_take(pack_pool_idle() + 2, 16);
        assert!(bufs.len() >= 2);
        assert!(bufs.iter().all(|b| b.len() == 16));
        ws_put(bufs);
        let idle = pack_pool_idle();
        assert!(idle >= 1 && idle <= PACK_POOL_CAP, "idle={idle}");
        // Buffers come back resized to the new request.
        let again = ws_take(1, 33);
        assert_eq!(again[0].len(), 33);
        ws_put(again);
    }

    #[test]
    fn tally_counts_calls_flops_and_shapes() {
        let _ = tally_take(); // isolate from anything earlier on this thread
        let a = vec![1.0f32; 4 * 3];
        let b = vec![1.0f32; 3 * 5];
        let mut out = vec![0.0f32; 4 * 5];
        gemm_acc(&a, 4, 3, &b, 5, &mut out);
        gemm_acc(&a, 4, 3, &b, 5, &mut out);
        let mut c = vec![0.0f32; 3 * 3];
        gemm_at_b_acc(&a, 4, 3, &b[..4 * 3], 3, &mut c);
        let t = tally_take();
        assert_eq!(t.calls, 3);
        assert_eq!(t.flops, (4 * 3 * 5 + 4 * 3 * 5 + 3 * 4 * 3) as u64);
        assert_eq!(t.shapes_seen, 2);
        assert_eq!(t.shape_names(), vec!["4x3x5".to_string(), "3x4x3".to_string()]);
        assert!(t.max_bands >= 1);
        // Drained: the next take is empty.
        assert_eq!(tally_take(), GemmTally::empty());
    }

    #[test]
    fn view_geometry() {
        // Stored [2, 3] row-major: [[1,2,3],[4,5,6]].
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = View::rowmajor(&data, 2, 3);
        assert_eq!((v.rows, v.cols), (2, 3));
        assert_eq!(v.data[v.rs + 2 * v.cs], 6.0); // v(1,2)
        let t = View::transposed(&data, 2, 3); // logical [3, 2]
        assert_eq!((t.rows, t.cols), (3, 2));
        assert_eq!(t.data[2 * t.rs + t.cs], 6.0); // t(2,1) = stored(1,2)
        let s = t.slice_rows(1, 2); // logical rows 1..3 of the transpose
        assert_eq!(s.data[s.cs], 5.0); // s(0,1) = t(1,1) = stored(1,1)
    }

    #[test]
    fn band_split_covers_all_rows() {
        // A 13-row GEMM forced into multiple bands must cover every row
        // exactly once (ragged last band).
        let p = GemmParams { mr: 4, kc: 8, jc: 8, max_bands: 4, par_min_flops: 0 };
        let m = 13;
        let (kd, n) = (5, 9);
        let a: Vec<f32> = (0..m * kd).map(|i| (i % 11) as f32 - 5.0).collect();
        let b: Vec<f32> = (0..kd * n).map(|i| (i % 5) as f32 * 0.25).collect();
        let mut got = vec![1.0f32; m * n];
        let mut want = vec![1.0f32; m * n];
        gemm_acc_with(p, simd::active(), &a, m, kd, &b, n, &mut got);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for t in 0..kd {
                    acc += a[i * kd + t] * b[t * n + j];
                }
                want[i * n + j] += acc;
            }
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "elem {i}: {g} vs {w}");
        }
    }
}
