//! ISA detection and the register-tile microkernels behind the blocked GEMM.
//!
//! The blocked engine in `tensor::gemm` packs an A block (`ap[kk*asr + r]`,
//! row-interleaved) and a B panel (`bp[kk*bs + j]`, row-major) and then calls
//! `block_kernel` to accumulate the `rows x jw` output tile. Two kernel
//! families sit behind that call:
//!
//! * `x86::mk4x8` / `x86::mk8x8` — hand-vectorized AVX2+FMA kernels that hold
//!   the C tile in ymm accumulators and broadcast-FMA one packed A column per
//!   k step. Selected at runtime (`is_x86_feature_detected!`), never at
//!   compile time, so one binary serves both old and new x86 boxes.
//! * `micro8::<ROWS>` — a portable const-generic 8-lane kernel whose fixed
//!   `[[f32; 8]; ROWS]` accumulator array autovectorizes on every target;
//!   also the fallback for row counts the AVX2 kernels don't cover.
//!
//! `PHANTOM_SIMD=portable` forces the portable path (used by the agreement
//! property tests and as an escape hatch on machines with broken AVX).

use std::sync::OnceLock;

/// Instruction-set tier the microkernels run at, detected once per process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Hand-vectorized AVX2+FMA kernels (x86-64 with both features).
    Avx2Fma,
    /// Autovectorized portable kernels (everything else).
    Portable,
}

impl Isa {
    /// Stable name used in logs and the tuning manifest.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2Fma => "avx2+fma",
            Isa::Portable => "portable",
        }
    }
}

static ACTIVE_ISA: OnceLock<Isa> = OnceLock::new();

/// The ISA the GEMM kernels dispatch to, cached after first detection.
/// `PHANTOM_SIMD=portable` overrides detection.
pub fn active() -> Isa {
    *ACTIVE_ISA.get_or_init(|| {
        if std::env::var("PHANTOM_SIMD").map(|v| v == "portable").unwrap_or(false) {
            Isa::Portable
        } else {
            detect_native()
        }
    })
}

/// Every ISA this machine can actually run (ignores the env override).
/// Property tests iterate this to pin all compiled-in kernel families
/// against the naive oracle.
pub fn available() -> Vec<Isa> {
    match detect_native() {
        Isa::Avx2Fma => vec![Isa::Avx2Fma, Isa::Portable],
        Isa::Portable => vec![Isa::Portable],
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_native() -> Isa {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Isa::Avx2Fma
    } else {
        Isa::Portable
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_native() -> Isa {
    Isa::Portable
}

/// Accumulate a packed block product into a C tile:
///
/// `C[r, j0+j] += sum_kk Ap[kk*asr + r] * Bp[kk*bs + j]` for
/// `r in 0..rows`, `j in 0..jw`, where the C tile starts at `cb[c0]` with
/// row stride `ldc`.
///
/// `rows` must be 1..=8; `asr >= rows` is the packed-A row stride (lets the
/// caller split one packed block into a 4-row SIMD span plus a remainder).
/// Full 8-column spans go to the ISA kernel, the `jw % 8` tail is scalar.
pub(crate) fn block_kernel(
    isa: Isa,
    rows: usize,
    ap: &[f32],
    asr: usize,
    bp: &[f32],
    bs: usize,
    kw: usize,
    jw: usize,
    cb: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    if rows == 0 || kw == 0 || jw == 0 {
        return;
    }
    debug_assert!(rows <= 8 && rows <= asr);
    let mut j = 0;
    while j + 8 <= jw {
        let done = isa == Isa::Avx2Fma
            && simd_span(rows, ap, asr, &bp[j..], bs, kw, cb, c0 + j, ldc);
        if !done {
            portable_span(rows, ap, asr, &bp[j..], bs, kw, cb, c0 + j, ldc);
        }
        j += 8;
    }
    if j < jw {
        scalar_tail(rows, ap, asr, &bp[j..], bs, kw, jw - j, cb, c0 + j, ldc);
    }
}

/// Dispatch one full 8-column span to the hand-vectorized kernels. Returns
/// false when no AVX2 kernel covers `rows` (caller falls back to portable).
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn simd_span(
    rows: usize,
    ap: &[f32],
    asr: usize,
    bp: &[f32],
    bs: usize,
    kw: usize,
    cb: &mut [f32],
    c0: usize,
    ldc: usize,
) -> bool {
    if rows != 4 && rows != 8 {
        return false;
    }
    // Bounds proven here once so the kernels can use raw pointers freely.
    assert!(ap.len() >= (kw - 1) * asr + rows, "simd_span: packed A too short");
    assert!(bp.len() >= (kw - 1) * bs + 8, "simd_span: packed B too short");
    assert!(cb.len() >= c0 + (rows - 1) * ldc + 8, "simd_span: C tile too short");
    // SAFETY: avx2+fma presence is guaranteed by the Isa::Avx2Fma dispatch
    // (runtime-detected), and the asserts above establish every pointer
    // offset the kernels touch is in bounds.
    unsafe {
        let c = cb.as_mut_ptr().add(c0);
        if rows == 4 {
            x86::mk4x8(ap.as_ptr(), asr, bp.as_ptr(), bs, kw, c, ldc);
        } else {
            x86::mk8x8(ap.as_ptr(), asr, bp.as_ptr(), bs, kw, c, ldc);
        }
    }
    true
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
fn simd_span(
    _rows: usize,
    _ap: &[f32],
    _asr: usize,
    _bp: &[f32],
    _bs: usize,
    _kw: usize,
    _cb: &mut [f32],
    _c0: usize,
    _ldc: usize,
) -> bool {
    false
}

/// Portable full-width span: pick the const-generic kernel for `rows`.
#[allow(clippy::too_many_arguments)]
fn portable_span(
    rows: usize,
    ap: &[f32],
    asr: usize,
    bp: &[f32],
    bs: usize,
    kw: usize,
    cb: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    match rows {
        1 => micro8::<1>(ap, asr, bp, bs, kw, cb, c0, ldc),
        2 => micro8::<2>(ap, asr, bp, bs, kw, cb, c0, ldc),
        3 => micro8::<3>(ap, asr, bp, bs, kw, cb, c0, ldc),
        4 => micro8::<4>(ap, asr, bp, bs, kw, cb, c0, ldc),
        5 => micro8::<5>(ap, asr, bp, bs, kw, cb, c0, ldc),
        6 => micro8::<6>(ap, asr, bp, bs, kw, cb, c0, ldc),
        7 => micro8::<7>(ap, asr, bp, bs, kw, cb, c0, ldc),
        8 => micro8::<8>(ap, asr, bp, bs, kw, cb, c0, ldc),
        _ => unreachable!("block_kernel rows must be 1..=8, got {rows}"),
    }
}

/// Portable ROWS x 8 register tile. The accumulator array has a fixed shape,
/// so LLVM keeps it in registers and autovectorizes the inner loop on any
/// target with 128/256-bit lanes.
#[inline]
fn micro8<const ROWS: usize>(
    ap: &[f32],
    asr: usize,
    bp: &[f32],
    bs: usize,
    kw: usize,
    cb: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    let mut acc = [[0.0f32; 8]; ROWS];
    for kk in 0..kw {
        let arow = &ap[kk * asr..kk * asr + ROWS];
        let brow = &bp[kk * bs..kk * bs + 8];
        for r in 0..ROWS {
            let v = arow[r];
            for j in 0..8 {
                acc[r][j] += v * brow[j];
            }
        }
    }
    for (r, arow) in acc.iter().enumerate() {
        let dst = &mut cb[c0 + r * ldc..c0 + r * ldc + 8];
        for j in 0..8 {
            dst[j] += arow[j];
        }
    }
}

/// Scalar tail for the last `jr < 8` columns of a panel.
#[allow(clippy::too_many_arguments)]
fn scalar_tail(
    rows: usize,
    ap: &[f32],
    asr: usize,
    bp: &[f32],
    bs: usize,
    kw: usize,
    jr: usize,
    cb: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    for kk in 0..kw {
        let arow = &ap[kk * asr..kk * asr + rows];
        let brow = &bp[kk * bs..kk * bs + jr];
        for (r, &v) in arow.iter().enumerate() {
            let dst = &mut cb[c0 + r * ldc..c0 + r * ldc + jr];
            for j in 0..jr {
                dst[j] += v * brow[j];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! Hand-vectorized AVX2+FMA microkernels. Raw-pointer based: bounds are
    //! asserted by `simd_span` before dispatch, and `#[target_feature]`
    //! keeps the vector code out of the baseline ISA budget of the rest of
    //! the binary.

    use std::arch::x86_64::*;

    /// 4x8 tile: `c[r*ldc + j] += sum_kk ap[kk*asr + r] * bp[kk*bs + j]`.
    ///
    /// # Safety
    /// Caller must ensure AVX2 and FMA are available and that `ap` holds
    /// `(kw-1)*asr + 4` floats, `bp` holds `(kw-1)*bs + 8`, and `c` points
    /// at a tile where rows `0..4` of width 8 at stride `ldc` are writable.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn mk4x8(
        ap: *const f32,
        asr: usize,
        bp: *const f32,
        bs: usize,
        kw: usize,
        c: *mut f32,
        ldc: usize,
    ) {
        let mut acc0 = _mm256_loadu_ps(c);
        let mut acc1 = _mm256_loadu_ps(c.add(ldc));
        let mut acc2 = _mm256_loadu_ps(c.add(2 * ldc));
        let mut acc3 = _mm256_loadu_ps(c.add(3 * ldc));
        for kk in 0..kw {
            let b = _mm256_loadu_ps(bp.add(kk * bs));
            let a = ap.add(kk * asr);
            acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), b, acc0);
            acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), b, acc1);
            acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), b, acc2);
            acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), b, acc3);
        }
        _mm256_storeu_ps(c, acc0);
        _mm256_storeu_ps(c.add(ldc), acc1);
        _mm256_storeu_ps(c.add(2 * ldc), acc2);
        _mm256_storeu_ps(c.add(3 * ldc), acc3);
    }

    /// 8x8 tile: the main-path kernel (8 ymm accumulators + 1 B vector).
    ///
    /// # Safety
    /// Same contract as [`mk4x8`] with rows `0..8`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn mk8x8(
        ap: *const f32,
        asr: usize,
        bp: *const f32,
        bs: usize,
        kw: usize,
        c: *mut f32,
        ldc: usize,
    ) {
        let mut acc0 = _mm256_loadu_ps(c);
        let mut acc1 = _mm256_loadu_ps(c.add(ldc));
        let mut acc2 = _mm256_loadu_ps(c.add(2 * ldc));
        let mut acc3 = _mm256_loadu_ps(c.add(3 * ldc));
        let mut acc4 = _mm256_loadu_ps(c.add(4 * ldc));
        let mut acc5 = _mm256_loadu_ps(c.add(5 * ldc));
        let mut acc6 = _mm256_loadu_ps(c.add(6 * ldc));
        let mut acc7 = _mm256_loadu_ps(c.add(7 * ldc));
        for kk in 0..kw {
            let b = _mm256_loadu_ps(bp.add(kk * bs));
            let a = ap.add(kk * asr);
            acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), b, acc0);
            acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), b, acc1);
            acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), b, acc2);
            acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), b, acc3);
            acc4 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(4)), b, acc4);
            acc5 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(5)), b, acc5);
            acc6 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(6)), b, acc6);
            acc7 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(7)), b, acc7);
        }
        _mm256_storeu_ps(c, acc0);
        _mm256_storeu_ps(c.add(ldc), acc1);
        _mm256_storeu_ps(c.add(2 * ldc), acc2);
        _mm256_storeu_ps(c.add(3 * ldc), acc3);
        _mm256_storeu_ps(c.add(4 * ldc), acc4);
        _mm256_storeu_ps(c.add(5 * ldc), acc5);
        _mm256_storeu_ps(c.add(6 * ldc), acc6);
        _mm256_storeu_ps(c.add(7 * ldc), acc7);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference for one packed block product (same layout as block_kernel).
    #[allow(clippy::too_many_arguments)]
    fn reference(
        rows: usize,
        ap: &[f32],
        asr: usize,
        bp: &[f32],
        bs: usize,
        kw: usize,
        jw: usize,
        cb: &mut [f32],
        c0: usize,
        ldc: usize,
    ) {
        for kk in 0..kw {
            for r in 0..rows {
                let v = ap[kk * asr + r];
                for j in 0..jw {
                    cb[c0 + r * ldc + j] += v * bp[kk * bs + j];
                }
            }
        }
    }

    #[test]
    fn block_kernel_matches_reference_all_row_counts() {
        for isa in available() {
            for rows in 1..=8usize {
                for (kw, jw) in [(1usize, 1usize), (3, 7), (5, 8), (7, 19), (16, 24)] {
                    let asr = rows; // packed tight
                    let bs = jw + 3; // padded panel stride
                    let ldc = jw + 5;
                    let ap: Vec<f32> = (0..kw * asr).map(|i| (i % 13) as f32 - 6.0).collect();
                    let bp: Vec<f32> = (0..kw * bs).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
                    let mut got = vec![0.25f32; rows * ldc + 8];
                    let mut want = got.clone();
                    block_kernel(isa, rows, &ap, asr, &bp, bs, kw, jw, &mut got, 2, ldc);
                    reference(rows, &ap, asr, &bp, bs, kw, jw, &mut want, 2, ldc);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert!(
                            (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                            "isa={isa:?} rows={rows} kw={kw} jw={jw} elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn isa_detection_is_stable() {
        let a = active();
        assert_eq!(a, active(), "cached ISA must not change");
        assert!(available().contains(&detect_native()));
        assert!(!Isa::Avx2Fma.name().is_empty() && !Isa::Portable.name().is_empty());
    }
}
