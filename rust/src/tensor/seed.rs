//! The seed-PR blocked GEMM, frozen verbatim as the regression-gate
//! baseline.
//!
//! This is the scalar MR=4, B-panel-only kernel the repo shipped with
//! (thread-local pack pool and all). It exists so the kernel benchmarks and
//! the CI regression gate (`tests/kernel_gate.rs`, `ci/kernel_baseline.json`)
//! can measure the live engine (tensor::gemm) against the exact code it
//! replaced — "≥1.5× geomean over the seed kernel" stays meaningful on any
//! machine because both sides run in the same process. Do not optimize this
//! file; it is a measurement artifact, not a code path.

use std::cell::RefCell;

use super::Scratch;

/// Register-block height of the seed microkernel.
const MR: usize = 4;
/// Depth (k) blocking of the seed kernel.
const KC: usize = 256;
/// Width (j) blocking of the seed kernel.
const JC: usize = 512;
/// Seed single-thread threshold.
const PAR_MIN_FLOPS: usize = 1 << 22;

thread_local! {
    /// The seed kernel's per-thread pack pool (spawned bands lose theirs
    /// when the scope ends — the waste the live engine's global pool fixes).
    static PACK_POOL: RefCell<Scratch> = RefCell::new(Scratch::new());
}

fn hw_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// C[m,n] += A[m,kd] @ B[kd,n]: the seed blocked kernel, row-band threaded.
pub fn gemm_acc_seed(a: &[f32], m: usize, kd: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * kd, "gemm_acc_seed: A length vs [{m}, {kd}]");
    assert_eq!(b.len(), kd * n, "gemm_acc_seed: B length vs [{kd}, {n}]");
    assert_eq!(out.len(), m * n, "gemm_acc_seed: C length vs [{m}, {n}]");
    let flops = m.saturating_mul(kd).saturating_mul(n);
    let bands = if flops >= PAR_MIN_FLOPS { hw_threads().min(m / MR).max(1) } else { 1 };
    if bands <= 1 {
        gemm_serial(a, m, kd, b, n, out);
        return;
    }
    let rows_per = m.div_ceil(bands);
    std::thread::scope(|s| {
        let mut first: Option<(&mut [f32], &[f32])> = None;
        for (band, a_band) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * kd)) {
            if first.is_none() {
                first = Some((band, a_band));
                continue;
            }
            let rows = band.len() / n;
            s.spawn(move || gemm_serial(a_band, rows, kd, b, n, band));
        }
        if let Some((band, a_band)) = first {
            let rows = band.len() / n;
            gemm_serial(a_band, rows, kd, b, n, band);
        }
    });
}

/// Single-threaded seed kernel: packs B panels only; A is read strided.
fn gemm_serial(a: &[f32], m: usize, kd: usize, b: &[f32], n: usize, out: &mut [f32]) {
    if m == 0 || kd == 0 || n == 0 {
        return;
    }
    PACK_POOL.with(|pool| {
        let mut bp = pool.borrow_mut().buf(KC.min(kd) * JC.min(n));
        let mut jc = 0;
        while jc < n {
            let jw = JC.min(n - jc);
            let mut kc = 0;
            while kc < kd {
                let kw = KC.min(kd - kc);
                for kk in 0..kw {
                    let src = (kc + kk) * n + jc;
                    bp[kk * jw..kk * jw + jw].copy_from_slice(&b[src..src + jw]);
                }
                let mut i = 0;
                while i + MR <= m {
                    let band = &mut out[i * n..(i + MR) * n];
                    let (r0, rest) = band.split_at_mut(n);
                    let (r1, rest) = rest.split_at_mut(n);
                    let (r2, r3) = rest.split_at_mut(n);
                    let o0 = &mut r0[jc..jc + jw];
                    let o1 = &mut r1[jc..jc + jw];
                    let o2 = &mut r2[jc..jc + jw];
                    let o3 = &mut r3[jc..jc + jw];
                    let a0 = &a[i * kd + kc..i * kd + kc + kw];
                    let a1 = &a[(i + 1) * kd + kc..(i + 1) * kd + kc + kw];
                    let a2 = &a[(i + 2) * kd + kc..(i + 2) * kd + kc + kw];
                    let a3 = &a[(i + 3) * kd + kc..(i + 3) * kd + kc + kw];
                    for kk in 0..kw {
                        let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
                        let brow = &bp[kk * jw..kk * jw + jw];
                        for j in 0..jw {
                            let bv = brow[j];
                            o0[j] += v0 * bv;
                            o1[j] += v1 * bv;
                            o2[j] += v2 * bv;
                            o3[j] += v3 * bv;
                        }
                    }
                    i += MR;
                }
                while i < m {
                    let orow = &mut out[i * n + jc..i * n + jc + jw];
                    let arow = &a[i * kd + kc..i * kd + kc + kw];
                    for kk in 0..kw {
                        let v = arow[kk];
                        let brow = &bp[kk * jw..kk * jw + jw];
                        for j in 0..jw {
                            orow[j] += v * brow[j];
                        }
                    }
                    i += 1;
                }
                kc += kw;
            }
            jc += jw;
        }
        pool.borrow_mut().put(bp);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_kernel_accumulates() {
        let a = vec![1.0f32; 6]; // [2, 3]
        let b = vec![2.0f32; 6]; // [3, 2]
        let mut out = vec![10.0f32; 4];
        gemm_acc_seed(&a, 2, 3, &b, 2, &mut out);
        assert_eq!(out, vec![16.0; 4]); // 10 + 1*2*3
    }
}
