//! Per-shape-class GEMM tuning: parameters, the versioned on-disk manifest,
//! and the `phantom tune` autotuner.
//!
//! The blocked engine (tensor::gemm) asks `params_for(m, k, n)` for its
//! block/thread configuration on every call. Shapes are bucketed into
//! power-of-two classes (capped at 4096) so one tuned entry covers a whole
//! neighborhood of shapes and the hot-path lookup is a `BTreeMap` probe on a
//! `(usize, usize, usize)` key — no string formatting per GEMM.
//!
//! Winners are persisted to `phantom-tune.json` (schema below), loaded once
//! per process at backend init (`ensure_loaded`), and survive restarts:
//!
//! ```text
//! {
//!   "version": 1,
//!   "isa": "avx2+fma",
//!   "classes": {
//!     "m512_k512_n512": {"mr": 8, "kc": 256, "jc": 512,
//!                        "max_bands": 0, "par_min_flops": 4194304}
//!   }
//! }
//! ```
//!
//! Compatibility contract (mirrors runtime/manifest.rs and the ckpt
//! manifest): unknown fields are ignored, missing per-class fields default,
//! and a `version` other than 1 is rejected with a clear error. Deleting the
//! manifest is always safe — every class falls back to `default_for(isa)`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::prng::Prng;

use super::simd::{self, Isa};

/// Manifest schema version this build reads and writes.
pub const TUNE_MANIFEST_VERSION: i64 = 1;

/// Default manifest filename, searched for in the CWD and its ancestors.
pub const TUNE_MANIFEST_NAME: &str = "phantom-tune.json";

/// Block/thread configuration for one GEMM shape class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmParams {
    /// Microkernel register-block height (output rows per pass); 4 or 8.
    pub mr: usize,
    /// Depth (k) blocking: packed-panel row count.
    pub kc: usize,
    /// Width (j) blocking: packed-panel width.
    pub jc: usize,
    /// Row-band thread cap; 0 means "all hardware threads".
    pub max_bands: usize,
    /// Below this many multiply-adds the GEMM stays single-threaded.
    pub par_min_flops: usize,
}

impl GemmParams {
    /// The untuned configuration for an ISA: the seed kernel's blocking with
    /// the microkernel height the ISA's widest kernel wants.
    pub fn default_for(isa: Isa) -> GemmParams {
        GemmParams {
            mr: if isa == Isa::Avx2Fma { 8 } else { 4 },
            kc: 256,
            jc: 512,
            max_bands: 0,
            par_min_flops: 1 << 22,
        }
    }

    /// Clamp into the range the engine supports (manifests are data; a
    /// hand-edited or stale file must not panic the hot path).
    pub fn sanitized(self) -> GemmParams {
        GemmParams {
            mr: if self.mr >= 8 { 8 } else { 4 },
            kc: self.kc.clamp(8, 1 << 16),
            jc: self.jc.clamp(8, 1 << 16),
            max_bands: self.max_bands,
            par_min_flops: self.par_min_flops,
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("mr", Json::int(self.mr as i64)),
            ("kc", Json::int(self.kc as i64)),
            ("jc", Json::int(self.jc as i64)),
            ("max_bands", Json::int(self.max_bands as i64)),
            ("par_min_flops", Json::int(self.par_min_flops as i64)),
        ])
    }

    /// Parse one class entry; missing fields take the `base` default,
    /// unknown fields are ignored (forward compatibility).
    fn from_json(j: &Json, base: GemmParams) -> GemmParams {
        GemmParams {
            mr: j.get("mr").as_usize().unwrap_or(base.mr),
            kc: j.get("kc").as_usize().unwrap_or(base.kc),
            jc: j.get("jc").as_usize().unwrap_or(base.jc),
            max_bands: j.get("max_bands").as_usize().unwrap_or(base.max_bands),
            par_min_flops: j.get("par_min_flops").as_usize().unwrap_or(base.par_min_flops),
        }
        .sanitized()
    }
}

impl Default for GemmParams {
    fn default() -> GemmParams {
        GemmParams::default_for(Isa::Portable)
    }
}

// ---------------------------------------------------------------------------
// Shape classes
// ---------------------------------------------------------------------------

/// Bucket one dimension: next power of two, capped at 4096 (beyond that the
/// best blocking stops changing with size).
fn bucket(d: usize) -> usize {
    if d == 0 {
        0
    } else {
        d.next_power_of_two().min(4096)
    }
}

/// The shape class a `[m,k] @ [k,n]` GEMM falls into.
pub fn class_key(m: usize, k: usize, n: usize) -> (usize, usize, usize) {
    (bucket(m), bucket(k), bucket(n))
}

/// Manifest key for a class, e.g. `m512_k512_n512`.
pub fn class_name(key: (usize, usize, usize)) -> String {
    format!("m{}_k{}_n{}", key.0, key.1, key.2)
}

/// Inverse of `class_name`; None for malformed keys (skipped with a warning
/// at load, not fatal).
pub fn parse_class_name(s: &str) -> Option<(usize, usize, usize)> {
    let rest = s.strip_prefix('m')?;
    let (m, rest) = rest.split_once("_k")?;
    let (k, n) = rest.split_once("_n")?;
    Some((m.parse().ok()?, k.parse().ok()?, n.parse().ok()?))
}

// ---------------------------------------------------------------------------
// Tuning: the manifest contents
// ---------------------------------------------------------------------------

/// A set of tuned shape classes, as loaded from / saved to the manifest.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Tuning {
    /// ISA the tuning was measured on (informational: a manifest tuned on
    /// another machine still loads; re-tune for best results).
    pub isa: String,
    pub classes: BTreeMap<(usize, usize, usize), GemmParams>,
}

impl Tuning {
    /// Best params for a shape: the tuned class entry if present, else the
    /// ISA default.
    pub fn params_for(&self, m: usize, k: usize, n: usize, isa: Isa) -> GemmParams {
        self.classes
            .get(&class_key(m, k, n))
            .copied()
            .unwrap_or_else(|| GemmParams::default_for(isa))
    }

    pub fn to_json(&self) -> Json {
        let classes: BTreeMap<String, Json> =
            self.classes.iter().map(|(k, p)| (class_name(*k), p.to_json())).collect();
        Json::obj(vec![
            ("version", Json::int(TUNE_MANIFEST_VERSION)),
            ("isa", Json::str(self.isa.clone())),
            ("classes", Json::Obj(classes)),
        ])
    }

    pub fn parse(text: &str) -> Result<Tuning> {
        let j = Json::parse(text).map_err(|e| anyhow!("tuning manifest: {e}"))?;
        let version = j.get("version").as_i64().unwrap_or(0);
        if version != TUNE_MANIFEST_VERSION {
            bail!(
                "unsupported tuning-manifest version {version} (this build reads \
                 {TUNE_MANIFEST_VERSION}; delete the file or re-run `phantom tune`)"
            );
        }
        let isa = j.get("isa").as_str().unwrap_or("unknown").to_string();
        let base = GemmParams::default_for(simd::active());
        let mut classes = BTreeMap::new();
        if let Some(obj) = j.get("classes").as_obj() {
            for (name, entry) in obj {
                match parse_class_name(name) {
                    Some(key) => {
                        classes.insert(key, GemmParams::from_json(entry, base));
                    }
                    None => crate::log_warn!(
                        "tune: warning: skipping malformed class key '{name}' in manifest"
                    ),
                }
            }
        }
        Ok(Tuning { isa, classes })
    }

    /// Load a manifest file; `Ok(None)` when the file does not exist (the
    /// documented fall-back-to-defaults path), `Err` on unreadable/invalid
    /// contents.
    pub fn load(path: &Path) -> Result<Option<Tuning>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => bail!("reading {}: {e}", path.display()),
        };
        Tuning::parse(&text).map(Some)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// Process-global active tuning
// ---------------------------------------------------------------------------

static ACTIVE: RwLock<Option<Tuning>> = RwLock::new(None);
static LOAD_ONCE: OnceLock<()> = OnceLock::new();

fn active_lock<T>(f: impl FnOnce(&Option<Tuning>) -> T) -> T {
    f(&ACTIVE.read().unwrap_or_else(|p| p.into_inner()))
}

/// Params the engine should use for a shape: the installed tuning's class
/// entry when present, ISA defaults otherwise.
pub fn params_for(m: usize, k: usize, n: usize) -> GemmParams {
    let isa = simd::active();
    active_lock(|t| match t {
        Some(t) => t.params_for(m, k, n, isa),
        None => GemmParams::default_for(isa),
    })
}

/// Number of tuned shape classes currently installed (0 = pure defaults).
pub fn installed_classes() -> usize {
    active_lock(|t| t.as_ref().map(|t| t.classes.len()).unwrap_or(0))
}

/// Make `tuning` the process-global active tuning.
pub fn install(tuning: Tuning) {
    *ACTIVE.write().unwrap_or_else(|p| p.into_inner()) = Some(tuning);
}

/// Drop the installed tuning (back to defaults). Test hook.
#[doc(hidden)]
pub fn clear_installed() {
    *ACTIVE.write().unwrap_or_else(|p| p.into_inner()) = None;
}

/// The manifest path this process reads at init: `$PHANTOM_TUNE` when set,
/// else the first `phantom-tune.json` found in the CWD or its ancestors,
/// else CWD/phantom-tune.json (which typically does not exist — defaults).
pub fn default_manifest_path() -> PathBuf {
    if let Ok(p) = std::env::var("PHANTOM_TUNE") {
        if !p.is_empty() {
            return PathBuf::from(p);
        }
    }
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir = Some(cwd.as_path());
    while let Some(d) = dir {
        let cand = d.join(TUNE_MANIFEST_NAME);
        if cand.exists() {
            return cand;
        }
        dir = d.parent();
    }
    cwd.join(TUNE_MANIFEST_NAME)
}

/// Load the default manifest into the process-global tuning, once per
/// process. Called from backend init so every entry point (train, serve,
/// bench, tests) inherits persisted tuning. Missing manifest is silent
/// (defaults); a malformed one warns and falls back rather than failing the
/// run — `phantom tune --show` surfaces the error loudly.
pub fn ensure_loaded() {
    LOAD_ONCE.get_or_init(|| {
        let path = default_manifest_path();
        match Tuning::load(&path) {
            Ok(Some(t)) => {
                // CI's tune-smoke job greps for this exact "tune: loaded"
                // text — keep it stable.
                crate::log_info!(
                    "tune: loaded {} shape classes from {} (tuned on {}, running {})",
                    t.classes.len(),
                    path.display(),
                    t.isa,
                    simd::active().name()
                );
                install(t);
            }
            Ok(None) => {} // no manifest: defaults, silently
            Err(e) => {
                crate::log_warn!("tune: warning: ignoring manifest {}: {e}", path.display());
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Autotuner
// ---------------------------------------------------------------------------

/// The GEMM shapes the autotuner and the CI regression gate track: square
/// compute-bound sizes plus the skinny/fat shapes the per-rank kernels
/// actually produce (activations tall-thin, reductions short-fat).
pub const TRACKED_SHAPES: &[(usize, usize, usize)] = &[
    (128, 128, 128),
    (512, 512, 512),
    (32, 256, 256),
    (256, 32, 256),
    (64, 2048, 64),
];

/// Small shapes for the CI tune smoke job (seconds, not minutes).
pub const TINY_SHAPES: &[(usize, usize, usize)] = &[(16, 32, 32), (8, 64, 16)];

/// One per-shape autotune outcome (for the CLI report).
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub shape: (usize, usize, usize),
    pub class: (usize, usize, usize),
    pub best: GemmParams,
    pub best_secs: f64,
    pub default_secs: f64,
    pub candidates: usize,
}

impl TuneOutcome {
    pub fn gflops(&self) -> f64 {
        let (m, k, n) = self.shape;
        2.0 * (m as f64) * (k as f64) * (n as f64) / self.best_secs / 1e9
    }

    pub fn speedup_vs_default(&self) -> f64 {
        self.default_secs / self.best_secs
    }
}

fn candidate_grid(quick: bool) -> Vec<GemmParams> {
    let mrs: &[usize] = &[4, 8];
    let (kcs, jcs, pmfs): (&[usize], &[usize], &[usize]) = if quick {
        (&[128, 256], &[256, 512], &[1 << 22])
    } else {
        (&[64, 128, 256, 512], &[128, 256, 512, 1024], &[1 << 20, 1 << 22])
    };
    let mut out = Vec::new();
    for &mr in mrs {
        for &kc in kcs {
            for &jc in jcs {
                for &pmf in pmfs {
                    out.push(GemmParams { mr, kc, jc, max_bands: 0, par_min_flops: pmf });
                }
            }
        }
    }
    out
}

/// Minimum wall time of `runs` executions of `f` (min is the stablest
/// estimator under background load).
fn best_of<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Benchmark the candidate grid on each shape and keep the winner per shape
/// class. Returns the tuning plus the per-shape report. Deterministic
/// inputs; timing is min-of-`iters`.
pub fn autotune(
    shapes: &[(usize, usize, usize)],
    iters: usize,
    quick: bool,
) -> (Tuning, Vec<TuneOutcome>) {
    let isa = simd::active();
    let grid = candidate_grid(quick);
    let mut rng = Prng::new(0xB10C5EED); // deterministic autotune inputs
    let mut tuning = Tuning { isa: isa.name().to_string(), ..Default::default() };
    let mut outcomes = Vec::new();
    for &(m, k, n) in shapes {
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let mut out = vec![0.0f32; m * n];

        let default = GemmParams::default_for(isa);
        let default_secs = best_of(iters, || {
            out.fill(0.0);
            super::gemm::gemm_acc_with(default, isa, &a, m, k, &b, n, &mut out);
        });

        let mut best = default;
        let mut best_secs = default_secs;
        for &cand in &grid {
            if cand == default {
                continue;
            }
            let secs = best_of(iters, || {
                out.fill(0.0);
                super::gemm::gemm_acc_with(cand, isa, &a, m, k, &b, n, &mut out);
            });
            if secs < best_secs {
                best_secs = secs;
                best = cand;
            }
        }
        let class = class_key(m, k, n);
        // First shape to land in a class wins (shapes list is ordered from
        // most to least representative).
        tuning.classes.entry(class).or_insert(best);
        outcomes.push(TuneOutcome {
            shape: (m, k, n),
            class,
            best,
            best_secs,
            default_secs,
            candidates: grid.len(),
        });
    }
    (tuning, outcomes)
}

/// Resolve a `--shapes` CLI argument: a named set (`tracked`, `tiny`) or a
/// comma-separated list of `MxKxN` triples.
pub fn parse_shapes_arg(arg: &str) -> Result<Vec<(usize, usize, usize)>> {
    match arg {
        "tracked" => return Ok(TRACKED_SHAPES.to_vec()),
        "tiny" => return Ok(TINY_SHAPES.to_vec()),
        _ => {}
    }
    let mut out = Vec::new();
    for part in arg.split(',') {
        let dims: Vec<usize> = part
            .split('x')
            .map(|d| d.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| anyhow!("bad shape '{part}' (want MxKxN, e.g. 512x512x512)"))?;
        if dims.len() != 3 {
            bail!("bad shape '{part}' (want MxKxN, e.g. 512x512x512)");
        }
        out.push((dims[0], dims[1], dims[2]));
    }
    if out.is_empty() {
        bail!("empty shape list");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_caps_and_handles_degenerates() {
        assert_eq!(class_key(0, 1, 2), (0, 1, 2));
        assert_eq!(class_key(3, 5, 9), (4, 8, 16));
        assert_eq!(class_key(512, 513, 8192), (512, 1024, 4096));
        assert_eq!(bucket(4096), 4096);
        assert_eq!(bucket(100_000), 4096);
    }

    #[test]
    fn class_name_roundtrip() {
        for key in [(0, 0, 0), (4, 8, 16), (512, 1024, 4096)] {
            assert_eq!(parse_class_name(&class_name(key)), Some(key));
        }
        for bad in ["", "m1_k2", "x1_k2_n3", "m1_k2_n3x", "m_k2_n3"] {
            assert_eq!(parse_class_name(bad), None, "{bad}");
        }
    }

    #[test]
    fn sanitize_clamps() {
        let p = GemmParams { mr: 0, kc: 0, jc: 1 << 30, max_bands: 3, par_min_flops: 7 };
        let p = p.sanitized();
        assert_eq!(p.mr, 4);
        assert_eq!(p.kc, 8);
        assert_eq!(p.jc, 1 << 16);
        assert_eq!(p.max_bands, 3);
        assert_eq!(p.par_min_flops, 7);
        assert_eq!(GemmParams { mr: 100, ..p }.sanitized().mr, 8);
    }

    #[test]
    fn shapes_arg_parses() {
        assert_eq!(parse_shapes_arg("tracked").unwrap(), TRACKED_SHAPES.to_vec());
        assert_eq!(parse_shapes_arg("tiny").unwrap(), TINY_SHAPES.to_vec());
        assert_eq!(parse_shapes_arg("4x5x6, 7x8x9").unwrap(), vec![(4, 5, 6), (7, 8, 9)]);
        assert!(parse_shapes_arg("4x5").is_err());
        assert!(parse_shapes_arg("axbxc").is_err());
    }
}
