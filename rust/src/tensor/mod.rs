//! Host tensor: a dense, row-major f32 array with shape.
//!
//! This is the currency between PJRT executions, the collective fabric, and
//! the optimizers. It deliberately implements only what the coordinator
//! needs — plus a reference `matmul` used by tests to cross-check the
//! AOT-compiled kernels and by the pure-Rust fallback path.

use crate::util::prng::Prng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; numel] }
    }

    /// N(0, sigma^2) initialization from a deterministic stream.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Prng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, sigma);
        t
    }

    // -- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat_index(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.flat_index(idx);
        self.data[i] = v;
    }

    fn flat_index(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let mut flat = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(i < s, "index {i} out of bounds for dim {d} (size {s})");
            flat = flat * s + i;
        }
        flat
    }

    // -- shape ops ----------------------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Split along axis 1 of a 2-D tensor into `p` equal column shards.
    /// This is the activation sharding used by both TP and PP.
    pub fn col_shards(&self, p: usize) -> Result<Vec<Tensor>> {
        if self.shape.len() != 2 {
            bail!("col_shards needs a 2-D tensor, got {:?}", self.shape);
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        if cols % p != 0 {
            bail!("cols {} not divisible by p {}", cols, p);
        }
        let w = cols / p;
        let mut shards = vec![Tensor::zeros(&[rows, w]); p];
        for r in 0..rows {
            for j in 0..p {
                let src = r * cols + j * w;
                let dst = r * w;
                shards[j].data[dst..dst + w].copy_from_slice(&self.data[src..src + w]);
            }
        }
        Ok(shards)
    }

    /// Inverse of `col_shards`.
    pub fn from_col_shards(shards: &[Tensor]) -> Result<Tensor> {
        if shards.is_empty() {
            bail!("no shards");
        }
        let rows = shards[0].shape[0];
        let w = shards[0].shape[1];
        for s in shards {
            if s.shape != [rows, w] {
                bail!("ragged shards: {:?} vs [{rows}, {w}]", s.shape);
            }
        }
        let p = shards.len();
        let mut out = Tensor::zeros(&[rows, w * p]);
        for r in 0..rows {
            for (j, s) in shards.iter().enumerate() {
                let dst = r * w * p + j * w;
                out.data[dst..dst + w].copy_from_slice(&s.data[r * w..(r + 1) * w]);
            }
        }
        Ok(out)
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of nothing");
        }
        let inner = parts[0].shape.clone();
        for t in parts {
            if t.shape != inner {
                bail!("ragged stack: {:?} vs {:?}", t.shape, inner);
            }
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner);
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for t in parts {
            data.extend_from_slice(&t.data);
        }
        Ok(Tensor { shape, data })
    }

    /// Slice out index `i` of the leading axis.
    pub fn unstack_at(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Zero the `i`-th slice of the leading axis in place (the own-slot
    /// convention after All-Gather; see python/compile/kernels/ref.py).
    pub fn zero_slot(&mut self, i: usize) {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        self.data[i * inner..(i + 1) * inner].fill(0.0);
    }

    /// Reassemble a stacked shard tensor [p, B, m] (All-Gather output) into
    /// the full activation [B, p*m] with shard j occupying columns
    /// [j*m, (j+1)*m). Inverse of `col_shards` + `stack`.
    pub fn concat_shards_stacked(&self) -> Result<Tensor> {
        if self.shape.len() != 3 {
            bail!("concat_shards_stacked needs [p, B, m], got {:?}", self.shape);
        }
        let (p, b, m) = (self.shape[0], self.shape[1], self.shape[2]);
        let mut out = Tensor::zeros(&[b, p * m]);
        for j in 0..p {
            for r in 0..b {
                let src = (j * b + r) * m;
                let dst = r * p * m + j * m;
                out.data[dst..dst + m].copy_from_slice(&self.data[src..src + m]);
            }
        }
        Ok(out)
    }

    /// Slice columns [start, start+width) of a 2-D tensor.
    pub fn col_slice(&self, start: usize, width: usize) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("col_slice needs a 2-D tensor, got {:?}", self.shape);
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        if start + width > cols {
            bail!("col_slice [{start}, {}) out of bounds for {cols} cols", start + width);
        }
        let mut out = Tensor::zeros(&[rows, width]);
        for r in 0..rows {
            let src = r * cols + start;
            out.data[r * width..(r + 1) * width]
                .copy_from_slice(&self.data[src..src + width]);
        }
        Ok(out)
    }

    // -- elementwise ---------------------------------------------------------

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// self -= lr * grad   (the SGD inner loop; optimizers build on this)
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn relu(&self) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x.max(0.0)).collect(),
        }
    }

    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    // -- reference linear algebra (tests / fallback; PJRT does the real work)

    /// C = A @ B for 2-D tensors. Naive triple loop with the k-loop innermost
    /// hoisted for cache friendliness; used by tests and the non-PJRT
    /// fallback path only.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 {
            bail!("matmul needs 2-D tensors: {:?} @ {:?}", self.shape, other.shape);
        }
        let (m, ka) = (self.shape[0], self.shape[1]);
        let (kb, n) = (other.shape[0], other.shape[1]);
        if ka != kb {
            bail!("matmul inner dim mismatch: {:?} @ {:?}", self.shape, other.shape);
        }
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for kk in 0..ka {
                let a = self.data[i * ka + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    /// 2-D transpose (reference).
    pub fn transpose(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose needs a 2-D tensor, got {:?}", self.shape);
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, quickcheck};

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn shard_roundtrip() {
        let mut rng = Prng::new(3);
        let t = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let shards = t.col_shards(4).unwrap();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0].shape(), &[4, 2]);
        let back = Tensor::from_col_shards(&shards).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn stack_unstack_zero_slot() {
        let a = Tensor::filled(&[2, 2], 1.0);
        let b = Tensor::filled(&[2, 2], 2.0);
        let mut s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert_eq!(s.unstack_at(1), b);
        s.zero_slot(0);
        assert_eq!(s.unstack_at(0), Tensor::zeros(&[2, 2]));
        assert_eq!(s.unstack_at(1), b);
    }

    #[test]
    fn transpose_involution() {
        quickcheck("transpose twice is identity", |rng| {
            let m = rng.int_in(1, 8) as usize;
            let n = rng.int_in(1, 8) as usize;
            let t = Tensor::randn(&[m, n], 1.0, rng);
            let tt = t.transpose().unwrap().transpose().unwrap();
            assert_close(t.data(), tt.data(), 0.0, 0.0)
        });
    }

    #[test]
    fn matmul_transpose_property() {
        // (A @ B)^T == B^T @ A^T
        quickcheck("matmul transpose identity", |rng| {
            let m = rng.int_in(1, 6) as usize;
            let k = rng.int_in(1, 6) as usize;
            let n = rng.int_in(1, 6) as usize;
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let lhs = a.matmul(&b).unwrap().transpose().unwrap();
            let rhs = b.transpose().unwrap().matmul(&a.transpose().unwrap()).unwrap();
            assert_close(lhs.data(), rhs.data(), 1e-5, 1e-6)
        });
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Tensor::filled(&[3], 1.0);
        let b = Tensor::filled(&[3], 2.0);
        a.add_assign(&b);
        assert_eq!(a.data(), &[3.0, 3.0, 3.0]);
        a.axpy(-0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.0, 2.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[4.0, 4.0, 4.0]);
        let r = Tensor::from_vec(&[2], vec![-1.0, 1.0]).unwrap().relu();
        assert_eq!(r.data(), &[0.0, 1.0]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Prng::new(11);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean: f64 = t.data().iter().map(|&x| x as f64).sum::<f64>() / 10_000.0;
        let var = t.sq_sum() / 10_000.0 - mean * mean;
        assert!(mean.abs() < 0.02);
        assert!((var - 0.25).abs() < 0.02);
    }
}
